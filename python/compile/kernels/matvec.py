"""L1 Pallas kernel: mixed-depth LUT-dequant matvec — the paper's
Appendix-A CUDA kernel rethought for TPU (DESIGN.md §Hardware-Adaptation).

CUDA → Pallas mapping:
- thread block (256×256)      → BlockSpec (K, TM) tile over output columns
- per-thread column walk      → vectorized (K, TM) dequant on the VPU
- __shared__ LUT              → VMEM-resident (9, 256) LUT table, gathered
- divergence-free 4-row depth → per-row group_id with uniform depth inside
                                a group (vector lanes stay contiguous)
- atomicAdd reduction         → full-K dot per grid step (no reduction
                                race exists: each step owns its columns)

Codes arrive unpacked (one int32 per weight) because interpret mode is a
functional check, not a bandwidth measurement; the bandwidth story is
measured by the Rust kernel (infer::matvec) and estimated for TPU in
EXPERIMENTS.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(codes_ref, x_ref, gid_ref, bits_ref, scale_ref, mean_ref, lut_ref, o_ref):
    codes = codes_ref[...]          # (K, TM) int32
    x = x_ref[...]                  # (K, 1)
    gid = gid_ref[...][:, 0]        # (K,)
    bits = bits_ref[...][:, 0]      # (G,)
    scales = scale_ref[...][:, 0]   # (G,)
    means = mean_ref[...][:, 0]     # (G,)
    luts = lut_ref[...]             # (9, 256)
    b_k = bits[gid]                 # (K,)
    std = luts[b_k[:, None], codes]  # gather: standardized dequant values
    w = means[gid][:, None] + scales[gid][:, None] * std
    o_ref[...] = jnp.sum(x * w, axis=0, keepdims=True)


def _pick_tile(dim: int, pref: int) -> int:
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


def quantized_matvec(codes, x, group_id, bits, scales, means, luts):
    """y (M,) from codes (K,M) int32, x (K,), per-row group_id (K,),
    per-group bits/scales/means (G,), luts (9, 256)."""
    k, m = codes.shape
    g = bits.shape[0]
    tm = _pick_tile(m, 256)
    grid = (m // tm,)
    y = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, tm), lambda j: (0, j)),
            pl.BlockSpec((k, 1), lambda j: (0, 0)),
            pl.BlockSpec((k, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((g, 1), lambda j: (0, 0)),
            pl.BlockSpec((9, 256), lambda j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, m), jnp.float32),
        interpret=True,
    )(
        codes.astype(jnp.int32),
        x.astype(jnp.float32).reshape(k, 1),
        group_id.astype(jnp.int32).reshape(k, 1),
        bits.astype(jnp.int32).reshape(g, 1),
        scales.astype(jnp.float32).reshape(g, 1),
        means.astype(jnp.float32).reshape(g, 1),
        luts.astype(jnp.float32),
    )
    return y.reshape(m)
