"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each `ref_*` function computes the same mathematical result as its Pallas
counterpart using plain jax.numpy; pytest (with hypothesis sweeps) asserts
allclose between the two across shapes and dtypes.
"""

import jax.numpy as jnp

SQRT2 = 1.4142135623730951


def ref_matmul(x, w):
    """x (N,K) @ w (K,M)."""
    return x @ w


def compand(theta, scale, mean):
    """Laplace compander σ(θ) ∈ (0,1) (paper Eq. 8, expanded form)."""
    d = theta - mean
    mag = 1.0 - jnp.exp(-(SQRT2 * jnp.abs(d)) / (3.0 * scale))
    return 0.5 + 0.5 * jnp.sign(d) * mag


def expand(t, scale, mean):
    """Inverse compander."""
    d = t - 0.5
    mag = jnp.maximum(1.0 - 2.0 * jnp.abs(d), 1e-12)
    return mean - (3.0 * scale / SQRT2) * jnp.sign(d) * jnp.log(mag)


def ref_compand_quantize(theta, scale, mean, bits: int):
    """Companded quantize-dequantize. theta (G,N); scale/mean (G,)."""
    levels = float(1 << bits)
    s = scale[:, None]
    m = mean[:, None]
    t = compand(theta, s, m)
    code = jnp.clip(jnp.floor(t * levels), 0.0, levels - 1.0)
    return expand((code + 0.5) / levels, s, m)


def ref_lut_matvec(codes, x, group_id, bits, scales, means, luts):
    """Mixed-depth LUT-dequant matvec (the Appendix-A kernel's math):

    y[j] = Σ_k x[k] · (means[g(k)] + scales[g(k)] · luts[bits[g(k)], codes[k, j]])
    """
    b_k = bits[group_id]            # (K,)
    deq = luts[b_k[:, None], codes]  # (K, M) standardized values
    w = means[group_id][:, None] + scales[group_id][:, None] * deq
    return x @ w


def make_companded_luts(max_bits: int = 8):
    """Standardized (µ=0, S=1) dequant LUTs per depth, padded to 2^max."""
    size = 1 << max_bits
    rows = []
    for b in range(max_bits + 1):
        if b == 0:
            rows.append(jnp.zeros((size,), jnp.float32))
            continue
        levels = 1 << b
        t = (jnp.arange(levels, dtype=jnp.float32) + 0.5) / levels
        vals = expand(t, 1.0, 0.0)
        rows.append(jnp.pad(vals, (0, size - levels)))
    return jnp.stack(rows)  # (max_bits+1, 2^max_bits)
