"""L1 Pallas kernel: tiled matmul for the model's MLP layers.

TPU mapping (DESIGN.md §Hardware-Adaptation): BlockSpec expresses the
HBM↔VMEM schedule; each grid step (i, j, k) loads a (TN, TK) tile of x and
a (TK, TM) tile of w into VMEM and feeds the MXU via `jnp.dot`, with the
output tile accumulated across the K grid dimension — the canonical Pallas
reduction replacing the CUDA kernel's atomicAdd.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU numbers are estimated in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _pick_tile(dim: int, pref: int) -> int:
    """Largest divisor of `dim` that is ≤ pref (keeps BlockSpecs exact)."""
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=())
def tiled_matmul(x, w):
    """x (N,K) @ w (K,M) via the Pallas kernel (interpret mode)."""
    n, k = x.shape
    k2, m = w.shape
    assert k == k2
    tn = _pick_tile(n, 128)
    tk = _pick_tile(k, 128)
    tm = _pick_tile(m, 128)
    grid = (n // tn, m // tm, k // tk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tm), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), w.astype(jnp.float32))


def vmem_estimate_bytes(tn=128, tk=128, tm=128, dtype_bytes=4):
    """VMEM footprint of one grid step (double-buffered), for §Perf."""
    tiles = tn * tk + tk * tm + tn * tm
    return 2 * tiles * dtype_bytes  # ×2: double buffering
