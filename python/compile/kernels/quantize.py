"""L1 Pallas kernel: companded quantize-dequantize (paper Eq. 8).

One grid step processes a block of groups: each group row is companded
with its own (scale, mean), uniformly quantized to 2^bits levels, and
expanded back. Pure VPU elementwise work; the per-group parameters ride
along as (G,1) blocks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT2 = 1.4142135623730951


def _quantize_kernel(theta_ref, scale_ref, mean_ref, o_ref, *, bits: int):
    theta = theta_ref[...]
    s = scale_ref[...]  # (G, 1)
    m = mean_ref[...]
    levels = float(1 << bits)
    d = theta - m
    t = 0.5 + 0.5 * jnp.sign(d) * (1.0 - jnp.exp(-(SQRT2 * jnp.abs(d)) / (3.0 * s)))
    code = jnp.clip(jnp.floor(t * levels), 0.0, levels - 1.0)
    tq = (code + 0.5) / levels
    dq = tq - 0.5
    mag = jnp.maximum(1.0 - 2.0 * jnp.abs(dq), 1e-12)
    o_ref[...] = m - (3.0 * s / SQRT2) * jnp.sign(dq) * jnp.log(mag)


def _pick_tile(dim: int, pref: int) -> int:
    t = min(pref, dim)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bits",))
def compand_quantize(theta, scale, mean, bits: int):
    """theta (G,N), scale (G,), mean (G,) → dequantized (G,N)."""
    g, n = theta.shape
    tg = _pick_tile(g, 64)
    tn = _pick_tile(n, 256)
    grid = (g // tg, n // tn)
    return pl.pallas_call(
        functools.partial(_quantize_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tg, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tg, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((tg, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tg, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((g, n), jnp.float32),
        interpret=True,
    )(
        theta.astype(jnp.float32),
        scale.astype(jnp.float32).reshape(g, 1),
        mean.astype(jnp.float32).reshape(g, 1),
    )
