"""AOT lowering: JAX/Pallas (L2+L1) → HLO text artifacts for the Rust
runtime. Runs ONCE at build time (`make artifacts`); Python is never on
the request path.

Emits (for the configured model preset):
  model_fwd.hlo.txt      (tokens, θ…) → (logits,)
  model_loss.hlo.txt     (tokens, targets, θ…) → (loss,)
  model_gradvar.hlo.txt  (tokens, u, s, θ…) → (∂c/∂Θ…, X̄…, Z)
  quantize_kernel.hlo.txt  standalone Pallas compand-quantize (B=3)
  matvec_kernel.hlo.txt    standalone Pallas LUT matvec
  model_config.json      config echo for the Rust loader

HLO *text* (not .serialize()): jax ≥ 0.5 emits 64-bit-id protos that
xla_extension 0.5.1 rejects; the text parser reassigns ids.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.quantize import compand_quantize
from .kernels.matvec import quantized_matvec
from .kernels.ref import make_companded_luts
from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(cfg: model.Config, batch: int, seq: int, out_dir: str):
    spec = model.weight_spec(cfg)
    wshapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    tok = jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    uvec = jax.ShapeDtypeStruct((cfg.dim,), jnp.float32)
    svec = jax.ShapeDtypeStruct((batch * seq,), jnp.float32)

    fwd = functools.partial(model.forward_logits, cfg=cfg, use_pallas=True)
    lowered = jax.jit(fwd).lower(tok, *wshapes)
    _write(out_dir, "model_fwd.hlo.txt", to_hlo_text(lowered))

    loss = functools.partial(model.loss_fn, cfg=cfg)
    lowered = jax.jit(loss).lower(tok, tok, *wshapes)
    _write(out_dir, "model_loss.hlo.txt", to_hlo_text(lowered))

    gradvar = functools.partial(model.gradvar_fn, cfg=cfg)
    lowered = jax.jit(gradvar).lower(tok, uvec, svec, *wshapes)
    _write(out_dir, "model_gradvar.hlo.txt", to_hlo_text(lowered))


def lower_kernels(out_dir: str):
    # Companded quantizer: 64 groups × 256 weights, 3 bits.
    theta = jax.ShapeDtypeStruct((64, 256), jnp.float32)
    gvec = jax.ShapeDtypeStruct((64,), jnp.float32)
    qfn = functools.partial(compand_quantize, bits=3)
    lowered = jax.jit(lambda t, s, m: (qfn(t, s, m),)).lower(theta, gvec, gvec)
    _write(out_dir, "quantize_kernel.hlo.txt", to_hlo_text(lowered))

    # LUT matvec: K=512 rows, M=256 cols, G=8 groups.
    k, m, g = 512, 256, 8
    codes = jax.ShapeDtypeStruct((k, m), jnp.int32)
    x = jax.ShapeDtypeStruct((k,), jnp.float32)
    gid = jax.ShapeDtypeStruct((k,), jnp.int32)
    bits = jax.ShapeDtypeStruct((g,), jnp.int32)
    sc = jax.ShapeDtypeStruct((g,), jnp.float32)
    luts = make_companded_luts(8)

    def mv(codes, x, gid, bits, scales, means):
        return (quantized_matvec(codes, x, gid, bits, scales, means, luts),)

    lowered = jax.jit(mv).lower(codes, x, gid, bits, sc, sc)
    _write(out_dir, "matvec_kernel.hlo.txt", to_hlo_text(lowered))


def _write(out_dir: str, name: str, text: str):
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] wrote {path} ({len(text)} chars)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--model", default="ropt-small")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model.PRESETS[args.model]
    lower_model(cfg, args.batch, args.seq, args.out)
    lower_kernels(args.out)
    meta = {
        "model": args.model,
        "vocab": cfg.vocab,
        "dim": cfg.dim,
        "heads": cfg.heads,
        "layers": cfg.layers,
        "mlp": cfg.mlp,
        "max_seq": cfg.max_seq,
        "batch": args.batch,
        "seq": args.seq,
    }
    with open(os.path.join(args.out, "model_config.json"), "w") as f:
        json.dump(meta, f, indent=2)
    print("[aot] wrote model_config.json")


if __name__ == "__main__":
    main()
