"""L2: the Radio transformer in JAX — numerically identical to the Rust
substrate (`rust/src/model/transformer.rs`): pre-LN GPT, `X @ W + b`
convention with W stored (d_in, d_out), tanh-GELU, tied embedding head,
LN eps 1e-5.

Three build-time graphs are lowered by `aot.py`:

- ``forward``   (tokens, θ…) → logits            — evaluation/serving path;
                MLP matmuls run through the Pallas tiled-matmul kernel
                (interpret mode) so the L1 kernel is on the artifact path.
- ``loss``      (tokens, targets, θ…) → scalar   — perplexity evaluation.
- ``gradvar``   (tokens, u, s, θ…) → (∂c/∂Θ_n …, X̄_n …, Z) with
                c = sᵀ(Z·u) — Algorithm 1's stochastic gradient sample.

Python never runs at inference time; these functions exist only to be
lowered once to HLO text.
"""

from dataclasses import dataclass
from typing import List, Tuple

import jax
import jax.numpy as jnp

LN_EPS = 1e-5
GELU_A = 0.7978845608028654  # sqrt(2/pi)
GELU_C = 0.044715

ROLES = ("q_proj", "k_proj", "v_proj", "o_proj", "mlp_up", "mlp_down")


@dataclass(frozen=True)
class Config:
    vocab: int
    dim: int
    heads: int
    layers: int
    mlp: int
    max_seq: int

    @property
    def head_dim(self) -> int:
        assert self.dim % self.heads == 0
        return self.dim // self.heads


PRESETS = {
    "ropt-nano": Config(256, 64, 2, 2, 256, 64),
    "ropt-micro": Config(256, 96, 3, 3, 384, 64),
    "ropt-small": Config(256, 128, 4, 4, 512, 64),
    "ropt-med": Config(256, 192, 6, 6, 768, 64),
    "ropt-large": Config(256, 256, 8, 8, 1024, 64),
    "ropt-xl": Config(256, 384, 8, 10, 1536, 64),
}


def weight_spec(cfg: Config) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list — EXACTLY the order of
    `Weights::param_slices_mut` on the Rust side."""
    e, f = cfg.dim, cfg.mlp
    spec = [("embed", (cfg.vocab, e)), ("pos", (cfg.max_seq, e))]
    for l in range(cfg.layers):
        spec += [
            (f"l{l}.ln1_g", (e,)),
            (f"l{l}.ln1_b", (e,)),
            (f"l{l}.wq", (e, e)),
            (f"l{l}.bq", (e,)),
            (f"l{l}.wk", (e, e)),
            (f"l{l}.bk", (e,)),
            (f"l{l}.wv", (e, e)),
            (f"l{l}.bv", (e,)),
            (f"l{l}.wo", (e, e)),
            (f"l{l}.bo", (e,)),
            (f"l{l}.ln2_g", (e,)),
            (f"l{l}.ln2_b", (e,)),
            (f"l{l}.w1", (e, f)),
            (f"l{l}.b1", (f,)),
            (f"l{l}.w2", (f, e)),
            (f"l{l}.b2", (e,)),
        ]
    spec += [("lnf_g", (e,)), ("lnf_b", (e,))]
    return spec


def quant_matrix_names(cfg: Config) -> List[str]:
    """The 6·L quantizable matrices, in Rust `matrix_ids()` order."""
    names = []
    for l in range(cfg.layers):
        names += [f"l{l}.wq", f"l{l}.wk", f"l{l}.wv", f"l{l}.wo", f"l{l}.w1", f"l{l}.w2"]
    return names


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return g * (x - mu) / jnp.sqrt(var + LN_EPS) + b


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(GELU_A * (x + GELU_C * x * x * x)))


def _attention(q, k, v, cfg: Config):
    """Causal multi-head attention. q/k/v: (B, T, E)."""
    bsz, t, e = q.shape
    h, dh = cfg.heads, cfg.head_dim
    qh = q.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)  # (B,H,T,dh)
    kh = k.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)
    vh = v.reshape(bsz, t, h, dh).transpose(0, 2, 1, 3)
    scores = jnp.einsum("bhqd,bhkd->bhqk", qh, kh) / jnp.sqrt(float(dh))
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, vh)
    return ctx.transpose(0, 2, 1, 3).reshape(bsz, t, e)


def _matmul(x, w, use_pallas: bool):
    if use_pallas:
        from .kernels.matmul import tiled_matmul

        b, t, din = x.shape
        y = tiled_matmul(x.reshape(b * t, din), w)
        return y.reshape(b, t, w.shape[1])
    return x @ w


def forward_intermediates(tokens, weights, cfg: Config, use_pallas: bool = False):
    """Forward pass. Returns (Z, logits, inputs) where `inputs[name]` is the
    (B,T,·) activation feeding quantizable matrix `name`."""
    names = [n for n, _ in weight_spec(cfg)]
    w = dict(zip(names, weights))
    bsz, t = tokens.shape
    x = w["embed"][tokens] + w["pos"][:t][None, :, :]
    inputs = {}
    for l in range(cfg.layers):
        p = f"l{l}."
        a = _ln(x, w[p + "ln1_g"], w[p + "ln1_b"])
        inputs[p + "wq"] = a
        inputs[p + "wk"] = a
        inputs[p + "wv"] = a
        q = a @ w[p + "wq"] + w[p + "bq"]
        k = a @ w[p + "wk"] + w[p + "bk"]
        v = a @ w[p + "wv"] + w[p + "bv"]
        ctx = _attention(q, k, v, cfg)
        inputs[p + "wo"] = ctx
        x = x + ctx @ w[p + "wo"] + w[p + "bo"]
        bn = _ln(x, w[p + "ln2_g"], w[p + "ln2_b"])
        inputs[p + "w1"] = bn
        u = _matmul(bn, w[p + "w1"], use_pallas) + w[p + "b1"]
        hmat = _gelu(u)
        inputs[p + "w2"] = hmat
        x = x + _matmul(hmat, w[p + "w2"], use_pallas) + w[p + "b2"]
    z = _ln(x, w["lnf_g"], w["lnf_b"])
    logits = z @ w["embed"].T
    return z, logits, inputs


def forward_logits(tokens, *weights, cfg: Config, use_pallas: bool = True):
    _, logits, _ = forward_intermediates(tokens, list(weights), cfg, use_pallas)
    return (logits,)


def loss_fn(tokens, targets, *weights, cfg: Config):
    _, logits, _ = forward_intermediates(tokens, list(weights), cfg, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return (jnp.mean(nll),)


def gradvar_fn(tokens, u, s, *weights, cfg: Config):
    """Gradient sample for Algorithm 1: grads of c = Σ_bt s_bt (Z_bt·u)
    with respect to each quantizable matrix; plus per-matrix input means
    (X̄ numerators) and Z itself (for PCA refresh)."""
    weights = list(weights)
    names = [n for n, _ in weight_spec(cfg)]
    qnames = quant_matrix_names(cfg)
    qidx = [names.index(n) for n in qnames]

    def c_of(qmats):
        wfull = list(weights)
        for i, qi in enumerate(qidx):
            wfull[qi] = qmats[i]
        z, _, inputs = forward_intermediates(tokens, wfull, cfg, use_pallas=False)
        proj = jnp.einsum("bte,e->bt", z, u)
        c = jnp.sum(proj * s.reshape(proj.shape))
        means = [jnp.mean(inputs[n], axis=(0, 1)) for n in qnames]
        return c, (means, z)

    qmats = [weights[i] for i in qidx]
    grads, (means, z) = jax.grad(c_of, has_aux=True)(qmats)
    return tuple(grads) + tuple(means) + (z,)
