"""L1 correctness: every Pallas kernel against its pure-jnp oracle,
with hypothesis sweeping shapes/values — the core kernel signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import tiled_matmul
from compile.kernels.matvec import quantized_matvec
from compile.kernels.quantize import compand_quantize

jax.config.update("jax_platform_name", "cpu")


# ------------------------------------------------------------ tiled matmul
@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([16, 48, 128]),
    k=st.sampled_from([32, 96, 128]),
    m=st.sampled_from([16, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(n, k, m, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, k)).astype(np.float32)
    w = rng.normal(size=(k, m)).astype(np.float32)
    got = np.asarray(tiled_matmul(jnp.asarray(x), jnp.asarray(w)))
    want = np.asarray(ref.ref_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_odd_divisor_shapes():
    # Shapes whose divisors are odd — exercises the tile picker.
    rng = np.random.default_rng(0)
    x = rng.normal(size=(18, 30)).astype(np.float32)
    w = rng.normal(size=(30, 42)).astype(np.float32)
    got = np.asarray(tiled_matmul(jnp.asarray(x), jnp.asarray(w)))
    np.testing.assert_allclose(got, x @ w, rtol=2e-4, atol=2e-4)


# ------------------------------------------------- companded quantization
@settings(max_examples=12, deadline=None)
@given(
    g=st.sampled_from([8, 64]),
    n=st.sampled_from([32, 256]),
    bits=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_compand_quantize_matches_ref(g, n, bits, seed):
    rng = np.random.default_rng(seed)
    theta = rng.laplace(scale=0.4, size=(g, n)).astype(np.float32)
    scale = (0.1 + rng.random(g)).astype(np.float32)
    mean = rng.normal(scale=0.05, size=g).astype(np.float32)
    got = np.asarray(compand_quantize(jnp.asarray(theta), jnp.asarray(scale), jnp.asarray(mean), bits))
    want = np.asarray(ref.ref_compand_quantize(jnp.asarray(theta), jnp.asarray(scale), jnp.asarray(mean), bits))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_compand_quantize_error_shrinks_with_bits():
    rng = np.random.default_rng(1)
    theta = rng.laplace(scale=1.0, size=(16, 512)).astype(np.float32)
    scale = np.ones(16, np.float32)
    mean = np.zeros(16, np.float32)
    errs = []
    for bits in (2, 4, 6):
        deq = np.asarray(compand_quantize(jnp.asarray(theta), jnp.asarray(scale), jnp.asarray(mean), bits))
        errs.append(float(np.mean((deq - theta) ** 2)))
    assert errs[0] > errs[1] > errs[2]


# ----------------------------------------------------------- LUT matvec
def _random_matvec_case(rng, k, m, g):
    group_id = rng.integers(0, g, size=k).astype(np.int32)
    bits = rng.integers(1, 9, size=g).astype(np.int32)
    # Codes must be < 2^bits of their row's group.
    codes = np.zeros((k, m), np.int32)
    for i in range(k):
        codes[i] = rng.integers(0, 1 << bits[group_id[i]], size=m)
    x = rng.normal(size=k).astype(np.float32)
    scales = (0.1 + rng.random(g)).astype(np.float32)
    means = rng.normal(scale=0.05, size=g).astype(np.float32)
    return codes, x, group_id, bits, scales, means


@settings(max_examples=8, deadline=None)
@given(
    k=st.sampled_from([32, 128]),
    m=st.sampled_from([64, 256]),
    g=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantized_matvec_matches_ref(k, m, g, seed):
    rng = np.random.default_rng(seed)
    codes, x, gid, bits, scales, means = _random_matvec_case(rng, k, m, g)
    luts = ref.make_companded_luts(8)
    got = np.asarray(
        quantized_matvec(
            jnp.asarray(codes), jnp.asarray(x), jnp.asarray(gid),
            jnp.asarray(bits), jnp.asarray(scales), jnp.asarray(means), luts,
        )
    )
    want = np.asarray(
        ref.ref_lut_matvec(
            jnp.asarray(codes), jnp.asarray(x), jnp.asarray(gid),
            jnp.asarray(bits), jnp.asarray(scales), jnp.asarray(means), luts,
        )
    )
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_compander_roundtrip():
    t = jnp.linspace(-3, 3, 101)
    c = ref.compand(t, 1.3, -0.2)
    back = ref.expand(c, 1.3, -0.2)
    np.testing.assert_allclose(np.asarray(back), np.asarray(t), rtol=1e-4, atol=1e-4)


def test_luts_match_quantizer_centers():
    luts = np.asarray(ref.make_companded_luts(8))
    for b in (1, 3, 5):
        levels = 1 << b
        t = (np.arange(levels) + 0.5) / levels
        want = np.asarray(ref.expand(jnp.asarray(t), 1.0, 0.0))
        np.testing.assert_allclose(luts[b, :levels], want, rtol=1e-5)
    # Padding is zero.
    assert luts[1, 2:].max() == 0.0
