"""L2 model checks: shapes, causality, gradvar structure, and consistency
between the Pallas-backed and plain forward paths."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_platform_name", "cpu")

CFG = model.Config(vocab=32, dim=16, heads=2, layers=2, mlp=32, max_seq=8)


def random_weights(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.normal(scale=0.05, size=s).astype(np.float32))
            if len(s) > 1 or not n.endswith("_g")
            else jnp.ones(s, jnp.float32)
            for n, s in model.weight_spec(cfg)]


def test_forward_shapes():
    w = random_weights(CFG)
    toks = jnp.zeros((2, 6), jnp.int32)
    z, logits, inputs = model.forward_intermediates(toks, w, CFG)
    assert z.shape == (2, 6, CFG.dim)
    assert logits.shape == (2, 6, CFG.vocab)
    assert inputs["l0.wq"].shape == (2, 6, CFG.dim)
    assert inputs["l1.w2"].shape == (2, 6, CFG.mlp)


def test_causality():
    w = random_weights(CFG, seed=1)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, CFG.vocab, size=(1, 6)).astype(np.int32)
    z1, _, _ = model.forward_intermediates(jnp.asarray(toks), w, CFG)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % CFG.vocab
    z2, _, _ = model.forward_intermediates(jnp.asarray(toks2), w, CFG)
    np.testing.assert_allclose(np.asarray(z1)[0, :5], np.asarray(z2)[0, :5], atol=1e-5)
    assert np.abs(np.asarray(z1)[0, 5] - np.asarray(z2)[0, 5]).sum() > 1e-4


def test_pallas_and_plain_forward_agree():
    w = random_weights(CFG, seed=3)
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 8)).astype(np.int32))
    (lp,) = model.forward_logits(toks, *w, cfg=CFG, use_pallas=True)
    (ld,) = model.forward_logits(toks, *w, cfg=CFG, use_pallas=False)
    np.testing.assert_allclose(np.asarray(lp), np.asarray(ld), rtol=2e-4, atol=2e-4)


def test_loss_of_uniform_model():
    # Zero weights → uniform logits → loss = ln(vocab).
    w = [jnp.zeros(s, jnp.float32) for _, s in model.weight_spec(CFG)]
    toks = jnp.zeros((1, 4), jnp.int32)
    (loss,) = model.loss_fn(toks, toks, *w, cfg=CFG)
    np.testing.assert_allclose(float(loss), np.log(CFG.vocab), rtol=1e-5)


def test_gradvar_outputs():
    w = random_weights(CFG, seed=5)
    rng = np.random.default_rng(6)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(2, 8)).astype(np.int32))
    u = jnp.asarray(rng.normal(size=CFG.dim).astype(np.float32))
    s = jnp.asarray(rng.choice([0.0, 1.0], size=16).astype(np.float32))
    outs = model.gradvar_fn(toks, u, s, *w, cfg=CFG)
    nq = 6 * CFG.layers
    assert len(outs) == 2 * nq + 1
    # Grad shapes match matrix shapes; means match input dims.
    qnames = model.quant_matrix_names(CFG)
    spec = dict(model.weight_spec(CFG))
    for i, name in enumerate(qnames):
        assert outs[i].shape == spec[name]
        assert outs[nq + i].shape == (spec[name][0],)
    assert outs[-1].shape == (2, 8, CFG.dim)
    # Nonzero gradients when s has support.
    assert float(jnp.sum(outs[0] ** 2)) > 0


def test_gradvar_zero_mask_gives_zero_grads():
    w = random_weights(CFG, seed=7)
    toks = jnp.zeros((1, 8), jnp.int32)
    u = jnp.ones(CFG.dim, jnp.float32)
    s = jnp.zeros(8, jnp.float32)
    outs = model.gradvar_fn(toks, u, s, *w, cfg=CFG)
    for i in range(6 * CFG.layers):
        assert float(jnp.sum(outs[i] ** 2)) == 0.0


def test_gradvar_matches_manual_fd():
    # Central finite difference on one weight entry.
    w = random_weights(CFG, seed=8)
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(0, CFG.vocab, size=(1, 6)).astype(np.int32))
    u = jnp.asarray(rng.normal(size=CFG.dim).astype(np.float32))
    s = jnp.asarray(np.ones(6, np.float32))
    names = [n for n, _ in model.weight_spec(CFG)]
    wq_idx = names.index("l0.wq")

    def c_value(wlist):
        z, _, _ = model.forward_intermediates(toks, wlist, CFG)
        return float(jnp.sum(jnp.einsum("bte,e->bt", z, u) * s.reshape(1, 6)))

    outs = model.gradvar_fn(toks, u, s, *w, cfg=CFG)
    analytic = float(outs[0][1, 2])  # l0.wq grad at (1,2)

    eps = 1e-3
    wp = list(w)
    wp[wq_idx] = w[wq_idx].at[1, 2].add(eps)
    cp = c_value(wp)
    wp[wq_idx] = w[wq_idx].at[1, 2].add(-eps)
    cm = c_value(wp)
    fd = (cp - cm) / (2 * eps)
    assert abs(fd - analytic) / max(abs(fd), abs(analytic), 1e-4) < 0.05
