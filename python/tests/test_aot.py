"""AOT lowering smoke tests: every artifact lowers to parseable HLO text
for a small config (full-size artifacts are built by `make artifacts`)."""

import functools

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

SMALL = model.Config(vocab=32, dim=16, heads=2, layers=1, mlp=32, max_seq=8)


def test_lower_forward_small():
    spec = model.weight_spec(SMALL)
    wshapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    tok = jax.ShapeDtypeStruct((2, 8), jnp.int32)
    fwd = functools.partial(model.forward_logits, cfg=SMALL, use_pallas=True)
    text = aot.to_hlo_text(jax.jit(fwd).lower(tok, *wshapes))
    assert "HloModule" in text
    assert len(text) > 1000


def test_lower_gradvar_small():
    spec = model.weight_spec(SMALL)
    wshapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    tok = jax.ShapeDtypeStruct((2, 8), jnp.int32)
    u = jax.ShapeDtypeStruct((SMALL.dim,), jnp.float32)
    s = jax.ShapeDtypeStruct((16,), jnp.float32)
    gv = functools.partial(model.gradvar_fn, cfg=SMALL)
    text = aot.to_hlo_text(jax.jit(gv).lower(tok, u, s, *wshapes))
    assert "HloModule" in text


def test_lower_loss_small():
    spec = model.weight_spec(SMALL)
    wshapes = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s in spec]
    tok = jax.ShapeDtypeStruct((2, 8), jnp.int32)
    loss = functools.partial(model.loss_fn, cfg=SMALL)
    text = aot.to_hlo_text(jax.jit(loss).lower(tok, tok, *wshapes))
    assert "HloModule" in text


def test_weight_spec_matches_rust_param_count():
    # Mirror of Rust ModelConfig::total_params — the cross-language
    # interchange contract.
    for name, cfg in model.PRESETS.items():
        spec = model.weight_spec(cfg)
        total = sum(int(jnp.prod(jnp.asarray(s))) for _, s in spec)
        e, f, l = cfg.dim, cfg.mlp, cfg.layers
        expect = (
            cfg.vocab * e + cfg.max_seq * e
            + l * (4 * e * e + 2 * e * f + 4 * e + f + e + 4 * e)
            + 2 * e
        )
        assert total == expect, name
