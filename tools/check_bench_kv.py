#!/usr/bin/env python3
"""CI regression gate for BENCH_kv.json (written by `cargo bench --bench bench_kv`).

Two layers of checks:

1. Within-run invariants — always enforced, no baseline needed:
   - paged-dense admits at least as many peak lanes as the seed-style
     flat accounting at the same KV byte budget;
   - quantized pages admit at least as many as paged-dense;
   - every arm completed every request (deferral must not drop work);
   - quantized-KV perplexity drift stays within the documented tolerance
     recorded in the artifact itself.

2. Baseline comparison — when a committed BENCH_kv.json is supplied:
   numeric fields under "gate.higher_better" may not drop, and fields
   under "gate.lower_better" may not rise, by more than --max-regression
   (default 20%). The bench only publishes deterministic fields (peak
   lanes, perplexity drift) into "gate"; wall-clock throughput stays
   informational in "arms" because shared-runner variance would flake
   any hard threshold.

Usage:
    tools/check_bench_kv.py BENCH_kv.json [baseline.json] [--max-regression 0.20]

Exit code 0 = green, 1 = regression, 2 = malformed input.
"""

import argparse
import json
import sys


def fail(msg: str) -> None:
    print(f"FAIL: {msg}")
    sys.exit(1)


def within_run_checks(cur: dict) -> None:
    arms = cur["arms"]
    flat = arms["dense_flat"]
    dense = arms["paged_dense"]
    quant = arms["paged_quant"]

    if dense["peak_lanes"] < flat["peak_lanes"]:
        fail(
            f"paged-dense peak lanes {dense['peak_lanes']} < flat accounting "
            f"{flat['peak_lanes']} at the same budget"
        )
    if quant["peak_lanes"] < dense["peak_lanes"]:
        fail(
            f"quantized-KV peak lanes {quant['peak_lanes']} < paged-dense "
            f"{dense['peak_lanes']} at the same budget"
        )
    if quant["peak_lanes"] <= flat["peak_lanes"]:
        fail(
            "quantized paging must strictly beat the seed's flat reservation "
            f"({quant['peak_lanes']} vs {flat['peak_lanes']} peak lanes)"
        )
    expected = cur["requests"]
    for name, arm in arms.items():
        if arm["completed"] != expected:
            fail(f"arm {name} completed {arm['completed']}/{expected} requests")

    ppl = cur["ppl"]
    if ppl["rel_drift"] > ppl["documented_tol"]:
        fail(
            f"quantized-KV perplexity drift {ppl['rel_drift']:.4f} exceeds the "
            f"documented tolerance {ppl['documented_tol']}"
        )
    print(
        "within-run OK: peak lanes "
        f"{flat['peak_lanes']} (flat) <= {dense['peak_lanes']} (paged) <= "
        f"{quant['peak_lanes']} (quant); ppl drift {ppl['rel_drift']:.4f}"
    )


def baseline_checks(cur: dict, base: dict, max_regression: float) -> None:
    if base.get("model") != cur.get("model"):
        # A silently-skipped comparison is a dead gate: fail loudly so the
        # baseline gets regenerated under the preset CI actually runs
        # (RADIO_BENCH_SMOKE=1 cargo bench --bench bench_kv).
        fail(
            f"baseline model {base.get('model')!r} != current {cur.get('model')!r}; "
            "regenerate the committed BENCH_kv.json with the same preset as this run"
        )
    cur_gate, base_gate = cur.get("gate", {}), base.get("gate", {})
    for direction, sign in (("higher_better", 1.0), ("lower_better", -1.0)):
        for key, base_val in base_gate.get(direction, {}).items():
            if key not in cur_gate.get(direction, {}):
                fail(f"gate field {direction}.{key} missing from current run")
            cur_val = cur_gate[direction][key]
            if base_val == 0:
                continue
            # Positive change = improvement under either direction.
            change = sign * (cur_val - base_val) / abs(base_val)
            status = "ok" if change >= -max_regression else "REGRESSION"
            print(f"  {direction}.{key}: {base_val} -> {cur_val} ({change:+.1%}) {status}")
            if change < -max_regression:
                fail(
                    f"{direction}.{key} regressed {-change:.1%} "
                    f"(limit {max_regression:.0%}): {base_val} -> {cur_val}"
                )
    print("baseline OK")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh BENCH_kv.json from this run")
    ap.add_argument("baseline", nargs="?", help="committed BENCH_kv.json to compare against")
    ap.add_argument("--max-regression", type=float, default=0.20)
    args = ap.parse_args()

    try:
        with open(args.current) as f:
            cur = json.load(f)
        within_run_checks(cur)
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"ERROR: cannot evaluate {args.current}: {e!r}")
        sys.exit(2)

    if args.baseline:
        try:
            with open(args.baseline) as f:
                base = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"ERROR: cannot read baseline {args.baseline}: {e!r}")
            sys.exit(2)
        baseline_checks(cur, base, args.max_regression)
    else:
        print("no baseline supplied; within-run checks only")


if __name__ == "__main__":
    main()
