#!/usr/bin/env python3
"""Generate the checked-in legacy (pre-checksum) `.radio` fixture.

Writes `rust/tests/fixtures/legacy_tiny.radio`: a RADIOQM2 container in
the PRE-integrity-frame byte layout — magic, self-delimiting packed
matrix records, end sentinel, side parameters — with NO "RADIOCK1"
marker, section table, or trailer. The fixture pins back-compat: every
future build must keep loading containers written before checksum
framing existed (`fault_injection.rs::checked_in_legacy_fixture_*`).

The model is a 1-layer toy (vocab 32, dim 8, heads 2, mlp 16, max_seq 8)
quantized at a uniform 4 bits, one row group per matrix, all-zero code
words — structurally a full, dequantizable model while keeping the
binary a few KB. Deterministic: re-running reproduces identical bytes.
"""

import struct
from pathlib import Path

OUT = Path(__file__).resolve().parent.parent / "rust" / "tests" / "fixtures" / "legacy_tiny.radio"

VOCAB, DIM, HEADS, LAYERS, MLP, MAX_SEQ = 32, 8, 2, 1, 16, 8
BITS = 4
END_OF_MATRICES = 0xFFFFFFFF

# (role tag, rows, cols) in Role::tag() order: Q K V O Up Down.
MATRICES = [
    (0, DIM, DIM),
    (1, DIM, DIM),
    (2, DIM, DIM),
    (3, DIM, DIM),
    (4, DIM, MLP),   # mlp_up: dim x mlp
    (5, MLP, DIM),   # mlp_down: mlp x dim
]


def lcg(seed):
    """Deterministic f32-friendly value stream (no float env dependence)."""
    state = seed
    while True:
        state = (state * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        yield ((state >> 40) % 2001 - 1000) / 1000.0  # [-1, 1] in 1e-3 steps


def packed_matrix_blob(rows, cols):
    """PackedMatrix::to_bytes layout: header, row_to_group, per-group
    meta (bits + f16 scale/mean), code words, col_bit_offset, AWQ flag,
    OWQ exception count. One group (m=1), all-zero codes."""
    out = bytearray()
    out += struct.pack("<III", rows, cols, 1)  # rows, cols, m
    out += struct.pack("<B", 0)  # QuantMode::Companded
    out += struct.pack("<I", 0) * rows  # row_to_group: all group 0
    for _ in range(cols):  # meta, indexed [col * m + group]
        out += struct.pack("<B", BITS)
        out += struct.pack("<e", 0.0625)  # scale (f16-exact)
        out += struct.pack("<e", 0.0)  # mean
    total_bits = rows * cols * BITS
    nwords = (total_bits + 63) // 64
    out += struct.pack("<I", nwords)
    out += struct.pack("<Q", 0) * nwords  # all codes zero
    for c in range(cols + 1):  # col_bit_offset: BITS * rows per column
        out += struct.pack("<Q", c * rows * BITS)
    out += struct.pack("<B", 0)  # no AWQ row scales
    out += struct.pack("<I", 0)  # no OWQ exception rows
    return bytes(out)


def side_params():
    """SideParams::write_to layout: u32-length JSON config, then
    u64-length-prefixed f32 slices in SideParams::slices() order."""
    cfg = (
        '{"vocab":%d,"dim":%d,"heads":%d,"layers":%d,"mlp":%d,"max_seq":%d}'
        % (VOCAB, DIM, HEADS, LAYERS, MLP, MAX_SEQ)
    )
    out = bytearray()
    out += struct.pack("<I", len(cfg))
    out += cfg.encode("ascii")
    vals = lcg(191)
    slices = [VOCAB * DIM, MAX_SEQ * DIM]  # embed, pos
    for _ in range(LAYERS):
        # ln1_g ln1_b bq bk bv bo ln2_g ln2_b b1 b2
        slices += [DIM] * 8 + [MLP, DIM]
    slices += [DIM, DIM]  # lnf_g, lnf_b
    for n in slices:
        out += struct.pack("<Q", n)
        for _ in range(n):
            out += struct.pack("<f", next(vals))
    return bytes(out)


def main():
    out = bytearray(b"RADIOQM2")  # magic only: no RADIOCK1 marker
    for tag, rows, cols in MATRICES:
        blob = packed_matrix_blob(rows, cols)
        out += struct.pack("<I", 0)  # layer 0
        out += struct.pack("<B", tag)
        out += struct.pack("<Q", len(blob))
        out += blob
    out += struct.pack("<I", END_OF_MATRICES)
    out += side_params()
    OUT.parent.mkdir(parents=True, exist_ok=True)
    OUT.write_bytes(bytes(out))
    print(f"wrote {OUT} ({len(out)} bytes)")


if __name__ == "__main__":
    main()
