//! Minimal API-compatible stand-in for the `anyhow` crate, vendored so the
//! workspace builds from an empty (offline) registry. Implements exactly
//! the subset the repo uses: `Result`/`Error`, `anyhow!`, `bail!`,
//! `ensure!`, and the `Context` extension trait for `Result` and `Option`.
//!
//! Swap back to the real crate by pointing the root `Cargo.toml` at the
//! registry version — no source changes needed.

use std::fmt;

/// Error: an ordered chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    fn wrap<C: fmt::Display>(mut self, ctx: C) -> Error {
        self.chain.insert(0, ctx.to_string());
        self
    }

    /// Iterate the message chain, outermost first (anyhow::Error::chain
    /// analogue, flattened to strings).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the whole chain, `a: b: c` (anyhow semantics).
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or("unknown error"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            Some((head, rest)) if !rest.is_empty() => {
                writeln!(f, "{head}")?;
                writeln!(f, "\nCaused by:")?;
                for (i, c) in rest.iter().enumerate() {
                    writeln!(f, "    {i}: {c}")?;
                }
                Ok(())
            }
            Some((head, _)) => write!(f, "{head}"),
            None => write!(f, "unknown error"),
        }
    }
}

// Mirrors anyhow: `Error` deliberately does NOT implement
// `std::error::Error`, which is what makes this blanket `From` coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        // Preserve source() chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).wrap(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("inner"))
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn io_error_converts() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).is_err());
        assert!(f(101).is_err());
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }
}
