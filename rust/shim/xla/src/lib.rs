//! Stub of the `xla` (PJRT) crate API used by `runtime/{artifact,provider}.rs`.
//!
//! The offline build environment carries no XLA shared library, so this
//! crate type-checks the runtime layer while making every entry point fail
//! fast with a clear error at `PjRtClient::cpu()`. The repo's behaviour is
//! unchanged: `integration_xla` tests and the `--provider xla` CLI path
//! already skip / error cleanly when artifacts or the runtime are absent.
//! Swap the root `Cargo.toml` dependency for the real crate to light up
//! the PJRT path; no source changes are needed.

use std::fmt;

#[derive(Debug)]
pub struct XlaError(String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: XLA/PJRT is unavailable in this build (the `xla` dependency is the offline stub)"
    ))
}

pub type Result<T> = std::result::Result<T, XlaError>;

/// Element types literals can carry (subset: what the repo moves across
/// the PJRT boundary).
pub trait NativeType: Copy + 'static {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}

pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::decompose_tuple"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The single failure point: everything downstream is unreachable in
    /// stub builds because no client can be constructed.
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_at_client_construction() {
        let err = PjRtClient::cpu().err().expect("stub must not construct a client");
        assert!(err.to_string().contains("unavailable"));
    }
}
