//! The `.radio` quantized-model container: packed transformer-block
//! matrices + full-precision "side" parameters (embeddings, LNs,
//! corrected biases), with save/load and dequantization back into a
//! `Weights` for evaluation.
//!
//! The single-point container (`RADIOQM2`) is *streaming-friendly*:
//! packed matrices are emitted first as self-delimiting records and the
//! side parameters follow a sentinel, so [`QuantizedModelWriter`] can
//! write each matrix the moment it is packed without ever holding the
//! whole model (or a dense `Weights` clone — the v1 format's base
//! section stored every block matrix twice) in memory.
//!
//! The multi-point revision (`RADIOQM3`) carries N *rate points* — the
//! same model packed at several average bit rates off one calibration
//! artifact — sharing one copy of the heavy side parameters, with only
//! the (tiny, rate-dependent) corrected biases stored per point. It is
//! written and read by `coordinator::ladder::RateLadder`;
//! [`QuantizedModel::load`] accepts both revisions and resolves a
//! `RADIOQM3` file to its highest-rate point. Byte-level specs for both
//! live in `docs/FORMATS.md`.
//!
//! Containers written by this build carry the `util::integrity` frame:
//! an integrity marker after the magic, per-section CRC32s, and a
//! trailing end magic, so truncation and bit flips are rejected at load
//! with a typed [`RadioError`] instead of decoding garbage. Legacy
//! (pre-checksum) containers — no marker after the magic — still load.

use std::collections::BTreeMap;
use std::io::{BufWriter, Cursor, Read, Write};
use std::path::{Path, PathBuf};

use crate::error::RadioError;
use crate::model::config::ModelConfig;
use crate::model::weights::{MatId, Role, SideParams, Weights};
use crate::quant::activations::{ActQuantParams, ActQuantSpec, ActScalePolicy};
use crate::quant::bitpack::{f16_to_f32, f32_to_f16, PackedMatrix};
use crate::util::atomic_io::{self, AtomicFile};
use crate::util::failpoint;
use crate::util::integrity::{
    self, Crc32, MappedContainer, SectionWriter, SEC_ACTQ, SEC_MATRICES, SEC_SIDE,
};
use crate::util::json::Json;

/// Record tag marking the end of a packed-matrix stream.
const END_OF_MATRICES: u32 = u32::MAX;

/// Magic of the single-point `.radio` container.
pub(crate) const MAGIC_QM2: &[u8; 8] = b"RADIOQM2";
/// Magic of the multi-rate-point `.radio` container.
pub(crate) const MAGIC_QM3: &[u8; 8] = b"RADIOQM3";
/// Sub-magic opening the optional activation-quantization section.
const ACTQ_MAGIC: &[u8; 8] = b"RADIOAQ1";

/// Serialize an [`ActQuantSpec`]: sub-magic, entry count, then per
/// entry `layer u32, role u8, bits u8, policy u8, scale f16`.
fn write_act_spec<W: Write>(f: &mut W, spec: &ActQuantSpec) -> std::io::Result<()> {
    f.write_all(ACTQ_MAGIC)?;
    f.write_all(&(spec.entries.len() as u32).to_le_bytes())?;
    for (id, p) in &spec.entries {
        f.write_all(&(id.layer as u32).to_le_bytes())?;
        f.write_all(&[id.role.tag(), p.bits, p.policy.tag()])?;
        f.write_all(&f32_to_f16(p.scale).to_le_bytes())?;
    }
    Ok(())
}

/// Probe for an activation-quantization section at the current read
/// position. `Ok(None)` on a clean EOF — the container predates the
/// section or was written weight-only; activation quantization is then
/// simply disabled. Anything else must parse fully.
fn read_act_spec<R: Read>(f: &mut R) -> std::io::Result<Option<ActQuantSpec>> {
    let mut magic = [0u8; 8];
    if !integrity::read_or_eof(f, &mut magic)? {
        return Ok(None);
    }
    if &magic != ACTQ_MAGIC {
        return Err(inv("bad activation-spec sub-magic"));
    }
    let mut l4 = [0u8; 4];
    f.read_exact(&mut l4)?;
    let n = u32::from_le_bytes(l4) as usize;
    let mut entries = Vec::with_capacity(n.min(1 << 16));
    for _ in 0..n {
        f.read_exact(&mut l4)?;
        let layer = u32::from_le_bytes(l4) as usize;
        let mut rec = [0u8; 3];
        f.read_exact(&mut rec)?;
        let role = Role::from_tag(rec[0]).ok_or_else(|| inv("bad role tag in act spec"))?;
        let policy =
            ActScalePolicy::from_tag(rec[2]).ok_or_else(|| inv("bad act scale policy tag"))?;
        let mut l2 = [0u8; 2];
        f.read_exact(&mut l2)?;
        let scale = f16_to_f32(u16::from_le_bytes(l2));
        let p = if rec[1] == 0 {
            ActQuantParams::full_precision()
        } else {
            ActQuantParams::new(rec[1], policy, scale)
        };
        entries.push((MatId { layer, role }, p));
    }
    if !entries.windows(2).all(|w| w[0].0 < w[1].0) {
        return Err(inv("act spec entries not sorted by matrix id"));
    }
    Ok(Some(ActQuantSpec { entries }))
}

/// Write one self-delimiting packed-matrix record (shared by the QM2
/// writer and the QM3 ladder writer).
pub(crate) fn write_matrix_record<W: Write>(
    f: &mut W,
    id: MatId,
    p: &PackedMatrix,
) -> std::io::Result<()> {
    assert!(
        (id.layer as u32) != END_OF_MATRICES,
        "layer index collides with the end sentinel"
    );
    f.write_all(&(id.layer as u32).to_le_bytes())?;
    f.write_all(&[id.role.tag()])?;
    let bytes = p.to_bytes();
    f.write_all(&(bytes.len() as u64).to_le_bytes())?;
    f.write_all(&bytes)?;
    Ok(())
}

/// Seal a packed-matrix stream with the end-of-matrices sentinel.
pub(crate) fn write_end_of_matrices<W: Write>(f: &mut W) -> std::io::Result<()> {
    f.write_all(&END_OF_MATRICES.to_le_bytes())
}

/// Read packed-matrix records up to (and consuming) the end sentinel —
/// the shared parser behind both container revisions.
pub(crate) fn read_matrix_records<R: Read>(
    f: &mut R,
) -> std::io::Result<Vec<(MatId, PackedMatrix)>> {
    let mut l4 = [0u8; 4];
    let mut l8 = [0u8; 8];
    let mut packed = Vec::new();
    loop {
        f.read_exact(&mut l4)?;
        let layer = u32::from_le_bytes(l4);
        if layer == END_OF_MATRICES {
            break;
        }
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        let role = Role::from_tag(tag[0]).ok_or_else(|| inv("bad role tag"))?;
        f.read_exact(&mut l8)?;
        let plen = u64::from_le_bytes(l8) as usize;
        let mut pbytes = vec![0u8; plen];
        f.read_exact(&mut pbytes)?;
        let (pm, used) = PackedMatrix::from_bytes(&pbytes).map_err(inv)?;
        if used != plen {
            return Err(inv("packed matrix trailing bytes"));
        }
        packed.push((MatId { layer: layer as usize, role }, pm));
    }
    Ok(packed)
}

/// A fully quantized model: the paper's deliverable artifact.
///
/// `base` holds only the full-precision *side* parameters (embeddings,
/// positional table, LayerNorms, corrected biases `b^q`) — the block
/// matrices exist solely in `packed`, so a resident `QuantizedModel` is
/// O(side + packed bits), not O(dense model).
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    /// Full-precision side parameters (embeddings, positional table,
    /// LayerNorms, corrected biases).
    pub base: SideParams,
    /// One packed matrix per quantizable MatId, in `matrix_ids()` order.
    pub packed: Vec<(MatId, PackedMatrix)>,
    /// Activation-quantization spec from the joint W·A allocation.
    /// `None` (weight-only container, or one written before the section
    /// existed) keeps inference on the f32 activation path.
    pub act_quant: Option<ActQuantSpec>,
}

impl QuantizedModel {
    /// Dequantize into dense weights for evaluation.
    pub fn to_weights(&self) -> Weights {
        let index: BTreeMap<MatId, &PackedMatrix> =
            self.packed.iter().map(|(id, p)| (*id, p)).collect();
        self.base.to_weights_with(|id| {
            index
                .get(&id)
                .map(|p| p.unpack())
                .unwrap_or_else(|| panic!("missing packed matrix {id}"))
        })
    }

    /// Average payload bits/weight across all packed matrices.
    pub fn avg_bits(&self) -> f64 {
        let (mut bits, mut count) = (0f64, 0usize);
        for (_, p) in &self.packed {
            bits += p.payload_bits() as f64;
            count += p.rows * p.cols;
        }
        bits / count as f64
    }

    /// Overhead bits as a fraction of payload bits (Table 3c).
    pub fn overhead_fraction(&self) -> f64 {
        let payload: usize = self.packed.iter().map(|(_, p)| p.payload_bits()).sum();
        let overhead: usize = self.packed.iter().map(|(_, p)| p.overhead_bits()).sum();
        overhead as f64 / payload.max(1) as f64
    }

    /// Fraction of block weights pruned to zero (Table 3b).
    pub fn pruned_fraction(&self) -> f64 {
        let (mut pruned, mut count) = (0f64, 0usize);
        for (_, p) in &self.packed {
            pruned += p.pruned_fraction() * (p.rows * p.cols) as f64;
            count += p.rows * p.cols;
        }
        pruned / count as f64
    }

    /// Compressed model size in bytes (payload + overhead + FP16 side
    /// params), vs the FP16 dense size.
    pub fn compression_summary(&self) -> (f64, f64) {
        let payload: usize = self.packed.iter().map(|(_, p)| p.payload_bits()).sum();
        let overhead: usize = self.packed.iter().map(|(_, p)| p.overhead_bits()).sum();
        let block_weights: usize = self.packed.iter().map(|(_, p)| p.rows * p.cols).sum();
        let compressed_bits = payload + overhead;
        let fp16_bits = block_weights * 16;
        (
            compressed_bits as f64 / 8.0,
            fp16_bits as f64 / compressed_bits as f64,
        )
    }

    /// Save the container (via the streaming writer, so the bytes are
    /// identical to a stream-written artifact). The write is atomic:
    /// bytes stage into `<path>.tmp` and replace `path` only on a
    /// successful [`QuantizedModelWriter::finish_with`], so a crash
    /// mid-save never clobbers an existing artifact.
    pub fn save(&self, path: &Path) -> Result<(), RadioError> {
        let mut w = QuantizedModelWriter::create(path)?;
        for (id, p) in &self.packed {
            w.write_matrix(*id, p)?;
        }
        w.finish_with(&self.base, self.act_quant.as_ref())
    }

    /// Load a `.radio` container. Accepts both revisions: a `RADIOQM2`
    /// file yields its single model; a multi-point `RADIOQM3` rate
    /// ladder resolves to its **highest-rate point** (the serving
    /// target). Use `coordinator::ladder::RateLadder::load` to access
    /// every point of a ladder.
    ///
    /// Checksummed containers (written by this build) are verified
    /// section-by-section before any payload byte is parsed; legacy
    /// containers fall back to the per-field structural validations.
    /// All failures are typed [`RadioError`]s — never a panic.
    pub fn load(path: &Path) -> Result<QuantizedModel, RadioError> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 8 {
            return Err(RadioError::Truncated { section: "container magic".into() });
        }
        let magic: [u8; 8] = bytes[..8].try_into().unwrap();
        let payload: &[u8] = match integrity::verify(&bytes)? {
            Some(checked) => checked.payload,
            None => &bytes[8..],
        };
        let mut f = Cursor::new(payload);
        if &magic == MAGIC_QM3 {
            let ladder = crate::coordinator::ladder::RateLadder::read_body(&mut f)
                .map_err(|e| RadioError::from(e).in_section("rate ladder body"))?;
            return ladder
                .points
                .len()
                .checked_sub(1)
                .map(|top| ladder.model(top))
                .ok_or_else(|| RadioError::Corrupt {
                    section: "rate ladder body".into(),
                    detail: "rate ladder carries no points".into(),
                });
        }
        if &magic != MAGIC_QM2 {
            return Err(RadioError::UnknownFormat {
                detail: format!(
                    "magic {:?} is not a .radio quantized model",
                    String::from_utf8_lossy(&magic)
                ),
            });
        }
        let packed = read_matrix_records(&mut f)
            .map_err(|e| RadioError::from(e).in_section("matrix stream"))?;
        let base = SideParams::read_from(&mut f)
            .map_err(|e| RadioError::from(e).in_section("side parameters"))?;
        let act_quant = read_act_spec(&mut f)
            .map_err(|e| RadioError::from(e).in_section("activation quant spec"))?;
        Ok(QuantizedModel { base, packed, act_quant })
    }

    /// Load a `.radio` container through the *mapped* path: the
    /// integrity frame (trailer + section table) is verified eagerly
    /// without reading any payload, then each section is read and
    /// CRC-verified on first touch via positioned I/O — so opening a
    /// large container costs table-sized reads, not a full-file
    /// checksum pass. Produces a model identical to [`Self::load`]
    /// (tested byte-for-byte on the packed streams).
    ///
    /// Legacy (pre-checksum) containers fall back to the resident
    /// loader unchanged. A `RADIOQM3` ladder resolves to its
    /// highest-rate point, exactly like [`Self::load`]; use
    /// `coordinator::ladder::RateLadder::load_mapped` for the
    /// degraded-mode (corrupt-point-tolerant) ladder path.
    pub fn load_mapped(path: &Path) -> Result<QuantizedModel, RadioError> {
        let Some(mc) = MappedContainer::open(path)? else {
            return Self::load(path);
        };
        if &mc.magic == MAGIC_QM3 {
            let (ladder, _) = crate::coordinator::ladder::RateLadder::from_mapped(&mc)?;
            return ladder
                .points
                .len()
                .checked_sub(1)
                .map(|top| ladder.model(top))
                .ok_or_else(|| RadioError::Corrupt {
                    section: "rate ladder body".into(),
                    detail: "rate ladder carries no points".into(),
                });
        }
        if &mc.magic != MAGIC_QM2 {
            return Err(RadioError::UnknownFormat {
                detail: format!(
                    "magic {:?} is not a .radio quantized model",
                    String::from_utf8_lossy(&mc.magic)
                ),
            });
        }
        let find = |tag: u8| mc.sections.iter().position(|s| s.tag == tag);
        let mi = find(SEC_MATRICES).ok_or_else(|| RadioError::Corrupt {
            section: "section table".into(),
            detail: "container has no matrix stream section".into(),
        })?;
        let si = find(SEC_SIDE).ok_or_else(|| RadioError::Corrupt {
            section: "section table".into(),
            detail: "container has no side-parameter section".into(),
        })?;
        let mbytes = mc.read_section(mi)?;
        let packed = read_matrix_records(&mut Cursor::new(&mbytes[..]))
            .map_err(|e| RadioError::from(e).in_section("matrix stream"))?;
        let sbytes = mc.read_section(si)?;
        let base = SideParams::read_from(&mut Cursor::new(&sbytes[..]))
            .map_err(|e| RadioError::from(e).in_section("side parameters"))?;
        let act_quant = match find(SEC_ACTQ) {
            Some(ai) => {
                let abytes = mc.read_section(ai)?;
                read_act_spec(&mut Cursor::new(&abytes[..]))
                    .map_err(|e| RadioError::from(e).in_section("activation quant spec"))?
            }
            None => None,
        };
        Ok(QuantizedModel { base, packed, act_quant })
    }

    /// Shape of the model this container was packed from.
    pub fn config(&self) -> &ModelConfig {
        &self.base.config
    }

    /// Human-readable summary as JSON (for reports).
    pub fn summary_json(&self) -> Json {
        let (bytes, ratio) = self.compression_summary();
        Json::obj(vec![
            ("avg_bits", Json::num(self.avg_bits())),
            ("overhead_fraction", Json::num(self.overhead_fraction())),
            ("pruned_fraction", Json::num(self.pruned_fraction())),
            ("compressed_bytes", Json::num(bytes)),
            ("ratio_vs_fp16", Json::num(ratio)),
        ])
    }

    /// Payload-balanced layer-pipeline shard plan over this container's
    /// matrices: partition the model's layers into `workers` contiguous
    /// spans so each span carries a near-equal share of packed bits
    /// (payload + side metadata, per the section table's own
    /// accounting). With rate-distortion-allocated mixed precision,
    /// layers carry *different* bit loads, so an even layer split can be
    /// badly skewed — the plan is what [`LayerPipeline::with_plan`]
    /// consumes to balance stage latency.
    ///
    /// Greedy contiguous partition: walk the layers accumulating bits
    /// and cut when the running share reaches the proportional target,
    /// while always leaving at least one layer per remaining stage.
    /// Returns exactly `workers + 1` strictly increasing bounds
    /// (`0 = b₀ < … < b_W = layers`); `workers` is clamped to
    /// `[1, layers]`.
    ///
    /// [`LayerPipeline::with_plan`]: crate::infer::backend::LayerPipeline::with_plan
    pub fn shard_plan(&self, workers: usize) -> ShardPlan {
        let layers = self.base.config.layers;
        let w = workers.clamp(1, layers.max(1));
        let mut per_layer = vec![0usize; layers];
        for (id, pm) in &self.packed {
            if id.layer < layers {
                per_layer[id.layer] += pm.payload_bits() + pm.overhead_bits();
            }
        }
        let total: usize = per_layer.iter().sum();
        let mut bounds = vec![0usize];
        let mut acc = 0usize;
        for (li, &bits) in per_layer.iter().enumerate() {
            acc += bits;
            let next = bounds.len(); // index of the stage being closed
            if next < w {
                let remaining_layers = layers - (li + 1);
                let remaining_stages = w - next;
                // Forced cut: exactly one layer left per remaining stage.
                let must = remaining_layers == remaining_stages;
                // Proportional cut: this stage has reached its share…
                let met = acc * w >= total * next;
                // …and cutting still leaves every later stage a layer.
                if must || (met && remaining_layers >= remaining_stages) {
                    bounds.push(li + 1);
                }
            }
        }
        bounds.push(layers);
        let stage_payload_bits = bounds
            .windows(2)
            .map(|wn| per_layer[wn[0]..wn[1]].iter().sum())
            .collect();
        ShardPlan { workers: w, stage_bounds: bounds, stage_payload_bits }
    }
}

/// A layer-pipeline partition of a container's transformer blocks —
/// `workers` contiguous stages balanced by packed payload size rather
/// than layer count. Built by [`QuantizedModel::shard_plan`]; consumed
/// by the layer-pipeline backend. The plan is advisory: an engine whose
/// layer count doesn't match the bounds falls back to an even split.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardPlan {
    /// Stage count W (after clamping to the layer count).
    pub workers: usize,
    /// `W + 1` strictly increasing layer cut points; stage `t` owns
    /// layers `stage_bounds[t]..stage_bounds[t + 1]`.
    pub stage_bounds: Vec<usize>,
    /// Packed bits (payload + side metadata) each stage carries —
    /// diagnostics for the operator sizing guide.
    pub stage_payload_bits: Vec<usize>,
}

// ---------------------------------------------------------------------
// Pack journal (`<container>.journal` sidecar)
// ---------------------------------------------------------------------

/// Magic opening the `.radio.journal` pack-resume sidecar.
const JOURNAL_MAGIC: &[u8; 8] = b"RADIOJL1";

/// Sidecar-path convention for a journaled pack: `<container>.journal`
/// (extension appended, so `model.radio` journals to
/// `model.radio.journal`).
pub fn journal_path(container: &Path) -> PathBuf {
    let mut os = container.as_os_str().to_os_string();
    os.push(".journal");
    PathBuf::from(os)
}

/// One durably-written matrix record, as recorded in the pack journal.
/// Byte-level spec in `docs/FORMATS.md`.
#[derive(Clone, Debug, PartialEq)]
pub struct JournalEntry {
    /// Zero-based position of this record in the pack order.
    pub index: usize,
    /// Which matrix the record holds.
    pub id: MatId,
    /// Absolute container offset one past the record's last byte.
    pub end_off: u64,
    /// CRC32 of the container's matrix-stream bytes `[16, end_off)` —
    /// both a torn-tail detector and the seed for the resumed section
    /// checksum.
    pub stream_crc: u32,
    /// The record's payload bits (restores the pack's rate accounting).
    pub payload_bits: u64,
    /// The record's weight count (restores the rate denominator).
    pub weights: u64,
    /// Corrected bias computed for this matrix, if bias correction was
    /// on — journaled so a resumed pack seals identical side params.
    pub bias: Option<Vec<f32>>,
}

fn encode_journal_entry(e: &JournalEntry) -> Vec<u8> {
    let mut body = Vec::with_capacity(38 + e.bias.as_ref().map_or(0, |b| 4 + 4 * b.len()));
    body.extend_from_slice(&(e.index as u32).to_le_bytes());
    body.extend_from_slice(&(e.id.layer as u32).to_le_bytes());
    body.push(e.id.role.tag());
    body.extend_from_slice(&e.end_off.to_le_bytes());
    body.extend_from_slice(&e.stream_crc.to_le_bytes());
    body.extend_from_slice(&e.payload_bits.to_le_bytes());
    body.extend_from_slice(&e.weights.to_le_bytes());
    match &e.bias {
        Some(b) => {
            body.push(1);
            body.extend_from_slice(&(b.len() as u32).to_le_bytes());
            for &x in b {
                body.extend_from_slice(&x.to_le_bytes());
            }
        }
        None => body.push(0),
    }
    let mut out = Vec::with_capacity(8 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    let crc = integrity::crc32(&body);
    out.extend_from_slice(&body);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

fn decode_journal_body(body: &[u8]) -> Option<JournalEntry> {
    if body.len() < 38 {
        return None;
    }
    let u32le = |o: usize| u32::from_le_bytes(body[o..o + 4].try_into().unwrap());
    let u64le = |o: usize| u64::from_le_bytes(body[o..o + 8].try_into().unwrap());
    let index = u32le(0) as usize;
    let layer = u32le(4) as usize;
    let role = Role::from_tag(body[8])?;
    let end_off = u64le(9);
    let stream_crc = u32le(17);
    let payload_bits = u64le(21);
    let weights = u64le(29);
    let bias = match body[37] {
        0 if body.len() == 38 => None,
        1 if body.len() >= 42 => {
            let blen = u32le(38) as usize;
            if body.len() != 42 + 4 * blen {
                return None;
            }
            let mut b = Vec::with_capacity(blen);
            for k in 0..blen {
                b.push(f32::from_le_bytes(body[42 + 4 * k..46 + 4 * k].try_into().unwrap()));
            }
            Some(b)
        }
        _ => return None,
    };
    Some(JournalEntry {
        index,
        id: MatId { layer, role },
        end_off,
        stream_crc,
        payload_bits,
        weights,
        bias,
    })
}

/// Parse the longest valid entry prefix of a journal file. A torn or
/// bit-flipped tail entry (interrupted append) is silently dropped —
/// resume then repacks from the last intact entry. `None` when the
/// file is unreadable or does not start with the journal magic.
fn read_journal(path: &Path) -> Option<Vec<JournalEntry>> {
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() < 8 || &bytes[..8] != JOURNAL_MAGIC {
        return None;
    }
    let mut entries = Vec::new();
    let mut off = 8usize;
    loop {
        if off + 4 > bytes.len() {
            break;
        }
        let blen = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        let Some(end) = off.checked_add(4 + blen + 4) else {
            break;
        };
        if end > bytes.len() {
            break;
        }
        let body = &bytes[off + 4..off + 4 + blen];
        let stored = u32::from_le_bytes(bytes[end - 4..end].try_into().unwrap());
        if integrity::crc32(body) != stored {
            break;
        }
        match decode_journal_body(body) {
            Some(e) if e.index == entries.len() => entries.push(e),
            _ => break,
        }
        off = end;
    }
    Some(entries)
}

struct Journal {
    file: std::fs::File,
    path: PathBuf,
    /// Entries written to the container since the last checkpoint;
    /// appended to the journal only after their bytes are durable.
    pending: Vec<JournalEntry>,
    checkpoints: u64,
}

/// Streaming `.radio` writer: emit packed matrices one at a time (each is
/// flushed to disk immediately and can be dropped by the caller), then
/// seal the container with the side parameters. The Pack stage of the
/// compression pipeline drives this so peak memory is one packing window,
/// not the whole quantized model.
///
/// The integrity frame is computed *while* streaming: bytes pass
/// through a CRC-tracking [`SectionWriter`], and the section table plus
/// trailer land on [`finish`](Self::finish) — no buffering, no second
/// pass over the file.
///
/// **Durability.** Every byte stages into `<path>.tmp`
/// ([`AtomicFile`]); the destination is replaced only by the rename
/// inside `finish`, so an existing artifact is never clobbered by a
/// partial write. The journaled variant
/// ([`create_journaled`](Self::create_journaled)) additionally records
/// each durably-flushed matrix record in a `<path>.journal` sidecar and
/// can resume a crashed pack from the last checkpoint, bit-identical
/// to an uninterrupted run.
pub struct QuantizedModelWriter {
    f: SectionWriter<BufWriter<AtomicFile>>,
    matrices: usize,
    journal: Option<Journal>,
}

impl QuantizedModelWriter {
    /// Begin staging a new container: write the `RADIOQM2` header plus
    /// integrity marker to `<path>.tmp` and open the matrix stream.
    pub fn create(path: &Path) -> Result<QuantizedModelWriter, RadioError> {
        let mut f = BufWriter::new(AtomicFile::create(path)?);
        f.write_all(MAGIC_QM2)?;
        f.write_all(integrity::CHECK_MAGIC)?;
        let mut f = SectionWriter::new(f);
        f.begin(SEC_MATRICES);
        Ok(QuantizedModelWriter { f, matrices: 0, journal: None })
    }

    /// [`create`](Self::create) with a pack journal: if a crashed
    /// journaled pack left `<path>.tmp` and `<path>.journal` behind,
    /// verify the journal against the staging file (header, per-entry
    /// running CRC) and resume after the last intact record; otherwise
    /// start fresh. Returns the writer plus the already-durable entries
    /// (empty on a fresh start) — the caller skips those records and
    /// replays their accounting.
    pub fn create_journaled(
        path: &Path,
    ) -> Result<(QuantizedModelWriter, Vec<JournalEntry>), RadioError> {
        if let Some(resumed) = Self::try_resume(path) {
            return Ok(resumed);
        }
        let jpath = journal_path(path);
        let mut jfile = std::fs::File::create(&jpath)?;
        jfile.write_all(JOURNAL_MAGIC)?;
        jfile.sync_data()?;
        let mut w = Self::create(path)?;
        w.journal =
            Some(Journal { file: jfile, path: jpath, pending: Vec::new(), checkpoints: 0 });
        Ok((w, Vec::new()))
    }

    /// Attempt to resume from a surviving staging file + journal. Any
    /// inconsistency (missing files, wrong header, CRC mismatch) yields
    /// `None` and the pack starts fresh — resume is best-effort, never
    /// a failure mode of its own.
    fn try_resume(path: &Path) -> Option<(QuantizedModelWriter, Vec<JournalEntry>)> {
        let jpath = journal_path(path);
        let tmp = atomic_io::tmp_path(path);
        let mut entries = read_journal(&jpath)?;
        if entries.is_empty() {
            return None;
        }
        let mut tf = std::fs::File::open(&tmp).ok()?;
        let tmp_len = tf.metadata().ok()?.len();
        let mut header = [0u8; integrity::HEADER_LEN];
        tf.read_exact(&mut header).ok()?;
        if &header[..8] != MAGIC_QM2 || &header[8..] != integrity::CHECK_MAGIC {
            return None;
        }
        // Walk the staging file once, re-checksumming the matrix stream
        // and snapshotting at every journaled boundary: keep the longest
        // entry prefix whose running CRC matches the file's bytes.
        let mut crc = Crc32::new();
        let mut pos = integrity::HEADER_LEN as u64;
        let mut good: Option<(usize, Crc32)> = None;
        let mut buf = vec![0u8; 1 << 16];
        for (i, e) in entries.iter().enumerate() {
            if e.end_off < pos || e.end_off > tmp_len {
                break;
            }
            let mut remaining = e.end_off - pos;
            while remaining > 0 {
                let take = remaining.min(buf.len() as u64) as usize;
                tf.read_exact(&mut buf[..take]).ok()?;
                crc.update(&buf[..take]);
                remaining -= take as u64;
            }
            pos = e.end_off;
            if crc.peek() == e.stream_crc {
                good = Some((i + 1, crc.clone()));
            } else {
                break;
            }
        }
        let (keep, crc) = good?;
        entries.truncate(keep);
        let end_off = entries.last().expect("keep >= 1").end_off;
        drop(tf);
        // Rewrite the journal as exactly the validated prefix, so its
        // byte length agrees with what resume will append after.
        let mut jfile = std::fs::File::create(&jpath).ok()?;
        jfile.write_all(JOURNAL_MAGIC).ok()?;
        for e in &entries {
            jfile.write_all(&encode_journal_entry(e)).ok()?;
        }
        jfile.sync_data().ok()?;
        let af = AtomicFile::resume(path, end_off).ok()?;
        let f = SectionWriter::resume_open(BufWriter::new(af), SEC_MATRICES, end_off, crc);
        let w = QuantizedModelWriter {
            f,
            matrices: entries.len(),
            journal: Some(Journal {
                file: jfile,
                path: jpath,
                pending: Vec::new(),
                checkpoints: 0,
            }),
        };
        Some((w, entries))
    }

    /// Remove any staging file and journal left behind by a crashed
    /// pack, so the next [`create_journaled`](Self::create_journaled)
    /// starts fresh (used when a surviving journal belongs to a
    /// different pack order).
    pub fn discard_partial(path: &Path) {
        let _ = std::fs::remove_file(atomic_io::tmp_path(path));
        let _ = std::fs::remove_file(journal_path(path));
    }

    /// Append one packed matrix record.
    pub fn write_matrix(&mut self, id: MatId, p: &PackedMatrix) -> Result<(), RadioError> {
        write_matrix_record(&mut self.f, id, p)?;
        failpoint::fire("format::writer::after_matrix", self.matrices as u64);
        self.matrices += 1;
        Ok(())
    }

    /// [`write_matrix`](Self::write_matrix), also staging a journal
    /// entry (made durable by the next [`checkpoint`](Self::checkpoint))
    /// that records the record's extent, running stream CRC, rate
    /// accounting, and the matrix's corrected bias.
    pub fn write_matrix_journaled(
        &mut self,
        id: MatId,
        p: &PackedMatrix,
        bias: Option<&[f32]>,
    ) -> Result<(), RadioError> {
        let index = self.matrices;
        let payload_bits = p.payload_bits() as u64;
        let weights = (p.rows * p.cols) as u64;
        self.write_matrix(id, p)?;
        if let Some(j) = self.journal.as_mut() {
            j.pending.push(JournalEntry {
                index,
                id,
                end_off: self.f.position(),
                stream_crc: self.f.open_section_crc(),
                payload_bits,
                weights,
                bias: bias.map(|b| b.to_vec()),
            });
        }
        Ok(())
    }

    /// Make everything written so far durable and journal it: flush and
    /// fsync the staging file, then append the pending entries to the
    /// journal and fsync that too. Strictly ordered — container bytes
    /// first, journal second — so a journal entry never describes bytes
    /// that could still be lost. No-op for unjournaled writers.
    pub fn checkpoint(&mut self) -> Result<(), RadioError> {
        let Some(j) = self.journal.as_mut() else {
            return Ok(());
        };
        if j.pending.is_empty() {
            return Ok(());
        }
        self.f.flush()?;
        self.f.get_ref().get_ref().sync_data()?;
        failpoint::fire("format::writer::checkpoint", j.checkpoints);
        for e in &j.pending {
            j.file.write_all(&encode_journal_entry(e))?;
        }
        j.file.sync_data()?;
        j.pending.clear();
        j.checkpoints += 1;
        Ok(())
    }

    /// Number of matrix records written so far.
    pub fn matrices_written(&self) -> usize {
        self.matrices
    }

    /// Seal the container: end-of-matrices sentinel, side params, then
    /// the integrity section table and trailer — and atomically publish
    /// the staged file over the destination.
    pub fn finish(self, side: &SideParams) -> Result<(), RadioError> {
        self.finish_with(side, None)
    }

    /// [`finish`](Self::finish), optionally appending an
    /// activation-quantization section (its own integrity section, so a
    /// flipped bit in the spec is caught before inference trusts it).
    /// On success the staging file has replaced the destination and the
    /// pack journal (if any) is deleted.
    pub fn finish_with(
        mut self,
        side: &SideParams,
        acts: Option<&ActQuantSpec>,
    ) -> Result<(), RadioError> {
        self.checkpoint()?;
        failpoint::fire("format::writer::before_seal", 0);
        write_end_of_matrices(&mut self.f)?;
        self.f.end();
        self.f.begin(SEC_SIDE);
        side.write_to(&mut self.f)?;
        self.f.end();
        if let Some(spec) = acts {
            self.f.begin(SEC_ACTQ);
            write_act_spec(&mut self.f, spec)?;
            self.f.end();
        }
        let bw = self.f.finish()?;
        let af = bw.into_inner().map_err(|e| RadioError::from(e.into_error()))?;
        af.commit()?;
        if let Some(j) = self.journal {
            let _ = std::fs::remove_file(&j.path);
        }
        Ok(())
    }
}

fn inv<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_matrix, Grouping, QuantMode, ScaleRule};
    use crate::util::rng::Rng;

    fn quantize_all(w: &Weights, bits: u8) -> QuantizedModel {
        let packed = w
            .matrix_ids()
            .into_iter()
            .map(|id| {
                let m = w.matrix(id);
                let grouping = Grouping::whole_columns(m.rows, m.cols);
                let bvec = vec![bits; grouping.num_groups()];
                (
                    id,
                    quantize_matrix(m, &grouping, &bvec, QuantMode::Companded, ScaleRule::Range),
                )
            })
            .collect();
        QuantizedModel { base: SideParams::from_weights(w), packed, act_quant: None }
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(91);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let path = std::env::temp_dir().join("radio_test_qm.radio");
        qm.save(&path).unwrap();
        let back = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(qm.to_weights().layers[0].wq.data, back.to_weights().layers[0].wq.data);
        assert!((qm.avg_bits() - back.avg_bits()).abs() < 1e-12);
    }

    #[test]
    fn shard_plan_partitions_all_layers_contiguously() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(97);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let layers = cfg.layers;
        let total: usize = qm
            .packed
            .iter()
            .map(|(_, pm)| pm.payload_bits() + pm.overhead_bits())
            .sum();
        for workers in [1usize, 2, 3, layers, layers + 5] {
            let plan = qm.shard_plan(workers);
            let w_eff = workers.clamp(1, layers);
            assert_eq!(plan.workers, w_eff);
            assert_eq!(plan.stage_bounds.len(), w_eff + 1);
            assert_eq!(plan.stage_bounds[0], 0);
            assert_eq!(*plan.stage_bounds.last().unwrap(), layers);
            assert!(
                plan.stage_bounds.windows(2).all(|b| b[0] < b[1]),
                "bounds must be strictly increasing: {:?}",
                plan.stage_bounds
            );
            assert_eq!(plan.stage_payload_bits.len(), w_eff);
            assert_eq!(plan.stage_payload_bits.iter().sum::<usize>(), total);
        }
    }

    #[test]
    fn container_does_not_store_dense_block_matrices() {
        // The v1 format serialized a full dense `Weights` clone inside
        // `base` even though `packed` replaces every block matrix on
        // dequantization. The v2 container must be far below the dense
        // block-parameter footprint (4 bytes/weight) at 4 bits/weight.
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(95);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let path = std::env::temp_dir().join("radio_test_qm_size.radio");
        qm.save(&path).unwrap();
        let on_disk = std::fs::metadata(&path).unwrap().len() as usize;
        let _ = std::fs::remove_file(&path);
        let dense_block_bytes = 4 * cfg.block_params();
        assert!(
            on_disk < dense_block_bytes,
            "container {on_disk} B should undercut dense block storage {dense_block_bytes} B"
        );
    }

    #[test]
    fn streaming_writer_matches_in_memory_path() {
        // stream-write → load → to_weights() must be bit-identical to the
        // resident model's to_weights().
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(96);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 3);

        let streamed = std::env::temp_dir().join("radio_test_qm_stream.radio");
        let mut writer = QuantizedModelWriter::create(&streamed).unwrap();
        for (id, p) in &qm.packed {
            writer.write_matrix(*id, p).unwrap();
        }
        assert_eq!(writer.matrices_written(), qm.packed.len());
        writer.finish(&qm.base).unwrap();

        let monolithic = std::env::temp_dir().join("radio_test_qm_mono.radio");
        qm.save(&monolithic).unwrap();
        let stream_bytes = std::fs::read(&streamed).unwrap();
        let mono_bytes = std::fs::read(&monolithic).unwrap();
        assert_eq!(stream_bytes, mono_bytes, "stream and save must emit identical bytes");

        let back = QuantizedModel::load(&streamed).unwrap();
        let _ = std::fs::remove_file(&streamed);
        let _ = std::fs::remove_file(&monolithic);
        let a = qm.to_weights();
        let b = back.to_weights();
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.wq.data, y.wq.data);
            assert_eq!(x.w2.data, y.w2.data);
            assert_eq!(x.bq, y.bq);
        }
        assert_eq!(a.embed.data, b.embed.data);
    }

    #[test]
    fn avg_bits_matches_requested() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(92);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 3);
        assert!((qm.avg_bits() - 3.0).abs() < 1e-9);
        let (_, ratio) = qm.compression_summary();
        assert!(ratio > 4.0, "compression vs fp16 should exceed 4x at 3 bits, got {ratio}");
    }

    #[test]
    fn dequantized_model_close_at_8_bits() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(93);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 8);
        let wq = qm.to_weights();
        let err: f64 = w.layers[0]
            .wq
            .data
            .iter()
            .zip(&wq.layers[0].wq.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.layers[0].wq.data.len() as f64;
        let var = crate::stats::moments::variance(&w.layers[0].wq.data);
        assert!(err < var * 0.01, "relative err {}", err / var);
    }

    #[test]
    fn load_rejects_garbage() {
        let p = std::env::temp_dir().join("radio_qm_garbage.radio");
        std::fs::write(&p, b"garbage file contents").unwrap();
        assert!(matches!(
            QuantizedModel::load(&p),
            Err(RadioError::UnknownFormat { .. })
        ));
        let _ = std::fs::remove_file(p);
    }

    /// Write `qm` in the pre-checksum layout: magic, records, sentinel,
    /// side parameters — no integrity marker, table, or trailer.
    fn write_legacy(qm: &QuantizedModel, path: &Path) {
        let mut f = BufWriter::new(std::fs::File::create(path).unwrap());
        f.write_all(MAGIC_QM2).unwrap();
        for (id, p) in &qm.packed {
            write_matrix_record(&mut f, *id, p).unwrap();
        }
        write_end_of_matrices(&mut f).unwrap();
        qm.base.write_to(&mut f).unwrap();
        f.flush().unwrap();
    }

    #[test]
    fn legacy_unchecksummed_container_still_loads() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(97);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let path = std::env::temp_dir().join("radio_test_qm_legacy.radio");
        write_legacy(&qm, &path);
        let back = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(qm.to_weights().layers[0].wq.data, back.to_weights().layers[0].wq.data);
        assert_eq!(qm.base.embed.data, back.base.embed.data);
        assert!(back.act_quant.is_none(), "legacy containers have no act spec");
    }

    #[test]
    fn act_spec_roundtrips_and_weight_only_container_loads_none() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(99);
        let w = Weights::init_training(cfg, &mut rng);
        let mut qm = quantize_all(&w, 4);

        // Weight-only container: no SEC_ACTQ, loads back as None.
        let path = std::env::temp_dir().join("radio_test_qm_noact.radio");
        qm.save(&path).unwrap();
        let sections = integrity::verify(&std::fs::read(&path).unwrap())
            .unwrap()
            .expect("checked")
            .sections
            .len();
        assert_eq!(sections, 2, "weight-only container: matrices + side");
        assert!(QuantizedModel::load(&path).unwrap().act_quant.is_none());
        let _ = std::fs::remove_file(&path);

        // Attach a spec exercising every field combination: dynamic
        // per-token, static with a calibrated scale, full precision.
        let ids: Vec<MatId> = qm.packed.iter().map(|(id, _)| *id).collect();
        let mut spec = ActQuantSpec::uniform(&ids, 8, ActScalePolicy::PerToken, 1.0);
        spec.entries[0].1 = ActQuantParams::full_precision();
        spec.entries[1].1 = ActQuantParams::new(4, ActScalePolicy::Static, 0.03);
        qm.act_quant = Some(spec.clone());
        let path = std::env::temp_dir().join("radio_test_qm_act.radio");
        qm.save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        let checked = integrity::verify(&bytes).unwrap().expect("checked");
        assert_eq!(checked.sections.len(), 3, "matrices + side + act spec");
        assert_eq!(checked.sections[2].tag, SEC_ACTQ);
        let back = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.act_quant, Some(spec), "act spec must roundtrip exactly");
        // Matrices and side params are untouched by the extra section.
        assert_eq!(qm.to_weights().layers[0].wq.data, back.to_weights().layers[0].wq.data);
    }

    #[test]
    fn truncation_and_bit_flip_at_every_section_boundary_are_rejected() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(98);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let path = std::env::temp_dir().join("radio_test_qm_corrupt.radio");
        qm.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        let checked = integrity::verify(&good).unwrap().expect("new containers are checked");
        // Interesting offsets: each section's start, midpoint, and end,
        // plus the table and trailer region.
        let mut offs: Vec<usize> = Vec::new();
        for s in &checked.sections {
            offs.push(s.off as usize);
            offs.push((s.off + s.len / 2) as usize);
            offs.push((s.off + s.len) as usize);
        }
        offs.push(good.len() - 10); // inside the trailer
        offs.push(good.len() - 1); // final end-magic byte

        let victim = std::env::temp_dir().join("radio_test_qm_victim.radio");
        for &o in &offs {
            // Truncate at the boundary: must fail typed, never panic.
            std::fs::write(&victim, &good[..o]).unwrap();
            let err = QuantizedModel::load(&victim).unwrap_err();
            assert!(
                matches!(
                    err,
                    RadioError::Truncated { .. }
                        | RadioError::Corrupt { .. }
                        | RadioError::ChecksumMismatch { .. }
                ),
                "truncation at {o} gave {err:?}"
            );
            // Bit-flip at the boundary (skipping offsets inside the
            // 16-byte magic region and one-past-the-end).
            if o >= integrity::HEADER_LEN && o < good.len() {
                let mut bad = good.clone();
                bad[o] ^= 0x10;
                std::fs::write(&victim, &bad).unwrap();
                let err = QuantizedModel::load(&victim).unwrap_err();
                assert!(
                    matches!(
                        err,
                        RadioError::Truncated { .. }
                            | RadioError::Corrupt { .. }
                            | RadioError::ChecksumMismatch { .. }
                    ),
                    "bit flip at {o} gave {err:?}"
                );
            }
        }
        let _ = std::fs::remove_file(&victim);
    }
}
