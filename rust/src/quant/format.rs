//! The `.radio` quantized-model container: packed transformer-block
//! matrices + full-precision "side" parameters (embeddings, LNs,
//! corrected biases), with save/load and dequantization back into a
//! `Weights` for evaluation.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::config::ModelConfig;
use crate::model::weights::{MatId, Role, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::util::json::Json;

/// A fully quantized model: the paper's deliverable artifact.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    /// Full-precision parameters with block matrices still present (they
    /// are *replaced* by `packed` on dequantization); biases are the
    /// corrected `b^q`.
    pub base: Weights,
    /// One packed matrix per quantizable MatId, in `matrix_ids()` order.
    pub packed: Vec<(MatId, PackedMatrix)>,
}

impl QuantizedModel {
    /// Dequantize into dense weights for evaluation.
    pub fn to_weights(&self) -> Weights {
        let mut w = self.base.clone();
        for (id, p) in &self.packed {
            *w.matrix_mut(*id) = p.unpack();
        }
        w
    }

    /// Average payload bits/weight across all packed matrices.
    pub fn avg_bits(&self) -> f64 {
        let (mut bits, mut count) = (0f64, 0usize);
        for (_, p) in &self.packed {
            bits += p.payload_bits() as f64;
            count += p.rows * p.cols;
        }
        bits / count as f64
    }

    /// Overhead bits as a fraction of payload bits (Table 3c).
    pub fn overhead_fraction(&self) -> f64 {
        let payload: usize = self.packed.iter().map(|(_, p)| p.payload_bits()).sum();
        let overhead: usize = self.packed.iter().map(|(_, p)| p.overhead_bits()).sum();
        overhead as f64 / payload.max(1) as f64
    }

    /// Fraction of block weights pruned to zero (Table 3b).
    pub fn pruned_fraction(&self) -> f64 {
        let (mut pruned, mut count) = (0f64, 0usize);
        for (_, p) in &self.packed {
            pruned += p.pruned_fraction() * (p.rows * p.cols) as f64;
            count += p.rows * p.cols;
        }
        pruned / count as f64
    }

    /// Compressed model size in bytes (payload + overhead + FP16 side
    /// params), vs the FP16 dense size.
    pub fn compression_summary(&self) -> (f64, f64) {
        let payload: usize = self.packed.iter().map(|(_, p)| p.payload_bits()).sum();
        let overhead: usize = self.packed.iter().map(|(_, p)| p.overhead_bits()).sum();
        let block_weights: usize = self.packed.iter().map(|(_, p)| p.rows * p.cols).sum();
        let compressed_bits = payload + overhead;
        let fp16_bits = block_weights * 16;
        (
            compressed_bits as f64 / 8.0,
            fp16_bits as f64 / compressed_bits as f64,
        )
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let tmp_weights = std::env::temp_dir().join(format!(
            "radio_qsave_{}.tmp",
            std::process::id()
        ));
        self.base.save(&tmp_weights)?;
        let base_bytes = std::fs::read(&tmp_weights)?;
        let _ = std::fs::remove_file(&tmp_weights);

        let mut f = std::fs::File::create(path)?;
        f.write_all(b"RADIOQM1")?;
        f.write_all(&(base_bytes.len() as u64).to_le_bytes())?;
        f.write_all(&base_bytes)?;
        f.write_all(&(self.packed.len() as u32).to_le_bytes())?;
        for (id, p) in &self.packed {
            f.write_all(&(id.layer as u32).to_le_bytes())?;
            f.write_all(&[role_tag(id.role)])?;
            let bytes = p.to_bytes();
            f.write_all(&(bytes.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<QuantizedModel> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"RADIOQM1" {
            return Err(inv("bad magic: not a .radio quantized model"));
        }
        let mut l8 = [0u8; 8];
        f.read_exact(&mut l8)?;
        let blen = u64::from_le_bytes(l8) as usize;
        let mut bbytes = vec![0u8; blen];
        f.read_exact(&mut bbytes)?;
        let tmp = std::env::temp_dir().join(format!("radio_qload_{}.tmp", std::process::id()));
        std::fs::write(&tmp, &bbytes)?;
        let base = Weights::load(&tmp)?;
        let _ = std::fs::remove_file(&tmp);

        let mut l4 = [0u8; 4];
        f.read_exact(&mut l4)?;
        let n = u32::from_le_bytes(l4) as usize;
        let mut packed = Vec::with_capacity(n);
        for _ in 0..n {
            f.read_exact(&mut l4)?;
            let layer = u32::from_le_bytes(l4) as usize;
            let mut tag = [0u8; 1];
            f.read_exact(&mut tag)?;
            let role = role_from_tag(tag[0]).ok_or_else(|| inv("bad role tag"))?;
            f.read_exact(&mut l8)?;
            let plen = u64::from_le_bytes(l8) as usize;
            let mut pbytes = vec![0u8; plen];
            f.read_exact(&mut pbytes)?;
            let (pm, used) = PackedMatrix::from_bytes(&pbytes).map_err(inv)?;
            if used != plen {
                return Err(inv("packed matrix trailing bytes"));
            }
            packed.push((MatId { layer, role }, pm));
        }
        Ok(QuantizedModel { base, packed })
    }

    pub fn config(&self) -> &ModelConfig {
        &self.base.config
    }

    /// Human-readable summary as JSON (for reports).
    pub fn summary_json(&self) -> Json {
        let (bytes, ratio) = self.compression_summary();
        Json::obj(vec![
            ("avg_bits", Json::num(self.avg_bits())),
            ("overhead_fraction", Json::num(self.overhead_fraction())),
            ("pruned_fraction", Json::num(self.pruned_fraction())),
            ("compressed_bytes", Json::num(bytes)),
            ("ratio_vs_fp16", Json::num(ratio)),
        ])
    }
}

fn role_tag(r: Role) -> u8 {
    match r {
        Role::Q => 0,
        Role::K => 1,
        Role::V => 2,
        Role::O => 3,
        Role::Up => 4,
        Role::Down => 5,
    }
}

fn role_from_tag(t: u8) -> Option<Role> {
    Some(match t {
        0 => Role::Q,
        1 => Role::K,
        2 => Role::V,
        3 => Role::O,
        4 => Role::Up,
        5 => Role::Down,
        _ => return None,
    })
}

fn inv<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_matrix, Grouping, QuantMode, ScaleRule};
    use crate::util::rng::Rng;

    fn quantize_all(w: &Weights, bits: u8) -> QuantizedModel {
        let packed = w
            .matrix_ids()
            .into_iter()
            .map(|id| {
                let m = w.matrix(id);
                let grouping = Grouping::whole_columns(m.rows, m.cols);
                let bvec = vec![bits; grouping.num_groups()];
                (
                    id,
                    quantize_matrix(m, &grouping, &bvec, QuantMode::Companded, ScaleRule::Range),
                )
            })
            .collect();
        QuantizedModel { base: w.clone(), packed }
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(91);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 4);
        let path = std::env::temp_dir().join("radio_test_qm.radio");
        qm.save(&path).unwrap();
        let back = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(qm.to_weights().layers[0].wq.data, back.to_weights().layers[0].wq.data);
        assert!((qm.avg_bits() - back.avg_bits()).abs() < 1e-12);
    }

    #[test]
    fn avg_bits_matches_requested() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(92);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 3);
        assert!((qm.avg_bits() - 3.0).abs() < 1e-9);
        let (_, ratio) = qm.compression_summary();
        assert!(ratio > 4.0, "compression vs fp16 should exceed 4x at 3 bits, got {ratio}");
    }

    #[test]
    fn dequantized_model_close_at_8_bits() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(93);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = quantize_all(&w, 8);
        let wq = qm.to_weights();
        let err: f64 = w.layers[0]
            .wq
            .data
            .iter()
            .zip(&wq.layers[0].wq.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.layers[0].wq.data.len() as f64;
        let var = crate::stats::moments::variance(&w.layers[0].wq.data);
        assert!(err < var * 0.01, "relative err {}", err / var);
    }

    #[test]
    fn load_rejects_garbage() {
        let p = std::env::temp_dir().join("radio_qm_garbage.radio");
        std::fs::write(&p, b"garbage file contents").unwrap();
        assert!(QuantizedModel::load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }
}
