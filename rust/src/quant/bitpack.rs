//! Bit-packed storage for mixed-precision quantized matrices — the
//! repository's on-disk/in-memory analogue of the paper's Appendix-A
//! format: per-group bit depths (4 b), FP16 scale/mean per group, per-row
//! sub-group indices, and a dense LSB-first code stream per column.
//!
//! Both quantizer families factor dequantization as
//! `deq = mean + scale · lut[bits][code]`, so the matvec kernel
//! (infer::matvec) only ever does a table lookup and a fused multiply-add:
//! - companded: lut = standardized inverse-compander bin midpoints,
//! - uniform:   lut[c] = c − 2^(B−1) + 0.5 (scale = step D).

use crate::model::tensor::Tensor;
use crate::quant::companding;
use crate::quant::grouping::Grouping;

/// Round-trip f32 → IEEE 754 half → f32 (storage emulation for group
/// scales/means, matching the paper's FP16 signaling overhead).
pub fn f16_round(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xFF) as i32 - 127 + 15;
    let mant = bits & 0x7F_FFFF;
    if exp >= 31 {
        // Overflow → inf (or NaN preserved).
        return sign | 0x7C00 | if mant != 0 && ((bits >> 23) & 0xFF) == 0xFF { 0x200 } else { 0 };
    }
    if exp <= 0 {
        // Subnormal / underflow.
        if exp < -10 {
            return sign;
        }
        let m = (mant | 0x80_0000) >> (1 - exp);
        return sign | ((m + 0x1000) >> 13) as u16;
    }
    let mut half = sign | ((exp as u16) << 10) | ((mant >> 13) as u16);
    // Round to nearest even.
    if mant & 0x1FFF > 0x1000 || (mant & 0x1FFF == 0x1000 && half & 1 == 1) {
        half = half.wrapping_add(1);
        if half & 0x7C00 == 0x7C00 {
            exp += 1;
            let _ = exp;
        }
    }
    half
}

pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let mant = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: value = mant · 2⁻²⁴; normalize so bit 10 is set
            // after k shifts ⇒ unbiased exponent = −14 − k.
            let mut k = 0i32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                k += 1;
            }
            sign | (((127 - 14 - k) as u32) << 23) | ((m & 0x3FF) << 13)
        }
    } else if exp == 31 {
        sign | 0x7F80_0000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// LSB-first bit stream writer.
#[derive(Default, Clone, Debug)]
pub struct BitWriter {
    pub words: Vec<u64>,
    pub bit_len: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn push(&mut self, code: u32, bits: u8) {
        debug_assert!(bits <= 32);
        if bits == 0 {
            return;
        }
        debug_assert!(bits == 32 || code < (1u32 << bits));
        let word = self.bit_len >> 6;
        let off = self.bit_len & 63;
        if word >= self.words.len() {
            self.words.push(0);
        }
        self.words[word] |= (code as u64) << off;
        let spill = off + bits as usize;
        if spill > 64 {
            self.words.push((code as u64) >> (64 - off));
        }
        self.bit_len += bits as usize;
    }
}

/// LSB-first bit stream reader.
#[derive(Clone, Copy)]
pub struct BitReader<'a> {
    words: &'a [u64],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(words: &'a [u64], bit_pos: usize) -> Self {
        Self { words, pos: bit_pos }
    }

    #[inline]
    pub fn read(&mut self, bits: u8) -> u32 {
        if bits == 0 {
            return 0;
        }
        let word = self.pos >> 6;
        let off = self.pos & 63;
        let mut v = self.words[word] >> off;
        if off + bits as usize > 64 {
            v |= self.words[word + 1] << (64 - off);
        }
        self.pos += bits as usize;
        (v & ((1u64 << bits) - 1)) as u32
    }

    pub fn bit_pos(&self) -> usize {
        self.pos
    }
}

/// Quantizer family used for a packed matrix.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// Laplace-companded (Radio's default).
    Companded,
    /// Mid-rise uniform (RTN / ablations).
    Uniform,
}

impl QuantMode {
    pub fn tag(&self) -> u8 {
        match self {
            QuantMode::Companded => 0,
            QuantMode::Uniform => 1,
        }
    }

    pub fn from_tag(t: u8) -> Option<QuantMode> {
        match t {
            0 => Some(QuantMode::Companded),
            1 => Some(QuantMode::Uniform),
            _ => None,
        }
    }

    /// Standardized dequant LUT for this family at `bits`.
    pub fn base_lut(&self, bits: u8) -> Vec<f32> {
        match self {
            QuantMode::Companded => companding::base_lut(bits),
            QuantMode::Uniform => {
                let half = (1i64 << bits) / 2;
                (0..(1i64 << bits))
                    .map(|c| (c - half) as f32 + 0.5)
                    .collect()
            }
        }
    }
}

/// Per-group quantization parameters (scale/mean FP16-rounded on pack).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GroupMeta {
    pub bits: u8,
    pub scale: f32,
    pub mean: f32,
}

/// A bit-packed mixed-precision quantized matrix.
///
/// Two baseline-supporting extensions beyond the plain Radio format:
/// - `row_scale` (AWQ): weights were scaled per input row before
///   quantization, `W[i][j] = deq[i][j] / row_scale[i]`;
/// - `fp_rows` (OWQ): outlier input rows kept in FP16, bypassing the
///   quantizer entirely (counted at 16 bits/weight in the rate).
#[derive(Clone, Debug)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    pub grouping: Grouping,
    /// cols × m metas, indexed `col * m + sub`.
    pub meta: Vec<GroupMeta>,
    pub mode: QuantMode,
    /// Code stream; per-column starting bit offsets in `col_bit_offset`.
    pub words: Vec<u64>,
    pub col_bit_offset: Vec<usize>,
    /// AWQ-style per-input-row scale applied before quantization.
    pub row_scale: Option<Vec<f32>>,
    /// OWQ-style full-precision rows: (row index, FP16-rounded values).
    pub fp_rows: Vec<(u32, Vec<f32>)>,
}

impl PackedMatrix {
    /// Quantize and pack `w` with the given grouping and per-group metas.
    /// Scales/means are FP16-rounded (overhead-faithful). Returns the
    /// packed matrix; use [`PackedMatrix::unpack`] for the dequantized
    /// tensor.
    pub fn pack(w: &Tensor, grouping: &Grouping, meta_in: &[GroupMeta], mode: QuantMode) -> PackedMatrix {
        Self::pack_full(w, grouping, meta_in, mode, None, &[])
    }

    /// Full-featured pack with optional AWQ row scales (applied to `w`
    /// before coding) and OWQ full-precision exception rows.
    pub fn pack_full(
        w: &Tensor,
        grouping: &Grouping,
        meta_in: &[GroupMeta],
        mode: QuantMode,
        row_scale: Option<Vec<f32>>,
        fp_row_idx: &[u32],
    ) -> PackedMatrix {
        assert_eq!(w.rows, grouping.rows);
        assert_eq!(w.cols, grouping.cols);
        assert_eq!(meta_in.len(), grouping.num_groups());
        let mut meta: Vec<GroupMeta> = meta_in
            .iter()
            .map(|g| GroupMeta {
                bits: g.bits.min(8),
                scale: f16_round(g.scale),
                mean: f16_round(g.mean),
            })
            .collect();
        // Guard degenerate scales.
        for g in meta.iter_mut() {
            if !(g.scale.is_finite()) || g.scale <= 0.0 {
                g.scale = 1e-6;
            }
            if !g.mean.is_finite() {
                g.mean = 0.0;
            }
        }
        let mut is_fp = vec![false; w.rows];
        for &r in fp_row_idx {
            is_fp[r as usize] = true;
        }
        // Scale weights per input row before coding if requested.
        let scaled;
        let w_eff: &Tensor = if let Some(s) = &row_scale {
            assert_eq!(s.len(), w.rows);
            let mut t = w.clone();
            for r in 0..w.rows {
                let sc = s[r];
                for v in t.row_mut(r) {
                    *v *= sc;
                }
            }
            scaled = t;
            &scaled
        } else {
            w
        };
        let mut writer = BitWriter::new();
        let mut col_bit_offset = Vec::with_capacity(w.cols + 1);
        for col in 0..w.cols {
            col_bit_offset.push(writer.bit_len);
            for sub in 0..grouping.m {
                let gm = meta[col * grouping.m + sub];
                if gm.bits == 0 {
                    continue; // pruned group: no codes
                }
                for &r in &grouping.group_rows[sub] {
                    if is_fp[r as usize] {
                        continue; // FP16 exception row: no codes
                    }
                    let x = w_eff.get(r as usize, col);
                    let code = match mode {
                        QuantMode::Companded => {
                            companding::quantize_code(x, gm.bits, gm.scale, gm.mean)
                        }
                        QuantMode::Uniform => {
                            let half = 1i64 << (gm.bits - 1);
                            (crate::quant::rtn::quantize_code(x, gm.bits, gm.scale, gm.mean)
                                as i64
                                + half) as u32
                        }
                    };
                    writer.push(code, gm.bits);
                }
            }
        }
        col_bit_offset.push(writer.bit_len);
        let fp_rows: Vec<(u32, Vec<f32>)> = fp_row_idx
            .iter()
            .map(|&r| {
                (
                    r,
                    // FP16-rounded ORIGINAL (unscaled) values.
                    w.row(r as usize).iter().map(|&x| f16_round(x)).collect(),
                )
            })
            .collect();
        PackedMatrix {
            rows: w.rows,
            cols: w.cols,
            grouping: grouping.clone(),
            meta,
            mode,
            words: writer.words,
            col_bit_offset,
            row_scale,
            fp_rows,
        }
    }

    /// Row mask of OWQ full-precision exception rows — the `is_fp` input
    /// to [`PackedMatrix::column_codes`] (computed once, shared across
    /// columns).
    pub fn fp_row_mask(&self) -> Vec<bool> {
        let mut is_fp = vec![false; self.rows];
        for (r, _) in &self.fp_rows {
            is_fp[*r as usize] = true;
        }
        is_fp
    }

    /// Streaming decoder over one column's packed code stream: yields
    /// `(sub, row, code)` for every *coded* weight in pack order —
    /// pruned (0-bit) groups and FP16 exception rows are skipped exactly
    /// as [`PackedMatrix::pack_full`] skipped them on write, so the
    /// cursor stays bit-aligned through mixed depths. This is the
    /// reference decode ([`PackedMatrix::unpack`] is built on it); the
    /// matvec kernels keep their own fused decoders, which consume whole
    /// 128-bit windows.
    pub fn column_codes<'a>(&'a self, col: usize, is_fp: &'a [bool]) -> ColumnCodes<'a> {
        debug_assert!(col < self.cols);
        debug_assert_eq!(is_fp.len(), self.rows);
        ColumnCodes {
            pm: self,
            is_fp,
            reader: BitReader::new(&self.words, self.col_bit_offset[col]),
            col,
            sub: 0,
            idx: 0,
            gm: GroupMeta { bits: 0, scale: 0.0, mean: 0.0 },
        }
    }

    /// Dequantize to a dense tensor.
    pub fn unpack(&self) -> Tensor {
        let mut out = Tensor::zeros(self.rows, self.cols);
        let is_fp = self.fp_row_mask();
        // Cache LUTs per bit depth. Pruned groups are never yielded and
        // stay zero (bias correction holds the mean).
        let luts: Vec<Vec<f32>> = (0..=8u8).map(|b| self.mode.base_lut(b)).collect();
        let m = self.grouping.m;
        for col in 0..self.cols {
            // Meta/LUT are per sub-group; hoist their fetch to group
            // transitions rather than paying it per weight.
            let mut cur_sub = usize::MAX;
            let mut gm = GroupMeta { bits: 0, scale: 0.0, mean: 0.0 };
            let mut lut: &[f32] = &[];
            for (sub, r, code) in self.column_codes(col, &is_fp) {
                if sub != cur_sub {
                    cur_sub = sub;
                    gm = self.meta[col * m + sub];
                    lut = &luts[gm.bits as usize];
                }
                out.set(r as usize, col, gm.mean + gm.scale * lut[code as usize]);
            }
        }
        // Undo AWQ row scaling.
        if let Some(s) = &self.row_scale {
            for r in 0..self.rows {
                let inv = 1.0 / s[r];
                for v in out.row_mut(r) {
                    *v *= inv;
                }
            }
        }
        // FP16 exception rows (stored unscaled).
        for (r, vals) in &self.fp_rows {
            out.row_mut(*r as usize).copy_from_slice(vals);
        }
        out
    }

    /// Code bits (packed payload only, excluding FP16 exception rows).
    pub fn code_bits(&self) -> usize {
        *self.col_bit_offset.last().unwrap()
    }

    /// Full payload bits: packed codes + FP16 exception rows.
    pub fn payload_bits(&self) -> usize {
        self.code_bits() + self.fp_rows.len() * self.cols * 16
    }

    /// Signaling overhead bits: per-row sub-group indices, per-group
    /// depth/scale/mean, plus AWQ row scales (FP16 each) and OWQ
    /// exception-row indices (32 b each).
    pub fn overhead_bits(&self) -> usize {
        self.grouping.overhead_bits()
            + self.row_scale.as_ref().map_or(0, |s| s.len() * 16)
            + self.fp_rows.len() * 32
    }

    /// Average payload bits per weight (FP16 exception rows at 16 b).
    pub fn avg_bits_per_weight(&self) -> f64 {
        self.payload_bits() as f64 / (self.rows * self.cols) as f64
    }

    /// Fraction of weights quantized to zero via 0-bit groups (pruning,
    /// Table 3b).
    pub fn pruned_fraction(&self) -> f64 {
        let mut pruned = 0usize;
        for col in 0..self.cols {
            for sub in 0..self.grouping.m {
                if self.meta[col * self.grouping.m + sub].bits == 0 {
                    pruned += self.grouping.group_len(sub);
                }
            }
        }
        pruned as f64 / (self.rows * self.cols) as f64
    }

    // ------------------------------------------------------ serialization

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let push_u32 = |out: &mut Vec<u8>, v: u32| out.extend_from_slice(&v.to_le_bytes());
        push_u32(&mut out, self.rows as u32);
        push_u32(&mut out, self.cols as u32);
        push_u32(&mut out, self.grouping.m as u32);
        out.push(self.mode.tag());
        for &g in &self.grouping.row_to_group {
            push_u32(&mut out, g);
        }
        for gm in &self.meta {
            out.push(gm.bits);
            out.extend_from_slice(&f32_to_f16(gm.scale).to_le_bytes());
            out.extend_from_slice(&f32_to_f16(gm.mean).to_le_bytes());
        }
        push_u32(&mut out, self.words.len() as u32);
        for &w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        for &o in &self.col_bit_offset {
            out.extend_from_slice(&(o as u64).to_le_bytes());
        }
        // AWQ row scales (flag + FP16 values).
        match &self.row_scale {
            Some(s) => {
                out.push(1);
                for &v in s {
                    out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
                }
            }
            None => out.push(0),
        }
        // OWQ exception rows.
        push_u32(&mut out, self.fp_rows.len() as u32);
        for (r, vals) in &self.fp_rows {
            push_u32(&mut out, *r);
            for &v in vals {
                out.extend_from_slice(&f32_to_f16(v).to_le_bytes());
            }
        }
        out
    }

    /// Parse a blob produced by [`to_bytes`](Self::to_bytes), returning
    /// the matrix and the bytes consumed.
    ///
    /// Every length field is untrusted: shapes are bounded against the
    /// buffer before any allocation, tags and indices are validated,
    /// and the bit geometry (`col_bit_offset` against per-group depths
    /// and the word buffer) is cross-checked so that decode-side
    /// readers — `BitReader` slicing and the unchecked-indexed matvec
    /// plans — can never read out of bounds on a matrix that came
    /// through this parser. A malformed header is an `Err`, never a
    /// panic or a wild read.
    pub fn from_bytes(buf: &[u8]) -> Result<(PackedMatrix, usize), String> {
        let mut pos = 0usize;
        let rd_u32 = |buf: &[u8], pos: &mut usize| -> Result<u32, String> {
            let b = buf
                .get(*pos..*pos + 4)
                .ok_or("truncated packed matrix")?;
            *pos += 4;
            Ok(u32::from_le_bytes(b.try_into().unwrap()))
        };
        // Bound a count field by the bytes actually present, *before*
        // allocating for it: a corrupt length can name gigabytes.
        let fits = |count: usize, unit: usize, pos: usize, buf: &[u8]| -> Result<(), String> {
            let need = count.checked_mul(unit).ok_or("packed matrix length overflow")?;
            if need > buf.len() - pos {
                return Err("truncated packed matrix".into());
            }
            Ok(())
        };
        let rows = rd_u32(buf, &mut pos)? as usize;
        let cols = rd_u32(buf, &mut pos)? as usize;
        let m = rd_u32(buf, &mut pos)? as usize;
        let mode = QuantMode::from_tag(*buf.get(pos).ok_or("truncated")?)
            .ok_or("bad quant mode tag")?;
        pos += 1;
        // Every producer has 1 <= m <= rows (m = ceil(rows / rows_per_group)).
        if rows == 0 || m == 0 || m > rows {
            return Err("bad grouping shape".into());
        }
        fits(rows, 4, pos, buf)?;
        let mut row_to_group = Vec::with_capacity(rows);
        for _ in 0..rows {
            row_to_group.push(rd_u32(buf, &mut pos)?);
        }
        let mut group_rows: Vec<Vec<u32>> = vec![Vec::new(); m];
        for (r, &g) in row_to_group.iter().enumerate() {
            group_rows
                .get_mut(g as usize)
                .ok_or("row group out of range")?
                .push(r as u32);
        }
        let grouping = Grouping { rows, cols, m, row_to_group, group_rows };
        let n_groups = cols.checked_mul(m).ok_or("packed matrix length overflow")?;
        fits(n_groups, 5, pos, buf)?;
        let mut meta = Vec::with_capacity(n_groups);
        for _ in 0..n_groups {
            let bits = *buf.get(pos).ok_or("truncated meta")?;
            pos += 1;
            if bits > 8 {
                return Err("group bit depth exceeds 8".into());
            }
            let s = u16::from_le_bytes(
                buf.get(pos..pos + 2).ok_or("truncated")?.try_into().unwrap(),
            );
            pos += 2;
            let mu = u16::from_le_bytes(
                buf.get(pos..pos + 2).ok_or("truncated")?.try_into().unwrap(),
            );
            pos += 2;
            meta.push(GroupMeta { bits, scale: f16_to_f32(s), mean: f16_to_f32(mu) });
        }
        let nwords = rd_u32(buf, &mut pos)? as usize;
        fits(nwords, 8, pos, buf)?;
        let mut words = Vec::with_capacity(nwords);
        for _ in 0..nwords {
            let w = u64::from_le_bytes(
                buf.get(pos..pos + 8).ok_or("truncated words")?.try_into().unwrap(),
            );
            pos += 8;
            words.push(w);
        }
        fits(cols + 1, 8, pos, buf)?;
        let mut col_bit_offset = Vec::with_capacity(cols + 1);
        for _ in 0..cols + 1 {
            let o = u64::from_le_bytes(
                buf.get(pos..pos + 8).ok_or("truncated offsets")?.try_into().unwrap(),
            );
            pos += 8;
            col_bit_offset.push(o as usize);
        }
        let rd_f16 = |buf: &[u8], pos: &mut usize| -> Result<f32, String> {
            let b = buf.get(*pos..*pos + 2).ok_or("truncated f16")?;
            *pos += 2;
            Ok(f16_to_f32(u16::from_le_bytes(b.try_into().unwrap())))
        };
        let has_scale = *buf.get(pos).ok_or("truncated row_scale flag")?;
        pos += 1;
        let row_scale = if has_scale == 1 {
            let mut s = Vec::with_capacity(rows);
            for _ in 0..rows {
                s.push(rd_f16(buf, &mut pos)?);
            }
            Some(s)
        } else {
            None
        };
        let n_fp = rd_u32(buf, &mut pos)? as usize;
        fits(n_fp, 4 + cols * 2, pos, buf)?;
        let mut fp_rows = Vec::with_capacity(n_fp);
        for _ in 0..n_fp {
            let r = rd_u32(buf, &mut pos)?;
            if r as usize >= rows {
                return Err("fp row index out of range".into());
            }
            let mut vals = Vec::with_capacity(cols);
            for _ in 0..cols {
                vals.push(rd_f16(buf, &mut pos)?);
            }
            fp_rows.push((r, vals));
        }
        // Cross-check the bit geometry decode relies on: each column's
        // code run must equal the sum of its groups' depths over the
        // non-exception rows, runs must be nondecreasing from zero, and
        // the stream must fit the word buffer. After this, `BitReader`
        // and the matvec plans provably stay in bounds.
        let mut is_fp = vec![false; rows];
        for (r, _) in &fp_rows {
            is_fp[*r as usize] = true;
        }
        let live_rows: Vec<usize> = grouping
            .group_rows
            .iter()
            .map(|g| g.iter().filter(|&&r| !is_fp[r as usize]).count())
            .collect();
        if col_bit_offset[0] != 0 {
            return Err("column offsets must start at zero".into());
        }
        for col in 0..cols {
            let expect: usize =
                (0..m).map(|sub| meta[col * m + sub].bits as usize * live_rows[sub]).sum();
            let run = col_bit_offset[col + 1]
                .checked_sub(col_bit_offset[col])
                .ok_or("column offsets must be nondecreasing")?;
            if run != expect {
                return Err("column bit run disagrees with group metadata".into());
            }
        }
        if *col_bit_offset.last().unwrap() > words.len() * 64 {
            return Err("code stream overruns word buffer".into());
        }
        Ok((
            PackedMatrix {
                rows,
                cols,
                grouping,
                meta,
                mode,
                words,
                col_bit_offset,
                row_scale,
                fp_rows,
            },
            pos,
        ))
    }
}

/// See [`PackedMatrix::column_codes`].
pub struct ColumnCodes<'a> {
    pm: &'a PackedMatrix,
    is_fp: &'a [bool],
    reader: BitReader<'a>,
    col: usize,
    /// Current sub-group.
    sub: usize,
    /// Next index within `group_rows[sub]`.
    idx: usize,
    /// Meta of the current sub-group, fetched once per group entry
    /// (`idx == 0`) rather than per yielded code.
    gm: GroupMeta,
}

impl<'a> ColumnCodes<'a> {
    /// Current absolute bit position of the underlying reader — after
    /// draining the iterator this must equal the next column's offset
    /// (the alignment property the roundtrip test pins down).
    pub fn bit_pos(&self) -> usize {
        self.reader.bit_pos()
    }
}

impl<'a> Iterator for ColumnCodes<'a> {
    type Item = (usize, u32, u32);

    fn next(&mut self) -> Option<(usize, u32, u32)> {
        let g = &self.pm.grouping;
        loop {
            if self.sub >= g.m {
                return None;
            }
            if self.idx == 0 {
                self.gm = self.pm.meta[self.col * g.m + self.sub];
            }
            if self.gm.bits == 0 {
                self.sub += 1;
                self.idx = 0;
                continue;
            }
            let rows = &g.group_rows[self.sub];
            while self.idx < rows.len() {
                let r = rows[self.idx];
                self.idx += 1;
                if self.is_fp[r as usize] {
                    continue;
                }
                return Some((self.sub, r, self.reader.read(self.gm.bits)));
            }
            self.sub += 1;
            self.idx = 0;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;
    use crate::util::rng::Rng;

    #[test]
    fn f16_roundtrip_accuracy() {
        for &x in &[0.0f32, 1.0, -1.0, 0.1234, 65504.0, -3.75] {
            let r = f16_round(x);
            assert!((r - x).abs() <= x.abs() * 1e-3 + 1e-7, "{x} -> {r}");
        }
        // Subnormal range: spacing is 2^-24, so tolerance is absolute.
        for &x in &[1e-5f32, -4e-5, 6e-8] {
            let r = f16_round(x);
            assert!((r - x).abs() <= 2.0 * 5.96e-8, "{x} -> {r}");
        }
        // Idempotence (required for serialization roundtrips).
        for &x in &[0.1234f32, 1e-5, -4e-5, 65504.0] {
            assert_eq!(f16_round(f16_round(x)), f16_round(x), "{x}");
        }
        assert_eq!(f16_round(0.0), 0.0);
        assert!(f16_round(1e9).is_infinite()); // overflow behaviour
    }

    #[test]
    fn bitstream_roundtrip_mixed_widths() {
        let mut w = BitWriter::new();
        let entries: Vec<(u32, u8)> = vec![
            (5, 3),
            (0, 1),
            (255, 8),
            (1, 2),
            (127, 7),
            (9, 4),
            (63, 6),
            (31, 5),
        ];
        for &(c, b) in &entries {
            w.push(c, b);
        }
        let mut r = BitReader::new(&w.words, 0);
        for &(c, b) in &entries {
            assert_eq!(r.read(b), c);
        }
    }

    #[test]
    fn bitstream_property_roundtrip() {
        Checker::new(64, 0x8817).run("bitstream-roundtrip", |rng, size| {
            let n = 1 + size;
            let entries: Vec<(u32, u8)> = (0..n)
                .map(|_| {
                    let b = 1 + rng.below(8) as u8;
                    (rng.below(1 << b) as u32, b)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(c, b) in &entries {
                w.push(c, b);
            }
            let mut r = BitReader::new(&w.words, 0);
            for (i, &(c, b)) in entries.iter().enumerate() {
                let got = r.read(b);
                crate::prop_assert!(got == c, "entry {i}: wrote {c} read {got}");
            }
            Ok(())
        });
    }

    fn random_meta(rng: &mut Rng, n: usize, allow_zero: bool) -> Vec<GroupMeta> {
        (0..n)
            .map(|_| GroupMeta {
                bits: if allow_zero { rng.below(9) as u8 } else { 1 + rng.below(8) as u8 },
                scale: 0.1 + rng.uniform_f32(),
                mean: rng.normal(0.0, 0.1) as f32,
            })
            .collect()
    }

    #[test]
    fn pack_unpack_error_bounded() {
        let mut rng = Rng::new(61);
        let (rows, cols) = (32, 12);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.5);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, 8, &scores);
        // High bit depth → small error.
        let meta: Vec<GroupMeta> = (0..grouping.num_groups())
            .map(|_| GroupMeta { bits: 8, scale: 0.5, mean: 0.0 })
            .collect();
        let packed = PackedMatrix::pack(&w, &grouping, &meta, QuantMode::Companded);
        let deq = packed.unpack();
        let mut err = 0f64;
        for (a, b) in w.data.iter().zip(&deq.data) {
            err += ((a - b) as f64).powi(2);
        }
        err /= w.data.len() as f64;
        assert!(err < 1e-3, "mse {err}");
    }

    #[test]
    fn packed_roundtrip_is_quantizer_fixed_point() {
        // unpack(pack(unpack(pack(w)))) == unpack(pack(w)) — idempotence.
        let mut rng = Rng::new(62);
        let (rows, cols) = (24, 6);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]);
        let meta = random_meta(&mut rng, grouping.num_groups(), false);
        for mode in [QuantMode::Companded, QuantMode::Uniform] {
            let p1 = PackedMatrix::pack(&w, &grouping, &meta, mode);
            let d1 = p1.unpack();
            let p2 = PackedMatrix::pack(&d1, &grouping, &meta, mode);
            let d2 = p2.unpack();
            for (a, b) in d1.data.iter().zip(&d2.data) {
                assert!((a - b).abs() < 1e-5, "{mode:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn serialization_roundtrip() {
        let mut rng = Rng::new(63);
        let (rows, cols) = (16, 5);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, 4, &scores);
        let meta = random_meta(&mut rng, grouping.num_groups(), true);
        let p = PackedMatrix::pack(&w, &grouping, &meta, QuantMode::Uniform);
        let bytes = p.to_bytes();
        let (q, used) = PackedMatrix::from_bytes(&bytes).unwrap();
        assert_eq!(used, bytes.len());
        assert_eq!(p.unpack().data, q.unpack().data);
        assert_eq!(p.code_bits(), q.code_bits());
    }

    #[test]
    fn avg_bits_and_pruning_accounting() {
        let (rows, cols) = (16, 4);
        let w = Tensor::zeros(rows, cols);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]); // m=2
        // Half the groups at 4 bits, half pruned.
        let meta: Vec<GroupMeta> = (0..grouping.num_groups())
            .map(|i| GroupMeta { bits: if i % 2 == 0 { 4 } else { 0 }, scale: 1.0, mean: 0.0 })
            .collect();
        let p = PackedMatrix::pack(&w, &grouping, &meta, QuantMode::Companded);
        assert!((p.avg_bits_per_weight() - 2.0).abs() < 1e-9);
        assert!((p.pruned_fraction() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn column_codes_stays_bit_aligned_through_mixed_depths() {
        // Drain the iterator for every column of a matrix with pruned
        // groups AND FP16 exception rows: each column must end exactly at
        // the next column's bit offset, every code in range, and the
        // yielded (row, count) structure must match the pack-time skips.
        let mut rng = Rng::new(65);
        let (rows, cols) = (24, 7);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, 6, &scores);
        let mut meta = random_meta(&mut rng, grouping.num_groups(), false);
        for (i, gm) in meta.iter_mut().enumerate() {
            if i % 4 == 0 {
                gm.bits = 0; // pruned
            }
        }
        let p = PackedMatrix::pack_full(
            &w,
            &grouping,
            &meta,
            QuantMode::Companded,
            None,
            &[3, 11],
        );
        let is_fp = p.fp_row_mask();
        assert_eq!(is_fp.iter().filter(|&&f| f).count(), 2);
        for col in 0..cols {
            let mut it = p.column_codes(col, &is_fp);
            let mut yielded = 0usize;
            let mut last_sub = 0usize;
            for (sub, r, code) in it.by_ref() {
                assert!(sub >= last_sub, "sub-groups must stream in pack order");
                last_sub = sub;
                let gm = p.meta[col * p.grouping.m + sub];
                assert!(gm.bits > 0, "pruned groups must not be yielded");
                assert!(code < (1 << gm.bits), "code out of range for depth");
                assert!(!is_fp[r as usize], "FP16 rows carry no codes");
                yielded += 1;
            }
            let expected: usize = (0..p.grouping.m)
                .filter(|&sub| p.meta[col * p.grouping.m + sub].bits > 0)
                .map(|sub| {
                    p.grouping.group_rows[sub]
                        .iter()
                        .filter(|&&r| !is_fp[r as usize])
                        .count()
                })
                .sum();
            assert_eq!(yielded, expected, "col {col}");
            assert_eq!(
                it.bit_pos(),
                p.col_bit_offset[col + 1],
                "col {col}: iterator must end exactly at the next column's offset"
            );
        }
    }

    #[test]
    fn from_bytes_rejects_truncated() {
        let mut rng = Rng::new(64);
        let mut w = Tensor::zeros(8, 2);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let grouping = Grouping::whole_columns(8, 2);
        let meta = random_meta(&mut rng, 2, false);
        let bytes = PackedMatrix::pack(&w, &grouping, &meta, QuantMode::Companded).to_bytes();
        assert!(PackedMatrix::from_bytes(&bytes[..bytes.len() / 2]).is_err());
        assert!(PackedMatrix::from_bytes(&[]).is_err());
    }

    #[test]
    fn from_bytes_rejects_malformed_headers_without_panicking() {
        let mut rng = Rng::new(66);
        let (rows, cols) = (16, 4);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, 4, &scores);
        let meta = random_meta(&mut rng, grouping.num_groups(), false);
        let p = PackedMatrix::pack(&w, &grouping, &meta, QuantMode::Companded);
        let good = p.to_bytes();
        // Sanity: the untampered blob parses and consumes everything.
        let (_, used) = PackedMatrix::from_bytes(&good).unwrap();
        assert_eq!(used, good.len());

        // A count field inflated to name gigabytes must fail fast
        // (bounded against the buffer), not allocate or read wild.
        let mut huge_rows = good.clone();
        huge_rows[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(PackedMatrix::from_bytes(&huge_rows).is_err());
        let mut huge_m = good.clone();
        huge_m[8..12].copy_from_slice(&0x4000_0000u32.to_le_bytes());
        assert!(PackedMatrix::from_bytes(&huge_m).is_err());

        // Group depth above 8 would index past the dequant LUT table.
        let meta_off = 13 + rows * 4;
        let mut deep = good.clone();
        deep[meta_off] = 9;
        assert!(PackedMatrix::from_bytes(&deep).is_err());

        // Corrupt column offsets: decode would walk the word buffer out
        // of bounds, so the geometry cross-check must reject them.
        let words_off = meta_off + grouping.num_groups() * 5;
        let nwords =
            u32::from_le_bytes(good[words_off..words_off + 4].try_into().unwrap()) as usize;
        let offsets_off = words_off + 4 + nwords * 8;
        let mut skewed = good.clone();
        let last = offsets_off + cols * 8;
        let big = (nwords as u64 * 64 + 64).to_le_bytes();
        skewed[last..last + 8].copy_from_slice(&big);
        assert!(PackedMatrix::from_bytes(&skewed).is_err());
        let mut nonzero_base = good.clone();
        nonzero_base[offsets_off..offsets_off + 8].copy_from_slice(&1u64.to_le_bytes());
        assert!(PackedMatrix::from_bytes(&nonzero_base).is_err());
    }
}
