//! Bias correction (paper §3.2): quantization errors are not zero-mean in
//! practice, producing a systematic output shift. Every time a matrix is
//! (re)quantized, the layer bias is updated as
//!
//! ```text
//! b^q = b − (Θ^q − Θ)ᵀ · X̄
//! ```
//!
//! where X̄ is the running mean of the layer's inputs (accumulated on the
//! forward pass, exactly like G² on the backward pass). Note: Algorithm 1
//! prints `b + (Θ^q − Θ)X̄`; cancelling the induced output shift
//! `(Θ^q − Θ)ᵀX̄` requires the minus sign (equivalently, the paper's Δ is
//! Θ − Θ^q). The linear-layer test below pins the correct orientation.

use crate::model::tensor::Tensor;

/// Compute the corrected bias from the ORIGINAL bias (not cumulative):
/// `b_corrected[j] = b[j] − Σ_i (Θq − Θ)[i,j] · x̄[i]`.
pub fn corrected_bias(
    orig_bias: &[f32],
    theta: &Tensor,
    theta_q: &Tensor,
    xbar: &[f32],
) -> Vec<f32> {
    assert_eq!(theta.rows, theta_q.rows);
    assert_eq!(theta.cols, theta_q.cols);
    assert_eq!(xbar.len(), theta.rows);
    assert_eq!(orig_bias.len(), theta.cols);
    let mut out = orig_bias.to_vec();
    for i in 0..theta.rows {
        let x = xbar[i];
        if x == 0.0 {
            continue;
        }
        let ro = theta.row(i);
        let rq = theta_q.row(i);
        for j in 0..theta.cols {
            out[j] -= (rq[j] - ro[j]) * x;
        }
    }
    out
}

/// Mean output shift ‖(Θq−Θ)ᵀx̄‖² — diagnostic for how much bias
/// correction is compensating.
pub fn output_shift_norm2(theta: &Tensor, theta_q: &Tensor, xbar: &[f32]) -> f64 {
    let b0 = vec![0.0; theta.cols];
    let shift = corrected_bias(&b0, theta, theta_q, xbar);
    shift.iter().map(|&s| (s as f64) * (s as f64)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn correction_cancels_mean_shift_exactly_for_linear_layer() {
        // For a linear layer y = xΘ + b with constant input x = x̄, the
        // corrected bias makes the quantized layer output *exactly* equal
        // the original: x̄Θ + b == x̄Θq + b^q.
        let mut rng = Rng::new(71);
        let (din, dout) = (12, 7);
        let mut theta = Tensor::zeros(din, dout);
        rng.fill_gauss(&mut theta.data, 0.0, 1.0);
        let mut theta_q = theta.clone();
        // Arbitrary perturbation standing in for quantization error.
        for v in theta_q.data.iter_mut() {
            *v += rng.normal(0.01, 0.05) as f32;
        }
        let mut xbar = vec![0f32; din];
        rng.fill_gauss(&mut xbar, 0.5, 1.0);
        let bias: Vec<f32> = (0..dout).map(|_| rng.normal(0.0, 0.3) as f32).collect();

        let bq = corrected_bias(&bias, &theta, &theta_q, &xbar);

        // y_orig[j] = Σ x̄[i]Θ[i,j] + b[j] ; y_quant[j] = Σ x̄[i]Θq[i,j] + bq[j]
        for j in 0..dout {
            let yo: f32 = (0..din).map(|i| xbar[i] * theta.get(i, j)).sum::<f32>() + bias[j];
            let yq: f32 =
                (0..din).map(|i| xbar[i] * theta_q.get(i, j)).sum::<f32>() + bq[j];
            assert!((yo - yq).abs() < 1e-4, "col {j}: {yo} vs {yq}");
        }
    }

    #[test]
    fn zero_error_means_no_correction() {
        let theta = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let bias = vec![0.5, -0.5];
        let bq = corrected_bias(&bias, &theta, &theta, &[1.0, 1.0]);
        assert_eq!(bq, bias);
    }

    #[test]
    fn shift_norm_positive_for_biased_error() {
        let theta = Tensor::zeros(3, 2);
        let mut theta_q = theta.clone();
        theta_q.data.fill(0.1); // systematic positive error
        let n = output_shift_norm2(&theta, &theta_q, &[1.0, 1.0, 1.0]);
        assert!(n > 0.0);
    }
}
