//! Weight grouping (paper §3.3): each matrix is quantized per *group*,
//! where a group is (one column) × (one of M row sub-groups). Rows are
//! assigned to sub-groups by ranking their total row sensitivity
//! G_r²·S_r², and the same row partition applies to every column, so the
//! grouping costs only ⌈log₂M⌉ bits per row to signal (Figure 4 of the
//! paper). Eq. 9's Jensen-gap bit saving is computed here too (Figure 3).

use crate::model::tensor::Tensor;

/// Row partition of one weight matrix into M sensitivity-ranked
/// sub-groups shared by all columns.
#[derive(Clone, Debug)]
pub struct Grouping {
    pub rows: usize,
    pub cols: usize,
    /// Number of row sub-groups M.
    pub m: usize,
    /// Sub-group id per row.
    pub row_to_group: Vec<u32>,
    /// Rows belonging to each sub-group (ascending row order within).
    pub group_rows: Vec<Vec<u32>>,
}

impl Grouping {
    /// Build a grouping with sub-groups of at most `rows_per_group` rows,
    /// ranking rows by `row_scores` (total row sensitivity; pass uniform
    /// scores for contiguous chunking).
    pub fn build(
        rows: usize,
        cols: usize,
        rows_per_group: usize,
        row_scores: &[f64],
    ) -> Grouping {
        assert_eq!(row_scores.len(), rows);
        assert!(rows_per_group >= 1);
        let m = rows.div_ceil(rows_per_group);
        let mut order: Vec<u32> = (0..rows as u32).collect();
        order.sort_by(|&a, &b| {
            row_scores[a as usize]
                .partial_cmp(&row_scores[b as usize])
                .unwrap()
                .then(a.cmp(&b))
        });
        let mut row_to_group = vec![0u32; rows];
        let mut group_rows: Vec<Vec<u32>> = vec![Vec::new(); m];
        for (rank, &row) in order.iter().enumerate() {
            let g = (rank * m / rows).min(m - 1);
            row_to_group[row as usize] = g as u32;
            group_rows[g].push(row);
        }
        for g in group_rows.iter_mut() {
            g.sort_unstable();
        }
        Grouping { rows, cols, m, row_to_group, group_rows }
    }

    /// Whole-matrix grouping (M = 1): every column is one group.
    pub fn whole_columns(rows: usize, cols: usize) -> Grouping {
        Grouping::build(rows, cols, rows, &vec![0.0; rows])
    }

    /// Total number of (column × sub-group) quantization groups.
    pub fn num_groups(&self) -> usize {
        self.cols * self.m
    }

    /// Flat group index for (column, sub-group).
    #[inline]
    pub fn group_index(&self, col: usize, sub: usize) -> usize {
        col * self.m + sub
    }

    /// Number of weights in sub-group `sub` (same for every column).
    pub fn group_len(&self, sub: usize) -> usize {
        self.group_rows[sub].len()
    }

    /// Gather the weights of group (col, sub) from a matrix.
    pub fn gather(&self, w: &Tensor, col: usize, sub: usize) -> Vec<f32> {
        self.group_rows[sub]
            .iter()
            .map(|&r| w.get(r as usize, col))
            .collect()
    }

    /// Gather into a caller-owned scratch buffer (cleared first). Lets
    /// per-group loops (quantize_matrix, the calibration EMA updates)
    /// avoid one heap allocation per group.
    pub fn gather_into(&self, w: &Tensor, col: usize, sub: usize, buf: &mut Vec<f32>) {
        buf.clear();
        buf.extend(self.group_rows[sub].iter().map(|&r| w.get(r as usize, col)));
    }

    /// Iterate the weights of group (col, sub) without materializing them.
    pub fn iter_group<'a>(
        &'a self,
        w: &'a Tensor,
        col: usize,
        sub: usize,
    ) -> impl Iterator<Item = f32> + 'a {
        self.group_rows[sub].iter().map(move |&r| w.get(r as usize, col))
    }

    /// Scatter values back into group (col, sub).
    pub fn scatter(&self, w: &mut Tensor, col: usize, sub: usize, vals: &[f32]) {
        assert_eq!(vals.len(), self.group_rows[sub].len());
        for (&r, &v) in self.group_rows[sub].iter().zip(vals) {
            w.set(r as usize, col, v);
        }
    }

    /// Signaling overhead in bits (Table 3c): per-row sub-group index +
    /// per-group bit depth (4 b) and FP16 scale and mean.
    pub fn overhead_bits(&self) -> usize {
        let row_index_bits = if self.m > 1 {
            self.rows * (usize::BITS - (self.m - 1).leading_zeros()) as usize
        } else {
            0
        };
        row_index_bits + self.num_groups() * (4 + 16 + 16)
    }
}

/// Eq. 9: the average bit-depth saving from splitting a pooled source of
/// sensitivity `pooled = G²S²` into units with sensitivities `parts`
/// (weighted by element counts). Non-negative by Jensen's inequality.
pub fn jensen_gain_bits(parts: &[(usize, f64)]) -> f64 {
    let total: usize = parts.iter().map(|&(n, _)| n).sum();
    if total == 0 {
        return 0.0;
    }
    let eps = 1e-30;
    // Pooled second moment = element-weighted mean of part moments.
    let pooled: f64 =
        parts.iter().map(|&(n, v)| n as f64 * v).sum::<f64>() / total as f64;
    let mean_log: f64 = parts
        .iter()
        .map(|&(n, v)| n as f64 * (v.max(eps)).log2())
        .sum::<f64>()
        / total as f64;
    0.5 * (pooled.max(eps).log2() - mean_log)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn build_partitions_all_rows() {
        let scores: Vec<f64> = (0..100).map(|i| (i * 37 % 100) as f64).collect();
        let g = Grouping::build(100, 8, 32, &scores);
        assert_eq!(g.m, 4);
        let total: usize = g.group_rows.iter().map(|v| v.len()).sum();
        assert_eq!(total, 100);
        // Every row assigned exactly once, consistent with row_to_group.
        for (sub, rows) in g.group_rows.iter().enumerate() {
            for &r in rows {
                assert_eq!(g.row_to_group[r as usize], sub as u32);
            }
        }
        // Groups are similarly sized.
        for rows in &g.group_rows {
            assert!(rows.len() == 25);
        }
    }

    #[test]
    fn grouping_ranks_by_score() {
        // Low-score rows land in sub-group 0, high-score in the last.
        let scores: Vec<f64> = (0..64).map(|i| i as f64).collect();
        let g = Grouping::build(64, 4, 16, &scores);
        assert!(g.group_rows[0].iter().all(|&r| r < 16));
        assert!(g.group_rows[3].iter().all(|&r| r >= 48));
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut rng = Rng::new(51);
        let mut w = Tensor::zeros(32, 8);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let scores: Vec<f64> = (0..32).map(|_| rng.uniform()).collect();
        let g = Grouping::build(32, 8, 8, &scores);
        let orig = w.clone();
        for col in 0..8 {
            for sub in 0..g.m {
                let vals = g.gather(&w, col, sub);
                g.scatter(&mut w, col, sub, &vals);
            }
        }
        assert_eq!(w.data, orig.data);
    }

    #[test]
    fn overhead_matches_paper_scaling() {
        // Table 3c shape: halving group size doubles the per-group
        // overhead share. 512 rows, group 64 → m=8 → 3 bits/row.
        let g64 = Grouping::build(512, 512, 64, &vec![0.0; 512]);
        let g512 = Grouping::build(512, 512, 512, &vec![0.0; 512]);
        assert!(g64.overhead_bits() > 4 * g512.overhead_bits());
        // Whole-column grouping has no row-index overhead.
        assert_eq!(
            g512.overhead_bits(),
            512 * (4 + 16 + 16)
        );
    }

    #[test]
    fn jensen_gain_nonnegative_and_zero_for_identical() {
        let same = vec![(10usize, 2.0f64); 8];
        assert!(jensen_gain_bits(&same).abs() < 1e-12);
        let mixed = vec![(10, 0.01), (10, 1.0), (10, 100.0)];
        let g = jensen_gain_bits(&mixed);
        assert!(g > 0.5, "gain {g}");
    }

    #[test]
    fn jensen_gain_matches_hand_computation() {
        // Two equal-size parts with variances 1 and 16:
        // pooled = 8.5, gain = ½(log2 8.5 − (0 + 4)/2) = ½(3.087 − 2) ≈ 0.544
        let g = jensen_gain_bits(&[(5, 1.0), (5, 16.0)]);
        assert!((g - 0.5 * ((8.5f64).log2() - 2.0)).abs() < 1e-9);
    }
}
