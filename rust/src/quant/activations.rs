//! Activation quantization (paper §1/§5): because Radio determines bit
//! depths analytically and quantizes with an integer-rounding heuristic —
//! no weight fine-tuning — the same machinery applies to *activations*
//! at inference time, where OBS-style methods would stall the pipeline.
//!
//! Activations are quantized per (token, channel-group) with companded
//! quantizers whose (S, µ) come from running calibration statistics, and
//! bit depths from the same dual-ascent allocator driven by per-channel
//! sensitivity (output-gradient second moments).

use crate::coordinator::dual_ascent::{solve_integer, DualAscentConfig};
use crate::model::tensor::Tensor;
use crate::model::weights::MatId;
use crate::quant::bitpack::f16_round;
use crate::quant::companding;
use crate::stats::distortion::GroupRd;
use crate::stats::moments::EmaVec;

/// Per-channel-group activation quantizer for one layer boundary.
#[derive(Clone, Debug)]
pub struct ActQuantizer {
    /// Channels per group.
    pub group: usize,
    /// Per-group bit depths.
    pub bits: Vec<u8>,
    /// Per-group compander scale/mean (from calibration EMA).
    pub scale: Vec<f32>,
    pub mean: Vec<f32>,
}

/// Streaming calibration state for one activation tensor (dim channels).
pub struct ActCalibrator {
    dim: usize,
    group: usize,
    mean: EmaVec,
    sq: EmaVec,
    /// Per-channel sensitivity (gradient second moments); uniform if the
    /// caller has no gradient signal.
    g2: Vec<f64>,
    samples: usize,
}

impl ActCalibrator {
    pub fn new(dim: usize, group: usize, alpha: f64) -> ActCalibrator {
        ActCalibrator {
            dim,
            group: group.max(1).min(dim),
            mean: EmaVec::new(dim, alpha),
            sq: EmaVec::new(dim, alpha),
            g2: vec![1.0; dim],
            samples: 0,
        }
    }

    /// Observe a batch of activations (N×dim).
    pub fn observe(&mut self, x: &Tensor) {
        assert_eq!(x.cols, self.dim);
        let mut mu = vec![0f32; self.dim];
        let mut sq = vec![0f32; self.dim];
        for r in 0..x.rows {
            for (j, &v) in x.row(r).iter().enumerate() {
                mu[j] += v;
                sq[j] += v * v;
            }
        }
        let inv = 1.0 / x.rows as f32;
        for j in 0..self.dim {
            mu[j] *= inv;
            sq[j] *= inv;
        }
        self.mean.update(&mu);
        self.sq.update(&sq);
        self.samples += 1;
    }

    /// Optional per-channel sensitivity from output gradients.
    pub fn set_sensitivity(&mut self, g2: Vec<f64>) {
        assert_eq!(g2.len(), self.dim);
        self.g2 = g2;
    }

    /// Finalize: allocate bit depths at `target_bits` via dual ascent and
    /// freeze the per-group companders.
    pub fn build(&self, target_bits: f64) -> ActQuantizer {
        assert!(self.samples > 0, "no calibration data observed");
        let ngroups = self.dim.div_ceil(self.group);
        let mut scale = Vec::with_capacity(ngroups);
        let mut mean = Vec::with_capacity(ngroups);
        let mut rd = Vec::with_capacity(ngroups);
        let mu = self.mean.get();
        let sq = self.sq.get();
        for g in 0..ngroups {
            let lo = g * self.group;
            let hi = ((g + 1) * self.group).min(self.dim);
            let count = hi - lo;
            let gm = mu[lo..hi].iter().sum::<f64>() / count as f64;
            let gsq = sq[lo..hi].iter().sum::<f64>() / count as f64;
            let var = (gsq - gm * gm).max(1e-12);
            let g2 = self.g2[lo..hi].iter().sum::<f64>() / count as f64;
            scale.push(var.sqrt() as f32);
            mean.push(gm as f32);
            rd.push(GroupRd::new(count, g2, var, 1.0));
        }
        let bits = solve_integer(&rd, target_bits, &DualAscentConfig::default());
        ActQuantizer { group: self.group, bits, scale, mean }
    }
}

impl ActQuantizer {
    /// Quantize-dequantize one activation vector in place; returns MSE.
    pub fn apply(&self, x: &mut [f32]) -> f64 {
        let mut mse = 0f64;
        let mut n = 0usize;
        for (g, chunk) in x.chunks_mut(self.group).enumerate() {
            let b = self.bits[g];
            if b == 0 {
                for v in chunk.iter_mut() {
                    mse += (*v as f64) * (*v as f64);
                    *v = 0.0;
                }
            } else {
                for v in chunk.iter_mut() {
                    let code = companding::quantize_code(*v, b, self.scale[g], self.mean[g]);
                    let deq = companding::dequantize_code(code, b, self.scale[g], self.mean[g]);
                    mse += ((*v - deq) as f64).powi(2);
                    *v = deq;
                }
            }
            n += chunk.len();
        }
        mse / n.max(1) as f64
    }

    /// Average bits per activation element.
    pub fn avg_bits(&self, dim: usize) -> f64 {
        let mut total = 0f64;
        for (g, &b) in self.bits.iter().enumerate() {
            let lo = g * self.group;
            let hi = ((g + 1) * self.group).min(dim);
            total += b as f64 * (hi - lo) as f64;
        }
        total / dim as f64
    }
}

// ------------------------------------------------------------- W·A specs
//
// The per-matrix *input* quantizers the joint weight+activation allocator
// produces. Unlike `ActQuantizer` above (per-channel-group companded
// fake-quant, used for analysis), these are deliberately symmetric-uniform
// per *row* (token): symmetric codes keep the integer GEMM's accumulation
// affine in the weight codes, which is what makes the fully-integer tile
// path in `infer::matvec` exact.

/// How an [`ActQuantParams`] entry derives its quantization scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActScalePolicy {
    /// One calibration-time scale for the whole tensor (cheapest: no
    /// runtime reduction, but outlier tokens clip).
    Static,
    /// Per-token absmax computed on the fly (LLM.int8()-style dynamic
    /// quantization; one extra pass over each activation row).
    PerToken,
}

impl ActScalePolicy {
    /// Stable one-byte tag for the persisted spec (append-only).
    pub fn tag(&self) -> u8 {
        match self {
            ActScalePolicy::Static => 0,
            ActScalePolicy::PerToken => 1,
        }
    }

    /// Inverse of [`ActScalePolicy::tag`].
    pub fn from_tag(t: u8) -> Option<ActScalePolicy> {
        Some(match t {
            0 => ActScalePolicy::Static,
            1 => ActScalePolicy::PerToken,
            _ => return None,
        })
    }
}

/// Input quantizer for one matrix: bit depth + scale policy.
///
/// `bits == 0` means the allocator left this input at full precision —
/// the inference layer keeps the f32 activation path for that matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ActQuantParams {
    /// Activation code width in bits; `0` = full precision (f32 path),
    /// otherwise clamped to [2, 8] by [`ActQuantParams::new`]. Symmetric
    /// signed codes in `[-(2^(bits-1)-1), 2^(bits-1)-1]`.
    pub bits: u8,
    /// Scale derivation policy.
    pub policy: ActScalePolicy,
    /// Static per-tensor dequant scale (`x ≈ scale · code`; FP16-rounded,
    /// strictly positive) — calibrated `absmax / qmax`. Unused under
    /// [`ActScalePolicy::PerToken`], where each row derives its own.
    pub scale: f32,
}

impl ActQuantParams {
    /// Clamps `bits` to [2, 8] (unless 0 = disabled) and FP16-rounds the
    /// static scale with the same degenerate-scale guard as
    /// `KvQuantParams::new`.
    pub fn new(bits: u8, policy: ActScalePolicy, scale: f32) -> ActQuantParams {
        let mut scale = f16_round(scale);
        if !scale.is_finite() || scale <= 0.0 {
            scale = 1e-6;
        }
        let bits = if bits == 0 { 0 } else { bits.clamp(2, 8) };
        ActQuantParams { bits, policy, scale }
    }

    /// Full-precision entry: the f32 activation path.
    pub fn full_precision() -> ActQuantParams {
        ActQuantParams { bits: 0, policy: ActScalePolicy::PerToken, scale: 1.0 }
    }

    /// Largest code magnitude: `2^(bits-1) - 1` (symmetric grid).
    pub fn qmax(&self) -> i32 {
        debug_assert!(self.bits >= 2);
        (1i32 << (self.bits - 1)) - 1
    }
}

/// Per-matrix activation bit assignment for a whole model — the
/// activation-side twin of the weight allocation, produced by
/// `CalibrationStats::allocate_joint` and carried by the `Engine`.
#[derive(Clone, Debug, PartialEq)]
pub struct ActQuantSpec {
    /// One entry per quantized matrix, sorted by `MatId`.
    pub entries: Vec<(MatId, ActQuantParams)>,
}

impl ActQuantSpec {
    /// Flat spec: every matrix input at `bits` under `policy` (ablation
    /// arms; the allocator produces mixed ones).
    pub fn uniform(ids: &[MatId], bits: u8, policy: ActScalePolicy, scale: f32) -> ActQuantSpec {
        let p = ActQuantParams::new(bits, policy, scale);
        let mut entries: Vec<(MatId, ActQuantParams)> = ids.iter().map(|&id| (id, p)).collect();
        entries.sort_by_key(|(id, _)| *id);
        ActQuantSpec { entries }
    }

    /// Look up the input quantizer for one matrix; `None` (matrix not in
    /// the spec) means full precision.
    pub fn get(&self, id: MatId) -> Option<ActQuantParams> {
        self.entries
            .binary_search_by_key(&id, |(i, _)| *i)
            .ok()
            .map(|i| self.entries[i].1)
    }

    /// Average activation bits per entry, counting full-precision entries
    /// as 32 bits (what they actually cost on the bus).
    pub fn mean_bits(&self) -> f64 {
        if self.entries.is_empty() {
            return 0.0;
        }
        let total: usize = self
            .entries
            .iter()
            .map(|(_, p)| if p.bits == 0 { 32 } else { p.bits as usize })
            .sum();
        total as f64 / self.entries.len() as f64
    }
}

/// Quantize one activation row to symmetric signed integer codes.
///
/// Returns `(codes, scale)` such that `x[i] ≈ scale · codes[i]` with
/// `codes[i] ∈ [-qmax, qmax]`. Under [`ActScalePolicy::PerToken`] the
/// scale is this row's `absmax / qmax` (exactly covering the row's
/// range); under [`ActScalePolicy::Static`] it is the calibrated
/// per-tensor scale and codes clamp. An all-zero row (or degenerate
/// scale) yields `scale == 0` with all-zero codes, so `scale · code`
/// reconstruction stays exact.
pub fn quantize_row(x: &[f32], p: ActQuantParams) -> (Vec<i8>, f32) {
    debug_assert!(p.bits >= 2, "quantize_row called on a full-precision entry");
    let qmax = p.qmax();
    let s = match p.policy {
        ActScalePolicy::PerToken => {
            let amax = x.iter().fold(0f32, |m, &v| m.max(v.abs()));
            if amax > 0.0 && amax.is_finite() {
                amax / qmax as f32
            } else {
                0.0
            }
        }
        ActScalePolicy::Static => p.scale,
    };
    if s <= 0.0 || !s.is_finite() {
        return (vec![0i8; x.len()], 0.0);
    }
    let inv = 1.0 / s;
    let codes = x
        .iter()
        .map(|&v| {
            let c = (v * inv).round();
            c.clamp(-(qmax as f32), qmax as f32) as i8
        })
        .collect();
    (codes, s)
}

/// Dequantize codes produced by [`quantize_row`] (test/reference path —
/// the integer GEMM never materializes this).
pub fn dequantize_row(codes: &[i8], scale: f32) -> Vec<f32> {
    codes.iter().map(|&c| scale * c as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn calibrated(rng: &mut Rng, dim: usize, group: usize, hot: &[usize]) -> ActCalibrator {
        let mut cal = ActCalibrator::new(dim, group, 0.3);
        for _ in 0..8 {
            let mut x = Tensor::zeros(16, dim);
            rng.fill_laplace(&mut x.data, 0.2, 0.5);
            // Hot channels with 8× larger magnitude.
            for r in 0..16 {
                for &h in hot {
                    let v = x.get(r, h);
                    x.set(r, h, v * 8.0);
                }
            }
            cal.observe(&x);
        }
        cal
    }

    #[test]
    fn allocator_gives_hot_channels_more_bits() {
        let mut rng = Rng::new(0xAC7);
        let (dim, group) = (64, 8);
        let cal = calibrated(&mut rng, dim, group, &[3, 4, 5]); // all in group 0
        let q = cal.build(4.0);
        assert!((q.avg_bits(dim) - 4.0).abs() < 0.13, "rate {}", q.avg_bits(dim));
        // Group 0 (hot) should get at least as many bits as the median.
        let mut sorted = q.bits.clone();
        sorted.sort_unstable();
        assert!(q.bits[0] >= sorted[sorted.len() / 2], "hot group bits {:?}", q.bits);
    }

    #[test]
    fn apply_reduces_to_low_error_at_8_bits() {
        let mut rng = Rng::new(0xAC8);
        let cal = calibrated(&mut rng, 32, 8, &[]);
        let q = cal.build(8.0);
        let mut x = vec![0f32; 32];
        rng.fill_laplace(&mut x, 0.2, 0.5);
        let orig = x.clone();
        let mse = q.apply(&mut x);
        let var = crate::stats::moments::variance(&orig);
        assert!(mse < var * 0.01, "mse {mse} vs var {var}");
    }

    #[test]
    fn quantized_activations_preserve_matvec_output() {
        // End use-case: quantize activations before a linear layer; the
        // output error should shrink as the activation rate grows.
        let mut rng = Rng::new(0xAC9);
        let (dim, dout) = (48, 24);
        let mut w = Tensor::zeros(dim, dout);
        rng.fill_gauss(&mut w.data, 0.0, 0.3);
        let cal = calibrated(&mut rng, dim, 8, &[]);
        let mut errs = Vec::new();
        for bits in [2.0, 4.0, 6.0] {
            let q = cal.build(bits);
            let mut x = vec![0f32; dim];
            rng.fill_laplace(&mut x, 0.2, 0.5);
            let y_ref = crate::infer::dense_matvec(&w, &x);
            let mut xq = x.clone();
            q.apply(&mut xq);
            let y_q = crate::infer::dense_matvec(&w, &xq);
            let err: f64 = y_ref
                .iter()
                .zip(&y_q)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            errs.push(err);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "errors {errs:?}");
    }

    #[test]
    #[should_panic(expected = "no calibration data")]
    fn build_without_observation_panics() {
        let cal = ActCalibrator::new(16, 4, 0.3);
        let _ = cal.build(4.0);
    }

    #[test]
    fn per_token_roundtrip_is_deterministic_and_bounded() {
        let mut rng = Rng::new(0xACA);
        for bits in [2u8, 4, 8] {
            let p = ActQuantParams::new(bits, ActScalePolicy::PerToken, 1.0);
            let mut x = vec![0f32; 96];
            rng.fill_laplace(&mut x, 0.1, 0.7);
            let (codes, s) = quantize_row(&x, p);
            // Determinism: same input, same codes, same scale — bit-exact.
            let (codes2, s2) = quantize_row(&x, p);
            assert_eq!(codes, codes2);
            assert_eq!(s.to_bits(), s2.to_bits());
            // Codes respect the symmetric grid.
            let qmax = p.qmax() as i32;
            assert!(codes.iter().all(|&c| (c as i32).abs() <= qmax));
            // Roundtrip error bounded by half a step per element.
            let deq = dequantize_row(&codes, s);
            for (a, b) in x.iter().zip(&deq) {
                assert!((a - b).abs() <= 0.5 * s + 1e-6, "bits {bits}: {a} vs {b} (s={s})");
            }
        }
    }

    #[test]
    fn zero_row_quantizes_to_zero_scale_and_codes() {
        let p = ActQuantParams::new(8, ActScalePolicy::PerToken, 1.0);
        let (codes, s) = quantize_row(&[0.0; 16], p);
        assert_eq!(s, 0.0);
        assert!(codes.iter().all(|&c| c == 0));
        assert!(dequantize_row(&codes, s).iter().all(|&v| v == 0.0));
    }

    #[test]
    fn static_policy_uses_calibrated_scale_and_clips() {
        // Static scale sized for |x| <= 1.27 at 8 bits; outliers clip.
        let p = ActQuantParams::new(8, ActScalePolicy::Static, 0.01);
        let (codes, s) = quantize_row(&[0.5, -0.5, 10.0, -10.0], p);
        assert_eq!(s, p.scale);
        assert_eq!(codes[2], 127);
        assert_eq!(codes[3], -127);
        assert_eq!(codes[0], 50);
        assert_eq!(codes[1], -50);
    }

    #[test]
    fn spec_lookup_and_bit_clamping() {
        let ids = [
            MatId { layer: 0, role: crate::model::weights::Role::Q },
            MatId { layer: 1, role: crate::model::weights::Role::Down },
        ];
        let spec = ActQuantSpec::uniform(&ids, 8, ActScalePolicy::PerToken, 1.0);
        assert_eq!(spec.get(ids[0]).unwrap().bits, 8);
        assert_eq!(spec.get(MatId { layer: 2, role: crate::model::weights::Role::Q }), None);
        assert!((spec.mean_bits() - 8.0).abs() < 1e-12);
        // bits=1 clamps up to 2; bits=0 stays disabled.
        assert_eq!(ActQuantParams::new(1, ActScalePolicy::PerToken, 1.0).bits, 2);
        assert_eq!(ActQuantParams::new(0, ActScalePolicy::PerToken, 1.0).bits, 0);
    }
}
