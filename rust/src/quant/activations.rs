//! Activation quantization (paper §1/§5): because Radio determines bit
//! depths analytically and quantizes with an integer-rounding heuristic —
//! no weight fine-tuning — the same machinery applies to *activations*
//! at inference time, where OBS-style methods would stall the pipeline.
//!
//! Activations are quantized per (token, channel-group) with companded
//! quantizers whose (S, µ) come from running calibration statistics, and
//! bit depths from the same dual-ascent allocator driven by per-channel
//! sensitivity (output-gradient second moments).

use crate::coordinator::dual_ascent::{solve_integer, DualAscentConfig};
use crate::model::tensor::Tensor;
use crate::quant::companding;
use crate::stats::distortion::GroupRd;
use crate::stats::moments::EmaVec;

/// Per-channel-group activation quantizer for one layer boundary.
#[derive(Clone, Debug)]
pub struct ActQuantizer {
    /// Channels per group.
    pub group: usize,
    /// Per-group bit depths.
    pub bits: Vec<u8>,
    /// Per-group compander scale/mean (from calibration EMA).
    pub scale: Vec<f32>,
    pub mean: Vec<f32>,
}

/// Streaming calibration state for one activation tensor (dim channels).
pub struct ActCalibrator {
    dim: usize,
    group: usize,
    mean: EmaVec,
    sq: EmaVec,
    /// Per-channel sensitivity (gradient second moments); uniform if the
    /// caller has no gradient signal.
    g2: Vec<f64>,
    samples: usize,
}

impl ActCalibrator {
    pub fn new(dim: usize, group: usize, alpha: f64) -> ActCalibrator {
        ActCalibrator {
            dim,
            group: group.max(1).min(dim),
            mean: EmaVec::new(dim, alpha),
            sq: EmaVec::new(dim, alpha),
            g2: vec![1.0; dim],
            samples: 0,
        }
    }

    /// Observe a batch of activations (N×dim).
    pub fn observe(&mut self, x: &Tensor) {
        assert_eq!(x.cols, self.dim);
        let mut mu = vec![0f32; self.dim];
        let mut sq = vec![0f32; self.dim];
        for r in 0..x.rows {
            for (j, &v) in x.row(r).iter().enumerate() {
                mu[j] += v;
                sq[j] += v * v;
            }
        }
        let inv = 1.0 / x.rows as f32;
        for j in 0..self.dim {
            mu[j] *= inv;
            sq[j] *= inv;
        }
        self.mean.update(&mu);
        self.sq.update(&sq);
        self.samples += 1;
    }

    /// Optional per-channel sensitivity from output gradients.
    pub fn set_sensitivity(&mut self, g2: Vec<f64>) {
        assert_eq!(g2.len(), self.dim);
        self.g2 = g2;
    }

    /// Finalize: allocate bit depths at `target_bits` via dual ascent and
    /// freeze the per-group companders.
    pub fn build(&self, target_bits: f64) -> ActQuantizer {
        assert!(self.samples > 0, "no calibration data observed");
        let ngroups = self.dim.div_ceil(self.group);
        let mut scale = Vec::with_capacity(ngroups);
        let mut mean = Vec::with_capacity(ngroups);
        let mut rd = Vec::with_capacity(ngroups);
        let mu = self.mean.get();
        let sq = self.sq.get();
        for g in 0..ngroups {
            let lo = g * self.group;
            let hi = ((g + 1) * self.group).min(self.dim);
            let count = hi - lo;
            let gm = mu[lo..hi].iter().sum::<f64>() / count as f64;
            let gsq = sq[lo..hi].iter().sum::<f64>() / count as f64;
            let var = (gsq - gm * gm).max(1e-12);
            let g2 = self.g2[lo..hi].iter().sum::<f64>() / count as f64;
            scale.push(var.sqrt() as f32);
            mean.push(gm as f32);
            rd.push(GroupRd::new(count, g2, var, 1.0));
        }
        let bits = solve_integer(&rd, target_bits, &DualAscentConfig::default());
        ActQuantizer { group: self.group, bits, scale, mean }
    }
}

impl ActQuantizer {
    /// Quantize-dequantize one activation vector in place; returns MSE.
    pub fn apply(&self, x: &mut [f32]) -> f64 {
        let mut mse = 0f64;
        let mut n = 0usize;
        for (g, chunk) in x.chunks_mut(self.group).enumerate() {
            let b = self.bits[g];
            if b == 0 {
                for v in chunk.iter_mut() {
                    mse += (*v as f64) * (*v as f64);
                    *v = 0.0;
                }
            } else {
                for v in chunk.iter_mut() {
                    let code = companding::quantize_code(*v, b, self.scale[g], self.mean[g]);
                    let deq = companding::dequantize_code(code, b, self.scale[g], self.mean[g]);
                    mse += ((*v - deq) as f64).powi(2);
                    *v = deq;
                }
            }
            n += chunk.len();
        }
        mse / n.max(1) as f64
    }

    /// Average bits per activation element.
    pub fn avg_bits(&self, dim: usize) -> f64 {
        let mut total = 0f64;
        for (g, &b) in self.bits.iter().enumerate() {
            let lo = g * self.group;
            let hi = ((g + 1) * self.group).min(dim);
            total += b as f64 * (hi - lo) as f64;
        }
        total / dim as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn calibrated(rng: &mut Rng, dim: usize, group: usize, hot: &[usize]) -> ActCalibrator {
        let mut cal = ActCalibrator::new(dim, group, 0.3);
        for _ in 0..8 {
            let mut x = Tensor::zeros(16, dim);
            rng.fill_laplace(&mut x.data, 0.2, 0.5);
            // Hot channels with 8× larger magnitude.
            for r in 0..16 {
                for &h in hot {
                    let v = x.get(r, h);
                    x.set(r, h, v * 8.0);
                }
            }
            cal.observe(&x);
        }
        cal
    }

    #[test]
    fn allocator_gives_hot_channels_more_bits() {
        let mut rng = Rng::new(0xAC7);
        let (dim, group) = (64, 8);
        let cal = calibrated(&mut rng, dim, group, &[3, 4, 5]); // all in group 0
        let q = cal.build(4.0);
        assert!((q.avg_bits(dim) - 4.0).abs() < 0.13, "rate {}", q.avg_bits(dim));
        // Group 0 (hot) should get at least as many bits as the median.
        let mut sorted = q.bits.clone();
        sorted.sort_unstable();
        assert!(q.bits[0] >= sorted[sorted.len() / 2], "hot group bits {:?}", q.bits);
    }

    #[test]
    fn apply_reduces_to_low_error_at_8_bits() {
        let mut rng = Rng::new(0xAC8);
        let cal = calibrated(&mut rng, 32, 8, &[]);
        let q = cal.build(8.0);
        let mut x = vec![0f32; 32];
        rng.fill_laplace(&mut x, 0.2, 0.5);
        let orig = x.clone();
        let mse = q.apply(&mut x);
        let var = crate::stats::moments::variance(&orig);
        assert!(mse < var * 0.01, "mse {mse} vs var {var}");
    }

    #[test]
    fn quantized_activations_preserve_matvec_output() {
        // End use-case: quantize activations before a linear layer; the
        // output error should shrink as the activation rate grows.
        let mut rng = Rng::new(0xAC9);
        let (dim, dout) = (48, 24);
        let mut w = Tensor::zeros(dim, dout);
        rng.fill_gauss(&mut w.data, 0.0, 0.3);
        let cal = calibrated(&mut rng, dim, 8, &[]);
        let mut errs = Vec::new();
        for bits in [2.0, 4.0, 6.0] {
            let q = cal.build(bits);
            let mut x = vec![0f32; dim];
            rng.fill_laplace(&mut x, 0.2, 0.5);
            let y_ref = crate::infer::dense_matvec(&w, &x);
            let mut xq = x.clone();
            q.apply(&mut xq);
            let y_q = crate::infer::dense_matvec(&w, &xq);
            let err: f64 = y_ref
                .iter()
                .zip(&y_q)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum();
            errs.push(err);
        }
        assert!(errs[0] > errs[1] && errs[1] > errs[2], "errors {errs:?}");
    }

    #[test]
    #[should_panic(expected = "no calibration data")]
    fn build_without_observation_panics() {
        let cal = ActCalibrator::new(16, 4, 0.3);
        let _ = cal.build(4.0);
    }
}
