//! Quantization core: uniform (Eq. 2) and companded (Eq. 8) scalar
//! quantizers, MMSE step sizes, sensitivity-ranked weight grouping
//! (§3.3), mixed-precision bit-packing, bias correction (§3.2), and the
//! `.radio` quantized-model container.

pub mod activations;
pub mod bias;
pub mod bitpack;
pub mod companding;
// Part of the documented API surface (see lib.rs): the container module
// keeps every public item doc-commented, gated by CI's rustdoc job.
#[warn(missing_docs)]
pub mod format;
pub mod grouping;
pub mod rtn;

pub use bitpack::{GroupMeta, PackedMatrix, QuantMode};
pub use grouping::Grouping;

use crate::model::tensor::Tensor;
use crate::stats::moments;

/// How per-group scales (step size / compander scale) are chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleRule {
    /// Range-covering step (classic RTN).
    Range,
    /// Grid-searched MMSE scale (paper's step-size optimization).
    Mmse,
}

/// Build per-group metadata (scale/mean) for a matrix given per-group bit
/// depths, then pack. This is the single quantization entry point shared
/// by Radio and the baselines.
pub fn quantize_matrix(
    w: &Tensor,
    grouping: &Grouping,
    bits: &[u8],
    mode: QuantMode,
    scale_rule: ScaleRule,
) -> PackedMatrix {
    assert_eq!(bits.len(), grouping.num_groups());
    let mut meta = Vec::with_capacity(bits.len());
    let mut vals = Vec::with_capacity(grouping.rows.div_ceil(grouping.m.max(1)) + 1);
    for col in 0..grouping.cols {
        for sub in 0..grouping.m {
            let b = bits[grouping.group_index(col, sub)];
            grouping.gather_into(w, col, sub, &mut vals);
            meta.push(group_meta(&vals, b, mode, scale_rule));
        }
    }
    PackedMatrix::pack(w, grouping, &meta, mode)
}

/// Compute (bits, scale, mean) for one group of weights.
pub fn group_meta(vals: &[f32], bits: u8, mode: QuantMode, rule: ScaleRule) -> GroupMeta {
    let mean = moments::mean(vals) as f32;
    if bits == 0 {
        return GroupMeta { bits, scale: 1e-6, mean };
    }
    match mode {
        QuantMode::Companded => {
            let std = moments::variance(vals).sqrt().max(1e-9) as f32;
            let scale = match rule {
                ScaleRule::Range => std,
                ScaleRule::Mmse => mmse_compander_scale(vals, bits, std, mean),
            };
            GroupMeta { bits, scale, mean }
        }
        QuantMode::Uniform => {
            let scale = match rule {
                ScaleRule::Range => rtn::range_step(vals, bits, mean),
                ScaleRule::Mmse => rtn::mmse_step(vals, bits, mean),
            };
            GroupMeta { bits, scale, mean }
        }
    }
}

/// Coarse 1-D grid fine-tuning of the compander scale (paper §3.2 treats
/// (S, µ) as hyperparameters tuned on coarse grids post-hoc).
fn mmse_compander_scale(vals: &[f32], bits: u8, std: f32, mean: f32) -> f32 {
    let mut best = (std, f64::INFINITY);
    for i in 0..16 {
        let s = std * (0.55 + 0.1 * i as f32);
        let mut mse = 0f64;
        for &x in vals {
            let code = companding::quantize_code(x, bits, s, mean);
            let deq = companding::dequantize_code(code, bits, s, mean);
            mse += ((x - deq) as f64).powi(2);
        }
        if mse < best.1 {
            best = (s, mse);
        }
    }
    best.0
}

/// Simple whole-matrix RTN quantization at fixed bit depth (the paper's
/// RTN baseline): per-column groups, uniform quantizer, range step.
pub fn rtn_quantize(w: &Tensor, bits: u8, rows_per_group: usize, rule: ScaleRule) -> PackedMatrix {
    let grouping = Grouping::build(w.rows, w.cols, rows_per_group, &vec![0.0; w.rows]);
    let bvec = vec![bits; grouping.num_groups()];
    quantize_matrix(w, &grouping, &bvec, QuantMode::Uniform, rule)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_matrix_hits_requested_rate() {
        let mut rng = Rng::new(81);
        let (rows, cols) = (32, 16);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.3);
        let grouping = Grouping::build(rows, cols, 16, &vec![0.0; rows]);
        let bits = vec![3u8; grouping.num_groups()];
        let p = quantize_matrix(&w, &grouping, &bits, QuantMode::Companded, ScaleRule::Range);
        assert!((p.avg_bits_per_weight() - 3.0).abs() < 1e-9);
    }

    #[test]
    fn mmse_no_worse_than_range_for_companded() {
        let mut rng = Rng::new(82);
        let (rows, cols) = (64, 8);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.05, 0.4);
        let grouping = Grouping::whole_columns(rows, cols);
        let bits = vec![3u8; grouping.num_groups()];
        let mse = |rule| {
            let p = quantize_matrix(&w, &grouping, &bits, QuantMode::Companded, rule);
            let d = p.unpack();
            w.data
                .iter()
                .zip(&d.data)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let m_range = mse(ScaleRule::Range);
        let m_mmse = mse(ScaleRule::Mmse);
        assert!(m_mmse <= m_range * 1.02, "mmse {m_mmse} vs range {m_range}");
    }

    #[test]
    fn rtn_reconstruction_reasonable_at_8_bits() {
        let mut rng = Rng::new(83);
        let mut w = Tensor::zeros(48, 12);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let p = rtn_quantize(&w, 8, 48, ScaleRule::Range);
        let d = p.unpack();
        let mse: f64 = w
            .data
            .iter()
            .zip(&d.data)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / w.data.len() as f64;
        assert!(mse < 1e-3, "mse {mse}");
    }
}
