//! Companded quantization (paper §3.2, Eq. 8, Appendix C).
//!
//! Weights are passed through the asymptotically-optimal compander for a
//! Laplace source — the normalized cube-root-density integral, i.e. a
//! rescaled Laplace CDF — mapped to (0,1), quantized uniformly with 2^B
//! levels, and inverted on dequantization. The printed Eq. 8 folds the
//! two branches; expanded, with mean µ and standard deviation S
//! (Laplace scale b = S/√2, cube-root scale 3b = 3S/√2):
//!
//! ```text
//! σ(θ) = ½ + ½·sgn(θ−µ)·(1 − exp(−√2·|θ−µ| / (3S)))
//! σ⁻¹(t) = µ + (3S/√2)·sgn(t−½)·(−ln(1 − 2|t−½|))
//! ```
//!
//! Because S enters only as a linear stretch of σ⁻¹ around µ, dequantized
//! values decompose as `µ + S·lut[B][code]` — the property the LUT-based
//! matvec kernel (Appendix A / infer::matvec) relies on.

/// Forward compander: weight → (0,1).
#[inline]
pub fn compand(theta: f32, scale: f32, mean: f32) -> f32 {
    debug_assert!(scale > 0.0);
    let d = theta - mean;
    let mag = 1.0 - (-(std::f32::consts::SQRT_2 * d.abs()) / (3.0 * scale)).exp();
    0.5 + 0.5 * d.signum() * mag
}

/// Inverse compander: (0,1) → weight.
#[inline]
pub fn expand(t: f32, scale: f32, mean: f32) -> f32 {
    let d = t - 0.5;
    let mag = (1.0 - 2.0 * d.abs()).max(1e-12);
    mean - (3.0 * scale / std::f32::consts::SQRT_2) * d.signum() * mag.ln()
}

/// Quantize one value with a B-bit companded quantizer; returns the code.
#[inline]
pub fn quantize_code(theta: f32, bits: u8, scale: f32, mean: f32) -> u32 {
    debug_assert!(bits >= 1);
    let levels = 1u32 << bits;
    let t = compand(theta, scale, mean);
    let q = (t * levels as f32).floor() as i64;
    q.clamp(0, levels as i64 - 1) as u32
}

/// Dequantize a code (bin midpoint in companded domain).
#[inline]
pub fn dequantize_code(code: u32, bits: u8, scale: f32, mean: f32) -> f32 {
    let levels = (1u32 << bits) as f32;
    expand((code as f32 + 0.5) / levels, scale, mean)
}

/// The per-bit-depth base lookup table: dequantized values for a
/// *standardized* compander (µ=0, S=1). Real values are `µ + S·lut[code]`.
pub fn base_lut(bits: u8) -> Vec<f32> {
    let levels = 1usize << bits;
    (0..levels)
        .map(|c| expand((c as f32 + 0.5) / levels as f32, 1.0, 0.0))
        .collect()
}

/// Quantize-dequantize a slice in place (codes discarded); returns MSE.
pub fn quantize_dequantize(xs: &mut [f32], bits: u8, scale: f32, mean: f32) -> f64 {
    if bits == 0 {
        // 0-bit group: pruned to zero (paper §4 "Pruning Due to
        // Quantization"); the bias correction absorbs the lost mean.
        let mse = xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len().max(1) as f64;
        xs.fill(0.0);
        return mse;
    }
    let mut mse = 0f64;
    for x in xs.iter_mut() {
        let code = quantize_code(*x, bits, scale, mean);
        let deq = dequantize_code(code, bits, scale, mean);
        mse += ((*x - deq) as f64).powi(2);
        *x = deq;
    }
    mse / xs.len().max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn compander_is_monotone_and_bounded() {
        let (s, mu) = (0.7, 0.2);
        let mut prev = -1.0f32;
        for i in -100..=100 {
            let theta = i as f32 * 0.05;
            let t = compand(theta, s, mu);
            assert!((0.0..=1.0).contains(&t), "t={t}");
            assert!(t >= prev, "not monotone at {theta}");
            prev = t;
        }
        assert!((compand(mu, s, mu) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn expand_inverts_compand() {
        let (s, mu) = (1.3, -0.4);
        for i in -50..=50 {
            let theta = i as f32 * 0.1;
            let t = compand(theta, s, mu);
            let back = expand(t, s, mu);
            assert!((theta - back).abs() < 1e-3 * theta.abs().max(1.0), "{theta} -> {t} -> {back}");
        }
    }

    #[test]
    fn dequantized_code_roundtrips_to_same_code() {
        // Quantizer idempotence: Q(deQ(c)) == c.
        let (s, mu) = (0.9, 0.1);
        for bits in 1..=8u8 {
            for code in 0..(1u32 << bits) {
                let deq = dequantize_code(code, bits, s, mu);
                assert_eq!(quantize_code(deq, bits, s, mu), code, "bits {bits} code {code}");
            }
        }
    }

    #[test]
    fn lut_linearity_matches_direct_dequant() {
        // deq(code; B,S,µ) == µ + S·base_lut[B][code]
        let (s, mu) = (2.3f32, -0.7f32);
        for bits in 1..=6u8 {
            let lut = base_lut(bits);
            for code in 0..(1u32 << bits) {
                let direct = dequantize_code(code, bits, s, mu);
                let via_lut = mu + s * lut[code as usize];
                assert!(
                    (direct - via_lut).abs() < 1e-4 * direct.abs().max(1.0),
                    "bits {bits} code {code}: {direct} vs {via_lut}"
                );
            }
        }
    }

    #[test]
    fn mse_decreases_about_4x_per_bit_on_laplace() {
        // The rate–distortion premise: each extra bit quarters the error.
        let mut rng = Rng::new(31);
        let mut base = vec![0f32; 50_000];
        rng.fill_laplace(&mut base, 0.0, 1.0);
        let mut prev_mse = f64::INFINITY;
        for bits in 2..=6u8 {
            let mut xs = base.clone();
            let mse = quantize_dequantize(&mut xs, bits, 1.0, 0.0);
            let ratio = prev_mse / mse;
            if bits > 2 {
                assert!(ratio > 2.8 && ratio < 5.5, "bits {bits}: ratio {ratio}");
            }
            prev_mse = mse;
        }
    }

    #[test]
    fn companding_beats_uniform_on_laplace_at_low_bits() {
        // Figure 2's claim, tested numerically at 3 bits.
        let mut rng = Rng::new(32);
        let mut xs = vec![0f32; 50_000];
        rng.fill_laplace(&mut xs, 0.0, 1.0);
        // Companded MSE.
        let mut cq = xs.clone();
        let mse_comp = quantize_dequantize(&mut cq, 3, 1.0, 0.0);
        // Uniform mid-rise covering the full range (classic RTN).
        let maxabs = xs.iter().fold(0f32, |a, &x| a.max(x.abs()));
        let d = 2.0 * maxabs / 8.0;
        let mse_unif: f64 = xs
            .iter()
            .map(|&x| {
                let q = (x / d).floor().clamp(-4.0, 3.0);
                let deq = d * (q + 0.5);
                ((x - deq) as f64).powi(2)
            })
            .sum::<f64>()
            / xs.len() as f64;
        assert!(
            mse_comp < mse_unif * 0.8,
            "companded {mse_comp} vs uniform {mse_unif}"
        );
    }

    #[test]
    fn zero_bits_prunes() {
        let mut xs = vec![0.5f32, -0.25, 0.1];
        quantize_dequantize(&mut xs, 0, 1.0, 0.0);
        assert_eq!(xs, vec![0.0, 0.0, 0.0]);
    }
}
