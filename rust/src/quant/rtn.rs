//! Uniform (round-to-nearest) scalar quantization — Eq. 2 of the paper —
//! plus MMSE step-size selection. This is both the RTN baseline and the
//! "uniform" mode of the Radio quantizer ablation (Table 3a).

/// Mid-rise uniform quantizer code for step `d`, `2^bits` levels centered
/// on `mean` (Eq. 2 with an explicit zero-point).
#[inline]
pub fn quantize_code(theta: f32, bits: u8, d: f32, mean: f32) -> i32 {
    debug_assert!(bits >= 1);
    let half = 1i64 << (bits - 1);
    let q = ((theta - mean) / d).floor() as i64;
    q.clamp(-half, half - 1) as i32
}

/// Dequantize a mid-rise code.
#[inline]
pub fn dequantize_code(code: i32, d: f32, mean: f32) -> f32 {
    mean + d * (code as f32 + 0.5)
}

/// Quantize-dequantize in place; returns MSE.
pub fn quantize_dequantize(xs: &mut [f32], bits: u8, d: f32, mean: f32) -> f64 {
    if bits == 0 {
        let mse =
            xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / xs.len().max(1) as f64;
        xs.fill(0.0);
        return mse;
    }
    let mut mse = 0f64;
    for x in xs.iter_mut() {
        let deq = dequantize_code(quantize_code(*x, bits, d, mean), d, mean);
        mse += ((*x - deq) as f64).powi(2);
        *x = deq;
    }
    mse / xs.len().max(1) as f64
}

/// Classic range-based step: the 2^B bins just cover [min, max].
pub fn range_step(xs: &[f32], bits: u8, mean: f32) -> f32 {
    debug_assert!(bits >= 1);
    let mut maxdev = 0f32;
    for &x in xs {
        maxdev = maxdev.max((x - mean).abs());
    }
    (2.0 * maxdev / (1u32 << bits) as f32).max(1e-12)
}

/// MSE of quantizing `xs` with step `d` (no mutation).
pub fn mse_for_step(xs: &[f32], bits: u8, d: f32, mean: f32) -> f64 {
    let mut mse = 0f64;
    for &x in xs {
        let deq = dequantize_code(quantize_code(x, bits, d, mean), d, mean);
        mse += ((x - deq) as f64).powi(2);
    }
    mse / xs.len().max(1) as f64
}

/// MMSE step-size search: golden-section-style scan over a log grid of
/// candidate steps around the range step (the paper fine-tunes (S, µ) on
/// coarse 1-D grids post-hoc; this is the uniform-quantizer analogue).
pub fn mmse_step(xs: &[f32], bits: u8, mean: f32) -> f32 {
    debug_assert!(bits >= 1);
    let d0 = range_step(xs, bits, mean);
    let mut best = (d0, mse_for_step(xs, bits, d0, mean));
    // Shrinking the range clips outliers but shrinks bins — usually wins.
    for i in 1..=24 {
        let d = d0 * (1.0 - i as f32 / 26.0);
        if d <= 0.0 {
            break;
        }
        let m = mse_for_step(xs, bits, d, mean);
        if m < best.1 {
            best = (d, m);
        }
    }
    best.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn codes_clamped_to_range() {
        let bits = 3u8;
        let d = 0.1;
        assert_eq!(quantize_code(100.0, bits, d, 0.0), 3);
        assert_eq!(quantize_code(-100.0, bits, d, 0.0), -4);
    }

    #[test]
    fn dequantize_is_bin_midpoint() {
        let d = 0.5;
        assert!((dequantize_code(0, d, 0.0) - 0.25).abs() < 1e-7);
        assert!((dequantize_code(-1, d, 0.0) - (-0.25)).abs() < 1e-7);
    }

    #[test]
    fn range_step_covers_data() {
        let xs = [-1.0f32, 0.3, 0.9];
        let d = range_step(&xs, 2, 0.0);
        // 4 levels, max |dev| = 1.0 → d = 0.5; codes within [-2, 1].
        assert!((d - 0.5).abs() < 1e-6);
        for &x in &xs {
            let c = quantize_code(x, 2, d, 0.0);
            assert!((-2..=1).contains(&c));
        }
    }

    #[test]
    fn mmse_step_beats_or_matches_range_step() {
        let mut rng = Rng::new(41);
        let mut xs = vec![0f32; 20_000];
        rng.fill_gauss(&mut xs, 0.0, 1.0);
        // Add outliers so range step is clearly suboptimal.
        xs[0] = 12.0;
        xs[1] = -11.0;
        for bits in [2u8, 3, 4] {
            let dr = range_step(&xs, bits, 0.0);
            let dm = mmse_step(&xs, bits, 0.0);
            let mr = mse_for_step(&xs, bits, dr, 0.0);
            let mm = mse_for_step(&xs, bits, dm, 0.0);
            assert!(mm <= mr + 1e-12, "bits {bits}: {mm} vs {mr}");
            if bits <= 3 {
                assert!(mm < 0.8 * mr, "expected big MMSE win with outliers at {bits} bits");
            }
        }
    }

    #[test]
    fn quantize_dequantize_error_bounded_by_step() {
        let mut rng = Rng::new(42);
        let mut xs = vec![0f32; 1000];
        rng.fill_gauss(&mut xs, 0.0, 0.5);
        let bits = 6u8;
        let d = range_step(&xs, bits, 0.0);
        let orig = xs.clone();
        quantize_dequantize(&mut xs, bits, d, 0.0);
        for (&o, &q) in orig.iter().zip(&xs) {
            assert!((o - q).abs() <= d / 2.0 + 1e-6, "{o} -> {q} (d={d})");
        }
    }
}
