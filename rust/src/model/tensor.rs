//! A minimal row-major 2-D tensor. Deliberately small: the heavy lifting
//! (threaded matmul, Gram, transpose) lives in [`crate::stats::linalg`];
//! `Tensor` is the ownership/shape wrapper used for model weights and
//! activations.

use crate::stats::linalg;

#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Tensor {
        Tensor { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Tensor {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Tensor { rows, cols, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// self[rows×cols] · other[cols×n]
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let data = linalg::matmul(&self.data, &other.data, self.rows, self.cols, other.cols);
        Tensor { rows: self.rows, cols: other.cols, data }
    }

    /// selfᵀ · other  (self[k×m]ᵀ → m×k, other[k×n]) without materializing
    /// the transpose.
    pub fn t_matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let t = linalg::transpose(&self.data, self.rows, self.cols);
        let data = linalg::matmul(&t, &other.data, self.cols, self.rows, other.cols);
        Tensor { rows: self.cols, cols: other.cols, data }
    }

    /// self · otherᵀ (other[n×cols]).
    pub fn matmul_t(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let t = linalg::transpose(&other.data, other.rows, other.cols);
        let data = linalg::matmul(&self.data, &t, self.rows, self.cols, other.rows);
        Tensor { rows: self.rows, cols: other.rows, data }
    }

    pub fn transpose(&self) -> Tensor {
        Tensor {
            rows: self.cols,
            cols: self.rows,
            data: linalg::transpose(&self.data, self.rows, self.cols),
        }
    }

    /// Add a row-vector bias to every row.
    pub fn add_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (x, &b) in self.row_mut(r).iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Elementwise in-place add.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Elementwise in-place scaled add: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.data.len(), other.data.len());
        for (a, &b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in self.data.iter_mut() {
            *x *= s;
        }
    }

    /// Column slice [c0, c1) as a new tensor.
    pub fn cols_slice(&self, c0: usize, c1: usize) -> Tensor {
        assert!(c0 <= c1 && c1 <= self.cols);
        let w = c1 - c0;
        let mut out = Tensor::zeros(self.rows, w);
        for r in 0..self.rows {
            out.row_mut(r).copy_from_slice(&self.row(r)[c0..c1]);
        }
        out
    }

    /// Write `src` into columns [c0, c0+src.cols).
    pub fn set_cols(&mut self, c0: usize, src: &Tensor) {
        assert_eq!(self.rows, src.rows);
        assert!(c0 + src.cols <= self.cols);
        for r in 0..self.rows {
            let dst = &mut self.data[r * self.cols + c0..r * self.cols + c0 + src.cols];
            dst.copy_from_slice(src.row(r));
        }
    }

    /// Accumulate `src` into columns [c0, ..).
    pub fn add_cols(&mut self, c0: usize, src: &Tensor) {
        assert_eq!(self.rows, src.rows);
        assert!(c0 + src.cols <= self.cols);
        for r in 0..self.rows {
            for j in 0..src.cols {
                self.data[r * self.cols + c0 + j] += src.get(r, j);
            }
        }
    }

    pub fn frob2(&self) -> f64 {
        linalg::frob2(&self.data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identities() {
        let a = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let mut eye = Tensor::zeros(3, 3);
        for i in 0..3 {
            eye.set(i, i, 1.0);
        }
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn t_matmul_matches_explicit() {
        let a = Tensor::from_vec(3, 2, vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(3, 2, vec![1., 0., 0., 1., 1., 1.]);
        let left = a.t_matmul(&b);
        let explicit = a.transpose().matmul(&b);
        assert_eq!(left, explicit);
    }

    #[test]
    fn matmul_t_matches_explicit() {
        let a = Tensor::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(4, 3, (0..12).map(|x| x as f32).collect());
        assert_eq!(a.matmul_t(&b), a.matmul(&b.transpose()));
    }

    #[test]
    fn col_slicing_roundtrip() {
        let a = Tensor::from_vec(2, 4, (0..8).map(|x| x as f32).collect());
        let s = a.cols_slice(1, 3);
        assert_eq!(s.data, vec![1., 2., 5., 6.]);
        let mut b = Tensor::zeros(2, 4);
        b.set_cols(1, &s);
        assert_eq!(b.get(0, 1), 1.0);
        assert_eq!(b.get(1, 2), 6.0);
    }

    #[test]
    fn bias_add() {
        let mut a = Tensor::zeros(2, 3);
        a.add_bias(&[1.0, 2.0, 3.0]);
        assert_eq!(a.row(1), &[1.0, 2.0, 3.0]);
    }
}
