//! Model weights: container, named access to the quantizable matrices,
//! initialization (training init and statistically-shaped synthetic
//! "pretrained-like" weights for scaling studies), and binary save/load.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::config::ModelConfig;
use crate::model::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Role of a quantizable matrix within its transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Role {
    Q,
    K,
    V,
    O,
    Up,
    Down,
}

impl Role {
    pub const ALL: [Role; 6] = [Role::Q, Role::K, Role::V, Role::O, Role::Up, Role::Down];

    pub fn name(&self) -> &'static str {
        match self {
            Role::Q => "q_proj",
            Role::K => "k_proj",
            Role::V => "v_proj",
            Role::O => "o_proj",
            Role::Up => "mlp_up",
            Role::Down => "mlp_down",
        }
    }
}

/// Identifier of one quantizable weight matrix: (block index, role).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatId {
    pub layer: usize,
    pub role: Role,
}

impl std::fmt::Display for MatId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "block{}.{}", self.layer, self.role.name())
    }
}

/// One transformer block's parameters. Weight matrices are stored
/// (d_in × d_out) so that forward is `X @ W + b`.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Tensor,
    pub bq: Vec<f32>,
    pub wk: Tensor,
    pub bk: Vec<f32>,
    pub wv: Tensor,
    pub bv: Vec<f32>,
    pub wo: Tensor,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Tensor,
    pub b1: Vec<f32>,
    pub w2: Tensor,
    pub b2: Vec<f32>,
}

impl LayerWeights {
    pub fn zeros(cfg: &ModelConfig) -> LayerWeights {
        let e = cfg.dim;
        let f = cfg.mlp;
        LayerWeights {
            ln1_g: vec![1.0; e],
            ln1_b: vec![0.0; e],
            wq: Tensor::zeros(e, e),
            bq: vec![0.0; e],
            wk: Tensor::zeros(e, e),
            bk: vec![0.0; e],
            wv: Tensor::zeros(e, e),
            bv: vec![0.0; e],
            wo: Tensor::zeros(e, e),
            bo: vec![0.0; e],
            ln2_g: vec![1.0; e],
            ln2_b: vec![0.0; e],
            w1: Tensor::zeros(e, f),
            b1: vec![0.0; f],
            w2: Tensor::zeros(f, e),
            b2: vec![0.0; e],
        }
    }

    pub fn matrix(&self, role: Role) -> &Tensor {
        match role {
            Role::Q => &self.wq,
            Role::K => &self.wk,
            Role::V => &self.wv,
            Role::O => &self.wo,
            Role::Up => &self.w1,
            Role::Down => &self.w2,
        }
    }

    pub fn matrix_mut(&mut self, role: Role) -> &mut Tensor {
        match role {
            Role::Q => &mut self.wq,
            Role::K => &mut self.wk,
            Role::V => &mut self.wv,
            Role::O => &mut self.wo,
            Role::Up => &mut self.w1,
            Role::Down => &mut self.w2,
        }
    }

    pub fn bias(&self, role: Role) -> &Vec<f32> {
        match role {
            Role::Q => &self.bq,
            Role::K => &self.bk,
            Role::V => &self.bv,
            Role::O => &self.bo,
            Role::Up => &self.b1,
            Role::Down => &self.b2,
        }
    }

    pub fn bias_mut(&mut self, role: Role) -> &mut Vec<f32> {
        match role {
            Role::Q => &mut self.bq,
            Role::K => &mut self.bk,
            Role::V => &mut self.bv,
            Role::O => &mut self.bo,
            Role::Up => &mut self.b1,
            Role::Down => &mut self.b2,
        }
    }
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct Weights {
    pub config: ModelConfig,
    /// Token embedding (V×E); the prediction head is tied to it.
    pub embed: Tensor,
    /// Positional embedding (max_seq×E).
    pub pos: Tensor,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Weights {
    pub fn zeros(cfg: ModelConfig) -> Weights {
        Weights {
            config: cfg,
            embed: Tensor::zeros(cfg.vocab, cfg.dim),
            pos: Tensor::zeros(cfg.max_seq, cfg.dim),
            layers: (0..cfg.layers).map(|_| LayerWeights::zeros(&cfg)).collect(),
            lnf_g: vec![1.0; cfg.dim],
            lnf_b: vec![0.0; cfg.dim],
        }
    }

    /// GPT-2-style training initialization.
    pub fn init_training(cfg: ModelConfig, rng: &mut Rng) -> Weights {
        let mut w = Weights::zeros(cfg);
        let std = 0.02f32;
        rng.fill_gauss(&mut w.embed.data, 0.0, std);
        rng.fill_gauss(&mut w.pos.data, 0.0, std * 0.5);
        let resid_scale = 1.0 / (2.0 * cfg.layers as f32).sqrt();
        for l in w.layers.iter_mut() {
            rng.fill_gauss(&mut l.wq.data, 0.0, std);
            rng.fill_gauss(&mut l.wk.data, 0.0, std);
            rng.fill_gauss(&mut l.wv.data, 0.0, std);
            rng.fill_gauss(&mut l.wo.data, 0.0, std * resid_scale);
            rng.fill_gauss(&mut l.w1.data, 0.0, std);
            rng.fill_gauss(&mut l.w2.data, 0.0, std * resid_scale);
        }
        w
    }

    /// Statistically-shaped synthetic "pretrained-like" weights for
    /// scaling studies: Laplace-ish heavy-tailed entries with per-channel
    /// scale variation and a few outlier channels, mimicking published
    /// LLM weight statistics (Zhao et al., 2019). Deterministic per seed.
    pub fn init_pretrained_like(cfg: ModelConfig, rng: &mut Rng) -> Weights {
        let mut w = Weights::init_training(cfg, rng);
        for l in w.layers.iter_mut() {
            for role in Role::ALL {
                let m = l.matrix_mut(role);
                let (rows, cols) = (m.rows, m.cols);
                // Per-output-channel log-normal scale + sparse outliers.
                let base = 0.03 / (rows as f32).sqrt() * 8.0;
                let scales: Vec<f32> = (0..cols)
                    .map(|_| base * (rng.normal(0.0, 0.8)).exp() as f32)
                    .collect();
                for r in 0..rows {
                    for c in 0..cols {
                        m.data[r * cols + c] = rng.laplace(0.0, scales[c] as f64) as f32;
                    }
                }
                // ~0.5% outlier channels with 8× scale.
                let n_out = (cols / 200).max(1);
                for _ in 0..n_out {
                    let c = rng.below(cols);
                    for r in 0..rows {
                        m.data[r * cols + c] *= 8.0;
                    }
                }
            }
        }
        w
    }

    /// Enumerate the quantizable matrices in block order.
    pub fn matrix_ids(&self) -> Vec<MatId> {
        let mut ids = Vec::with_capacity(self.layers.len() * 6);
        for layer in 0..self.layers.len() {
            for role in Role::ALL {
                ids.push(MatId { layer, role });
            }
        }
        ids
    }

    pub fn matrix(&self, id: MatId) -> &Tensor {
        self.layers[id.layer].matrix(id.role)
    }

    pub fn matrix_mut(&mut self, id: MatId) -> &mut Tensor {
        self.layers[id.layer].matrix_mut(id.role)
    }

    pub fn bias(&self, id: MatId) -> &Vec<f32> {
        self.layers[id.layer].bias(id.role)
    }

    pub fn bias_mut(&mut self, id: MatId) -> &mut Vec<f32> {
        self.layers[id.layer].bias_mut(id.role)
    }

    /// Iterate over all parameter slices (for the optimizer).
    pub fn param_slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = Vec::new();
        v.push(&mut self.embed.data);
        v.push(&mut self.pos.data);
        for l in self.layers.iter_mut() {
            v.push(&mut l.ln1_g);
            v.push(&mut l.ln1_b);
            v.push(&mut l.wq.data);
            v.push(&mut l.bq);
            v.push(&mut l.wk.data);
            v.push(&mut l.bk);
            v.push(&mut l.wv.data);
            v.push(&mut l.bv);
            v.push(&mut l.wo.data);
            v.push(&mut l.bo);
            v.push(&mut l.ln2_g);
            v.push(&mut l.ln2_b);
            v.push(&mut l.w1.data);
            v.push(&mut l.b1);
            v.push(&mut l.w2.data);
            v.push(&mut l.b2);
        }
        v.push(&mut self.lnf_g);
        v.push(&mut self.lnf_b);
        v
    }

    /// Save to a binary container: magic, JSON config, then raw f32 LE
    /// tensors in `param_slices_mut` order.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut me = self.clone();
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"RADIOWT1")?;
        let cfg = self.config.to_json().to_string();
        f.write_all(&(cfg.len() as u32).to_le_bytes())?;
        f.write_all(cfg.as_bytes())?;
        for s in me.param_slices_mut() {
            let bytes: Vec<u8> = s.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&(s.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Weights> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"RADIOWT1" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic: not a radio weights file",
            ));
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let clen = u32::from_le_bytes(len4) as usize;
        let mut cbuf = vec![0u8; clen];
        f.read_exact(&mut cbuf)?;
        let cfg_json = Json::parse(std::str::from_utf8(&cbuf).map_err(err_inv)?)
            .map_err(err_inv)?;
        let cfg = ModelConfig::from_json(&cfg_json).map_err(err_inv)?;
        let mut w = Weights::zeros(cfg);
        for s in w.param_slices_mut() {
            let mut len8 = [0u8; 8];
            f.read_exact(&mut len8)?;
            let n = u64::from_le_bytes(len8) as usize;
            if n != s.len() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("tensor length mismatch: file {n}, expected {}", s.len()),
                ));
            }
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            for (i, x) in s.iter_mut().enumerate() {
                *x = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        Ok(w)
    }
}

fn err_inv<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_ids_cover_all_blocks() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let w = Weights::zeros(cfg);
        let ids = w.matrix_ids();
        assert_eq!(ids.len(), cfg.layers * 6);
        let total: usize = ids.iter().map(|&id| w.matrix(id).len()).sum();
        assert_eq!(total, cfg.block_params());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(42);
        let w = Weights::init_training(cfg, &mut rng);
        let dir = std::env::temp_dir().join("radio_test_weights.bin");
        w.save(&dir).unwrap();
        let back = Weights::load(&dir).unwrap();
        assert_eq!(w.embed.data, back.embed.data);
        assert_eq!(w.layers[1].w2.data, back.layers[1].w2.data);
        assert_eq!(w.lnf_g, back.lnf_g);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let p = std::env::temp_dir().join("radio_bad_magic.bin");
        std::fs::write(&p, b"NOTRADIO123456").unwrap();
        assert!(Weights::load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn pretrained_like_is_heavy_tailed() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(7);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let m = &w.layers[0].wq.data;
        // Kurtosis should exceed Gaussian's 3 (log-normal channel scales +
        // Laplace entries + outliers).
        let mean: f64 = m.iter().map(|&x| x as f64).sum::<f64>() / m.len() as f64;
        let var: f64 =
            m.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / m.len() as f64;
        let k: f64 = m.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>()
            / m.len() as f64
            / (var * var);
        assert!(k > 4.0, "kurtosis {k}");
    }

    #[test]
    fn param_slices_count_matches_total() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut w = Weights::zeros(cfg);
        let total: usize = w.param_slices_mut().iter().map(|s| s.len()).sum();
        assert_eq!(total, cfg.total_params());
    }
}
