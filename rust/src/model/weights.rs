//! Model weights: container, named access to the quantizable matrices,
//! initialization (training init and statistically-shaped synthetic
//! "pretrained-like" weights for scaling studies), and binary save/load.

use std::io::{Read, Write};
use std::path::Path;

use crate::model::config::ModelConfig;
use crate::model::tensor::Tensor;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Role of a quantizable matrix within its transformer block.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Role {
    Q,
    K,
    V,
    O,
    Up,
    Down,
}

impl Role {
    pub const ALL: [Role; 6] = [Role::Q, Role::K, Role::V, Role::O, Role::Up, Role::Down];

    pub fn name(&self) -> &'static str {
        match self {
            Role::Q => "q_proj",
            Role::K => "k_proj",
            Role::V => "v_proj",
            Role::O => "o_proj",
            Role::Up => "mlp_up",
            Role::Down => "mlp_down",
        }
    }

    /// Stable one-byte tag for binary containers (`.radio`, calibration
    /// artifacts). Append-only: existing tags must never be renumbered.
    pub fn tag(&self) -> u8 {
        match self {
            Role::Q => 0,
            Role::K => 1,
            Role::V => 2,
            Role::O => 3,
            Role::Up => 4,
            Role::Down => 5,
        }
    }

    pub fn from_tag(t: u8) -> Option<Role> {
        Some(match t {
            0 => Role::Q,
            1 => Role::K,
            2 => Role::V,
            3 => Role::O,
            4 => Role::Up,
            5 => Role::Down,
            _ => return None,
        })
    }
}

/// Identifier of one quantizable weight matrix: (block index, role).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MatId {
    pub layer: usize,
    pub role: Role,
}

impl std::fmt::Display for MatId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "block{}.{}", self.layer, self.role.name())
    }
}

/// One transformer block's parameters. Weight matrices are stored
/// (d_in × d_out) so that forward is `X @ W + b`.
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub wq: Tensor,
    pub bq: Vec<f32>,
    pub wk: Tensor,
    pub bk: Vec<f32>,
    pub wv: Tensor,
    pub bv: Vec<f32>,
    pub wo: Tensor,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub w1: Tensor,
    pub b1: Vec<f32>,
    pub w2: Tensor,
    pub b2: Vec<f32>,
}

impl LayerWeights {
    pub fn zeros(cfg: &ModelConfig) -> LayerWeights {
        let e = cfg.dim;
        let f = cfg.mlp;
        LayerWeights {
            ln1_g: vec![1.0; e],
            ln1_b: vec![0.0; e],
            wq: Tensor::zeros(e, e),
            bq: vec![0.0; e],
            wk: Tensor::zeros(e, e),
            bk: vec![0.0; e],
            wv: Tensor::zeros(e, e),
            bv: vec![0.0; e],
            wo: Tensor::zeros(e, e),
            bo: vec![0.0; e],
            ln2_g: vec![1.0; e],
            ln2_b: vec![0.0; e],
            w1: Tensor::zeros(e, f),
            b1: vec![0.0; f],
            w2: Tensor::zeros(f, e),
            b2: vec![0.0; e],
        }
    }

    pub fn matrix(&self, role: Role) -> &Tensor {
        match role {
            Role::Q => &self.wq,
            Role::K => &self.wk,
            Role::V => &self.wv,
            Role::O => &self.wo,
            Role::Up => &self.w1,
            Role::Down => &self.w2,
        }
    }

    pub fn matrix_mut(&mut self, role: Role) -> &mut Tensor {
        match role {
            Role::Q => &mut self.wq,
            Role::K => &mut self.wk,
            Role::V => &mut self.wv,
            Role::O => &mut self.wo,
            Role::Up => &mut self.w1,
            Role::Down => &mut self.w2,
        }
    }

    pub fn bias(&self, role: Role) -> &Vec<f32> {
        match role {
            Role::Q => &self.bq,
            Role::K => &self.bk,
            Role::V => &self.bv,
            Role::O => &self.bo,
            Role::Up => &self.b1,
            Role::Down => &self.b2,
        }
    }

    pub fn bias_mut(&mut self, role: Role) -> &mut Vec<f32> {
        match role {
            Role::Q => &mut self.bq,
            Role::K => &mut self.bk,
            Role::V => &mut self.bv,
            Role::O => &mut self.bo,
            Role::Up => &mut self.b1,
            Role::Down => &mut self.b2,
        }
    }
}

/// Full model parameters.
#[derive(Clone, Debug)]
pub struct Weights {
    pub config: ModelConfig,
    /// Token embedding (V×E); the prediction head is tied to it.
    pub embed: Tensor,
    /// Positional embedding (max_seq×E).
    pub pos: Tensor,
    pub layers: Vec<LayerWeights>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl Weights {
    pub fn zeros(cfg: ModelConfig) -> Weights {
        Weights {
            config: cfg,
            embed: Tensor::zeros(cfg.vocab, cfg.dim),
            pos: Tensor::zeros(cfg.max_seq, cfg.dim),
            layers: (0..cfg.layers).map(|_| LayerWeights::zeros(&cfg)).collect(),
            lnf_g: vec![1.0; cfg.dim],
            lnf_b: vec![0.0; cfg.dim],
        }
    }

    /// GPT-2-style training initialization.
    pub fn init_training(cfg: ModelConfig, rng: &mut Rng) -> Weights {
        let mut w = Weights::zeros(cfg);
        let std = 0.02f32;
        rng.fill_gauss(&mut w.embed.data, 0.0, std);
        rng.fill_gauss(&mut w.pos.data, 0.0, std * 0.5);
        let resid_scale = 1.0 / (2.0 * cfg.layers as f32).sqrt();
        for l in w.layers.iter_mut() {
            rng.fill_gauss(&mut l.wq.data, 0.0, std);
            rng.fill_gauss(&mut l.wk.data, 0.0, std);
            rng.fill_gauss(&mut l.wv.data, 0.0, std);
            rng.fill_gauss(&mut l.wo.data, 0.0, std * resid_scale);
            rng.fill_gauss(&mut l.w1.data, 0.0, std);
            rng.fill_gauss(&mut l.w2.data, 0.0, std * resid_scale);
        }
        w
    }

    /// Statistically-shaped synthetic "pretrained-like" weights for
    /// scaling studies: Laplace-ish heavy-tailed entries with per-channel
    /// scale variation and a few outlier channels, mimicking published
    /// LLM weight statistics (Zhao et al., 2019). Deterministic per seed.
    pub fn init_pretrained_like(cfg: ModelConfig, rng: &mut Rng) -> Weights {
        let mut w = Weights::init_training(cfg, rng);
        for l in w.layers.iter_mut() {
            for role in Role::ALL {
                let m = l.matrix_mut(role);
                let (rows, cols) = (m.rows, m.cols);
                // Per-output-channel log-normal scale + sparse outliers.
                let base = 0.03 / (rows as f32).sqrt() * 8.0;
                let scales: Vec<f32> = (0..cols)
                    .map(|_| base * (rng.normal(0.0, 0.8)).exp() as f32)
                    .collect();
                for r in 0..rows {
                    for c in 0..cols {
                        m.data[r * cols + c] = rng.laplace(0.0, scales[c] as f64) as f32;
                    }
                }
                // ~0.5% outlier channels with 8× scale.
                let n_out = (cols / 200).max(1);
                for _ in 0..n_out {
                    let c = rng.below(cols);
                    for r in 0..rows {
                        m.data[r * cols + c] *= 8.0;
                    }
                }
            }
        }
        w
    }

    /// Enumerate the quantizable matrices in block order.
    pub fn matrix_ids(&self) -> Vec<MatId> {
        let mut ids = Vec::with_capacity(self.layers.len() * 6);
        for layer in 0..self.layers.len() {
            for role in Role::ALL {
                ids.push(MatId { layer, role });
            }
        }
        ids
    }

    pub fn matrix(&self, id: MatId) -> &Tensor {
        self.layers[id.layer].matrix(id.role)
    }

    pub fn matrix_mut(&mut self, id: MatId) -> &mut Tensor {
        self.layers[id.layer].matrix_mut(id.role)
    }

    pub fn bias(&self, id: MatId) -> &Vec<f32> {
        self.layers[id.layer].bias(id.role)
    }

    pub fn bias_mut(&mut self, id: MatId) -> &mut Vec<f32> {
        self.layers[id.layer].bias_mut(id.role)
    }

    /// Iterate over all parameter slices (for the optimizer).
    pub fn param_slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = Vec::new();
        v.push(&mut self.embed.data);
        v.push(&mut self.pos.data);
        for l in self.layers.iter_mut() {
            v.push(&mut l.ln1_g);
            v.push(&mut l.ln1_b);
            v.push(&mut l.wq.data);
            v.push(&mut l.bq);
            v.push(&mut l.wk.data);
            v.push(&mut l.bk);
            v.push(&mut l.wv.data);
            v.push(&mut l.bv);
            v.push(&mut l.wo.data);
            v.push(&mut l.bo);
            v.push(&mut l.ln2_g);
            v.push(&mut l.ln2_b);
            v.push(&mut l.w1.data);
            v.push(&mut l.b1);
            v.push(&mut l.w2.data);
            v.push(&mut l.b2);
        }
        v.push(&mut self.lnf_g);
        v.push(&mut self.lnf_b);
        v
    }

    /// Save to a binary container: magic, JSON config, then raw f32 LE
    /// tensors in `param_slices_mut` order.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let mut me = self.clone();
        let mut f = std::fs::File::create(path)?;
        f.write_all(b"RADIOWT1")?;
        let cfg = self.config.to_json().to_string();
        f.write_all(&(cfg.len() as u32).to_le_bytes())?;
        f.write_all(cfg.as_bytes())?;
        for s in me.param_slices_mut() {
            let bytes: Vec<u8> = s.iter().flat_map(|x| x.to_le_bytes()).collect();
            f.write_all(&(s.len() as u64).to_le_bytes())?;
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> std::io::Result<Weights> {
        let mut f = std::fs::File::open(path)?;
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != b"RADIOWT1" {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic: not a radio weights file",
            ));
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let clen = u32::from_le_bytes(len4) as usize;
        let mut cbuf = vec![0u8; clen];
        f.read_exact(&mut cbuf)?;
        let cfg_json = Json::parse(std::str::from_utf8(&cbuf).map_err(err_inv)?)
            .map_err(err_inv)?;
        let cfg = ModelConfig::from_json(&cfg_json).map_err(err_inv)?;
        let mut w = Weights::zeros(cfg);
        for s in w.param_slices_mut() {
            let mut len8 = [0u8; 8];
            f.read_exact(&mut len8)?;
            let n = u64::from_le_bytes(len8) as usize;
            if n != s.len() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("tensor length mismatch: file {n}, expected {}", s.len()),
                ));
            }
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            for (i, x) in s.iter_mut().enumerate() {
                *x = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        Ok(w)
    }
}

fn err_inv<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

/// Per-block side parameters: everything a transformer block carries
/// *besides* its six quantizable matrices (LayerNorms and biases).
#[derive(Clone, Debug)]
pub struct LayerSide {
    pub ln1_g: Vec<f32>,
    pub ln1_b: Vec<f32>,
    pub bq: Vec<f32>,
    pub bk: Vec<f32>,
    pub bv: Vec<f32>,
    pub bo: Vec<f32>,
    pub ln2_g: Vec<f32>,
    pub ln2_b: Vec<f32>,
    pub b1: Vec<f32>,
    pub b2: Vec<f32>,
}

impl LayerSide {
    pub fn bias(&self, role: Role) -> &Vec<f32> {
        match role {
            Role::Q => &self.bq,
            Role::K => &self.bk,
            Role::V => &self.bv,
            Role::O => &self.bo,
            Role::Up => &self.b1,
            Role::Down => &self.b2,
        }
    }

    pub fn bias_mut(&mut self, role: Role) -> &mut Vec<f32> {
        match role {
            Role::Q => &mut self.bq,
            Role::K => &mut self.bk,
            Role::V => &mut self.bv,
            Role::O => &mut self.bo,
            Role::Up => &mut self.b1,
            Role::Down => &mut self.b2,
        }
    }
}

/// The full-precision "side" of a quantized model: embeddings, positional
/// table, LayerNorms, (corrected) biases and the final norm — everything
/// except the packed block matrices. Holding this instead of a dense
/// `Weights` clone keeps a `QuantizedModel` O(side) rather than O(model)
/// resident, which is what lets packing stream layer by layer.
#[derive(Clone, Debug)]
pub struct SideParams {
    pub config: ModelConfig,
    pub embed: Tensor,
    pub pos: Tensor,
    pub layers: Vec<LayerSide>,
    pub lnf_g: Vec<f32>,
    pub lnf_b: Vec<f32>,
}

impl SideParams {
    /// Extract the side parameters of a dense model (block matrices are
    /// dropped, not copied).
    pub fn from_weights(w: &Weights) -> SideParams {
        SideParams {
            config: w.config,
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers: w
                .layers
                .iter()
                .map(|l| LayerSide {
                    ln1_g: l.ln1_g.clone(),
                    ln1_b: l.ln1_b.clone(),
                    bq: l.bq.clone(),
                    bk: l.bk.clone(),
                    bv: l.bv.clone(),
                    bo: l.bo.clone(),
                    ln2_g: l.ln2_g.clone(),
                    ln2_b: l.ln2_b.clone(),
                    b1: l.b1.clone(),
                    b2: l.b2.clone(),
                })
                .collect(),
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    pub fn bias(&self, id: MatId) -> &Vec<f32> {
        self.layers[id.layer].bias(id.role)
    }

    pub fn bias_mut(&mut self, id: MatId) -> &mut Vec<f32> {
        self.layers[id.layer].bias_mut(id.role)
    }

    /// Rebuild a dense `Weights` by combining these side parameters with
    /// a per-matrix supplier for the block matrices.
    pub fn to_weights_with(&self, mut matrix: impl FnMut(MatId) -> Tensor) -> Weights {
        let layers = self
            .layers
            .iter()
            .enumerate()
            .map(|(layer, l)| LayerWeights {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: matrix(MatId { layer, role: Role::Q }),
                bq: l.bq.clone(),
                wk: matrix(MatId { layer, role: Role::K }),
                bk: l.bk.clone(),
                wv: matrix(MatId { layer, role: Role::V }),
                bv: l.bv.clone(),
                wo: matrix(MatId { layer, role: Role::O }),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: matrix(MatId { layer, role: Role::Up }),
                b1: l.b1.clone(),
                w2: matrix(MatId { layer, role: Role::Down }),
                b2: l.b2.clone(),
            })
            .collect();
        Weights {
            config: self.config,
            embed: self.embed.clone(),
            pos: self.pos.clone(),
            layers,
            lnf_g: self.lnf_g.clone(),
            lnf_b: self.lnf_b.clone(),
        }
    }

    /// Parameter slices in the fixed serialization order.
    fn slices(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = Vec::new();
        v.push(&self.embed.data);
        v.push(&self.pos.data);
        for l in &self.layers {
            v.push(&l.ln1_g);
            v.push(&l.ln1_b);
            v.push(&l.bq);
            v.push(&l.bk);
            v.push(&l.bv);
            v.push(&l.bo);
            v.push(&l.ln2_g);
            v.push(&l.ln2_b);
            v.push(&l.b1);
            v.push(&l.b2);
        }
        v.push(&self.lnf_g);
        v.push(&self.lnf_b);
        v
    }

    fn slices_mut(&mut self) -> Vec<&mut [f32]> {
        let mut v: Vec<&mut [f32]> = Vec::new();
        v.push(&mut self.embed.data);
        v.push(&mut self.pos.data);
        for l in self.layers.iter_mut() {
            v.push(&mut l.ln1_g);
            v.push(&mut l.ln1_b);
            v.push(&mut l.bq);
            v.push(&mut l.bk);
            v.push(&mut l.bv);
            v.push(&mut l.bo);
            v.push(&mut l.ln2_g);
            v.push(&mut l.ln2_b);
            v.push(&mut l.b1);
            v.push(&mut l.b2);
        }
        v.push(&mut self.lnf_g);
        v.push(&mut self.lnf_b);
        v
    }

    /// Serialize into any byte sink: JSON config header, then raw f32 LE
    /// slices (length-prefixed) in `slices` order. No temp files — this
    /// is what lets `.radio` containers stream.
    pub fn write_to<W: Write>(&self, f: &mut W) -> std::io::Result<()> {
        let cfg = self.config.to_json().to_string();
        f.write_all(&(cfg.len() as u32).to_le_bytes())?;
        f.write_all(cfg.as_bytes())?;
        // Fixed-size staging buffer: no transient per-slice byte Vec
        // (the embedding table alone would be vocab·dim·4 bytes), which
        // keeps the streaming container path at bounded peak memory.
        let mut buf = [0u8; 4096];
        for s in self.slices() {
            f.write_all(&(s.len() as u64).to_le_bytes())?;
            for chunk in s.chunks(buf.len() / 4) {
                for (i, x) in chunk.iter().enumerate() {
                    buf[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
                }
                f.write_all(&buf[..chunk.len() * 4])?;
            }
        }
        Ok(())
    }

    pub fn read_from<R: Read>(f: &mut R) -> std::io::Result<SideParams> {
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let clen = u32::from_le_bytes(len4) as usize;
        let mut cbuf = vec![0u8; clen];
        f.read_exact(&mut cbuf)?;
        let cfg_json =
            Json::parse(std::str::from_utf8(&cbuf).map_err(err_inv)?).map_err(err_inv)?;
        let cfg = ModelConfig::from_json(&cfg_json).map_err(err_inv)?;
        // Shaped directly from the config — never materializes the dense
        // block matrices a `Weights::zeros` would allocate.
        let (e, mlp) = (cfg.dim, cfg.mlp);
        let mut side = SideParams {
            config: cfg,
            embed: Tensor::zeros(cfg.vocab, cfg.dim),
            pos: Tensor::zeros(cfg.max_seq, cfg.dim),
            layers: (0..cfg.layers)
                .map(|_| LayerSide {
                    ln1_g: vec![0.0; e],
                    ln1_b: vec![0.0; e],
                    bq: vec![0.0; e],
                    bk: vec![0.0; e],
                    bv: vec![0.0; e],
                    bo: vec![0.0; e],
                    ln2_g: vec![0.0; e],
                    ln2_b: vec![0.0; e],
                    b1: vec![0.0; mlp],
                    b2: vec![0.0; e],
                })
                .collect(),
            lnf_g: vec![0.0; e],
            lnf_b: vec![0.0; e],
        };
        for s in side.slices_mut() {
            let mut len8 = [0u8; 8];
            f.read_exact(&mut len8)?;
            let n = u64::from_le_bytes(len8) as usize;
            if n != s.len() {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("side-param length mismatch: file {n}, expected {}", s.len()),
                ));
            }
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            for (i, x) in s.iter_mut().enumerate() {
                *x = f32::from_le_bytes(buf[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        Ok(side)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_ids_cover_all_blocks() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let w = Weights::zeros(cfg);
        let ids = w.matrix_ids();
        assert_eq!(ids.len(), cfg.layers * 6);
        let total: usize = ids.iter().map(|&id| w.matrix(id).len()).sum();
        assert_eq!(total, cfg.block_params());
    }

    #[test]
    fn save_load_roundtrip() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(42);
        let w = Weights::init_training(cfg, &mut rng);
        let dir = std::env::temp_dir().join("radio_test_weights.bin");
        w.save(&dir).unwrap();
        let back = Weights::load(&dir).unwrap();
        assert_eq!(w.embed.data, back.embed.data);
        assert_eq!(w.layers[1].w2.data, back.layers[1].w2.data);
        assert_eq!(w.lnf_g, back.lnf_g);
        let _ = std::fs::remove_file(dir);
    }

    #[test]
    fn load_rejects_bad_magic() {
        let p = std::env::temp_dir().join("radio_bad_magic.bin");
        std::fs::write(&p, b"NOTRADIO123456").unwrap();
        assert!(Weights::load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }

    #[test]
    fn pretrained_like_is_heavy_tailed() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(7);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let m = &w.layers[0].wq.data;
        // Kurtosis should exceed Gaussian's 3 (log-normal channel scales +
        // Laplace entries + outliers).
        let mean: f64 = m.iter().map(|&x| x as f64).sum::<f64>() / m.len() as f64;
        let var: f64 =
            m.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / m.len() as f64;
        let k: f64 = m.iter().map(|&x| (x as f64 - mean).powi(4)).sum::<f64>()
            / m.len() as f64
            / (var * var);
        assert!(k > 4.0, "kurtosis {k}");
    }

    #[test]
    fn role_tags_roundtrip() {
        for role in Role::ALL {
            assert_eq!(Role::from_tag(role.tag()), Some(role));
        }
        assert_eq!(Role::from_tag(6), None);
    }

    #[test]
    fn side_params_roundtrip_and_rebuild() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut rng = Rng::new(44);
        let w = Weights::init_training(cfg, &mut rng);
        let side = SideParams::from_weights(&w);
        let mut buf: Vec<u8> = Vec::new();
        side.write_to(&mut buf).unwrap();
        let back = SideParams::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(side.embed.data, back.embed.data);
        assert_eq!(side.layers[1].bq, back.layers[1].bq);
        assert_eq!(side.lnf_g, back.lnf_g);
        // Rebuilding with the original matrices reproduces the model.
        let rebuilt = back.to_weights_with(|id| w.matrix(id).clone());
        assert_eq!(rebuilt.layers[0].wq.data, w.layers[0].wq.data);
        assert_eq!(rebuilt.layers[1].b2, w.layers[1].b2);
        // The serialized side is a small fraction of the dense model.
        assert!(buf.len() < 4 * cfg.total_params(), "side {} bytes", buf.len());
    }

    #[test]
    fn param_slices_count_matches_total() {
        let cfg = ModelConfig::preset("ropt-nano").unwrap();
        let mut w = Weights::zeros(cfg);
        let total: usize = w.param_slices_mut().iter().map(|s| s.len()).sum();
        assert_eq!(total, cfg.total_params());
    }
}
