//! Synthetic corpus substrate — the stand-in for C4/WikiText2.
//!
//! A seeded Zipf–Mandelbrot lexicon of "words" (2–6 byte tokens each) is
//! sampled into sentences with light bigram structure, giving a corpus a
//! small char-level transformer can genuinely learn (loss well below the
//! uniform ln 256 ≈ 5.55). Two *domains* with partially-overlapping
//! lexicons model the paper's calibration-vs-test distribution shift
//! (C4-train → C4-test is in-domain; C4-train → WikiText2 is shifted).

use crate::util::rng::{Rng, Zipf};

/// Which synthetic distribution a corpus is drawn from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Domain {
    /// "C4-like": the calibration/training domain.
    Calib,
    /// "WikiText-like": shares 60% of the lexicon, different word
    /// frequencies and sentence lengths.
    Shifted,
}

#[derive(Clone, Debug)]
pub struct Corpus {
    pub data: Vec<u8>,
    pub domain: Domain,
}

const ALPHABET: &[u8] = b"abcdefghijklmnopqrstuvwxyz";
const LEXICON_SIZE: usize = 512;

fn build_lexicon(rng: &mut Rng) -> Vec<Vec<u8>> {
    (0..LEXICON_SIZE)
        .map(|_| {
            let len = 2 + rng.below(5);
            (0..len)
                .map(|_| ALPHABET[rng.below(ALPHABET.len())])
                .collect()
        })
        .collect()
}

impl Corpus {
    /// Generate `bytes` of corpus text for the given domain. The lexicon
    /// is derived from a *fixed* base seed so the two domains share words;
    /// `seed` controls the sampled stream itself.
    pub fn synthetic(seed: u64, domain: Domain, bytes: usize) -> Corpus {
        // Shared lexicon across domains (deterministic).
        let mut lex_rng = Rng::new(0xBA5E_5EED);
        let lexicon = build_lexicon(&mut lex_rng);

        let mut rng = Rng::new(seed ^ (domain as u64).wrapping_mul(0x1234_5678_9ABC_DEF1));
        let (zipf_s, zipf_q, offset, sent_len) = match domain {
            Domain::Calib => (1.1, 2.0, 0usize, 12usize),
            // Shifted domain: re-ranks 40% of the lexicon (disjoint
            // frequency structure) and uses longer sentences.
            Domain::Shifted => (1.3, 4.0, LEXICON_SIZE * 2 / 5, 18usize),
        };
        let zipf = Zipf::new(LEXICON_SIZE, zipf_s, zipf_q);

        let mut data = Vec::with_capacity(bytes + 16);
        // Light bigram structure: with probability p_follow, the next word
        // is a deterministic "successor" of the previous (rank+1 mod N);
        // this gives the model learnable transition structure.
        let mut prev: Option<usize> = None;
        while data.len() < bytes {
            let mut words_in_sentence = 0;
            let target = sent_len / 2 + rng.below(sent_len);
            while words_in_sentence < target && data.len() < bytes {
                let w = match prev {
                    Some(p) if rng.uniform() < 0.35 => (p + 1) % LEXICON_SIZE,
                    _ => (zipf.sample(&mut rng) + offset) % LEXICON_SIZE,
                };
                data.extend_from_slice(&lexicon[w]);
                data.push(b' ');
                prev = Some(w);
                words_in_sentence += 1;
            }
            if !data.is_empty() {
                // Replace trailing space with sentence end.
                let n = data.len();
                data[n - 1] = b'.';
                data.push(b' ');
            }
        }
        data.truncate(bytes);
        Corpus { data, domain }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// A random minibatch of (inputs, targets): `batch` windows of length
    /// `seq`, targets are inputs shifted by one byte.
    pub fn sample_batch(&self, rng: &mut Rng, batch: usize, seq: usize) -> (Vec<u32>, Vec<u32>) {
        assert!(self.data.len() > seq + 1, "corpus too small");
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below(self.data.len() - seq - 1);
            for i in 0..seq {
                toks.push(self.data[start + i] as u32);
                tgts.push(self.data[start + i + 1] as u32);
            }
        }
        (toks, tgts)
    }

    /// Deterministic evaluation windows covering the corpus with stride
    /// `seq` (non-overlapping), up to `max_windows`.
    pub fn eval_windows(&self, seq: usize, max_windows: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
        let mut out = Vec::new();
        let mut start = 0;
        while start + seq + 1 <= self.data.len() && out.len() < max_windows {
            let toks: Vec<u32> = (0..seq).map(|i| self.data[start + i] as u32).collect();
            let tgts: Vec<u32> = (0..seq).map(|i| self.data[start + i + 1] as u32).collect();
            out.push((toks, tgts));
            start += seq;
        }
        out
    }

    /// Split into (train, val, test) by byte ranges (80/10/10).
    pub fn split(&self) -> (Corpus, Corpus, Corpus) {
        let n = self.data.len();
        let a = n * 8 / 10;
        let b = n * 9 / 10;
        let mk = |range: std::ops::Range<usize>| Corpus {
            data: self.data[range].to_vec(),
            domain: self.domain,
        };
        (mk(0..a), mk(a..b), mk(b..n))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = Corpus::synthetic(1, Domain::Calib, 4096);
        let b = Corpus::synthetic(1, Domain::Calib, 4096);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn domains_differ() {
        let a = Corpus::synthetic(1, Domain::Calib, 4096);
        let b = Corpus::synthetic(1, Domain::Shifted, 4096);
        assert_ne!(a.data, b.data);
    }

    #[test]
    fn batch_targets_shift_by_one() {
        let c = Corpus::synthetic(2, Domain::Calib, 4096);
        let mut rng = Rng::new(3);
        let (toks, tgts) = c.sample_batch(&mut rng, 2, 16);
        assert_eq!(toks.len(), 32);
        // Within each window the target at i equals the input at i+1.
        for w in 0..2 {
            for i in 0..15 {
                assert_eq!(tgts[w * 16 + i], toks[w * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn eval_windows_cover_without_overlap() {
        let c = Corpus::synthetic(4, Domain::Calib, 1000);
        let ws = c.eval_windows(64, 100);
        assert!(ws.len() >= 14);
        assert_eq!(ws[0].0.len(), 64);
        // First byte of window 1 follows last byte of window 0.
        assert_eq!(ws[1].0[0], c.data[64] as u32);
    }

    #[test]
    fn corpus_has_structure() {
        // Space should be the most frequent byte (word separator), giving
        // the corpus learnable statistics.
        let c = Corpus::synthetic(5, Domain::Calib, 20_000);
        let mut counts = [0usize; 256];
        for &b in &c.data {
            counts[b as usize] += 1;
        }
        let max_byte = (0..256).max_by_key(|&i| counts[i]).unwrap();
        assert_eq!(max_byte, b' ' as usize);
        // Only printable subset used.
        assert!(counts.iter().enumerate().all(|(i, &c)| c == 0
            || i == b' ' as usize
            || i == b'.' as usize
            || (b'a' as usize..=b'z' as usize).contains(&i)));
    }

    #[test]
    fn split_proportions() {
        let c = Corpus::synthetic(6, Domain::Calib, 10_000);
        let (tr, va, te) = c.split();
        assert_eq!(tr.len(), 8000);
        assert_eq!(va.len(), 1000);
        assert_eq!(te.len(), 1000);
    }
}
