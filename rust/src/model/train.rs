//! Adam trainer for the transformer substrate. Produces the "pretrained"
//! checkpoints that the quantization experiments compress — the in-repo
//! stand-in for downloading OPT/Llama weights.

use crate::model::config::ModelConfig;
use crate::model::corpus::Corpus;
use crate::model::transformer;
use crate::model::weights::Weights;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub batch: usize,
    pub seq: usize,
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub weight_decay: f64,
    pub grad_clip: f64,
    pub warmup: usize,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            steps: 300,
            batch: 8,
            seq: 64,
            lr: 3e-3,
            beta1: 0.9,
            beta2: 0.95,
            eps: 1e-8,
            weight_decay: 0.01,
            grad_clip: 1.0,
            warmup: 20,
            log_every: 25,
        }
    }
}

/// Adam state (first/second moments per parameter), flat over the same
/// slice ordering as `Weights::param_slices_mut`.
struct Adam {
    m: Vec<Vec<f64>>,
    v: Vec<Vec<f64>>,
    t: usize,
}

impl Adam {
    fn new(w: &mut Weights) -> Adam {
        let sizes: Vec<usize> = w.param_slices_mut().iter().map(|s| s.len()).collect();
        Adam {
            m: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            v: sizes.iter().map(|&n| vec![0.0; n]).collect(),
            t: 0,
        }
    }

    fn step(&mut self, w: &mut Weights, g: &mut Weights, cfg: &TrainConfig, lr: f64) {
        self.t += 1;
        let bc1 = 1.0 - cfg.beta1.powi(self.t as i32);
        let bc2 = 1.0 - cfg.beta2.powi(self.t as i32);
        let wslices = w.param_slices_mut();
        let gslices = g.param_slices_mut();
        for ((ws, gs), (m, v)) in wslices
            .into_iter()
            .zip(gslices)
            .zip(self.m.iter_mut().zip(self.v.iter_mut()))
        {
            for i in 0..ws.len() {
                let grad = gs[i] as f64;
                m[i] = cfg.beta1 * m[i] + (1.0 - cfg.beta1) * grad;
                v[i] = cfg.beta2 * v[i] + (1.0 - cfg.beta2) * grad * grad;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                let mut update = mhat / (vhat.sqrt() + cfg.eps);
                update += cfg.weight_decay * ws[i] as f64;
                ws[i] -= (lr * update) as f32;
            }
        }
    }
}

/// Training summary: loss curve and timing.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f64>,
    pub final_loss: f64,
    pub seconds: f64,
}

/// Train `weights` in place on the corpus. Deterministic given `seed`.
pub fn train(
    weights: &mut Weights,
    corpus: &Corpus,
    cfg: &TrainConfig,
    seed: u64,
) -> TrainReport {
    let start = std::time::Instant::now();
    let mut rng = Rng::new(seed);
    let mut adam = Adam::new(weights);
    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let (toks, tgts) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
        let (loss, mut grads) = transformer::loss_and_grads(weights, &toks, &tgts, cfg.batch, cfg.seq);
        losses.push(loss);

        // Global-norm gradient clipping.
        let mut norm2 = 0f64;
        for s in grads.param_slices_mut() {
            for &x in s.iter() {
                norm2 += (x as f64) * (x as f64);
            }
        }
        let norm = norm2.sqrt();
        if norm > cfg.grad_clip {
            let scale = (cfg.grad_clip / norm) as f32;
            for s in grads.param_slices_mut() {
                for x in s.iter_mut() {
                    *x *= scale;
                }
            }
        }

        // LR schedule: linear warmup then cosine decay to 10%.
        let lr = if step < cfg.warmup {
            cfg.lr * (step + 1) as f64 / cfg.warmup as f64
        } else {
            let p = (step - cfg.warmup) as f64 / (cfg.steps - cfg.warmup).max(1) as f64;
            cfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + (std::f64::consts::PI * p).cos()))
        };
        adam.step(weights, &mut grads, cfg, lr);

        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            crate::log_info!("train step {step:4}  loss {loss:.4}  lr {lr:.2e}");
        }
    }
    let final_loss = losses.iter().rev().take(10).sum::<f64>() / losses.len().min(10) as f64;
    TrainReport { losses, final_loss, seconds: start.elapsed().as_secs_f64() }
}

/// Convenience: build corpus, init weights, train, return (weights, report).
pub fn train_preset(
    preset: &str,
    corpus: &Corpus,
    steps: usize,
    seed: u64,
) -> (Weights, TrainReport) {
    let cfg = ModelConfig::preset(preset).unwrap_or_else(|| panic!("unknown preset {preset}"));
    let mut rng = Rng::new(seed);
    let mut w = Weights::init_training(cfg, &mut rng);
    let tcfg = TrainConfig { steps, ..Default::default() };
    let report = train(&mut w, corpus, &tcfg, seed ^ 0xDEAD_BEEF);
    (w, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::corpus::Domain;

    #[test]
    fn loss_decreases_on_tiny_model() {
        let corpus = Corpus::synthetic(11, Domain::Calib, 32 * 1024);
        let cfg = ModelConfig { vocab: 256, dim: 32, heads: 2, layers: 1, mlp: 64, max_seq: 32 };
        let mut rng = Rng::new(12);
        let mut w = Weights::init_training(cfg, &mut rng);
        let tcfg = TrainConfig {
            steps: 60,
            batch: 4,
            seq: 32,
            log_every: 0,
            ..Default::default()
        };
        let report = train(&mut w, &corpus, &tcfg, 13);
        let first: f64 = report.losses[..5].iter().sum::<f64>() / 5.0;
        let last: f64 = report.losses[report.losses.len() - 5..].iter().sum::<f64>() / 5.0;
        // Uniform is ln(256) ≈ 5.55; must have learned something real.
        assert!(first > 4.0, "first {first}");
        assert!(last < first - 1.0, "no learning: first {first} last {last}");
    }

    #[test]
    fn training_is_deterministic() {
        let corpus = Corpus::synthetic(21, Domain::Calib, 16 * 1024);
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let run = || {
            let mut rng = Rng::new(5);
            let mut w = Weights::init_training(cfg, &mut rng);
            let tcfg = TrainConfig { steps: 5, batch: 2, seq: 16, log_every: 0, ..Default::default() };
            train(&mut w, &corpus, &tcfg, 6);
            w.layers[0].wq.data.clone()
        };
        assert_eq!(run(), run());
    }
}
