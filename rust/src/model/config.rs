//! Model configuration and the `ropt` scaling family — the in-repo stand-in
//! for the paper's OPT/Llama-2 model grid (see DESIGN.md §Substitutions).

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    /// Vocabulary size (char-level: 256).
    pub vocab: usize,
    /// Embedding dimension E.
    pub dim: usize,
    /// Attention heads (must divide `dim`).
    pub heads: usize,
    /// Transformer blocks.
    pub layers: usize,
    /// MLP hidden width F (usually 4·E).
    pub mlp: usize,
    /// Maximum sequence length (positional table size).
    pub max_seq: usize,
}

impl ModelConfig {
    /// Named presets mirroring the paper's model grid at laptop scale.
    pub fn preset(name: &str) -> Option<ModelConfig> {
        let c = |dim, heads, layers, mlp| ModelConfig {
            vocab: 256,
            dim,
            heads,
            layers,
            mlp,
            max_seq: 64,
        };
        Some(match name {
            // param counts below count transformer-block weights only
            "ropt-nano" => c(64, 2, 2, 256),    // ~0.15M
            "ropt-micro" => c(96, 3, 3, 384),   // ~0.5M
            "ropt-small" => c(128, 4, 4, 512),  // ~1.1M
            "ropt-med" => c(192, 6, 6, 768),    // ~3.7M
            "ropt-large" => c(256, 8, 8, 1024), // ~8.7M
            "ropt-xl" => c(384, 8, 10, 1536),   // ~24.5M
            _ => return None,
        })
    }

    /// All preset names in ascending size order.
    pub fn family() -> &'static [&'static str] {
        &["ropt-nano", "ropt-micro", "ropt-small", "ropt-med", "ropt-large", "ropt-xl"]
    }

    pub fn head_dim(&self) -> usize {
        debug_assert_eq!(self.dim % self.heads, 0);
        self.dim / self.heads
    }

    /// Number of quantizable (transformer-block) weight parameters.
    pub fn block_params(&self) -> usize {
        // per layer: 4 E×E attention mats + E×F + F×E
        self.layers * (4 * self.dim * self.dim + 2 * self.dim * self.mlp)
    }

    /// Total parameters including embeddings/LN/biases.
    pub fn total_params(&self) -> usize {
        let e = self.dim;
        let embed = self.vocab * e + self.max_seq * e;
        let per_layer = 4 * e * e + 2 * e * self.mlp // matrices
            + 4 * e + self.mlp + e                   // biases (q,k,v,o,b1,b2)
            + 4 * e; // ln1/ln2 gains+biases
        embed + self.layers * per_layer + 2 * e // final LN
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab", Json::num(self.vocab as f64)),
            ("dim", Json::num(self.dim as f64)),
            ("heads", Json::num(self.heads as f64)),
            ("layers", Json::num(self.layers as f64)),
            ("mlp", Json::num(self.mlp as f64)),
            ("max_seq", Json::num(self.max_seq as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig, String> {
        let grab = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| format!("config missing field {k:?}"))
        };
        let cfg = ModelConfig {
            vocab: grab("vocab")?,
            dim: grab("dim")?,
            heads: grab("heads")?,
            layers: grab("layers")?,
            mlp: grab("mlp")?,
            max_seq: grab("max_seq")?,
        };
        if cfg.dim % cfg.heads != 0 {
            return Err("heads must divide dim".into());
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve_and_divide() {
        for name in ModelConfig::family() {
            let c = ModelConfig::preset(name).unwrap();
            assert_eq!(c.dim % c.heads, 0, "{name}");
            assert!(c.block_params() > 0);
        }
        assert!(ModelConfig::preset("bogus").is_none());
    }

    #[test]
    fn family_sizes_ascend() {
        let sizes: Vec<usize> = ModelConfig::family()
            .iter()
            .map(|n| ModelConfig::preset(n).unwrap().block_params())
            .collect();
        for w in sizes.windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn json_roundtrip() {
        let c = ModelConfig::preset("ropt-small").unwrap();
        let back = ModelConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }
}
