//! Decoder-only transformer substrate: forward pass, cross-entropy loss
//! and full manual reverse-mode backprop (the offline registry has no
//! autograd — and the paper's Algorithm 1 needs gradients of arbitrary
//! projections of the output, not just the loss).
//!
//! Layout: pre-LN GPT. `X @ W + b` convention with W stored (d_in×d_out).
//! A batch of B sequences of length T is processed as a stacked
//! (B·T)×E activation matrix; attention runs per (sequence, head).
//!
//! Gradients are returned in a `Weights`-shaped container (`Grads`), so
//! the Adam trainer and the Radio gradient-variance accumulator share the
//! same plumbing.

use crate::model::tensor::Tensor;
use crate::model::weights::{Role, Weights};

/// Gradient container: same shape as the weights.
pub type Grads = Weights;

const LN_EPS: f32 = 1e-5;

/// Per-layer forward cache needed by backward.
pub struct LayerCache {
    /// Residual-stream input to the block (pre-LN1), (N×E).
    pub x_in: Tensor,
    /// LN1 output = input to Q/K/V projections.
    pub a: Tensor,
    pub ln1_xhat: Tensor,
    pub ln1_rstd: Vec<f32>,
    pub q: Tensor,
    pub k: Tensor,
    pub v: Tensor,
    /// Softmax probabilities per (batch, head): B·H tensors of T×T.
    pub probs: Vec<Tensor>,
    /// Concatenated attention context (input to Wo), (N×E).
    pub ctx: Tensor,
    /// After attention residual (pre-LN2), (N×E).
    pub x_mid: Tensor,
    /// LN2 output = input to W1.
    pub bn: Tensor,
    pub ln2_xhat: Tensor,
    pub ln2_rstd: Vec<f32>,
    /// Pre-GELU activations, (N×F).
    pub u: Tensor,
    /// Post-GELU = input to W2, (N×F).
    pub h: Tensor,
}

/// Whole-model forward cache.
pub struct Cache {
    pub batch: usize,
    pub seq: usize,
    pub tokens: Vec<u32>,
    pub layers: Vec<LayerCache>,
    /// Input to the final LN (N×E).
    pub x_final: Tensor,
    pub lnf_xhat: Tensor,
    pub lnf_rstd: Vec<f32>,
    /// Final LN output Z (the paper's next-token embeddings), (N×E).
    pub z: Tensor,
}

impl Cache {
    /// Column means of the input activations feeding the given matrix —
    /// the `X̄_n` of the paper's bias correction.
    pub fn input_means(&self, layer: usize, role: Role) -> Vec<f32> {
        let t = match role {
            Role::Q | Role::K | Role::V => &self.layers[layer].a,
            Role::O => &self.layers[layer].ctx,
            Role::Up => &self.layers[layer].bn,
            Role::Down => &self.layers[layer].h,
        };
        let mut mu = vec![0f32; t.cols];
        for r in 0..t.rows {
            for (m, &x) in mu.iter_mut().zip(t.row(r)) {
                *m += x;
            }
        }
        let inv = 1.0 / t.rows as f32;
        for m in mu.iter_mut() {
            *m *= inv;
        }
        mu
    }

    /// Per-channel second moments and absolute maxima of the input
    /// activations feeding the given matrix — the activation-side
    /// statistics the joint weight+activation allocator consumes
    /// (`E[x²]` drives the rate-distortion sensitivity, absmax the
    /// static quantizer scale). One pass over the same tensor
    /// [`Cache::input_means`] reads.
    pub fn input_moments(&self, layer: usize, role: Role) -> (Vec<f32>, Vec<f32>) {
        let t = match role {
            Role::Q | Role::K | Role::V => &self.layers[layer].a,
            Role::O => &self.layers[layer].ctx,
            Role::Up => &self.layers[layer].bn,
            Role::Down => &self.layers[layer].h,
        };
        let mut sq = vec![0f32; t.cols];
        let mut amax = vec![0f32; t.cols];
        for r in 0..t.rows {
            for ((s, m), &x) in sq.iter_mut().zip(amax.iter_mut()).zip(t.row(r)) {
                *s += x * x;
                *m = m.max(x.abs());
            }
        }
        let inv = 1.0 / t.rows as f32;
        for s in sq.iter_mut() {
            *s *= inv;
        }
        (sq, amax)
    }
}

// ---------------------------------------------------------------- forward

fn layer_norm(x: &Tensor, g: &[f32], b: &[f32]) -> (Tensor, Tensor, Vec<f32>) {
    let (n, e) = (x.rows, x.cols);
    let mut out = Tensor::zeros(n, e);
    let mut xhat = Tensor::zeros(n, e);
    let mut rstd = vec![0f32; n];
    for r in 0..n {
        let row = x.row(r);
        let mu = row.iter().sum::<f32>() / e as f32;
        let var = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / e as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        rstd[r] = rs;
        let xh = xhat.row_mut(r);
        let o = &mut out.data[r * e..(r + 1) * e];
        for j in 0..e {
            let h = (row[j] - mu) * rs;
            xh[j] = h;
            o[j] = g[j] * h + b[j];
        }
    }
    (out, xhat, rstd)
}

fn layer_norm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    rstd: &[f32],
    g: &[f32],
    dg: &mut [f32],
    db: &mut [f32],
) -> Tensor {
    let (n, e) = (dy.rows, dy.cols);
    let mut dx = Tensor::zeros(n, e);
    for r in 0..n {
        let dyr = dy.row(r);
        let xh = xhat.row(r);
        let mut sum_gdy = 0f32;
        let mut sum_gdy_xh = 0f32;
        for j in 0..e {
            let gd = g[j] * dyr[j];
            sum_gdy += gd;
            sum_gdy_xh += gd * xh[j];
            dg[j] += dyr[j] * xh[j];
            db[j] += dyr[j];
        }
        let inv_e = 1.0 / e as f32;
        let dxr = dx.row_mut(r);
        for j in 0..e {
            let gd = g[j] * dyr[j];
            dxr[j] = (gd - sum_gdy * inv_e - xh[j] * sum_gdy_xh * inv_e) * rstd[r];
        }
    }
    dx
}

const GELU_A: f32 = 0.797_884_6; // sqrt(2/pi)
const GELU_C: f32 = 0.044_715;

#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (GELU_A * (x + GELU_C * x * x * x)).tanh())
}

#[inline]
fn gelu_grad(x: f32) -> f32 {
    let t = (GELU_A * (x + GELU_C * x * x * x)).tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * GELU_A * (1.0 + 3.0 * GELU_C * x * x)
}

/// Copy the (batch b, head h) block of a stacked (B·T)×E matrix into T×dh.
fn head_block(x: &Tensor, b: usize, h: usize, t: usize, dh: usize) -> Tensor {
    let mut out = Tensor::zeros(t, dh);
    for i in 0..t {
        let src = &x.row(b * t + i)[h * dh..(h + 1) * dh];
        out.row_mut(i).copy_from_slice(src);
    }
    out
}

fn add_head_block(x: &mut Tensor, src: &Tensor, b: usize, h: usize, t: usize, dh: usize) {
    for i in 0..t {
        let dst = &mut x.row_mut(b * t + i)[h * dh..(h + 1) * dh];
        for (d, &s) in dst.iter_mut().zip(src.row(i)) {
            *d += s;
        }
    }
}

/// Causal softmax(QKᵀ/√dh)·V for one (batch, head); returns (ctx, probs).
fn attention_head(q: &Tensor, k: &Tensor, v: &Tensor) -> (Tensor, Tensor) {
    let (t, dh) = (q.rows, q.cols);
    let scale = 1.0 / (dh as f32).sqrt();
    let mut probs = Tensor::zeros(t, t);
    for i in 0..t {
        // scores for j <= i
        let qi = q.row(i);
        let mut maxs = f32::NEG_INFINITY;
        let mut scores = vec![0f32; i + 1];
        for (j, sj) in scores.iter_mut().enumerate() {
            let s = crate::stats::linalg::dot(qi, k.row(j)) as f32 * scale;
            *sj = s;
            maxs = maxs.max(s);
        }
        let mut denom = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - maxs).exp();
            denom += *s;
        }
        let pr = probs.row_mut(i);
        for (j, &s) in scores.iter().enumerate() {
            pr[j] = s / denom;
        }
    }
    let ctx = probs.matmul(v);
    (ctx, probs)
}

/// Row source for cached attention: hands back the head slice
/// `[h0, h0 + buf.len())` of cache row `ti`. Dense backings return a
/// borrow straight out of their storage (zero copy — the hot decode
/// path pays nothing for the abstraction); quantized KV pages decode
/// codes into the caller's scratch `buf` and return that — the fused
/// dequant that lets [`attend_kv`] read packed pages without densifying
/// a lane's cache. The unified `'a` ties the return to whichever of
/// `self`/`buf` actually backs it.
pub trait KvRows {
    fn head_slice<'a>(&'a self, ti: usize, h0: usize, buf: &'a mut [f32]) -> &'a [f32];
}

/// A flat row-major (≥t×width) f32 buffer with head-interleaved columns
/// — the [`KvRows`] backing for contiguous dense caches.
pub struct FlatKvRows<'b> {
    pub buf: &'b [f32],
    pub width: usize,
}

impl KvRows for FlatKvRows<'_> {
    #[inline]
    fn head_slice<'a>(&'a self, ti: usize, h0: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let off = ti * self.width + h0;
        &self.buf[off..off + buf.len()]
    }
}

/// Causal attention for ONE query position against a cached K/V prefix —
/// the helper shared by the decode engine's `step_batch` and chunked
/// `prefill_batch` paths. Both lean on it accumulating in exactly this
/// order (f32 score dots, max-subtracted softmax, value accumulation in
/// cache order) for their bit-identity contract: a position's context
/// depends only on its query and the cache contents up to `t`, never on
/// how many positions were fed in the same engine call, how the cache
/// rows are paged, or what backing stores them ([`KvRows`] impls only
/// materialize values; the dot/softmax op order is fixed here). The
/// training path's [`attention_head`] keeps its own f64-dot variant and
/// agrees with this one only to rounding tolerance.
///
/// `q` is one e-wide query row; the window is cache rows `0..t`.
pub fn attend_kv(
    q: &[f32],
    k: &impl KvRows,
    v: &impl KvRows,
    t: usize,
    e: usize,
    heads: usize,
    dh: usize,
) -> Vec<f32> {
    debug_assert_eq!(q.len(), e);
    let mut ctx = vec![0f32; e];
    let mut buf = vec![0f32; dh];
    let scale = 1.0 / (dh as f32).sqrt();
    for h in 0..heads {
        let qh = &q[h * dh..(h + 1) * dh];
        let mut scores = Vec::with_capacity(t);
        let mut maxs = f32::NEG_INFINITY;
        for ti in 0..t {
            let kh = k.head_slice(ti, h * dh, &mut buf);
            let s: f32 = qh.iter().zip(kh).map(|(&a, &b)| a * b).sum::<f32>() * scale;
            scores.push(s);
            maxs = maxs.max(s);
        }
        let mut denom = 0f32;
        for s in scores.iter_mut() {
            *s = (*s - maxs).exp();
            denom += *s;
        }
        for ti in 0..t {
            let p = scores[ti] / denom;
            let vh = v.head_slice(ti, h * dh, &mut buf);
            let ctx_h = &mut ctx[h * dh..(h + 1) * dh];
            for (c, &vv) in ctx_h.iter_mut().zip(vh) {
                *c += p * vv;
            }
        }
    }
    ctx
}

/// [`attend_kv`] over flat contiguous (≥t×e) K/V buffers — the
/// historical entry point, kept so callers with plain slices (and the
/// training-path agreement test) don't build views by hand.
pub fn attend_cached(
    q: &[f32],
    kbuf: &[f32],
    vbuf: &[f32],
    t: usize,
    e: usize,
    heads: usize,
    dh: usize,
) -> Vec<f32> {
    debug_assert!(kbuf.len() >= t * e && vbuf.len() >= t * e);
    attend_kv(
        q,
        &FlatKvRows { buf: kbuf, width: e },
        &FlatKvRows { buf: vbuf, width: e },
        t,
        e,
        heads,
        dh,
    )
}

fn attention_head_backward(
    dctx: &Tensor,
    probs: &Tensor,
    q: &Tensor,
    k: &Tensor,
    v: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (t, dh) = (q.rows, q.cols);
    let scale = 1.0 / (dh as f32).sqrt();
    // dP = dctx·Vᵀ ; dV = Pᵀ·dctx
    let dp = dctx.matmul_t(v);
    let dv = probs.t_matmul(dctx);
    // softmax backward, row-wise (masked entries have P = 0 ⇒ dS = 0).
    let mut ds = Tensor::zeros(t, t);
    for i in 0..t {
        let pr = probs.row(i);
        let dpr = dp.row(i);
        let dot: f32 = pr.iter().zip(dpr).map(|(&p, &d)| p * d).sum();
        let dsr = ds.row_mut(i);
        for j in 0..=i {
            dsr[j] = pr[j] * (dpr[j] - dot);
        }
    }
    // dQ = dS·K·scale ; dK = dSᵀ·Q·scale
    let mut dq = ds.matmul(k);
    dq.scale(scale);
    let mut dk = ds.t_matmul(q);
    dk.scale(scale);
    (dq, dk, dv)
}

/// Run the model forward, returning the final-LN output `Z` (the paper's
/// next-token embedding matrix, stacked (B·T)×E) and the cache.
pub fn forward(w: &Weights, tokens: &[u32], batch: usize, seq: usize) -> Cache {
    let cfg = &w.config;
    assert_eq!(tokens.len(), batch * seq);
    assert!(seq <= cfg.max_seq, "sequence longer than positional table");
    let (e, hds, dh) = (cfg.dim, cfg.heads, cfg.head_dim());
    let n = batch * seq;

    // Embedding + positions.
    let mut x = Tensor::zeros(n, e);
    for (i, &tok) in tokens.iter().enumerate() {
        let trow = w.embed.row(tok as usize % cfg.vocab);
        let prow = w.pos.row(i % seq);
        let dst = x.row_mut(i);
        for j in 0..e {
            dst[j] = trow[j] + prow[j];
        }
    }

    let mut layer_caches = Vec::with_capacity(cfg.layers);
    for l in &w.layers {
        let x_in = x.clone();
        let (a, ln1_xhat, ln1_rstd) = layer_norm(&x, &l.ln1_g, &l.ln1_b);
        let mut q = a.matmul(&l.wq);
        q.add_bias(&l.bq);
        let mut k = a.matmul(&l.wk);
        k.add_bias(&l.bk);
        let mut v = a.matmul(&l.wv);
        v.add_bias(&l.bv);

        let mut ctx = Tensor::zeros(n, e);
        let mut probs = Vec::with_capacity(batch * hds);
        for b in 0..batch {
            for h in 0..hds {
                let qh = head_block(&q, b, h, seq, dh);
                let kh = head_block(&k, b, h, seq, dh);
                let vh = head_block(&v, b, h, seq, dh);
                let (ctx_h, p) = attention_head(&qh, &kh, &vh);
                add_head_block(&mut ctx, &ctx_h, b, h, seq, dh);
                probs.push(p);
            }
        }
        let mut attn_out = ctx.matmul(&l.wo);
        attn_out.add_bias(&l.bo);
        x.add_assign(&attn_out);
        let x_mid = x.clone();

        let (bn, ln2_xhat, ln2_rstd) = layer_norm(&x, &l.ln2_g, &l.ln2_b);
        let mut u = bn.matmul(&l.w1);
        u.add_bias(&l.b1);
        let mut hmat = u.clone();
        for vv in hmat.data.iter_mut() {
            *vv = gelu(*vv);
        }
        let mut mlp_out = hmat.matmul(&l.w2);
        mlp_out.add_bias(&l.b2);
        x.add_assign(&mlp_out);

        layer_caches.push(LayerCache {
            x_in,
            a,
            ln1_xhat,
            ln1_rstd,
            q,
            k,
            v,
            probs,
            ctx,
            x_mid,
            bn,
            ln2_xhat,
            ln2_rstd,
            u,
            h: hmat,
        });
    }

    let x_final = x.clone();
    let (z, lnf_xhat, lnf_rstd) = layer_norm(&x, &w.lnf_g, &w.lnf_b);
    Cache {
        batch,
        seq,
        tokens: tokens.to_vec(),
        layers: layer_caches,
        x_final,
        lnf_xhat,
        lnf_rstd,
        z,
    }
}

/// Logits via the tied head: Z @ Wembᵀ, (B·T)×V.
pub fn logits(w: &Weights, z: &Tensor) -> Tensor {
    z.matmul_t(&w.embed)
}

/// Mean cross-entropy over all positions + gradient wrt logits.
pub fn cross_entropy(logits: &Tensor, targets: &[u32]) -> (f64, Tensor) {
    let (n, v) = (logits.rows, logits.cols);
    assert_eq!(targets.len(), n);
    let mut dlogits = Tensor::zeros(n, v);
    let mut loss = 0f64;
    let invn = 1.0 / n as f32;
    for r in 0..n {
        let row = logits.row(r);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f64;
        for &x in row {
            denom += ((x - maxv) as f64).exp();
        }
        let tgt = targets[r] as usize % v;
        let logp = (row[tgt] - maxv) as f64 - denom.ln();
        loss -= logp;
        let dr = dlogits.row_mut(r);
        for j in 0..v {
            let p = (((row[j] - maxv) as f64).exp() / denom) as f32;
            dr[j] = (p - if j == tgt { 1.0 } else { 0.0 }) * invn;
        }
    }
    (loss / n as f64, dlogits)
}

/// Backprop from an arbitrary `dZ` (gradient wrt the final-LN output) down
/// to every parameter. Used directly by Radio's gradient-variance pass.
pub fn backward_from_dz(w: &Weights, cache: &Cache, dz: &Tensor) -> Grads {
    let cfg = &w.config;
    let (e, hds, dh) = (cfg.dim, cfg.heads, cfg.head_dim());
    let (batch, seq) = (cache.batch, cache.seq);
    let n = batch * seq;
    let mut g = Grads::zeros(*cfg);
    // Zero the LN gains that `zeros` initializes to one — this is a
    // gradient container.
    for l in g.layers.iter_mut() {
        l.ln1_g.fill(0.0);
        l.ln2_g.fill(0.0);
    }
    g.lnf_g.fill(0.0);

    // Final LN.
    let mut dx = layer_norm_backward(
        dz,
        &cache.lnf_xhat,
        &cache.lnf_rstd,
        &w.lnf_g,
        &mut g.lnf_g,
        &mut g.lnf_b,
    );

    for (li, l) in w.layers.iter().enumerate().rev() {
        let lc = &cache.layers[li];
        let gl = &mut g.layers[li];

        // ---- MLP branch: x = x_mid + W2·gelu(W1·LN2(x_mid)+b1)+b2
        // dx flows to both the residual and the MLP path.
        let dmlp_out = &dx; // (N×E)
        // W2: h (N×F) → out (N×E)
        let dw2 = lc.h.t_matmul(dmlp_out);
        gl.w2.add_assign(&dw2);
        for r in 0..n {
            for (bj, &d) in gl.b2.iter_mut().zip(dmlp_out.row(r)) {
                *bj += d;
            }
        }
        let mut dh_mat = dmlp_out.matmul_t(&l.w2); // (N×F)
        // GELU
        for (d, &uu) in dh_mat.data.iter_mut().zip(&lc.u.data) {
            *d *= gelu_grad(uu);
        }
        // W1: bn (N×E) → u (N×F)
        let dw1 = lc.bn.t_matmul(&dh_mat);
        gl.w1.add_assign(&dw1);
        for r in 0..n {
            for (bj, &d) in gl.b1.iter_mut().zip(dh_mat.row(r)) {
                *bj += d;
            }
        }
        let dbn = dh_mat.matmul_t(&l.w1); // (N×E)
        let dx_ln2 = layer_norm_backward(
            &dbn,
            &lc.ln2_xhat,
            &lc.ln2_rstd,
            &l.ln2_g,
            &mut gl.ln2_g,
            &mut gl.ln2_b,
        );
        // Residual join: d(x_mid) = dx (residual) + dx_ln2 (MLP path).
        dx.add_assign(&dx_ln2);

        // ---- Attention branch: x_mid = x_in + Wo·ctx + bo
        let dattn_out = &dx;
        let dwo = lc.ctx.t_matmul(dattn_out);
        gl.wo.add_assign(&dwo);
        for r in 0..n {
            for (bj, &d) in gl.bo.iter_mut().zip(dattn_out.row(r)) {
                *bj += d;
            }
        }
        let dctx = dattn_out.matmul_t(&l.wo); // (N×E)

        let mut dq = Tensor::zeros(n, e);
        let mut dk = Tensor::zeros(n, e);
        let mut dv = Tensor::zeros(n, e);
        for b in 0..batch {
            for h in 0..hds {
                let p = &lc.probs[b * hds + h];
                let qh = head_block(&lc.q, b, h, seq, dh);
                let kh = head_block(&lc.k, b, h, seq, dh);
                let vh = head_block(&lc.v, b, h, seq, dh);
                let dctx_h = head_block(&dctx, b, h, seq, dh);
                let (dqh, dkh, dvh) = attention_head_backward(&dctx_h, p, &qh, &kh, &vh);
                add_head_block(&mut dq, &dqh, b, h, seq, dh);
                add_head_block(&mut dk, &dkh, b, h, seq, dh);
                add_head_block(&mut dv, &dvh, b, h, seq, dh);
            }
        }

        // Projections Q/K/V from A.
        let dwq = lc.a.t_matmul(&dq);
        gl.wq.add_assign(&dwq);
        let dwk = lc.a.t_matmul(&dk);
        gl.wk.add_assign(&dwk);
        let dwv = lc.a.t_matmul(&dv);
        gl.wv.add_assign(&dwv);
        for r in 0..n {
            for (bj, &d) in gl.bq.iter_mut().zip(dq.row(r)) {
                *bj += d;
            }
            for (bj, &d) in gl.bk.iter_mut().zip(dk.row(r)) {
                *bj += d;
            }
            for (bj, &d) in gl.bv.iter_mut().zip(dv.row(r)) {
                *bj += d;
            }
        }
        let mut da = dq.matmul_t(&l.wq);
        da.add_assign(&dk.matmul_t(&l.wk));
        da.add_assign(&dv.matmul_t(&l.wv));
        let dx_ln1 = layer_norm_backward(
            &da,
            &lc.ln1_xhat,
            &lc.ln1_rstd,
            &l.ln1_g,
            &mut gl.ln1_g,
            &mut gl.ln1_b,
        );
        dx.add_assign(&dx_ln1);
        // dx now is the gradient wrt this block's input x_in; continue down.
    }

    // Embedding + positional gradients.
    for (i, &tok) in cache.tokens.iter().enumerate() {
        let drow = dx.row(i);
        let erow = g.embed.row_mut(tok as usize % cfg.vocab);
        for j in 0..e {
            erow[j] += drow[j];
        }
        let prow = g.pos.row_mut(i % seq);
        for j in 0..e {
            prow[j] += drow[j];
        }
    }
    g
}

/// Full training step gradient: forward, tied-head logits, cross-entropy,
/// backward. Returns (loss, grads).
pub fn loss_and_grads(
    w: &Weights,
    tokens: &[u32],
    targets: &[u32],
    batch: usize,
    seq: usize,
) -> (f64, Grads) {
    let cache = forward(w, tokens, batch, seq);
    let lg = logits(w, &cache.z);
    let (loss, dlogits) = cross_entropy(&lg, targets);
    // Head (tied): logits = Z·Wembᵀ ⇒ dZ = dlogits·Wemb, dWemb += dlogitsᵀ·Z.
    let dz = dlogits.matmul(&w.embed);
    let mut g = backward_from_dz(w, &cache, &dz);
    let dwemb = dlogits.t_matmul(&cache.z);
    g.embed.add_assign(&dwemb);
    (loss, g)
}

/// Evaluation-time loss (no gradients).
pub fn loss_only(w: &Weights, tokens: &[u32], targets: &[u32], batch: usize, seq: usize) -> f64 {
    let cache = forward(w, tokens, batch, seq);
    let lg = logits(w, &cache.z);
    let (n, v) = (lg.rows, lg.cols);
    let mut loss = 0f64;
    for r in 0..n {
        let row = lg.row(r);
        let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0f64;
        for &x in row {
            denom += ((x - maxv) as f64).exp();
        }
        let tgt = targets[r] as usize % v;
        loss -= (row[tgt] - maxv) as f64 - denom.ln();
    }
    loss / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 17, dim: 8, heads: 2, layers: 2, mlp: 16, max_seq: 6 }
    }

    fn rand_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<u32> {
        (0..n).map(|_| rng.below(vocab) as u32).collect()
    }

    #[test]
    fn forward_shapes() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(1);
        let w = Weights::init_training(cfg, &mut rng);
        let toks = rand_tokens(&mut rng, 2 * 5, cfg.vocab);
        let cache = forward(&w, &toks, 2, 5);
        assert_eq!(cache.z.rows, 10);
        assert_eq!(cache.z.cols, cfg.dim);
        let lg = logits(&w, &cache.z);
        assert_eq!(lg.cols, cfg.vocab);
    }

    #[test]
    fn causality_future_tokens_do_not_affect_past() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(2);
        let w = Weights::init_training(cfg, &mut rng);
        let mut t1 = rand_tokens(&mut rng, 6, cfg.vocab);
        let c1 = forward(&w, &t1, 1, 6);
        // Change the last token; logits for earlier positions must not move.
        t1[5] = (t1[5] + 1) % cfg.vocab as u32;
        let c2 = forward(&w, &t1, 1, 6);
        for pos in 0..5 {
            for j in 0..cfg.dim {
                assert!(
                    (c1.z.get(pos, j) - c2.z.get(pos, j)).abs() < 1e-6,
                    "pos {pos} leaked future info"
                );
            }
        }
        // Position 5 itself should change.
        let diff: f32 = (0..cfg.dim)
            .map(|j| (c1.z.get(5, j) - c2.z.get(5, j)).abs())
            .sum();
        assert!(diff > 1e-4);
    }

    #[test]
    fn cross_entropy_of_uniform_logits() {
        let n = 4;
        let v = 10;
        let lg = Tensor::zeros(n, v);
        let targets = vec![3u32; n];
        let (loss, dlg) = cross_entropy(&lg, &targets);
        assert!((loss - (v as f64).ln()).abs() < 1e-9);
        // Gradient sums to zero per row.
        for r in 0..n {
            let s: f32 = dlg.row(r).iter().sum();
            assert!(s.abs() < 1e-6);
        }
    }

    /// The critical test: analytic gradients vs central finite differences
    /// through the entire model (loss path), for a sample of parameters
    /// from every tensor class.
    #[test]
    fn grad_check_finite_difference() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(3);
        let mut w = Weights::init_training(cfg, &mut rng);
        // Make LN params non-trivial so their grads are exercised.
        for l in w.layers.iter_mut() {
            for v in l.ln1_g.iter_mut() {
                *v = 1.0 + rng.normal(0.0, 0.1) as f32;
            }
            for v in l.ln2_b.iter_mut() {
                *v = rng.normal(0.0, 0.1) as f32;
            }
        }
        let (batch, seq) = (2, 4);
        let toks = rand_tokens(&mut rng, batch * seq, cfg.vocab);
        let tgts = rand_tokens(&mut rng, batch * seq, cfg.vocab);

        let (_, grads) = loss_and_grads(&w, &toks, &tgts, batch, seq);

        // Probe a handful of coordinates in each parameter tensor.
        let eps = 1e-3f32;
        let mut check = |get: &dyn Fn(&Weights) -> f32,
                         set: &dyn Fn(&mut Weights, f32),
                         analytic: f32,
                         label: &str| {
            let orig = get(&w);
            let mut wp = w.clone();
            set(&mut wp, orig + eps);
            let lp = loss_only(&wp, &toks, &tgts, batch, seq);
            set(&mut wp, orig - eps);
            let lm = loss_only(&wp, &toks, &tgts, batch, seq);
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let denom = fd.abs().max(analytic.abs()).max(1e-4);
            assert!(
                (fd - analytic).abs() / denom < 0.08,
                "{label}: fd {fd} vs analytic {analytic}"
            );
        };

        // Attention weight.
        check(
            &|w| w.layers[0].wq.get(1, 2),
            &|w, v| w.layers[0].wq.set(1, 2, v),
            grads.layers[0].wq.get(1, 2),
            "wq[1,2]",
        );
        // MLP down-projection in the last layer.
        check(
            &|w| w.layers[1].w2.get(3, 1),
            &|w, v| w.layers[1].w2.set(3, 1, v),
            grads.layers[1].w2.get(3, 1),
            "w2[3,1]",
        );
        // Output projection.
        check(
            &|w| w.layers[0].wo.get(0, 5),
            &|w, v| w.layers[0].wo.set(0, 5, v),
            grads.layers[0].wo.get(0, 5),
            "wo[0,5]",
        );
        // Value projection.
        check(
            &|w| w.layers[1].wv.get(2, 2),
            &|w, v| w.layers[1].wv.set(2, 2, v),
            grads.layers[1].wv.get(2, 2),
            "wv[2,2]",
        );
        // Key projection.
        check(
            &|w| w.layers[0].wk.get(4, 4),
            &|w, v| w.layers[0].wk.set(4, 4, v),
            grads.layers[0].wk.get(4, 4),
            "wk[4,4]",
        );
        // MLP up bias.
        check(
            &|w| w.layers[0].b1[3],
            &|w, v| w.layers[0].b1[3] = v,
            grads.layers[0].b1[3],
            "b1[3]",
        );
        // LN gain and bias.
        check(
            &|w| w.layers[0].ln1_g[2],
            &|w, v| w.layers[0].ln1_g[2] = v,
            grads.layers[0].ln1_g[2],
            "ln1_g[2]",
        );
        check(
            &|w| w.lnf_b[1],
            &|w, v| w.lnf_b[1] = v,
            grads.lnf_b[1],
            "lnf_b[1]",
        );
        // Embedding row used by a token in the batch.
        let tok = toks[0] as usize;
        check(
            &|w| w.embed.get(tok, 0),
            &|w, v| {
                let c = w.embed.cols;
                w.embed.data[tok * c] = v;
            },
            grads.embed.get(tok, 0),
            "embed[tok,0]",
        );
        // Positional embedding.
        check(
            &|w| w.pos.get(1, 3),
            &|w, v| w.pos.set(1, 3, v),
            grads.pos.get(1, 3),
            "pos[1,3]",
        );
    }

    #[test]
    fn backward_from_dz_matches_projection_fd() {
        // Gradient of c = sᵀ·(Z·u) — exactly the Radio gradvar scalar —
        // checked against finite differences on one weight.
        let cfg = tiny_cfg();
        let mut rng = Rng::new(4);
        let w = Weights::init_training(cfg, &mut rng);
        let (batch, seq) = (1, 5);
        let toks = rand_tokens(&mut rng, batch * seq, cfg.vocab);
        let mut u = vec![0f32; cfg.dim];
        let mut s = vec![0f32; batch * seq];
        rng.fill_gauss(&mut u, 0.0, 1.0);
        rng.fill_sign(&mut s);

        let scalar = |w: &Weights| -> f64 {
            let c = forward(w, &toks, batch, seq);
            let mut acc = 0f64;
            for r in 0..c.z.rows {
                let zu: f64 = c.z.row(r).iter().zip(&u).map(|(&z, &uu)| (z * uu) as f64).sum();
                acc += s[r] as f64 * zu;
            }
            acc
        };

        let cache = forward(&w, &toks, batch, seq);
        // dZ[r][j] = s[r]·u[j]
        let mut dz = Tensor::zeros(batch * seq, cfg.dim);
        for r in 0..batch * seq {
            for j in 0..cfg.dim {
                dz.set(r, j, s[r] * u[j]);
            }
        }
        let grads = backward_from_dz(&w, &cache, &dz);

        let eps = 1e-3f32;
        let mut wp = w.clone();
        let orig = wp.layers[0].w1.get(2, 7);
        wp.layers[0].w1.set(2, 7, orig + eps);
        let cp = scalar(&wp);
        wp.layers[0].w1.set(2, 7, orig - eps);
        let cm = scalar(&wp);
        let fd = ((cp - cm) / (2.0 * eps as f64)) as f32;
        let an = grads.layers[0].w1.get(2, 7);
        assert!(
            (fd - an).abs() / fd.abs().max(an.abs()).max(1e-4) < 0.08,
            "fd {fd} vs analytic {an}"
        );
    }

    #[test]
    fn attend_cached_matches_training_attention() {
        // The engine-path helper must reproduce the training-path
        // attention (last row of a causal T×T block) to rounding: same
        // math, f32 vs f64 score accumulation.
        let mut rng = Rng::new(6);
        let (t, e, heads) = (5usize, 8usize, 2usize);
        let dh = e / heads;
        let mut q = Tensor::zeros(t, e);
        let mut k = Tensor::zeros(t, e);
        let mut v = Tensor::zeros(t, e);
        rng.fill_gauss(&mut q.data, 0.0, 1.0);
        rng.fill_gauss(&mut k.data, 0.0, 1.0);
        rng.fill_gauss(&mut v.data, 0.0, 1.0);
        let mut want = vec![0f32; e];
        for h in 0..heads {
            let qh = head_block(&q, 0, h, t, dh);
            let kh = head_block(&k, 0, h, t, dh);
            let vh = head_block(&v, 0, h, t, dh);
            let (ctx_h, _) = attention_head(&qh, &kh, &vh);
            want[h * dh..(h + 1) * dh].copy_from_slice(ctx_h.row(t - 1));
        }
        let got = attend_cached(q.row(t - 1), &k.data, &v.data, t, e, heads, dh);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    #[test]
    fn input_means_shapes() {
        let cfg = tiny_cfg();
        let mut rng = Rng::new(5);
        let w = Weights::init_training(cfg, &mut rng);
        let toks = rand_tokens(&mut rng, 6, cfg.vocab);
        let cache = forward(&w, &toks, 1, 6);
        assert_eq!(cache.input_means(0, Role::Q).len(), cfg.dim);
        assert_eq!(cache.input_means(1, Role::Down).len(), cfg.mlp);
    }
}
