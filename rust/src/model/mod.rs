//! The model substrate: a decoder-only transformer with manual backprop,
//! an Adam trainer, synthetic corpora, and the `ropt` scaling family —
//! everything the paper sources from HuggingFace/PyTorch, built in-repo.

pub mod config;
pub mod corpus;
pub mod tensor;
pub mod train;
pub mod transformer;
pub mod weights;

pub use config::ModelConfig;
pub use tensor::Tensor;
pub use weights::{MatId, Role, Weights};
