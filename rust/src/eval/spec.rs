//! Draft-quality qualification for speculative decoding: how often does
//! a low-rate draft's greedy choice match the high-rate target's?
//!
//! Greedy speculative acceptance is exactly top-1 agreement — a proposal
//! survives iff it IS the target's argmax — so measuring agreement over
//! evaluation windows predicts the serving acceptance rate *before*
//! committing a draft rate to a deployment. Qualify a `(draft, target)`
//! pair here, the way `perplexity_packed_kv` qualifies a KV rate: when
//! the draft rate drops too low its agreement (and therefore serving
//! acceptance) collapses and speculation degrades to pure overhead —
//! see DESIGN.md §Speculative decoding.

use crate::infer::engine::argmax;
use crate::infer::Engine;
use crate::model::corpus::Corpus;
use crate::util::threadpool::parallel_map;

/// Fraction of window positions where `draft` and `target` pick the same
/// greedy token, over `max_windows` evaluation windows of length `seq` —
/// the predicted speculative acceptance rate of this draft/target pair.
///
/// Both engines run their deployment numerics (packed bitstreams, their
/// own KV configurations) through one chunked forward per window
/// ([`Engine::prefill_positions`]), so the number reflects exactly the
/// comparison [`Engine::step_speculative`] performs per proposal.
/// Deterministic; an engine agrees with itself at exactly 1.0 (tested).
pub fn draft_agreement(
    target: &Engine,
    draft: &Engine,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> f64 {
    assert_eq!(
        target.config, draft.config,
        "draft and target must share one model shape (self-speculative)"
    );
    assert!(
        seq <= target.config.max_seq,
        "eval window {seq} longer than positional table {}",
        target.config.max_seq
    );
    let windows = corpus.eval_windows(seq, max_windows);
    assert!(!windows.is_empty(), "corpus too small for evaluation");
    let counts: Vec<(usize, usize)> = parallel_map(windows.len(), 1, |i| {
        let (toks, _) = &windows[i];
        let chunk: &[u32] = toks;
        let mut tc = target.new_cache();
        let mut dc = draft.new_cache();
        let tl = target
            .prefill_positions(&[chunk], std::slice::from_mut(&mut tc))
            .pop()
            .expect("one lane yields one logit list");
        let dl = draft
            .prefill_positions(&[chunk], std::slice::from_mut(&mut dc))
            .pop()
            .expect("one lane yields one logit list");
        let agree = tl.iter().zip(&dl).filter(|(t, d)| argmax(t) == argmax(d)).count();
        (agree, tl.len())
    });
    let agree: usize = counts.iter().map(|(a, _)| a).sum();
    let total: usize = counts.iter().map(|(_, n)| n).sum();
    agree as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn setup() -> (Engine, Corpus) {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(701);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let corpus = Corpus::synthetic(702, Domain::Calib, 8 * 1024);
        (Engine::from_dense(&w), corpus)
    }

    #[test]
    fn engine_fully_agrees_with_itself() {
        let (engine, corpus) = setup();
        // Same seed -> same weights, independent engine instance.
        let mut r = Rng::new(701);
        let twin = Engine::from_dense(&Weights::init_pretrained_like(engine.config, &mut r));
        let a = draft_agreement(&engine, &twin, &corpus, 16, 4);
        assert_eq!(a, 1.0, "identical weights must agree at every position");
    }

    #[test]
    fn agreement_orders_draft_rates() {
        // A higher-rate draft of the same model must agree with the
        // target at least as often as a 1-bit draft (which is near
        // garbage), and both land in [0, 1].
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(704);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let corpus = Corpus::synthetic(705, Domain::Calib, 8 * 1024);
        let target = Engine::from_dense(&w);
        let strong = Engine::from_quantized(&rtn_quantize_model(&w, 8, 8));
        let weak = Engine::from_quantized(&rtn_quantize_model(&w, 1, 8));
        let a_strong = draft_agreement(&target, &strong, &corpus, 16, 6);
        let a_weak = draft_agreement(&target, &weak, &corpus, 16, 6);
        assert!((0.0..=1.0).contains(&a_strong));
        assert!((0.0..=1.0).contains(&a_weak));
        assert!(
            a_strong >= a_weak,
            "8-bit draft ({a_strong}) should agree at least as often as 1-bit ({a_weak})"
        );
        assert!(a_strong > 0.5, "8-bit quantization barely perturbs greedy choices");
    }

    #[test]
    fn agreement_is_deterministic() {
        let (engine, corpus) = setup();
        let w2 = {
            let mut r = Rng::new(706);
            Weights::init_pretrained_like(engine.config, &mut r)
        };
        let other = Engine::from_dense(&w2);
        let a = draft_agreement(&engine, &other, &corpus, 16, 4);
        let b = draft_agreement(&engine, &other, &corpus, 16, 4);
        assert_eq!(a, b);
    }
}
