//! Perplexity evaluation over deterministic corpus windows — the paper's
//! headline metric (Tables 1, 2, 4, 5; Figures 4–5).
//!
//! Two routes to the number:
//! - [`perplexity`] / [`perplexity_quantized`]: the training-path forward
//!   over dense `Weights` (quantized models are densified first) — the
//!   historical reference path.
//! - [`perplexity_packed`]: drives each window through the inference
//!   engine's chunked prefill forward **directly off the packed
//!   bitstreams**, so evaluating a quantized model costs the packed
//!   container (plus one decode plan per matrix) instead of a full dense
//!   clone. See DESIGN.md §Prefill/decode split for when to use which.

use crate::infer::kv::KvCacheConfig;
use crate::infer::Engine;
use crate::model::corpus::Corpus;
use crate::model::transformer;
use crate::model::weights::Weights;
use crate::quant::activations::ActQuantSpec;
use crate::quant::format::QuantizedModel;
use crate::util::threadpool::parallel_map;

/// Perplexity of `w` on non-overlapping windows of `corpus`:
/// exp(mean NLL per token). `max_windows` caps evaluation cost.
pub fn perplexity(w: &Weights, corpus: &Corpus, seq: usize, max_windows: usize) -> f64 {
    let windows = corpus.eval_windows(seq, max_windows);
    assert!(!windows.is_empty(), "corpus too small for evaluation");
    // Each window is independent; parallelize across windows (the matmul
    // inside is itself threaded, so use coarse chunks).
    let losses: Vec<f64> = parallel_map(windows.len(), 4, |i| {
        let (toks, tgts) = &windows[i];
        transformer::loss_only(w, toks, tgts, 1, seq)
    });
    let mean = losses.iter().sum::<f64>() / losses.len() as f64;
    mean.exp()
}

/// Perplexity from a quantized model via the dense reference path
/// (dequantize once, then evaluate through the training forward).
pub fn perplexity_quantized(
    qm: &QuantizedModel,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> f64 {
    perplexity(&qm.to_weights(), corpus, seq, max_windows)
}

/// Perplexity from a quantized model **without densifying**: windows run
/// through [`Engine::window_nll`]'s chunked forward, every matmul
/// straight off the packed code streams. Peak memory is the packed
/// container + decode plans, not a dense `Weights` clone — on larger
/// models the difference is the whole dense model.
///
/// Numerics: the engine forward accumulates attention scores in f32
/// where the training forward uses f64 (and its GEMM op order differs),
/// so this agrees with [`perplexity_quantized`] on the same model to
/// rounding tolerance — ~1e-3 relative on the `ropt` family — not
/// bit-for-bit. The tolerance is pinned by a test and documented in
/// DESIGN.md §Prefill/decode split.
pub fn perplexity_packed(
    qm: &QuantizedModel,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> f64 {
    perplexity_engine(&Engine::from_quantized(qm), corpus, seq, max_windows)
}

/// [`perplexity_packed`] with an explicit KV cache configuration — the
/// tolerance check for quantized-KV serving: evaluate the same packed
/// model with dense and quantized caches and compare. Windows run the
/// exact deployment numerics (paged cache, fused page dequant in
/// attention). With allocator-chosen specs at ≥ 4 average KV bits the
/// quantized number tracks the dense one within ~2% relative on the
/// `ropt` family (pinned at 5% by a test and documented in DESIGN.md
/// §KV cache); lower KV rates trade accuracy for resident lanes and
/// should be qualified with this function before deployment.
pub fn perplexity_packed_kv(
    qm: &QuantizedModel,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
    kv: &KvCacheConfig,
) -> f64 {
    let engine = Engine::from_quantized(qm).with_kv_config(kv.clone());
    perplexity_engine(&engine, corpus, seq, max_windows)
}

/// [`perplexity_packed`] with an explicit activation-quantization spec —
/// the accuracy gate for the fully-integer W·A path: evaluate the same
/// packed model with f32 and quantized activations and compare. Note
/// that [`perplexity_packed`] already honors a spec *persisted in the
/// container* (`qm.act_quant`); this entry point overrides it, which is
/// how the W·A benchmark sweeps activation depths off one container. At
/// ≥ 8 activation bits the drift stays within 5% relative of the
/// f32-activation number (pinned by a test and documented in DESIGN.md
/// §Activation quantization); 4-bit activations trade more accuracy for
/// bandwidth and should be qualified with this function first.
pub fn perplexity_packed_act(
    qm: &QuantizedModel,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
    spec: &ActQuantSpec,
) -> f64 {
    let engine = Engine::from_quantized(qm).with_act_quant(spec);
    perplexity_engine(&engine, corpus, seq, max_windows)
}

/// Shared engine-path evaluation loop (any weights backing, any KV cache
/// configuration — whatever the engine was built with).
pub fn perplexity_engine(engine: &Engine, corpus: &Corpus, seq: usize, max_windows: usize) -> f64 {
    assert!(
        seq <= engine.config.max_seq,
        "eval window {seq} longer than positional table {}",
        engine.config.max_seq
    );
    let windows = corpus.eval_windows(seq, max_windows);
    assert!(!windows.is_empty(), "corpus too small for evaluation");
    let losses: Vec<f64> = parallel_map(windows.len(), 4, |i| {
        let (toks, tgts) = &windows[i];
        engine.window_nll(toks, tgts)
    });
    let mean = losses.iter().sum::<f64>() / losses.len() as f64;
    mean.exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;
    use crate::util::rng::Rng;

    #[test]
    fn random_model_ppl_near_vocab_size() {
        // An untrained model ≈ uniform predictor: PPL ≈ vocab (256) —
        // a calibration check for the metric itself.
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(201);
        let w = Weights::init_training(cfg, &mut rng);
        let corpus = Corpus::synthetic(202, Domain::Calib, 8 * 1024);
        let ppl = perplexity(&w, &corpus, 32, 8);
        assert!(ppl > 120.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn ppl_is_deterministic() {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(203);
        let w = Weights::init_training(cfg, &mut rng);
        let corpus = Corpus::synthetic(204, Domain::Calib, 8 * 1024);
        let a = perplexity(&w, &corpus, 32, 6);
        let b = perplexity(&w, &corpus, 32, 6);
        assert_eq!(a, b);
    }

    #[test]
    fn packed_ppl_matches_dense_path_within_tolerance() {
        // The acceptance bar for the packed path: same model, same
        // windows, two numeric routes (engine f32-attention chunked
        // forward vs dense training forward) — values must agree to the
        // documented rounding tolerance with NO dense densification on
        // the packed side.
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(205);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = rtn_quantize_model(&w, 6, 8);
        let corpus = Corpus::synthetic(206, Domain::Calib, 8 * 1024);
        let dense = perplexity_quantized(&qm, &corpus, 32, 6);
        let packed = perplexity_packed(&qm, &corpus, 32, 6);
        assert!(
            (packed - dense).abs() <= 5e-3 * dense,
            "packed {packed} vs dense {dense}: beyond documented tolerance"
        );
    }

    #[test]
    fn quantized_kv_ppl_within_documented_tolerance_of_dense_kv() {
        // The serve-time acceptance bar: the SAME packed model evaluated
        // with an allocator-chosen quantized KV cache must track the
        // dense-KV number within the documented 5% relative tolerance
        // (observed ~2% at ≥4 average KV bits), and higher KV rates must
        // not be (meaningfully) worse than lower ones.
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(209);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = rtn_quantize_model(&w, 6, 8);
        let corpus = Corpus::synthetic(210, Domain::Calib, 8 * 1024);
        let dense = perplexity_packed(&qm, &corpus, 32, 6);
        let engine = Engine::from_quantized(&qm);
        for target in [4.0, 8.0] {
            let spec = crate::coordinator::kvquant::kv_spec_for(
                &engine, &corpus, 32, 4, target, 8,
            );
            let kvcfg = KvCacheConfig::quantized(spec);
            let quant = perplexity_packed_kv(&qm, &corpus, 32, 6, &kvcfg);
            assert!(
                (quant - dense).abs() <= 5e-2 * dense,
                "{target}-bit KV ppl {quant} vs dense-KV {dense}: beyond documented tolerance"
            );
        }
        // Dense-KV via the explicit-config entry point is the packed
        // path exactly.
        let via_cfg = perplexity_packed_kv(&qm, &corpus, 32, 6, &KvCacheConfig::dense());
        assert_eq!(via_cfg, dense);
    }

    #[test]
    fn act_quantized_ppl_within_documented_tolerance_of_f32_activations() {
        // The W·A acceptance bar (ISSUE 7): the SAME packed model
        // evaluated with 8-bit per-token activation quantization must
        // track the f32-activation perplexity within 5% relative, and a
        // persisted spec must produce the identical number through the
        // automatic [`perplexity_packed`] route.
        use crate::quant::activations::ActScalePolicy;
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(211);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = rtn_quantize_model(&w, 6, 8); // Uniform mode → integer tiles
        let corpus = Corpus::synthetic(212, Domain::Calib, 8 * 1024);
        let f32_ppl = perplexity_packed(&qm, &corpus, 32, 6);
        let ids: Vec<_> = qm.packed.iter().map(|(id, _)| *id).collect();
        let spec = ActQuantSpec::uniform(&ids, 8, ActScalePolicy::PerToken, 1.0);
        let int_ppl = perplexity_packed_act(&qm, &corpus, 32, 6, &spec);
        assert!(
            (int_ppl - f32_ppl).abs() <= 5e-2 * f32_ppl,
            "8-bit-activation ppl {int_ppl} vs f32-activation {f32_ppl}: beyond 5% gate"
        );
        // Same spec persisted in the container: the automatic route must
        // agree exactly (same engine configuration, same windows).
        let mut with_spec = rtn_quantize_model(&w, 6, 8);
        with_spec.act_quant = Some(spec);
        let auto = perplexity_packed(&with_spec, &corpus, 32, 6);
        assert_eq!(auto, int_ppl, "persisted spec must drive the same numerics");
    }

    #[test]
    fn packed_ppl_is_deterministic() {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(207);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = rtn_quantize_model(&w, 5, 8);
        let corpus = Corpus::synthetic(208, Domain::Calib, 8 * 1024);
        let a = perplexity_packed(&qm, &corpus, 32, 4);
        let b = perplexity_packed(&qm, &corpus, 32, 4);
        assert_eq!(a, b);
    }
}
