//! Perplexity evaluation over deterministic corpus windows — the paper's
//! headline metric (Tables 1, 2, 4, 5; Figures 4–5).

use crate::model::corpus::Corpus;
use crate::model::transformer;
use crate::model::weights::Weights;
use crate::util::threadpool::parallel_map;

/// Perplexity of `w` on non-overlapping windows of `corpus`:
/// exp(mean NLL per token). `max_windows` caps evaluation cost.
pub fn perplexity(w: &Weights, corpus: &Corpus, seq: usize, max_windows: usize) -> f64 {
    let windows = corpus.eval_windows(seq, max_windows);
    assert!(!windows.is_empty(), "corpus too small for evaluation");
    // Each window is independent; parallelize across windows (the matmul
    // inside is itself threaded, so use coarse chunks).
    let losses: Vec<f64> = parallel_map(windows.len(), 4, |i| {
        let (toks, tgts) = &windows[i];
        transformer::loss_only(w, toks, tgts, 1, seq)
    });
    let mean = losses.iter().sum::<f64>() / losses.len() as f64;
    mean.exp()
}

/// Perplexity from a quantized model (dequantize once, then evaluate).
pub fn perplexity_quantized(
    qm: &crate::quant::format::QuantizedModel,
    corpus: &Corpus,
    seq: usize,
    max_windows: usize,
) -> f64 {
    perplexity(&qm.to_weights(), corpus, seq, max_windows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;
    use crate::util::rng::Rng;

    #[test]
    fn random_model_ppl_near_vocab_size() {
        // An untrained model ≈ uniform predictor: PPL ≈ vocab (256) —
        // a calibration check for the metric itself.
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(201);
        let w = Weights::init_training(cfg, &mut rng);
        let corpus = Corpus::synthetic(202, Domain::Calib, 8 * 1024);
        let ppl = perplexity(&w, &corpus, 32, 8);
        assert!(ppl > 120.0 && ppl < 400.0, "ppl {ppl}");
    }

    #[test]
    fn ppl_is_deterministic() {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(203);
        let w = Weights::init_training(cfg, &mut rng);
        let corpus = Corpus::synthetic(204, Domain::Calib, 8 * 1024);
        let a = perplexity(&w, &corpus, 32, 6);
        let b = perplexity(&w, &corpus, 32, 6);
        assert_eq!(a, b);
    }
}
