//! Evaluation harnesses: perplexity (next-token prediction), the
//! synthetic downstream-task suite, and speculative draft-quality
//! qualification.

/// Perplexity over deterministic corpus windows (dense + packed paths).
pub mod perplexity;
/// Draft/target greedy-agreement qualification for speculative decoding.
pub mod spec;
/// Synthetic downstream-task proxies.
pub mod tasks;

pub use perplexity::{
    perplexity, perplexity_engine, perplexity_packed, perplexity_packed_act, perplexity_packed_kv,
    perplexity_quantized,
};
pub use spec::draft_agreement;
pub use tasks::{average_score, score_task, Task};
