//! Evaluation harnesses: perplexity (next-token prediction) and the
//! synthetic downstream-task suite.

pub mod perplexity;
pub mod tasks;

pub use perplexity::{
    perplexity, perplexity_engine, perplexity_packed, perplexity_packed_kv, perplexity_quantized,
};
pub use tasks::{average_score, score_task, Task};
