//! Synthetic downstream tasks — the stand-in for GSM8K/ARC/HellaSwag etc.
//! (Table 4b–c). The paper's observation is that quantized models with
//! near-identical perplexity can diverge sharply on *structured* tasks;
//! these tasks are derived from the synthetic corpus's latent structure
//! (lexicon membership, word completion, n-gram modes) and are scored by
//! exact match under greedy decoding, exactly like the 5-shot GSM8K
//! protocol scores final answers.

use std::collections::HashMap;

use crate::infer::engine::{argmax, Engine};
use crate::model::corpus::Corpus;
use crate::util::rng::Rng;

/// The synthetic downstream tasks (Table 4b–c stand-ins).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Given the first `k` characters of a frequent corpus word (with a
    /// leading space), greedily decode the rest: exact-match the word.
    WordCompletion,
    /// Given a frequent 6-gram's first 5 bytes, predict the 6th.
    NgramContinuation,
    /// Predict whether the next byte is a word boundary (space/period).
    BoundaryDetection,
}

impl Task {
    /// Every task, in scoring order.
    pub const ALL: [Task; 3] =
        [Task::WordCompletion, Task::NgramContinuation, Task::BoundaryDetection];

    /// Short display name used in tables.
    pub fn name(&self) -> &'static str {
        match self {
            Task::WordCompletion => "WordComplete",
            Task::NgramContinuation => "NgramCont",
            Task::BoundaryDetection => "Boundary",
        }
    }
}

/// A scored evaluation: fraction of exact matches in [0, 1].
pub fn score_task(engine: &Engine, corpus: &Corpus, task: Task, cases: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    match task {
        Task::WordCompletion => word_completion(engine, corpus, cases, &mut rng),
        Task::NgramContinuation => ngram_continuation(engine, corpus, cases, &mut rng),
        Task::BoundaryDetection => boundary_detection(engine, corpus, cases, &mut rng),
    }
}

/// Harvest frequent words (≥4 chars) from the corpus.
fn frequent_words(corpus: &Corpus, min_len: usize) -> Vec<(Vec<u8>, usize)> {
    let mut counts: HashMap<Vec<u8>, usize> = HashMap::new();
    for chunk in corpus.data.split(|&b| b == b' ' || b == b'.') {
        if chunk.len() >= min_len {
            *counts.entry(chunk.to_vec()).or_insert(0) += 1;
        }
    }
    let mut v: Vec<(Vec<u8>, usize)> = counts.into_iter().collect();
    v.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    v.truncate(200);
    v
}

fn word_completion(engine: &Engine, corpus: &Corpus, cases: usize, rng: &mut Rng) -> f64 {
    let words = frequent_words(corpus, 4);
    if words.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    let mut total = 0usize;
    for _ in 0..cases {
        let (word, _) = &words[rng.below(words.len().min(60))];
        let k = word.len() - 2; // reveal all but the last 2 chars
        // Context: a space then the prefix (mirrors corpus tokenization).
        let mut prompt: Vec<u32> = vec![b' ' as u32];
        prompt.extend(word[..k].iter().map(|&b| b as u32));
        let completion = engine.generate(&prompt, word.len() - k);
        let want: Vec<u32> = word[k..].iter().map(|&b| b as u32).collect();
        total += 1;
        if completion == want {
            hits += 1;
        }
    }
    hits as f64 / total.max(1) as f64
}

fn ngram_continuation(engine: &Engine, corpus: &Corpus, cases: usize, rng: &mut Rng) -> f64 {
    // Mode continuation of frequent 6-grams from the corpus itself.
    let n = 6usize;
    let mut counts: HashMap<&[u8], HashMap<u8, usize>> = HashMap::new();
    let data = &corpus.data;
    for i in 0..data.len().saturating_sub(n) {
        let ctx = &data[i..i + n - 1];
        *counts.entry(ctx).or_default().entry(data[i + n - 1]).or_insert(0) += 1;
    }
    let mut contexts: Vec<(&[u8], u8, usize)> = counts
        .iter()
        .map(|(ctx, nexts)| {
            let (&best, &cnt) = nexts.iter().max_by_key(|(_, &c)| c).unwrap();
            (*ctx, best, cnt)
        })
        .filter(|&(_, _, c)| c >= 3)
        .collect();
    contexts.sort_by(|a, b| b.2.cmp(&a.2).then(a.0.cmp(b.0)));
    contexts.truncate(300);
    if contexts.is_empty() {
        return 0.0;
    }
    let mut hits = 0usize;
    for _ in 0..cases {
        let (ctx, want, _) = contexts[rng.below(contexts.len())];
        let prompt: Vec<u32> = ctx.iter().map(|&b| b as u32).collect();
        let mut kv = engine.new_cache();
        let mut logits = vec![0f32; engine.config.vocab];
        for &t in &prompt {
            logits = engine.step(t, &mut kv);
        }
        if argmax(&logits) == want as usize {
            hits += 1;
        }
    }
    hits as f64 / cases.max(1) as f64
}

fn boundary_detection(engine: &Engine, corpus: &Corpus, cases: usize, rng: &mut Rng) -> f64 {
    // Sample positions; ask whether the model's argmax is a boundary char
    // exactly when the corpus has one.
    let data = &corpus.data;
    let ctx_len = 16usize;
    let mut hits = 0usize;
    for _ in 0..cases {
        let start = rng.below(data.len() - ctx_len - 1);
        let prompt: Vec<u32> = data[start..start + ctx_len].iter().map(|&b| b as u32).collect();
        let truth = {
            let b = data[start + ctx_len];
            b == b' ' || b == b'.'
        };
        let mut kv = engine.new_cache();
        let mut logits = vec![0f32; engine.config.vocab];
        for &t in &prompt {
            logits = engine.step(t, &mut kv);
        }
        let p = argmax(&logits) as u8;
        let pred = p == b' ' || p == b'.';
        if pred == truth {
            hits += 1;
        }
    }
    hits as f64 / cases.max(1) as f64
}

/// Average score across all tasks (the paper's "Average QA" column).
pub fn average_score(engine: &Engine, corpus: &Corpus, cases: usize, seed: u64) -> f64 {
    let scores: Vec<f64> = Task::ALL
        .iter()
        .map(|&t| score_task(engine, corpus, t, cases, seed))
        .collect();
    scores.iter().sum::<f64>() / scores.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;
    use crate::model::weights::Weights;

    #[test]
    fn scores_are_probabilities() {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(211);
        let w = Weights::init_training(cfg, &mut rng);
        let engine = Engine::from_dense(&w);
        let corpus = Corpus::synthetic(212, Domain::Calib, 16 * 1024);
        for task in Task::ALL {
            let s = score_task(&engine, &corpus, task, 10, 213);
            assert!((0.0..=1.0).contains(&s), "{task:?}: {s}");
        }
    }

    #[test]
    fn frequent_words_found() {
        let corpus = Corpus::synthetic(214, Domain::Calib, 32 * 1024);
        let words = frequent_words(&corpus, 4);
        assert!(words.len() > 20);
        assert!(words[0].1 >= words[1].1);
    }

    #[test]
    fn scoring_is_deterministic() {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 32 };
        let mut rng = Rng::new(215);
        let w = Weights::init_training(cfg, &mut rng);
        let engine = Engine::from_dense(&w);
        let corpus = Corpus::synthetic(216, Domain::Calib, 16 * 1024);
        let a = score_task(&engine, &corpus, Task::NgramContinuation, 8, 7);
        let b = score_task(&engine, &corpus, Task::NgramContinuation, 8, 7);
        assert_eq!(a, b);
    }
}
