//! Paged, optionally-quantized KV cache — the serving-side twin of the
//! paper's rate–distortion machinery. The seed's `KvCache` pre-reserved
//! `max_seq · dim` f32s per layer per lane, so KV memory — not compute —
//! capped how many sequences could be resident at once. This module
//! replaces it with fixed-size *pages* allocated lazily as a lane grows:
//!
//! - **Dense-f32 pages** hold raw K/V rows. Page boundaries are a pure
//!   storage concern: attention reads rows through [`KvRows`]
//!   (`transformer::attend_kv`), whose FP op order is independent of the
//!   backing, so paged-dense decode is bit-identical to the seed's flat
//!   cache (pinned by tests at page boundaries and mid-page splits).
//! - **Quantized pages** compand + bit-pack each appended row with a
//!   per-(layer, K|V) B-bit quantizer (`quant::companding` codes in a
//!   `quant::bitpack` LSB-first stream). Bit widths come from the same
//!   dual-ascent allocator the weights use, fed calibration-time KV
//!   variance stats (`coordinator::kvquant`): bits go to the layers
//!   whose cache rows vary most, exactly Eq. 6 applied at serve time.
//!   Attention dequantizes head slices on the fly (`deq = µ + S·lut[c]`)
//!   — pages are never densified into whole-lane buffers.
//!
//! [`KvPool`] is the admission-control side: a byte budget (from
//! `ServeConfig`) that the scheduler reserves a lane's *worst-case*
//! footprint against before admitting it, and releases at retirement.
//! Pages themselves are owned by each lane and allocated lazily, so the
//! heap footprint tracks actual sequence length while the budget
//! accounting is exhaustion-proof: nothing is ever evicted — admission
//! is simply deferred until a retiring lane frees budget. See DESIGN.md
//! §KV cache.

use crate::model::config::ModelConfig;
use crate::model::transformer::KvRows;
use crate::quant::bitpack::{f16_round, BitReader, BitWriter};
use crate::quant::companding;
use std::sync::Arc;

/// Default rows per page. Small enough that a short lane wastes at most
/// one mostly-empty page per layer per K/V tensor, large enough that
/// page headers and the page-lookup divide stay negligible next to a
/// row's `dim` floats.
pub const KV_PAGE_ROWS: usize = 16;

/// One quantizer: B-bit companded codes with FP16-rounded scale/mean
/// (the same `deq = mean + scale · lut[code]` factorization the packed
/// weight matrices use).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KvQuantParams {
    /// Code width in bits (clamped to [1, 8] by [`KvQuantParams::new`]).
    pub bits: u8,
    /// Dequantization scale S (FP16-rounded, strictly positive).
    pub scale: f32,
    /// Dequantization mean µ (FP16-rounded).
    pub mean: f32,
}

impl KvQuantParams {
    /// Clamps `bits` to [1, 8] (a 0-bit cache row would zero the keys it
    /// stores — pruning is meaningful for weights, fatal for attention)
    /// and FP16-rounds scale/mean with the same degenerate-scale guard
    /// as `PackedMatrix::pack`.
    pub fn new(bits: u8, scale: f32, mean: f32) -> KvQuantParams {
        let mut scale = f16_round(scale);
        if !scale.is_finite() || scale <= 0.0 {
            scale = 1e-6;
        }
        let mut mean = f16_round(mean);
        if !mean.is_finite() {
            mean = 0.0;
        }
        KvQuantParams { bits: bits.clamp(1, 8), scale, mean }
    }
}

/// Per-layer K and V quantizers — K and V get independent bit widths
/// (their variances differ, and the allocator exploits it).
#[derive(Clone, Debug, PartialEq)]
pub struct KvLayerQuant {
    /// Quantizer for the layer's key rows.
    pub k: KvQuantParams,
    /// Quantizer for the layer's value rows.
    pub v: KvQuantParams,
}

/// Bit-width/scale assignment for a whole model's KV cache.
#[derive(Clone, Debug, PartialEq)]
pub struct KvQuantSpec {
    /// One entry per transformer layer.
    pub layers: Vec<KvLayerQuant>,
}

impl KvQuantSpec {
    /// Flat spec: every layer, K and V alike, at `bits` with the given
    /// scale/mean (the ablation arm; the allocator produces mixed ones).
    pub fn uniform(layers: usize, bits: u8, scale: f32, mean: f32) -> KvQuantSpec {
        let p = KvQuantParams::new(bits, scale, mean);
        KvQuantSpec { layers: vec![KvLayerQuant { k: p, v: p }; layers] }
    }

    /// Average bits per stored KV value.
    pub fn mean_bits(&self) -> f64 {
        if self.layers.is_empty() {
            return 0.0;
        }
        let total: usize = self.layers.iter().map(|l| l.k.bits as usize + l.v.bits as usize).sum();
        total as f64 / (2 * self.layers.len()) as f64
    }
}

/// KV cache geometry + mode. Lives on the `Engine` (so `generate`,
/// `serve`, and evaluation all build identically-shaped caches — the
/// serve == generate token-identity invariant needs one source of
/// truth); `ServeConfig` contributes only the pool budget.
#[derive(Clone, Debug, PartialEq)]
pub struct KvCacheConfig {
    /// Rows per page (≥ 1).
    pub page_rows: usize,
    /// `Some` = quantized pages under this spec; `None` = dense f32.
    pub quant: Option<KvQuantSpec>,
    /// Admission-accounting emulation of the seed's flat cache: charge
    /// every lane the full `max_seq` footprint regardless of its actual
    /// need. Page allocation stays lazy — this only changes what
    /// [`lane_cost_bytes`] reports, so `bench_kv` can run the old
    /// reservation policy as its baseline arm.
    pub flat_reserve: bool,
}

impl KvCacheConfig {
    /// Paged dense f32 — the default; bit-identical to the seed cache.
    pub fn dense() -> KvCacheConfig {
        KvCacheConfig { page_rows: KV_PAGE_ROWS, quant: None, flat_reserve: false }
    }

    /// Dense with the seed's worst-case admission accounting (bench
    /// baseline arm).
    pub fn dense_flat() -> KvCacheConfig {
        KvCacheConfig { flat_reserve: true, ..KvCacheConfig::dense() }
    }

    /// Quantized pages under `spec`.
    pub fn quantized(spec: KvQuantSpec) -> KvCacheConfig {
        KvCacheConfig { page_rows: KV_PAGE_ROWS, quant: Some(spec), flat_reserve: false }
    }
}

impl Default for KvCacheConfig {
    fn default() -> KvCacheConfig {
        KvCacheConfig::dense()
    }
}

/// One bit-packed page: up to `page_rows` rows of `width` codes.
#[derive(Clone, Debug)]
struct QuantPage {
    words: Vec<u64>,
    rows: usize,
}

/// Truncate a quantized page to `rows` in place, masking the stale bits
/// of the final partial word. `BitWriter` appends OR into the open word,
/// so a later `push_row` must find zeros exactly where a never-extended
/// page would have them — the bit-identity contract both speculative
/// rollback and prefix-cache COW splits rely on.
fn truncate_quant_page(page: &mut QuantPage, rows: usize, width: usize, bits: u8) {
    if page.rows <= rows {
        return;
    }
    page.rows = rows;
    let bit_len = rows * width * bits as usize;
    page.words.truncate(bit_len.div_ceil(64));
    let rem = bit_len & 63;
    if rem != 0 {
        if let Some(w) = page.words.last_mut() {
            *w &= (1u64 << rem) - 1;
        }
    }
}

/// One immutable page shared between lanes (dense or quantized backing).
/// The `Arc` keeps the payload alive while any lane's store or any
/// cached [`KvPageSet`] still points at it; *budget* accounting (who is
/// charged for the bytes) is the prefix cache's job, not this type's.
#[derive(Clone, Debug)]
enum SharedPage {
    Dense(Arc<Vec<f32>>),
    Quant(Arc<QuantPage>),
}

impl SharedPage {
    /// Whole-page payload bytes — what admission accounting charges for
    /// a page regardless of fill (pages are charged whole everywhere).
    fn cost_bytes(&self) -> usize {
        match self {
            SharedPage::Dense(p) => p.len() * 4,
            SharedPage::Quant(p) => p.words.len() * 8,
        }
    }
}

/// One *full* page per (layer, K|V) store, exported from a lane's cache
/// — the immutable unit the cross-request prefix cache
/// (`infer::prefix`) shares between lanes. Page payloads sit behind
/// `Arc`s, so attaching a set to a new lane is a refcount bump, never a
/// copy, and a "write" below an attached page is a copy-out-and-detach
/// ([`KvCache::truncate_to`]) that can never disturb other readers.
#[derive(Clone, Debug)]
pub struct KvPageSet {
    k: Vec<SharedPage>,
    v: Vec<SharedPage>,
}

impl KvPageSet {
    /// Payload bytes across every page in the set — the amount the
    /// prefix cache charges the pool ONCE per cached set, however many
    /// lanes attach it. For full pages this equals the per-page share
    /// of [`lane_cost_bytes`] (both charge whole pages).
    pub fn cost_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(SharedPage::cost_bytes).sum()
    }
}

#[derive(Clone, Debug)]
enum StoreKind {
    Dense { pages: Vec<Vec<f32>> },
    Quant { pages: Vec<QuantPage>, params: KvQuantParams, lut: Vec<f32> },
}

/// Per-(layer, K|V) page store. Row space is `shared` (immutable,
/// refcounted, always full pages, always the strict prefix) followed by
/// lane-owned pages; all mutation targets the owned run.
#[derive(Clone, Debug)]
struct PageStore {
    page_rows: usize,
    width: usize,
    /// Attached prefix pages (possibly referenced by other lanes and by
    /// the prefix cache). Invariant: every entry holds exactly
    /// `page_rows` rows, and owned pages start page-aligned after them.
    shared: Vec<SharedPage>,
    kind: StoreKind,
}

impl PageStore {
    fn dense(page_rows: usize, width: usize) -> PageStore {
        PageStore { page_rows, width, shared: Vec::new(), kind: StoreKind::Dense { pages: Vec::new() } }
    }

    fn quant(page_rows: usize, width: usize, params: KvQuantParams) -> PageStore {
        let lut = companding::base_lut(params.bits);
        PageStore {
            page_rows,
            width,
            shared: Vec::new(),
            kind: StoreKind::Quant { pages: Vec::new(), params, lut },
        }
    }

    /// Rows covered by the attached shared run (always page-aligned).
    fn shared_rows(&self) -> usize {
        self.shared.len() * self.page_rows
    }

    /// Payload bytes of the attached shared run (charged to the prefix
    /// cache, not this lane).
    fn shared_bytes(&self) -> usize {
        self.shared.iter().map(SharedPage::cost_bytes).sum()
    }

    /// Attach one full shared page to the end of the shared run. Only
    /// legal while the store holds no lane-owned rows (shared pages form
    /// the strict prefix of the row space).
    fn attach_full(&mut self, page: &SharedPage) {
        debug_assert_eq!(self.rows(), self.shared_rows(), "attach after owned rows");
        match (&self.kind, page) {
            (StoreKind::Dense { .. }, SharedPage::Dense(p)) => {
                debug_assert_eq!(p.len(), self.page_rows * self.width, "shared pages must be full");
            }
            (StoreKind::Quant { .. }, SharedPage::Quant(p)) => {
                debug_assert_eq!(p.rows, self.page_rows, "shared pages must be full");
            }
            _ => panic!("shared page backing does not match the store mode"),
        }
        self.shared.push(page.clone());
    }

    /// Append a truncated copy of a shared page as a fresh lane-owned
    /// page — the copy half of a COW split. Dense pages copy the kept
    /// rows; quantized pages copy the kept words and mask the final
    /// partial word, exactly like an owned-tail truncation, so later
    /// appends are bit-identical to a never-shared cache. The owned run
    /// must currently end page-aligned (it does at both call sites:
    /// prefix attach and shared-run truncation).
    fn copy_in_tail(&mut self, src: &SharedPage, rows: usize) {
        debug_assert!(rows > 0 && rows <= self.page_rows);
        let (page_rows, width) = (self.page_rows, self.width);
        match (&mut self.kind, src) {
            (StoreKind::Dense { pages }, SharedPage::Dense(p)) => {
                let mut page = Vec::with_capacity(page_rows * width);
                page.extend_from_slice(&p[..rows * width]);
                pages.push(page);
            }
            (StoreKind::Quant { pages, params, .. }, SharedPage::Quant(p)) => {
                let mut page = QuantPage { words: p.words.clone(), rows: p.rows };
                truncate_quant_page(&mut page, rows, width, params.bits);
                pages.push(page);
            }
            _ => panic!("shared page backing does not match the store mode"),
        }
    }

    /// Export page `pi` (row-space index) as an immutable shared page:
    /// an already-shared page is a refcount bump; an owned page's
    /// payload is copied once, becoming the single immutable copy every
    /// later lane attaches.
    fn export_page(&self, pi: usize) -> SharedPage {
        if pi < self.shared.len() {
            return self.shared[pi].clone();
        }
        let oi = pi - self.shared.len();
        match &self.kind {
            StoreKind::Dense { pages } => SharedPage::Dense(Arc::new(pages[oi].clone())),
            StoreKind::Quant { pages, .. } => SharedPage::Quant(Arc::new(pages[oi].clone())),
        }
    }

    /// Append one e-wide row, opening a fresh page when the last is full.
    fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        let (page_rows, width) = (self.page_rows, self.width);
        match &mut self.kind {
            StoreKind::Dense { pages } => {
                let open = matches!(pages.last(), Some(p) if p.len() < page_rows * width);
                if !open {
                    pages.push(Vec::with_capacity(page_rows * width));
                }
                pages.last_mut().unwrap().extend_from_slice(row);
            }
            StoreKind::Quant { pages, params, .. } => {
                let open = matches!(pages.last(), Some(p) if p.rows < page_rows);
                if !open {
                    let cap = (page_rows * width * params.bits as usize).div_ceil(64);
                    pages.push(QuantPage { words: Vec::with_capacity(cap), rows: 0 });
                }
                let page = pages.last_mut().unwrap();
                let mut w = BitWriter {
                    words: std::mem::take(&mut page.words),
                    bit_len: page.rows * width * params.bits as usize,
                };
                for &x in row {
                    w.push(
                        companding::quantize_code(x, params.bits, params.scale, params.mean),
                        params.bits,
                    );
                }
                page.words = w.words;
                page.rows += 1;
            }
        }
    }

    /// Logical rows currently stored (shared prefix + owned).
    fn rows(&self) -> usize {
        let owned = match &self.kind {
            StoreKind::Dense { pages } => {
                pages.iter().map(|p| p.len()).sum::<usize>() / self.width.max(1)
            }
            StoreKind::Quant { pages, .. } => pages.iter().map(|p| p.rows).sum(),
        };
        self.shared_rows() + owned
    }

    /// Heap bytes actually allocated for *lane-owned* page payloads.
    /// Attached shared pages are excluded: their bytes are charged once,
    /// by the prefix cache, however many lanes attach them.
    fn allocated_bytes(&self) -> usize {
        match &self.kind {
            StoreKind::Dense { pages } => pages.iter().map(|p| p.capacity() * 4).sum(),
            StoreKind::Quant { pages, .. } => pages.iter().map(|p| p.words.capacity() * 8).sum(),
        }
    }

    /// Drop every row past `rows`: whole pages beyond the new tail are
    /// freed outright (their heap goes with them); the new tail page is
    /// truncated in place. Quantized tails also mask the stale bits of
    /// the final partial word — `BitWriter` appends OR into the open
    /// word, so a later `push_row` must find zeros exactly where a
    /// never-extended page would have them (the rollback bit-identity
    /// contract speculative decoding relies on). A cut below the shared
    /// run is a COW split: full shared pages below it stay attached, the
    /// divergence page is copied out as a truncated owned tail, and the
    /// shared suffix is detached (refcount drop) — never mutated.
    fn truncate_rows(&mut self, rows: usize) {
        if self.rows() <= rows {
            return;
        }
        let (page_rows, width) = (self.page_rows, self.width);
        let sr = self.shared_rows();
        if rows < sr {
            let keep_full = rows / page_rows;
            let tail_rows = rows % page_rows;
            let tail_src = if tail_rows > 0 { Some(self.shared[keep_full].clone()) } else { None };
            self.shared.truncate(keep_full);
            match &mut self.kind {
                StoreKind::Dense { pages } => pages.clear(),
                StoreKind::Quant { pages, .. } => pages.clear(),
            }
            if let Some(src) = tail_src {
                self.copy_in_tail(&src, tail_rows);
            }
            return;
        }
        let owned_rows = rows - sr;
        let keep_pages = owned_rows.div_ceil(page_rows);
        match &mut self.kind {
            StoreKind::Dense { pages } => {
                pages.truncate(keep_pages);
                if let Some(last) = pages.last_mut() {
                    let tail_rows = owned_rows - (keep_pages - 1) * page_rows;
                    last.truncate(tail_rows * width);
                }
            }
            StoreKind::Quant { pages, params, .. } => {
                let bits = params.bits;
                pages.truncate(keep_pages);
                if let Some(last) = pages.last_mut() {
                    let tail_rows = owned_rows - (keep_pages - 1) * page_rows;
                    truncate_quant_page(last, tail_rows, width, bits);
                }
            }
        }
    }

    fn view(&self) -> KvLayerRows<'_> {
        KvLayerRows { store: self }
    }

    /// Dequantized/densified logical contents, row-major — the test and
    /// calibration accessor. For dense stores this is the exact bytes
    /// appended (shared then owned pages, concatenated in order).
    fn flat(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows() * self.width);
        match &self.kind {
            StoreKind::Dense { pages } => {
                for p in &self.shared {
                    let SharedPage::Dense(p) = p else {
                        panic!("shared page backing does not match the store mode")
                    };
                    out.extend_from_slice(p);
                }
                for p in pages {
                    out.extend_from_slice(p);
                }
            }
            StoreKind::Quant { pages, params, lut } => {
                let mut decode = |words: &[u64], rows: usize| {
                    let mut rd = BitReader::new(words, 0);
                    for _ in 0..rows * self.width {
                        out.push(params.mean + params.scale * lut[rd.read(params.bits) as usize]);
                    }
                };
                for p in &self.shared {
                    let SharedPage::Quant(p) = p else {
                        panic!("shared page backing does not match the store mode")
                    };
                    decode(&p.words, p.rows);
                }
                for p in pages {
                    decode(&p.words, p.rows);
                }
            }
        }
        out
    }
}

/// [`KvRows`] view over one page store — what `attend_kv` reads.
pub struct KvLayerRows<'a> {
    store: &'a PageStore,
}

impl KvRows for KvLayerRows<'_> {
    #[inline]
    fn head_slice<'a>(&'a self, ti: usize, h0: usize, buf: &'a mut [f32]) -> &'a [f32] {
        let s = self.store;
        let (page, row) = (ti / s.page_rows, ti % s.page_rows);
        let shared = s.shared.len();
        match &s.kind {
            StoreKind::Dense { pages } => {
                // Rows never straddle pages, so dense reads are zero-copy
                // borrows out of the page — shared or lane-owned backing
                // alike. This backing-independence is why token identity
                // survives cross-request page sharing: attention never
                // sees *where* a row lives, only its bytes.
                let off = row * s.width + h0;
                if page < shared {
                    let SharedPage::Dense(p) = &s.shared[page] else {
                        panic!("shared page backing does not match the store mode")
                    };
                    &p[off..off + buf.len()]
                } else {
                    &pages[page - shared][off..off + buf.len()]
                }
            }
            StoreKind::Quant { pages, params, lut } => {
                let words = if page < shared {
                    let SharedPage::Quant(p) = &s.shared[page] else {
                        panic!("shared page backing does not match the store mode")
                    };
                    &p.words
                } else {
                    &pages[page - shared].words
                };
                let bit = (row * s.width + h0) * params.bits as usize;
                let mut rd = BitReader::new(words, bit);
                for b in buf.iter_mut() {
                    *b = params.mean + params.scale * lut[rd.read(params.bits) as usize];
                }
                buf
            }
        }
    }
}

/// Per-sequence attention cache: paged K and V stores per layer. Pages
/// are allocated lazily on append, so a lane's heap footprint tracks its
/// actual sequence length — the seed's eager `max_seq · dim` reservation
/// is gone (admission worst-cases are accounted by [`KvPool`] instead).
#[derive(Clone, Debug)]
pub struct KvCache {
    k: Vec<PageStore>,
    v: Vec<PageStore>,
    /// Lane clock: positions appended so far. Advanced once per engine
    /// forward (after all layers appended), exactly as before.
    pub len: usize,
}

impl KvCache {
    /// Empty cache shaped for `model` under the `kv` geometry/mode. No
    /// pages are allocated until rows are appended.
    pub fn new(model: &ModelConfig, kv: &KvCacheConfig) -> KvCache {
        let page_rows = kv.page_rows.max(1);
        if let Some(spec) = &kv.quant {
            assert_eq!(
                spec.layers.len(),
                model.layers,
                "KV quant spec layer count must match the model"
            );
        }
        let mk = |sel: fn(&KvLayerQuant) -> KvQuantParams| -> Vec<PageStore> {
            (0..model.layers)
                .map(|li| match &kv.quant {
                    None => PageStore::dense(page_rows, model.dim),
                    Some(spec) => PageStore::quant(page_rows, model.dim, sel(&spec.layers[li])),
                })
                .collect()
        };
        KvCache { k: mk(|l| l.k), v: mk(|l| l.v), len: 0 }
    }

    /// Number of transformer layers the cache covers.
    pub fn layers(&self) -> usize {
        self.k.len()
    }

    /// Whether this cache quantizes its pages.
    pub fn is_quantized(&self) -> bool {
        matches!(self.k.first(), Some(s) if matches!(s.kind, StoreKind::Quant { .. }))
    }

    /// Append a T-position chunk of K/V rows to `layer` (oldest-first;
    /// one position at a time yields byte-identical page contents — the
    /// chunked append equality test pins this down). `len` is NOT
    /// advanced here: the engine advances every lane's clock once per
    /// forward pass, after all layers have appended.
    pub(crate) fn append_chunk(&mut self, layer: usize, k_rows: &[Vec<f32>], v_rows: &[Vec<f32>]) {
        debug_assert_eq!(k_rows.len(), v_rows.len());
        for r in k_rows {
            self.k[layer].push_row(r);
        }
        for r in v_rows {
            self.v[layer].push_row(r);
        }
    }

    /// Attention views over layer `layer`'s K and V pages.
    pub fn layer_rows(&self, layer: usize) -> (KvLayerRows<'_>, KvLayerRows<'_>) {
        (self.k[layer].view(), self.v[layer].view())
    }

    /// Logical (dequantized) K contents of `layer`, row-major. For dense
    /// caches these are the exact appended bytes — tests compare them
    /// across paging/chunking configurations.
    pub fn k_flat(&self, layer: usize) -> Vec<f32> {
        self.k[layer].flat()
    }

    /// Logical (dequantized) V contents of `layer`, row-major.
    pub fn v_flat(&self, layer: usize) -> Vec<f32> {
        self.v[layer].flat()
    }

    /// Heap bytes allocated across all layers' *lane-owned* page
    /// payloads. Attached shared pages are excluded — see
    /// [`KvCache::shared_bytes`].
    pub fn allocated_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(PageStore::allocated_bytes).sum()
    }

    /// Positions covered by attached shared pages — always a whole-page
    /// prefix of the row space (0 for a never-attached cache).
    pub fn shared_rows(&self) -> usize {
        self.k.first().map_or(0, PageStore::shared_rows)
    }

    /// Shared pages attached per store — the page count admission
    /// accounting discounts via [`lane_cost_bytes_shared`].
    pub fn shared_pages(&self) -> usize {
        self.k.first().map_or(0, |s| s.shared.len())
    }

    /// Payload bytes of attached shared pages across all stores. These
    /// are charged to the prefix cache (once), not to this lane.
    pub fn shared_bytes(&self) -> usize {
        self.k.iter().chain(&self.v).map(PageStore::shared_bytes).sum()
    }

    /// Export row-space page `pi` — which must be fully populated in
    /// every store — as an immutable [`KvPageSet`] for the prefix cache.
    /// Already-shared pages are refcount bumps; owned pages are copied
    /// once into their single immutable incarnation.
    pub fn export_page_set(&self, pi: usize) -> KvPageSet {
        let page_rows = self.k.first().map_or(1, |s| s.page_rows);
        let rows = self.k.first().map_or(0, PageStore::rows);
        assert!(
            (pi + 1) * page_rows <= rows,
            "export_page_set({pi}) needs {} rows, store has {rows}",
            (pi + 1) * page_rows
        );
        KvPageSet {
            k: self.k.iter().map(|s| s.export_page(pi)).collect(),
            v: self.v.iter().map(|s| s.export_page(pi)).collect(),
        }
    }

    /// Attach the first `rows` positions of a cached prefix to this
    /// (fresh, empty) cache: whole pages covered by `rows` are shared by
    /// refcount bump; a partial tail is copied out of the divergence
    /// page, truncated + bit-masked exactly like [`KvCache::truncate_to`]'s
    /// tail handling (the COW split). Subsequent appends are therefore
    /// bit-identical to a cache that prefilled those rows itself.
    /// `pages` must hold at least `rows.div_ceil(page_rows)` page sets
    /// shaped for the same model/mode.
    pub fn attach_prefix(&mut self, pages: &[Arc<KvPageSet>], rows: usize) {
        assert_eq!(self.len, 0, "attach_prefix requires a fresh cache");
        if rows == 0 {
            return;
        }
        let page_rows = self.k.first().map_or(1, |s| s.page_rows);
        let full = rows / page_rows;
        let tail = rows % page_rows;
        let need = full + usize::from(tail > 0);
        assert!(
            pages.len() >= need,
            "attach_prefix: {rows} rows need {need} page sets, got {}",
            pages.len()
        );
        for set in pages.iter().take(need) {
            assert_eq!(set.k.len(), self.k.len(), "page set layer count must match the cache");
        }
        for li in 0..self.k.len() {
            for set in pages.iter().take(full) {
                self.k[li].attach_full(&set.k[li]);
                self.v[li].attach_full(&set.v[li]);
            }
            if tail > 0 {
                self.k[li].copy_in_tail(&pages[full].k[li], tail);
                self.v[li].copy_in_tail(&pages[full].v[li], tail);
            }
        }
        self.len = rows;
    }

    /// Roll the cache back to its first `len` positions, freeing whole
    /// pages past the new tail — the speculative-decoding rollback: draft
    /// rows appended during a verify pass are provisional, and a rejected
    /// suffix must leave the cache *bit-identical* to one that never held
    /// it (subsequent appends reproduce a never-extended cache exactly;
    /// pinned by tests at page boundaries, mid-page, and in both dense
    /// and quantized backings). No-op when `len == self.len`.
    pub fn truncate_to(&mut self, len: usize) {
        assert!(len <= self.len, "truncate_to({len}) beyond cache length {}", self.len);
        for store in self.k.iter_mut() {
            store.truncate_rows(len);
        }
        // Fault-injection site: a panic here leaves K truncated and V
        // not, with `self.len` untouched. Because `truncate_rows` is
        // per-store and trims to an absolute row count, re-running
        // `truncate_to(len)` completes the rollback (K's truncation is
        // a no-op the second time) — pinned by the mid-rollback test.
        crate::util::failpoint::fire("kv::truncate_to::between_stores", 0);
        for store in self.v.iter_mut() {
            store.truncate_rows(len);
        }
        self.len = len;
    }
}

/// Bytes of one full page across every (layer, K|V) store under `kv` —
/// the shared unit both lane admission ([`lane_cost_bytes`]) and the
/// prefix cache ([`KvPageSet::cost_bytes`]) charge in.
pub fn page_set_bytes(model: &ModelConfig, kv: &KvCacheConfig) -> usize {
    let page_rows = kv.page_rows.max(1);
    let dense_page = page_rows * model.dim * 4;
    let mut total = 0usize;
    for li in 0..model.layers {
        let (kb, vb) = match &kv.quant {
            None => (dense_page, dense_page),
            Some(spec) => {
                let bytes = |bits: u8| (page_rows * model.dim * bits as usize).div_ceil(64) * 8;
                (bytes(spec.layers[li].k.bits), bytes(spec.layers[li].v.bits))
            }
        };
        total += kb + vb;
    }
    total
}

/// Worst-case page bytes a lane occupying `rows` cache positions can
/// consume under `kv` — the amount the scheduler reserves at admission.
/// Pages are charged whole (a lane owns its last, partially-filled page)
/// and `flat_reserve` charges the full positional table, reproducing the
/// seed's accounting.
pub fn lane_cost_bytes(model: &ModelConfig, kv: &KvCacheConfig, rows: usize) -> usize {
    lane_cost_bytes_shared(model, kv, rows, 0)
}

/// [`lane_cost_bytes`] for a lane admitted through a prefix-cache hit:
/// `shared_pages` whole pages at the front of its row space come from
/// refcounted shared pages whose bytes the prefix cache already charged
/// (once), so the lane reserves only its non-shared remainder. A
/// mid-page divergence tail is copied into lane-owned storage and so
/// stays charged to the lane. `flat_reserve` ignores the discount — the
/// seed accounting it emulates has no sharing.
pub fn lane_cost_bytes_shared(
    model: &ModelConfig,
    kv: &KvCacheConfig,
    rows: usize,
    shared_pages: usize,
) -> usize {
    let page_rows = kv.page_rows.max(1);
    let rows = if kv.flat_reserve { model.max_seq } else { rows.min(model.max_seq) };
    let mut pages = rows.div_ceil(page_rows);
    if !kv.flat_reserve {
        pages = pages.saturating_sub(shared_pages);
    }
    pages * page_set_bytes(model, kv)
}

/// Byte budget for the whole KV pool with reservation accounting — the
/// scheduler's admission gate. Pure bookkeeping: pages live in each
/// lane's `KvCache`; the pool only guarantees that the sum of admitted
/// lanes' worst cases never exceeds the budget, so admission is deferred
/// (never evicted) when the pool is exhausted.
#[derive(Clone, Debug)]
pub struct KvPool {
    budget: Option<usize>,
    reserved: usize,
}

impl KvPool {
    /// `None` = unbounded (accounting only).
    pub fn new(budget_bytes: Option<usize>) -> KvPool {
        KvPool { budget: budget_bytes, reserved: 0 }
    }

    /// Reserve `bytes` if they fit the budget; `false` defers admission.
    pub fn try_reserve(&mut self, bytes: usize) -> bool {
        if let Some(b) = self.budget {
            if self.reserved + bytes > b {
                return false;
            }
        }
        self.reserved += bytes;
        true
    }

    /// Reserve unconditionally — the scheduler's progress guarantee for
    /// a single lane whose worst case alone exceeds the budget (it must
    /// still run, alone, or the queue would deadlock).
    pub fn reserve_unchecked(&mut self, bytes: usize) {
        self.reserved += bytes;
    }

    /// Return a retired lane's reservation to the pool.
    pub fn release(&mut self, bytes: usize) {
        debug_assert!(bytes <= self.reserved, "releasing more than reserved");
        self.reserved = self.reserved.saturating_sub(bytes);
    }

    /// Bytes currently reserved across admitted lanes.
    pub fn reserved(&self) -> usize {
        self.reserved
    }

    /// The configured budget (`None` = unbounded).
    pub fn budget(&self) -> Option<usize> {
        self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::transformer::{attend_cached, attend_kv};
    use crate::util::rng::Rng;

    fn tiny_cfg(layers: usize) -> ModelConfig {
        ModelConfig { vocab: 32, dim: 8, heads: 2, layers, mlp: 16, max_seq: 24 }
    }

    fn rand_rows(rng: &mut Rng, n: usize, e: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                let mut r = vec![0f32; e];
                rng.fill_gauss(&mut r, 0.0, 1.0);
                r
            })
            .collect()
    }

    #[test]
    fn dense_pages_store_exact_bytes_across_boundaries() {
        // 11 rows across page_rows=4 pages: flat contents must equal the
        // appended rows bit-for-bit, page boundaries invisible.
        let cfg = tiny_cfg(2);
        let mut rng = Rng::new(301);
        let rows = rand_rows(&mut rng, 11, cfg.dim);
        let vals = rand_rows(&mut rng, 11, cfg.dim);
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let mut cache = KvCache::new(&cfg, &kvcfg);
        cache.append_chunk(1, &rows, &vals);
        let want: Vec<f32> = rows.iter().flatten().copied().collect();
        assert_eq!(cache.k_flat(1), want);
        assert_eq!(cache.v_flat(1), vals.iter().flatten().copied().collect::<Vec<f32>>());
        assert!(cache.k_flat(0).is_empty(), "only the targeted layer grows");
    }

    #[test]
    fn chunked_append_matches_per_row_append() {
        let cfg = tiny_cfg(2);
        let mut rng = Rng::new(302);
        let rows = rand_rows(&mut rng, 7, cfg.dim);
        let vals = rand_rows(&mut rng, 7, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 3, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 3,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(2, 5, 1.0, 0.0))
            },
        ] {
            let mut chunked = KvCache::new(&cfg, &kvcfg);
            chunked.append_chunk(0, &rows, &vals);
            let mut per_row = KvCache::new(&cfg, &kvcfg);
            for (kr, vr) in rows.iter().zip(&vals) {
                per_row.append_chunk(0, std::slice::from_ref(kr), std::slice::from_ref(vr));
            }
            assert_eq!(chunked.k_flat(0), per_row.k_flat(0));
            assert_eq!(chunked.v_flat(0), per_row.v_flat(0));
        }
    }

    #[test]
    fn paged_attend_matches_flat_attend_bit_for_bit() {
        // The dense bit-identity keystone: attention through paged views
        // must equal attend_cached over the flat concatenation exactly,
        // for windows ending mid-page and at page boundaries.
        let cfg = tiny_cfg(1);
        let (e, heads) = (cfg.dim, cfg.heads);
        let dh = e / heads;
        let mut rng = Rng::new(303);
        let rows = rand_rows(&mut rng, 13, e);
        let vals = rand_rows(&mut rng, 13, e);
        let mut q = vec![0f32; e];
        rng.fill_gauss(&mut q, 0.0, 1.0);
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let mut cache = KvCache::new(&cfg, &kvcfg);
        cache.append_chunk(0, &rows, &vals);
        let (kflat, vflat) = (cache.k_flat(0), cache.v_flat(0));
        for t in [1usize, 3, 4, 5, 8, 13] {
            let (kv_k, kv_v) = cache.layer_rows(0);
            let paged = attend_kv(&q, &kv_k, &kv_v, t, e, heads, dh);
            let flat = attend_cached(&q, &kflat, &vflat, t, e, heads, dh);
            assert_eq!(paged, flat, "window t={t} diverged across page backing");
        }
    }

    #[test]
    fn quantized_pages_roundtrip_through_quantizer() {
        // Quant pages must store exactly quantize(dequantize) fixed
        // points: flat() values re-encode to the same codes, and the
        // attend view reads the same values flat() reports.
        let cfg = tiny_cfg(1);
        let e = cfg.dim;
        let mut rng = Rng::new(304);
        let rows = rand_rows(&mut rng, 9, e);
        let spec = KvQuantSpec::uniform(1, 4, 1.0, 0.1);
        let params = spec.layers[0].k;
        let kvcfg = KvCacheConfig { page_rows: 4, quant: Some(spec), flat_reserve: false };
        let mut cache = KvCache::new(&cfg, &kvcfg);
        cache.append_chunk(0, &rows, &rows);
        assert!(cache.is_quantized());
        let flat = cache.k_flat(0);
        assert_eq!(flat.len(), 9 * e);
        for (orig, deq) in rows.iter().flatten().zip(&flat) {
            let code = companding::quantize_code(*orig, params.bits, params.scale, params.mean);
            let want = params.mean
                + params.scale * companding::base_lut(params.bits)[code as usize];
            assert!((deq - want).abs() < 1e-6, "{orig} -> {deq}, want {want}");
        }
        // View agrees with flat() on every head slice.
        let (kv_k, _) = cache.layer_rows(0);
        let mut buf = vec![0f32; e / cfg.heads];
        for ti in 0..9 {
            for h in 0..cfg.heads {
                let got = kv_k.head_slice(ti, h * buf.len(), &mut buf).to_vec();
                let want = &flat[ti * e + h * got.len()..ti * e + (h + 1) * got.len()];
                assert_eq!(got, want, "row {ti} head {h}");
            }
        }
    }

    #[test]
    fn quantized_error_shrinks_with_bits() {
        let cfg = tiny_cfg(1);
        let mut rng = Rng::new(305);
        let rows = rand_rows(&mut rng, 16, cfg.dim);
        let mse = |bits: u8| -> f64 {
            let spec = KvQuantSpec::uniform(1, bits, 1.0, 0.0);
            let kvcfg = KvCacheConfig { page_rows: 8, quant: Some(spec), flat_reserve: false };
            let mut cache = KvCache::new(&cfg, &kvcfg);
            cache.append_chunk(0, &rows, &rows);
            cache
                .k_flat(0)
                .iter()
                .zip(rows.iter().flatten())
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
        };
        let (m3, m6, m8) = (mse(3), mse(6), mse(8));
        assert!(m6 < m3 / 4.0, "6-bit {m6} vs 3-bit {m3}");
        assert!(m8 < m6, "8-bit {m8} vs 6-bit {m6}");
    }

    #[test]
    fn footprint_tracks_rows_not_max_seq() {
        // The seed bugfix: a short lane must not pay the positional
        // table. 3 rows at page_rows=4 allocates exactly one page per
        // (layer, K|V), far below the max_seq footprint.
        let cfg = tiny_cfg(2);
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let mut cache = KvCache::new(&cfg, &kvcfg);
        assert_eq!(cache.allocated_bytes(), 0, "empty cache allocates nothing");
        let mut rng = Rng::new(306);
        let rows = rand_rows(&mut rng, 3, cfg.dim);
        for li in 0..cfg.layers {
            cache.append_chunk(li, &rows, &rows);
        }
        let one_page = 4 * cfg.dim * 4;
        // One page per (layer, K|V). Vec::with_capacity guarantees "at
        // least", so allow a small allocator margin above the exact size.
        let got = cache.allocated_bytes();
        assert!(
            got >= cfg.layers * 2 * one_page && got <= cfg.layers * 2 * one_page * 2,
            "allocated {got}, expected ~{}",
            cfg.layers * 2 * one_page
        );
        let full = lane_cost_bytes(&cfg, &kvcfg, cfg.max_seq);
        assert!(cache.allocated_bytes() < full / 2, "short lane must undercut max_seq");
        // And the worst-case accounting bounds the actual footprint
        // (2x margin: with_capacity guarantees "at least").
        assert!(cache.allocated_bytes() <= 2 * lane_cost_bytes(&cfg, &kvcfg, 3));
    }

    #[test]
    fn lane_cost_accounting() {
        let cfg = tiny_cfg(2);
        let dense = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        // 5 rows -> 2 pages; per layer K+V.
        let want = cfg.layers * 2 * 2 * (4 * cfg.dim * 4);
        assert_eq!(lane_cost_bytes(&cfg, &dense, 5), want);
        // flat_reserve charges max_seq (24 rows -> 6 pages) regardless.
        let flat = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense_flat() };
        assert_eq!(lane_cost_bytes(&cfg, &flat, 5), cfg.layers * 2 * 6 * (4 * cfg.dim * 4));
        // Quantized pages cost ~bits/32 of dense.
        let q = KvCacheConfig {
            page_rows: 4,
            quant: Some(KvQuantSpec::uniform(cfg.layers, 4, 1.0, 0.0)),
            flat_reserve: false,
        };
        let qcost = lane_cost_bytes(&cfg, &q, 5);
        assert!(qcost * 6 < lane_cost_bytes(&cfg, &dense, 5), "4-bit pages ~8x smaller");
        // Rows clamp to max_seq.
        assert_eq!(
            lane_cost_bytes(&cfg, &dense, 10_000),
            lane_cost_bytes(&cfg, &dense, cfg.max_seq)
        );
    }

    #[test]
    fn pool_reserve_release() {
        let mut pool = KvPool::new(Some(100));
        assert!(pool.try_reserve(60));
        assert!(!pool.try_reserve(50), "over budget must defer");
        assert!(pool.try_reserve(40));
        pool.release(60);
        assert_eq!(pool.reserved(), 40);
        assert!(pool.try_reserve(60));
        // Unbounded pool never defers.
        let mut open = KvPool::new(None);
        assert!(open.try_reserve(usize::MAX / 2));
        // Progress guarantee: unchecked reservation may exceed budget.
        let mut tight = KvPool::new(Some(10));
        tight.reserve_unchecked(50);
        assert_eq!(tight.reserved(), 50);
    }

    #[test]
    fn truncate_to_frees_pages_at_boundary_mid_page_and_zero() {
        // 11 rows over page_rows=4 pages = 3 pages. Truncating to a page
        // boundary (8), mid-page (5), and zero must keep exactly the
        // logical prefix and shrink the heap footprint page by page.
        let cfg = tiny_cfg(2);
        let mut rng = Rng::new(310);
        let rows = rand_rows(&mut rng, 11, cfg.dim);
        let vals = rand_rows(&mut rng, 11, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(2, 3, 1.0, 0.0))
            },
        ] {
            let mut cache = KvCache::new(&cfg, &kvcfg);
            for li in 0..cfg.layers {
                cache.append_chunk(li, &rows, &vals);
            }
            cache.len = 11;
            let full_bytes = cache.allocated_bytes();
            let want_k: Vec<f32> = cache.k_flat(0);
            let want_v: Vec<f32> = cache.v_flat(0);

            cache.truncate_to(8); // page boundary: third page freed
            assert_eq!(cache.len, 8);
            assert_eq!(cache.k_flat(0), want_k[..8 * cfg.dim]);
            assert_eq!(cache.v_flat(0), want_v[..8 * cfg.dim]);
            assert!(
                cache.allocated_bytes() < full_bytes,
                "freeing a whole page must shrink the footprint"
            );
            let after_boundary = cache.allocated_bytes();

            cache.truncate_to(5); // mid-page: second page truncated in place
            assert_eq!(cache.k_flat(0), want_k[..5 * cfg.dim]);
            assert_eq!(cache.v_flat(1), want_v[..5 * cfg.dim]);
            assert!(cache.allocated_bytes() <= after_boundary);

            cache.truncate_to(0);
            assert_eq!(cache.len, 0);
            assert!(cache.k_flat(0).is_empty());
            assert_eq!(cache.allocated_bytes(), 0, "empty cache frees every page");
            // No-op truncation to the current length is fine.
            cache.truncate_to(0);
        }
    }

    #[test]
    fn truncate_then_append_is_bit_identical_to_never_extended() {
        // The speculative-rollback contract, dense AND quantized: a cache
        // that grew to 13 rows, rolled back to `keep`, and then appended
        // a fresh suffix must match — logical contents and subsequent
        // attention reads — a cache that only ever held keep + suffix.
        // `keep` values land mid-page (5), on a boundary (8), and at 0.
        let cfg = tiny_cfg(1);
        let mut rng = Rng::new(311);
        let rows = rand_rows(&mut rng, 13, cfg.dim);
        let vals = rand_rows(&mut rng, 13, cfg.dim);
        let ext_k = rand_rows(&mut rng, 6, cfg.dim);
        let ext_v = rand_rows(&mut rng, 6, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(1, 5, 1.0, 0.1))
            },
        ] {
            for keep in [0usize, 5, 8] {
                let mut rolled = KvCache::new(&cfg, &kvcfg);
                rolled.append_chunk(0, &rows, &vals);
                rolled.len = 13;
                rolled.truncate_to(keep);
                rolled.append_chunk(0, &ext_k, &ext_v);
                rolled.len = keep + 6;

                let mut fresh = KvCache::new(&cfg, &kvcfg);
                fresh.append_chunk(0, &rows[..keep], &vals[..keep]);
                fresh.append_chunk(0, &ext_k, &ext_v);
                fresh.len = keep + 6;

                assert_eq!(rolled.k_flat(0), fresh.k_flat(0), "keep={keep} K diverged");
                assert_eq!(rolled.v_flat(0), fresh.v_flat(0), "keep={keep} V diverged");
                // Attention-path reads agree row by row (quantized pages
                // exercise the masked-tail-word append path here).
                let (rk, _) = rolled.layer_rows(0);
                let (fk, _) = fresh.layer_rows(0);
                let mut ba = vec![0f32; cfg.dim / cfg.heads];
                let mut bb = vec![0f32; cfg.dim / cfg.heads];
                for ti in 0..keep + 6 {
                    for h in 0..cfg.heads {
                        assert_eq!(
                            rk.head_slice(ti, h * ba.len(), &mut ba),
                            fk.head_slice(ti, h * bb.len(), &mut bb),
                            "keep={keep} row {ti} head {h}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn interrupted_rollback_resumes_bit_identical() {
        // Satellite: inject a panic INSIDE truncate_to (between the K
        // and V stores) and verify the rollback is resumable — a second
        // truncate_to(keep) completes it, and the cache then behaves
        // bit-identically to one that never held the rolled-back rows,
        // including the quantized tail-word masking of the final
        // partial page. A half-truncated page must never survive.
        let cfg = tiny_cfg(1);
        let mut rng = Rng::new(313);
        let rows = rand_rows(&mut rng, 13, cfg.dim);
        let vals = rand_rows(&mut rng, 13, cfg.dim);
        let ext_k = rand_rows(&mut rng, 6, cfg.dim);
        let ext_v = rand_rows(&mut rng, 6, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(1, 5, 1.0, 0.1))
            },
        ] {
            for keep in [0usize, 5, 8] {
                let mut rolled = KvCache::new(&cfg, &kvcfg);
                rolled.append_chunk(0, &rows, &vals);
                rolled.len = 13;
                {
                    let _scenario = crate::util::failpoint::scenario();
                    crate::util::failpoint::arm("kv::truncate_to::between_stores", 0, 1);
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        rolled.truncate_to(keep)
                    }));
                    assert!(r.is_err(), "failpoint must interrupt the rollback");
                }
                // K is truncated, V is not, len is untouched.
                assert_eq!(rolled.len, 13, "len must not advance past a failed rollback");
                // Resume: the re-run completes the interrupted rollback.
                rolled.truncate_to(keep);
                assert_eq!(rolled.len, keep);
                rolled.append_chunk(0, &ext_k, &ext_v);
                rolled.len = keep + 6;

                let mut fresh = KvCache::new(&cfg, &kvcfg);
                fresh.append_chunk(0, &rows[..keep], &vals[..keep]);
                fresh.append_chunk(0, &ext_k, &ext_v);
                fresh.len = keep + 6;
                assert_eq!(rolled.k_flat(0), fresh.k_flat(0), "keep={keep} K diverged");
                assert_eq!(rolled.v_flat(0), fresh.v_flat(0), "keep={keep} V diverged");
            }
        }
    }

    #[test]
    #[should_panic(expected = "beyond cache length")]
    fn truncate_beyond_length_panics() {
        let cfg = tiny_cfg(1);
        let mut cache = KvCache::new(&cfg, &KvCacheConfig::dense());
        cache.truncate_to(1);
    }

    #[test]
    fn quant_params_clamp_and_round() {
        let p = KvQuantParams::new(0, f32::NAN, f32::INFINITY);
        assert_eq!(p.bits, 1);
        assert!(p.scale > 0.0 && p.scale.is_finite());
        assert_eq!(p.mean, 0.0);
        let p = KvQuantParams::new(12, 1.0, 0.5);
        assert_eq!(p.bits, 8);
        assert_eq!(p.scale, f16_round(1.0));
    }

    /// Donor cache with 13 rows in every (layer, K|V) store plus the
    /// three full page sets it can export (page_rows = 4).
    fn donor_and_sets(
        cfg: &ModelConfig,
        kvcfg: &KvCacheConfig,
        rows: &[Vec<f32>],
        vals: &[Vec<f32>],
    ) -> (KvCache, Vec<Arc<KvPageSet>>) {
        let mut donor = KvCache::new(cfg, kvcfg);
        for li in 0..cfg.layers {
            donor.append_chunk(li, rows, vals);
        }
        donor.len = rows.len();
        let sets: Vec<Arc<KvPageSet>> =
            (0..rows.len() / 4).map(|pi| Arc::new(donor.export_page_set(pi))).collect();
        (donor, sets)
    }

    #[test]
    fn attach_prefix_matches_fresh_cache_at_every_alignment() {
        // The prefix-cache COW keystone: a cache that attaches `keep`
        // rows of shared pages and then appends a fresh suffix must be
        // bit-identical — flat contents AND attention-path reads — to a
        // cache that appended keep + suffix itself. `keep` sweeps page
        // boundaries (4, 8, 12), one row past them (5, 9), and cuts
        // inside the bit-packed tail word of a quantized page (7, 11:
        // 3·8·5 = 120 bits masks mid-word at bits = 5).
        let cfg = tiny_cfg(2);
        let mut rng = Rng::new(401);
        let rows = rand_rows(&mut rng, 12, cfg.dim);
        let vals = rand_rows(&mut rng, 12, cfg.dim);
        let ext_k = rand_rows(&mut rng, 5, cfg.dim);
        let ext_v = rand_rows(&mut rng, 5, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(2, 5, 1.0, 0.1))
            },
        ] {
            let (_donor, sets) = donor_and_sets(&cfg, &kvcfg, &rows, &vals);
            for keep in [4usize, 5, 7, 8, 9, 11, 12] {
                let mut attached = KvCache::new(&cfg, &kvcfg);
                attached.attach_prefix(&sets, keep);
                assert_eq!(attached.len, keep);
                assert_eq!(attached.shared_rows(), (keep / 4) * 4);
                assert_eq!(attached.shared_pages(), keep / 4);
                for li in 0..cfg.layers {
                    attached.append_chunk(li, &ext_k, &ext_v);
                }
                attached.len = keep + 5;

                let mut fresh = KvCache::new(&cfg, &kvcfg);
                for li in 0..cfg.layers {
                    fresh.append_chunk(li, &rows[..keep], &vals[..keep]);
                    fresh.append_chunk(li, &ext_k, &ext_v);
                }
                fresh.len = keep + 5;

                for li in 0..cfg.layers {
                    assert_eq!(attached.k_flat(li), fresh.k_flat(li), "keep={keep} K layer {li}");
                    assert_eq!(attached.v_flat(li), fresh.v_flat(li), "keep={keep} V layer {li}");
                }
                let (ak, av) = attached.layer_rows(0);
                let (fk, fv) = fresh.layer_rows(0);
                let mut ba = vec![0f32; cfg.dim / cfg.heads];
                let mut bb = vec![0f32; cfg.dim / cfg.heads];
                for ti in 0..keep + 5 {
                    for h in 0..cfg.heads {
                        assert_eq!(
                            ak.head_slice(ti, h * ba.len(), &mut ba),
                            fk.head_slice(ti, h * bb.len(), &mut bb),
                            "keep={keep} K row {ti} head {h}"
                        );
                        assert_eq!(
                            av.head_slice(ti, h * ba.len(), &mut ba),
                            fv.head_slice(ti, h * bb.len(), &mut bb),
                            "keep={keep} V row {ti} head {h}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn truncate_below_shared_run_cow_splits_without_touching_the_donor() {
        // COW split at a page boundary (8), one row past one (5, 9), and
        // inside the bit-packed tail word of a quantized page (7), plus
        // to zero: the lane detaches/copies, the donor's exported pages
        // must remain byte-identical throughout (other lanes may still
        // be attached to them).
        let cfg = tiny_cfg(1);
        let mut rng = Rng::new(402);
        let rows = rand_rows(&mut rng, 12, cfg.dim);
        let vals = rand_rows(&mut rng, 12, cfg.dim);
        let ext_k = rand_rows(&mut rng, 4, cfg.dim);
        let ext_v = rand_rows(&mut rng, 4, cfg.dim);
        for kvcfg in [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(1, 5, 1.0, 0.1))
            },
        ] {
            let (donor, sets) = donor_and_sets(&cfg, &kvcfg, &rows, &vals);
            let donor_k = donor.k_flat(0);
            for keep in [0usize, 5, 7, 8, 9] {
                let mut lane = KvCache::new(&cfg, &kvcfg);
                lane.attach_prefix(&sets, 12);
                lane.truncate_to(keep);
                assert_eq!(lane.len, keep);
                assert_eq!(lane.shared_pages(), keep / 4, "full pages below the cut stay shared");
                lane.append_chunk(0, &ext_k, &ext_v);
                lane.len = keep + 4;

                let mut fresh = KvCache::new(&cfg, &kvcfg);
                fresh.append_chunk(0, &rows[..keep], &vals[..keep]);
                fresh.append_chunk(0, &ext_k, &ext_v);
                fresh.len = keep + 4;
                assert_eq!(lane.k_flat(0), fresh.k_flat(0), "keep={keep} K diverged");
                assert_eq!(lane.v_flat(0), fresh.v_flat(0), "keep={keep} V diverged");
                // The donor (and thus every other attached lane) is
                // untouched by this lane's COW writes.
                assert_eq!(donor.k_flat(0), donor_k, "keep={keep} donor mutated");
            }
        }
    }

    #[test]
    fn shared_pages_are_charged_to_the_cache_not_the_lane() {
        let cfg = tiny_cfg(2);
        let mut rng = Rng::new(403);
        let rows = rand_rows(&mut rng, 12, cfg.dim);
        let vals = rand_rows(&mut rng, 12, cfg.dim);
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let (_donor, sets) = donor_and_sets(&cfg, &kvcfg, &rows, &vals);
        // A page set's cost is exactly one page of lane accounting, so
        // cache-side charges and lane-side discounts cancel.
        let ps = page_set_bytes(&cfg, &kvcfg);
        assert_eq!(sets[0].cost_bytes(), ps);
        assert_eq!(lane_cost_bytes(&cfg, &kvcfg, 4), ps);
        // Whole-page attach: the lane owns nothing, shares everything.
        let mut lane = KvCache::new(&cfg, &kvcfg);
        lane.attach_prefix(&sets, 8);
        assert_eq!(lane.allocated_bytes(), 0, "attach allocates no lane-owned pages");
        assert_eq!(lane.shared_bytes(), 2 * ps);
        // Mid-page attach: the copied COW tail is lane-owned.
        let mut lane = KvCache::new(&cfg, &kvcfg);
        lane.attach_prefix(&sets, 9);
        assert_eq!(lane.shared_bytes(), 2 * ps);
        assert!(lane.allocated_bytes() > 0, "the COW tail is lane-owned");
        // Admission discount mirrors the split: 9 rows = 3 pages, 2
        // shared, so the lane reserves exactly one page set.
        assert_eq!(lane_cost_bytes_shared(&cfg, &kvcfg, 9, 2), ps);
        assert_eq!(
            lane_cost_bytes_shared(&cfg, &kvcfg, 9, 0),
            lane_cost_bytes(&cfg, &kvcfg, 9)
        );
        // flat_reserve emulates the seed: no sharing, no discount.
        let flat = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense_flat() };
        assert_eq!(
            lane_cost_bytes_shared(&cfg, &flat, 9, 2),
            lane_cost_bytes(&cfg, &flat, 9)
        );
    }
}
