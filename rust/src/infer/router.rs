//! Admission router: continuous batching across R engine replicas.
//!
//! One packed model, R independent serving loops. Each replica is the
//! SAME [`Engine`] (replicas of one process share the packed weights —
//! and, via [`Engine::load_mapped`], the mmap'd container pages — so R
//! replicas cost one model's RSS), but gets its own request stream,
//! its own [`crate::infer::server::serve_with`] scheduler instance,
//! and therefore its own `KvPool` budget, shed/deadline ladder, and
//! fault containment: a `LaneFault`, shed, or degraded section on one
//! replica never touches another's lanes.
//!
//! Determinism: [`route`] assigns requests by deterministic
//! least-loaded-first (worst-case token cost, lowest replica index on
//! ties) over the caller's arrival order, and each replica's scheduler
//! is FIFO over its bucket — so for a fixed request list and
//! [`RouterConfig`], every run produces identical per-replica batches
//! and identical tokens. Combined with the backend bit-identity
//! contract ([`crate::infer::backend`]), replicated serving stays
//! token-identical to single-engine [`Engine::generate`] per request —
//! the property the router tests pin.
//!
//! Scaling shape: replicas multiply *throughput* for small models
//! (independent forwards, no cross-replica synchronization), while
//! shards ([`crate::infer::backend::ColumnSharded`] /
//! [`crate::infer::backend::LayerPipeline`]) divide *per-forward
//! latency* for big ones. The two compose — each replica can itself run
//! a sharded backend — and `docs/SERVING.md` §Sizing covers how to
//! split cores between W and R.
//!
//! Prefix caching is per replica: when
//! [`ServeConfig::prefix_cache`] is set on [`RouterConfig::replica`],
//! each replica's scheduler builds its own
//! [`crate::infer::prefix::PrefixCache`] scoped to its call — caches
//! are never shared across replicas (no cross-thread page traffic, and
//! each cache's reservations stay inside that replica's own `KvPool`
//! budget). Per-replica hit/reuse/eviction counters surface through
//! [`RouterStats::replicas`]. The cost: a prefix family split across
//! replicas by least-loaded routing warms R caches instead of one, so
//! workloads dominated by one hot prefix may prefer fewer, larger
//! replicas.

use crate::infer::engine::Engine;
use crate::infer::server::{serve_with, Request, Response, ServeConfig, ServeStats};
use crate::util::threadpool::scoped_map;
use std::sync::Mutex;
use std::time::Instant;

/// Configuration for replicated serving.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Replica count R (clamped to ≥ 1 at serve time).
    pub replicas: usize,
    /// Per-replica scheduler configuration — notably
    /// [`ServeConfig::kv_budget_bytes`] is enforced per replica, so
    /// total KV memory is `R × kv_budget_bytes`.
    pub replica: ServeConfig,
}

impl RouterConfig {
    /// `replicas` replicas, each running `replica`'s scheduler config.
    pub fn new(replicas: usize, replica: ServeConfig) -> RouterConfig {
        RouterConfig { replicas, replica }
    }
}

/// Aggregate statistics for one [`serve_replicated`] call.
#[derive(Clone, Debug)]
pub struct RouterStats {
    /// Each replica's full [`ServeStats`], in replica order (empty
    /// buckets still produce an entry, so `replicas.len() == R`).
    pub replicas: Vec<ServeStats>,
    /// Sum of per-replica clean completions.
    pub completed: usize,
    /// Sum of per-replica sheds.
    pub shed: usize,
    /// Sum of per-replica deadline retirements.
    pub timed_out: usize,
    /// Sum of per-replica isolated lane faults.
    pub lane_faults: usize,
    /// Generated tokens across all replicas.
    pub total_tokens: usize,
    /// Wall clock for the whole replicated serve (replicas run
    /// concurrently, so this tracks the slowest replica, not the sum).
    pub wall: std::time::Duration,
    /// Generated tokens per second of router wall clock.
    pub throughput_tps: f64,
}

impl RouterStats {
    /// Responses produced for any reason across all replicas —
    /// `completed + shed + timed_out + lane_faults`. Equals the
    /// submitted request count (every request is answered exactly once
    /// by exactly one replica).
    pub fn accounted(&self) -> usize {
        self.completed + self.shed + self.timed_out + self.lane_faults
    }
}

/// Deterministic replica assignment: walk `requests` in arrival order,
/// sending each to the least-loaded replica by accumulated worst-case
/// token cost (`prompt.len() + max_new`), breaking ties toward the
/// lowest index. Returns one replica index per request.
///
/// Pure function of the request list and R — no clock, no randomness —
/// so a fixed arrival order always yields the same assignment (the
/// router-determinism test replays it). Worst-case cost mirrors the
/// scheduler's own admission reservation rule, which makes the load
/// estimate consistent with what each replica will actually reserve.
pub fn route(requests: &[Request], replicas: usize) -> Vec<usize> {
    let r = replicas.max(1);
    let mut load = vec![0usize; r];
    let mut assign = Vec::with_capacity(requests.len());
    for req in requests {
        let mut best = 0usize;
        for i in 1..r {
            if load[i] < load[best] {
                best = i;
            }
        }
        assign.push(best);
        load[best] += req.prompt.len() + req.max_new;
    }
    assign
}

/// Serve `requests` across `cfg.replicas` concurrent scheduler
/// instances sharing one engine, and merge the results.
///
/// Each replica runs the full [`serve_with`] machinery — continuous
/// batching, chunked prefill, KV-budget admission, shed/deadline/
/// degradation ladder, lane-fault containment — over its
/// [`route`]-assigned bucket, on its own scoped worker thread (the
/// caller's thread runs replica 0). Responses are re-merged and sorted
/// by request id, so callers see the same shape `serve_with` returns.
///
/// Token identity: replica assignment only partitions the request list;
/// each request's tokens are produced by an unmodified `serve_with`
/// loop, which is token-identical to [`Engine::generate`] per request
/// under every batching configuration — so routing never changes
/// tokens, only which replica computes them. A panic inside a replica's
/// scheduler propagates with its original payload after all replicas
/// are joined ([`scoped_map`]'s contract); faults *within* a replica
/// are already contained per lane by `serve_with` itself.
pub fn serve_replicated(
    engine: &Engine,
    requests: Vec<Request>,
    cfg: RouterConfig,
) -> (Vec<Response>, RouterStats) {
    let t0 = Instant::now();
    let r = cfg.replicas.max(1);
    let assign = route(&requests, r);
    let mut buckets: Vec<Vec<Request>> = (0..r).map(|_| Vec::new()).collect();
    for (req, &to) in requests.into_iter().zip(&assign) {
        buckets[to].push(req);
    }
    // Slots let the Fn closure below take ownership of exactly its own
    // bucket (scoped_map wants Fn, not FnOnce-per-index).
    let slots: Vec<Mutex<Option<Vec<Request>>>> =
        buckets.into_iter().map(|b| Mutex::new(Some(b))).collect();
    let results: Vec<(Vec<Response>, ServeStats)> = scoped_map(r, |i| {
        let bucket = slots[i]
            .lock()
            .expect("bucket mutex poisoned")
            .take()
            .expect("each bucket is taken exactly once");
        serve_with(engine, bucket, cfg.replica)
    });

    let wall = t0.elapsed();
    let mut responses = Vec::new();
    let mut stats = RouterStats {
        replicas: Vec::with_capacity(r),
        completed: 0,
        shed: 0,
        timed_out: 0,
        lane_faults: 0,
        total_tokens: 0,
        wall,
        throughput_tps: 0.0,
    };
    for (resp, st) in results {
        responses.extend(resp);
        stats.completed += st.completed;
        stats.shed += st.shed;
        stats.timed_out += st.timed_out;
        stats.lane_faults += st.lane_faults;
        stats.total_tokens += st.total_tokens;
        stats.replicas.push(st);
    }
    responses.sort_by_key(|resp| resp.id);
    stats.throughput_tps = if wall.as_secs_f64() > 0.0 {
        stats.total_tokens as f64 / wall.as_secs_f64()
    } else {
        0.0
    };
    (responses, stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: usize, plen: usize, max_new: usize) -> Request {
        Request { id, prompt: vec![1u32; plen], max_new }
    }

    #[test]
    fn route_is_deterministic_least_loaded() {
        let reqs = vec![req(0, 4, 4), req(1, 1, 1), req(2, 1, 1), req(3, 6, 2)];
        // r0 gets 8 cost, r1 gets 2, then 2 more (still lightest), then
        // the heavy one lands on r1 (4 < 8).
        assert_eq!(route(&reqs, 2), vec![0, 1, 1, 1]);
        // Replays identically.
        assert_eq!(route(&reqs, 2), route(&reqs, 2));
        // Ties break toward the lowest index.
        let even = vec![req(0, 1, 1), req(1, 1, 1), req(2, 1, 1)];
        assert_eq!(route(&even, 3), vec![0, 1, 2]);
        // Degenerate replica counts clamp.
        assert_eq!(route(&reqs, 0), vec![0, 0, 0, 0]);
    }
}
