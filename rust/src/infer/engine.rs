//! Quantized autoregressive inference engine: KV-cached decode running
//! every transformer-block matmul straight off the packed bitstreams via
//! the mixed-precision kernels. A dense-f32 engine over the same code
//! path provides the FP baseline (Table 7's comparison and the serving
//! example's control arm).
//!
//! The hot entry point is [`Engine::prefill_batch`]: ONE forward pass
//! over a chunk of T tokens for each of B independent sequences, with
//! every per-layer linear running as a (ΣT)-row GEMM so the packed code
//! streams are decoded once per row tile rather than once per (sequence,
//! position) — see [`crate::infer::matvec::MatvecPlan::matgem`].
//! [`Engine::step_batch`] is the chunks-of-one wrapper (decode), and
//! [`Engine::step`] the batch-of-one wrapper on top of that, so prefill,
//! batched decode, and single-request decode share ONE numeric path:
//! per-position results are bit-identical no matter how tokens are
//! chunked or what else is co-scheduled — the invariant the serving and
//! prefill determinism tests pin down.

use crate::infer::backend::{Backend, SingleThread};
use crate::infer::kv::{KvCache, KvCacheConfig, KvPageSet};
use crate::infer::matvec::{
    dense_matmul, dense_matmul_cols, split_rows, MatvecPlan, SendMut,
};
use crate::model::config::ModelConfig;
use crate::model::tensor::Tensor;
use crate::model::transformer;
use crate::model::weights::{MatId, Role, Weights};
use crate::quant::activations::{ActQuantParams, ActQuantSpec};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::format::QuantizedModel;
use crate::util::threadpool::{parallel_for_chunks, parallel_map, scoped_map};
use std::sync::Arc;

const LN_EPS: f32 = 1e-5;

/// How a backend wants each linear executed — threaded through
/// [`Engine::run_layers`] so every projection in a forward uses the same
/// execution shape. `Full` is the pooled full-width GEMM; `Sharded(w)`
/// splits the column axis across `w` scoped workers (see
/// [`Linear::apply_sharded`]). Numerically the two are bit-identical —
/// that is the whole point of the `_cols` kernel seam in
/// [`crate::infer::matvec`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum GemmMode {
    /// Pooled full-width sweep (the classic single-backend path).
    Full,
    /// Column-sharded across this many workers.
    Sharded(usize),
}

/// One linear layer: dense or packed-quantized. Quantized linears also
/// carry their input (activation) quantization parameters — bits 0 means
/// full-precision f32 inputs, the default until a spec is installed via
/// [`Engine::with_act_quant`].
pub(crate) enum Linear {
    Dense(Tensor),
    Quant { pm: PackedMatrix, plan: MatvecPlan, act: ActQuantParams },
}

impl Linear {
    /// Sequence-parallel apply over N = B·T activation rows. The packed
    /// path row-tiles the chunk so bitstream decode amortizes across
    /// positions without blowing the cache; dense weights already stream
    /// row-by-row once per column chunk for the whole batch, so tiling
    /// would only re-stream them and the dense path stays un-tiled.
    /// Quantized linears route through `matgem_act`, which is the plain
    /// f32 `matgem` when `act.bits == 0` and the integer-integer W·A
    /// tile path otherwise.
    fn apply_gemm(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match self {
            Linear::Dense(w) => dense_matmul(w, xs),
            Linear::Quant { pm, plan, act } => plan.matgem_act(pm, xs, *act),
        }
    }

    /// Output width (columns) of this linear.
    fn out_dim(&self) -> usize {
        match self {
            Linear::Dense(w) => w.cols,
            Linear::Quant { pm, .. } => pm.cols,
        }
    }

    /// Column-range apply: only columns `c0..c1`, computed serially via
    /// the `_cols` kernels (bit-identical to that slice of
    /// [`Linear::apply_gemm`]'s output — the sharding contract the
    /// matvec stitching tests pin down).
    fn apply_gemm_cols(&self, xs: &[Vec<f32>], c0: usize, c1: usize) -> Vec<Vec<f32>> {
        match self {
            Linear::Dense(w) => dense_matmul_cols(w, xs, c0, c1),
            Linear::Quant { pm, plan, act } => plan.matgem_act_cols(pm, xs, *act, c0, c1),
        }
    }

    /// Column-sharded apply: split the output columns into `workers`
    /// contiguous ranges (`bounds[i] = i·cols/w`, the same fixed split
    /// for a given `w` no matter the host), decode each range on its own
    /// scoped worker, and stitch by concatenation.
    ///
    /// Bit-identity: stitching is a pure memcpy — no cross-worker FP
    /// reduction exists, because every output column is computed whole by
    /// exactly one worker through the same per-column kernel the pooled
    /// sweep uses. The result is therefore bit-identical to
    /// `apply_gemm(xs)` for EVERY worker count, which is what lets the
    /// sharded backend honor the serve == generate token-identity
    /// invariant.
    ///
    /// A worker panic propagates with its original payload
    /// ([`scoped_map`]'s contract), so the serving scheduler's
    /// `LaneFault` containment names the real site under sharding too.
    fn apply_sharded(&self, xs: &[Vec<f32>], workers: usize) -> Vec<Vec<f32>> {
        let cols = self.out_dim();
        let w = workers.min(cols.max(1));
        if w <= 1 || xs.is_empty() {
            return self.apply_gemm(xs);
        }
        let bounds: Vec<usize> = (0..=w).map(|i| i * cols / w).collect();
        let parts = scoped_map(w, |i| self.apply_gemm_cols(xs, bounds[i], bounds[i + 1]));
        let mut ys: Vec<Vec<f32>> = xs.iter().map(|_| Vec::with_capacity(cols)).collect();
        for part in parts {
            for (lane, p) in ys.iter_mut().zip(part) {
                lane.extend_from_slice(&p);
            }
        }
        ys
    }

    /// Dispatch on the backend's execution shape.
    fn apply(&self, xs: &[Vec<f32>], mode: GemmMode) -> Vec<Vec<f32>> {
        match mode {
            GemmMode::Full => self.apply_gemm(xs),
            GemmMode::Sharded(w) => self.apply_sharded(xs, w),
        }
    }
}

struct EngineLayer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Linear,
    bq: Vec<f32>,
    wk: Linear,
    bk: Vec<f32>,
    wv: Linear,
    bv: Vec<f32>,
    wo: Linear,
    bo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Linear,
    b1: Vec<f32>,
    w2: Linear,
    b2: Vec<f32>,
}

/// The decode engine.
pub struct Engine {
    /// Shape of the model the engine was built from.
    pub config: ModelConfig,
    /// KV cache geometry/mode used by [`Engine::new_cache`] — one source
    /// of truth shared by `generate`, the serving scheduler, and the
    /// packed evaluator, so all three build identically-shaped caches
    /// (the serve == generate token-identity invariant needs this).
    kv: KvCacheConfig,
    /// Execution backend every forward routes through — single-thread by
    /// default; swap with [`Engine::with_backend`]. All backends are
    /// bit-identical by contract (see [`crate::infer::backend`]), so
    /// this choice affects wall-clock only, never tokens.
    backend: Arc<dyn Backend>,
    embed: Tensor,
    pos: Tensor,
    layers: Vec<EngineLayer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

fn ln_vec(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let e = x.len();
    let mu = x.iter().sum::<f32>() / e as f32;
    let var = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / e as f32;
    let rs = 1.0 / (var + LN_EPS).sqrt();
    x.iter()
        .zip(g.iter().zip(b))
        .map(|(&v, (&gv, &bv))| gv * (v - mu) * rs + bv)
        .collect()
}

#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

impl Engine {
    /// Build a quantized engine (weights stay packed; decode runs the
    /// mixed-precision kernel).
    pub fn from_quantized(qm: &QuantizedModel) -> Engine {
        let w = &qm.base;
        let mut layers = Vec::with_capacity(w.layers.len());
        let find = |layer: usize, role: Role| -> Linear {
            let pm = qm
                .packed
                .iter()
                .find(|(id, _)| id.layer == layer && id.role == role)
                .map(|(_, p)| p.clone())
                .expect("missing packed matrix");
            let plan = MatvecPlan::new(&pm);
            Linear::Quant { pm, plan, act: ActQuantParams::full_precision() }
        };
        for (li, l) in w.layers.iter().enumerate() {
            layers.push(EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: find(li, Role::Q),
                bq: l.bq.clone(),
                wk: find(li, Role::K),
                bk: l.bk.clone(),
                wv: find(li, Role::V),
                bv: l.bv.clone(),
                wo: find(li, Role::O),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: find(li, Role::Up),
                b1: l.b1.clone(),
                w2: find(li, Role::Down),
                b2: l.b2.clone(),
            });
        }
        let engine = Engine {
            config: w.config,
            kv: KvCacheConfig::dense(),
            backend: Arc::new(SingleThread),
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        };
        // Containers that persisted an activation-quant spec (RADIOQM2
        // with a SEC_ACTQ section) serve fully-integer out of the box;
        // weight-only containers keep f32 activations.
        match &qm.act_quant {
            Some(spec) => engine.with_act_quant(spec),
            None => engine,
        }
    }

    /// Build an engine straight from a container on disk via the
    /// integrity-checked lazy load
    /// ([`QuantizedModel::load_mapped`]): the section table is verified
    /// eagerly, payload CRCs on first touch, and for a RADIOQM3 ladder
    /// the top (highest-rate) point is served. Legacy containers fall
    /// back to the eager loader.
    pub fn load_mapped(path: &std::path::Path) -> Result<Engine, crate::error::RadioError> {
        Ok(Engine::from_quantized(&QuantizedModel::load_mapped(path)?))
    }

    /// Dense-f32 engine (the FP baseline arm).
    pub fn from_dense(w: &Weights) -> Engine {
        let layers = w
            .layers
            .iter()
            .map(|l| EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: Linear::Dense(l.wq.clone()),
                bq: l.bq.clone(),
                wk: Linear::Dense(l.wk.clone()),
                bk: l.bk.clone(),
                wv: Linear::Dense(l.wv.clone()),
                bv: l.bv.clone(),
                wo: Linear::Dense(l.wo.clone()),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: Linear::Dense(l.w1.clone()),
                b1: l.b1.clone(),
                w2: Linear::Dense(l.w2.clone()),
                b2: l.b2.clone(),
            })
            .collect();
        Engine {
            config: w.config,
            kv: KvCacheConfig::dense(),
            backend: Arc::new(SingleThread),
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// Replace the engine's KV cache configuration (builder style) —
    /// how callers opt into quantized KV pages or a non-default page
    /// size. Affects only caches built *after* the call. A quant spec
    /// whose layer count mismatches the model is rejected by
    /// `KvCache::new` on the first cache build.
    pub fn with_kv_config(mut self, kv: KvCacheConfig) -> Engine {
        self.kv = kv;
        self
    }

    /// Install an execution backend (builder style): single-thread
    /// ([`crate::infer::backend::SingleThread`], the default),
    /// column-sharded ([`crate::infer::backend::ColumnSharded`]), or
    /// layer-pipeline ([`crate::infer::backend::LayerPipeline`]). Every
    /// forward — `generate`, prefill, decode, serving, speculative
    /// verify — routes through it. Backends are bit-identical by
    /// contract, so swapping one in changes wall-clock, never tokens;
    /// the sharding test suite pins this for W ∈ {1, 2, 4} on both
    /// shard axes.
    pub fn with_backend(mut self, backend: impl Backend + 'static) -> Engine {
        self.backend = Arc::new(backend);
        self
    }

    /// Name of the installed execution backend (diagnostics/benches).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Install an activation-quantization spec (builder style): every
    /// packed linear looks up its `(layer, role)` entry and quantizes
    /// its *input* rows to that depth on the fly during decode/prefill,
    /// running the integer-integer W·A tile path. Matrices without an
    /// entry (or with a `bits == 0` entry) keep full-precision f32
    /// inputs; dense linears always do — the spec only governs packed
    /// weights, so a dense baseline engine is unaffected by design.
    /// [`Engine::from_quantized`] applies a container's persisted spec
    /// automatically; this entry point lets callers override it (e.g.
    /// the W·A benchmark's per-arm sweeps).
    pub fn with_act_quant(mut self, spec: &ActQuantSpec) -> Engine {
        for (li, l) in self.layers.iter_mut().enumerate() {
            let slots: [(Role, &mut Linear); 6] = [
                (Role::Q, &mut l.wq),
                (Role::K, &mut l.wk),
                (Role::V, &mut l.wv),
                (Role::O, &mut l.wo),
                (Role::Up, &mut l.w1),
                (Role::Down, &mut l.w2),
            ];
            for (role, lin) in slots {
                if let Linear::Quant { act, .. } = lin {
                    *act = spec
                        .get(MatId { layer: li, role })
                        .unwrap_or_else(ActQuantParams::full_precision);
                }
            }
        }
        self
    }

    /// The KV cache configuration caches are built with.
    pub fn kv_config(&self) -> &KvCacheConfig {
        &self.kv
    }

    /// Fresh paged cache under the engine's KV configuration. Pages are
    /// allocated lazily as the lane grows — the seed's eager
    /// `max_seq · dim` reservation is gone; serving budgets are enforced
    /// by `KvPool` admission accounting instead (see `infer::kv`).
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.config, &self.kv)
    }

    /// Fresh cache under an explicit KV configuration (calibration and
    /// tests; serving goes through [`Engine::new_cache`]).
    pub fn new_cache_with(&self, kv: &KvCacheConfig) -> KvCache {
        KvCache::new(&self.config, kv)
    }

    /// Fresh cache with its first `rows` positions attached from shared
    /// prefix pages (`infer::prefix`) — the prefill-from-attached-pages
    /// entry point. The scheduler then feeds the REMAINING prompt
    /// through the ordinary chunked prefill: positional embeddings
    /// continue from `cache.len` exactly as for a resumed lane, and
    /// attention reads the attached rows through the same `KvRows` views
    /// as lane-owned rows, so decode is bit-identical to a lane that
    /// prefilled the whole prompt itself.
    pub fn new_cache_with_prefix(&self, pages: &[Arc<KvPageSet>], rows: usize) -> KvCache {
        let mut cache = self.new_cache();
        cache.attach_prefix(pages, rows);
        cache
    }

    /// Decode one token for one sequence. Batch-of-one wrapper around
    /// [`Engine::step_batch`] — see there for the token contract.
    pub fn step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        self.step_batch(&[token], std::slice::from_mut(cache))
            .pop()
            .expect("batch of one yields one logit vector")
    }

    /// Decode one token for each of B independent sequences, appending to
    /// each sequence's KV cache and returning per-sequence logits.
    /// Chunks-of-one wrapper around [`Engine::prefill_batch_masked`], so
    /// decode and prefill share one numeric path.
    ///
    /// Token contract: callers must pass `token < config.vocab`. Debug
    /// builds assert; release builds clamp to the last vocab entry rather
    /// than silently wrapping (the seed's `token % vocab` hid caller
    /// bugs by aliasing distinct tokens).
    pub fn step_batch(&self, tokens: &[u32], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        self.step_batch_masked(tokens, caches, None)
    }

    /// [`Engine::step_batch`] with an optional per-lane emit mask: lanes
    /// whose flag is `false` still run the full transformer step (their
    /// KV caches must advance) but skip the tied-head logits — the
    /// dominant cost on small models — and get an empty vector back.
    pub fn step_batch_masked(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
        emit: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let chunks: Vec<&[u32]> = tokens.iter().map(std::slice::from_ref).collect();
        self.prefill_batch_masked(&chunks, caches, emit)
    }

    /// Chunked prefill: feed each lane a chunk of consecutive tokens in
    /// ONE forward pass and return, per lane, the logits after its final
    /// chunk position — exactly what a `step()` loop over the same
    /// tokens would have left in hand, but with every linear running as
    /// a (ΣT)-row GEMM so bitstream decode amortizes across positions as
    /// well as lanes.
    ///
    /// Bit-identity: the per-position FP reduction order is identical to
    /// token-by-token stepping — each position's linears accumulate in
    /// the row-order-independent `matgem` path, and its attention runs
    /// over the same causal window (cached prefix + earlier chunk
    /// positions) in the same cache order via
    /// [`transformer::attend_cached`] — so chunked prefill reproduces
    /// the sequential `step()` loop exactly (logits AND cache contents).
    pub fn prefill_batch(&self, chunks: &[&[u32]], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        self.prefill_batch_masked(chunks, caches, None)
    }

    /// [`Engine::prefill_batch`] with an optional per-lane emit mask.
    /// Masked lanes (and lanes given an empty chunk, which the scheduler
    /// uses to idle a lane for an iteration without dropping it from the
    /// batch) return an empty logits vector; empty-chunk lanes' caches
    /// are untouched.
    pub fn prefill_batch_masked(
        &self,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        emit: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let bn = chunks.len();
        assert_eq!(bn, caches.len(), "one KV cache per sequence");
        if let Some(m) = emit {
            assert_eq!(bn, m.len(), "one emit flag per sequence");
        }
        if bn == 0 {
            return Vec::new();
        }
        let cfg = &self.config;
        let emits = |b: usize| emit.map_or(true, |m| m[b]) && !chunks[b].is_empty();
        // One prefix-sum shared with forward_chunk — the
        // `xs[row_off[b + 1] - 1]` last-row indexing below relies on the
        // same layout the forward used.
        let row_off = row_offsets(chunks);
        let xs = self.forward_chunk(chunks, caches, &row_off);

        // Final LN + tied head for the LAST chunk position of each
        // emitting lane only (earlier positions exist to fill the KV
        // cache; their logits would be discarded). Same per-(v, lane)
        // dot order as the decode path always used: chunk the vocab
        // across the pool, disjoint writes into a flat lane-major
        // buffer.
        let live: Vec<(usize, Vec<f32>)> = (0..bn)
            .filter(|&b| emits(b))
            .map(|b| (b, ln_vec(&xs[row_off[b + 1] - 1], &self.lnf_g, &self.lnf_b)))
            .collect();
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); bn];
        if live.is_empty() {
            return out;
        }
        let mut logits_flat = vec![0f32; live.len() * cfg.vocab];
        let out_ptr = SendMut(logits_flat.as_mut_ptr());
        parallel_for_chunks(cfg.vocab, 64, |c0, c1| {
            let out_ptr = out_ptr;
            for vi in c0..c1 {
                let row = self.embed.row(vi);
                for (j, (_, z)) in live.iter().enumerate() {
                    let dot: f32 = z.iter().zip(row).map(|(&a, &w)| a * w).sum();
                    // SAFETY: vocab chunks are disjoint, so each (j, vi)
                    // slot is written by exactly one lane.
                    unsafe { *out_ptr.0.add(j * cfg.vocab + vi) = dot };
                }
            }
        });
        for ((b, _), row) in live.iter().zip(split_rows(logits_flat, live.len())) {
            out[*b] = row;
        }
        out
    }

    /// Chunked forward returning the logits after **every** chunk
    /// position, per lane — the speculative-decoding verify primitive:
    /// feeding `[pending, draft₀, …, draftₖ₋₁]` scores all k draft
    /// positions in ONE target forward (GEMM-amortized like any prefill)
    /// instead of k sequential steps. Caches advance by the full chunk
    /// (provisional rows; reject a suffix with [`KvCache::truncate_to`]).
    ///
    /// Bit-identity: position `p`'s logits equal what
    /// [`Engine::prefill_batch`] would return for a chunk ending at `p`
    /// — same forward, same tied-head dot order — so a verify pass and a
    /// step loop see identical numbers (the speculative token-identity
    /// guarantee builds on this). Lanes with empty chunks return an
    /// empty vector and their caches are untouched.
    pub fn prefill_positions(
        &self,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
    ) -> Vec<Vec<Vec<f32>>> {
        let bn = chunks.len();
        assert_eq!(bn, caches.len(), "one KV cache per sequence");
        if bn == 0 {
            return Vec::new();
        }
        let cfg = &self.config;
        let row_off = row_offsets(chunks);
        let xs = self.forward_chunk(chunks, caches, &row_off);
        // Per-position final LN + tied head. Positions are independent;
        // each logit uses the same `z · embed_row` dot order as the
        // last-position head in `prefill_batch_masked`, so the two entry
        // points agree bit-for-bit on shared positions.
        let rows: Vec<Vec<f32>> = parallel_map(xs.len(), 1, |r| {
            let z = ln_vec(&xs[r], &self.lnf_g, &self.lnf_b);
            let mut row = vec![0f32; cfg.vocab];
            for (vi, lr) in row.iter_mut().enumerate() {
                *lr = z.iter().zip(self.embed.row(vi)).map(|(&a, &w)| a * w).sum();
            }
            row
        });
        let mut rows = rows.into_iter();
        (0..bn)
            .map(|b| (row_off[b]..row_off[b + 1]).map(|_| rows.next().unwrap()).collect())
            .collect()
    }

    /// The shared transformer body: embed every chunk position, run all
    /// blocks (GEMM linears + causal attention against each lane's
    /// cache), append each lane's K/V chunk per layer in one batched
    /// reservation, advance every lane's clock by its chunk length, and
    /// return all N = ΣT hidden rows (lane-major, pre-final-LN).
    /// `row_off` must be `row_offsets(chunks)` — passed in so the caller
    /// indexes the returned rows with the exact layout used here.
    ///
    /// Routes through the installed [`Backend`]; the pieces a backend
    /// composes are [`Engine::embed_rows`], [`Engine::run_layers`], and
    /// [`advance_clock`], with [`Engine::forward_chunk_mode`] as the
    /// whole-forward shortcut.
    fn forward_chunk(
        &self,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
    ) -> Vec<Vec<f32>> {
        let backend = Arc::clone(&self.backend);
        backend.forward_chunk(self, chunks, caches, row_off)
    }

    /// One whole forward (embed → all layers → clock advance) with every
    /// linear executed under `mode` — the single-process backends are
    /// thin wrappers over this.
    pub(crate) fn forward_chunk_mode(
        &self,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
        mode: GemmMode,
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(row_off, row_offsets(chunks).as_slice());
        let n = *row_off.last().unwrap();
        if n == 0 {
            return Vec::new();
        }
        let (xs, row_win) = self.embed_rows(chunks, caches);
        let xs = self.run_layers(0, self.layers.len(), xs, &row_win, caches, row_off, mode);
        advance_clock(chunks, caches);
        xs
    }

    /// Embedding + positions for every chunk position; returns the N
    /// hidden rows and each row's `(lane, causal window end)` for
    /// attention. Pure read of the caches (clocks advance only in
    /// [`advance_clock`], after all layers have run).
    pub(crate) fn embed_rows(
        &self,
        chunks: &[&[u32]],
        caches: &[KvCache],
    ) -> (Vec<Vec<f32>>, Vec<(usize, usize)>) {
        let cfg = &self.config;
        let n: usize = chunks.iter().map(|c| c.len()).sum();
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(n);
        let mut row_win: Vec<(usize, usize)> = Vec::with_capacity(n);
        for (b, (chunk, cache)) in chunks.iter().zip(caches.iter()).enumerate() {
            let base = cache.len;
            debug_assert!(
                base + chunk.len() <= cfg.max_seq,
                "chunk overruns the positional table ({base} cached + {} fed > max_seq {}): \
                 truncate at admission (Engine::admit_prompt)",
                chunk.len(),
                cfg.max_seq
            );
            for (p, &t) in chunk.iter().enumerate() {
                debug_assert!(
                    (t as usize) < cfg.vocab,
                    "token {t} out of vocab (vocab size {})",
                    cfg.vocab
                );
                let tok = (t as usize).min(cfg.vocab - 1);
                let pos_idx = (base + p).min(cfg.max_seq - 1);
                xs.push(
                    self.embed
                        .row(tok)
                        .iter()
                        .zip(self.pos.row(pos_idx))
                        .map(|(&a, &b2)| a + b2)
                        .collect(),
                );
                row_win.push((b, base + p + 1));
            }
        }
        (xs, row_win)
    }

    /// Run transformer blocks `lo..hi` over the hidden rows: per-layer
    /// LN → Q/K/V projections → K/V append (absolute layer index) →
    /// causal attention → output/MLP projections, all linears executed
    /// under `mode`. `row_win` must be lane-rebased to THESE
    /// chunks/caches (the layer-pipeline backend hands each micro-batch
    /// a cache sub-slice); `row_off` likewise. Caches' `len` clocks are
    /// NOT advanced — a pipeline stage runs only its layer span, and
    /// every stage's `embed`-time `cache.len` must mean the same prefix
    /// length, so the clock moves once per forward in [`advance_clock`].
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn run_layers(
        &self,
        lo: usize,
        hi: usize,
        mut xs: Vec<Vec<f32>>,
        row_win: &[(usize, usize)],
        caches: &mut [KvCache],
        row_off: &[usize],
        mode: GemmMode,
    ) -> Vec<Vec<f32>> {
        let cfg = &self.config;
        let (e, hds, dh) = (cfg.dim, cfg.heads, cfg.head_dim());
        let n = xs.len();
        debug_assert_eq!(n, row_win.len());
        debug_assert!(lo <= hi && hi <= self.layers.len());
        for (off, l) in self.layers[lo..hi].iter().enumerate() {
            let li = lo + off;
            let a: Vec<Vec<f32>> = xs.iter().map(|x| ln_vec(x, &l.ln1_g, &l.ln1_b)).collect();
            let mut q = l.wq.apply(&a, mode);
            for qb in q.iter_mut() {
                for (qv, &b) in qb.iter_mut().zip(&l.bq) {
                    *qv += b;
                }
            }
            let mut k = l.wk.apply(&a, mode);
            for kb in k.iter_mut() {
                for (kv, &b) in kb.iter_mut().zip(&l.bk) {
                    *kv += b;
                }
            }
            let mut v = l.wv.apply(&a, mode);
            for vb in v.iter_mut() {
                for (vv, &b) in vb.iter_mut().zip(&l.bv) {
                    *vv += b;
                }
            }
            for (b, cache) in caches.iter_mut().enumerate() {
                let (r0, r1) = (row_off[b], row_off[b + 1]);
                if r0 < r1 {
                    cache.append_chunk(li, &k[r0..r1], &v[r0..r1]);
                }
            }
            // Fault-injection site (tag = layer): fires after this
            // layer's K/V rows are appended but before any lane's clock
            // advances, so an injected panic leaves rows dangling past
            // `cache.len` — exactly the state the scheduler's
            // `truncate_to(pre_len)` rollback must clean up.
            crate::util::failpoint::fire("engine::forward_chunk::after_append", li as u64);

            // Attention: every row is independent given the (now
            // chunk-inclusive) caches — row r attends over its lane's
            // rows 0..win, i.e. the cached prefix plus chunk positions
            // up to and including its own. Parallel across rows; the
            // per-row op order is fixed by attend_kv regardless of how
            // the cache pages its rows (or quantizes them), which is
            // what keeps paged-dense decode bit-identical to the
            // historical flat cache.
            let caches_ro: &[KvCache] = caches;
            let ctx_all: Vec<Vec<f32>> = parallel_map(n, 8, |r| {
                let (b, win) = row_win[r];
                let (krows, vrows) = caches_ro[b].layer_rows(li);
                transformer::attend_kv(&q[r], &krows, &vrows, win, e, hds, dh)
            });

            let attn = l.wo.apply(&ctx_all, mode);
            for (r, x) in xs.iter_mut().enumerate() {
                for ((xv, &av), &bias) in x.iter_mut().zip(&attn[r]).zip(&l.bo) {
                    *xv += av + bias;
                }
            }

            let bnorm: Vec<Vec<f32>> = xs.iter().map(|x| ln_vec(x, &l.ln2_g, &l.ln2_b)).collect();
            let mut u = l.w1.apply(&bnorm, mode);
            for ub in u.iter_mut() {
                for (uv, &b) in ub.iter_mut().zip(&l.b1) {
                    *uv = gelu(*uv + b);
                }
            }
            let mm = l.w2.apply(&u, mode);
            for (r, x) in xs.iter_mut().enumerate() {
                for ((xv, &mv), &bias) in x.iter_mut().zip(&mm[r]).zip(&l.b2) {
                    *xv += mv + bias;
                }
            }
        }
        xs
    }

    /// Number of transformer blocks (the layer-pipeline backend's
    /// partition axis).
    pub(crate) fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Admission rule shared by [`Engine::generate`] and the serving
    /// scheduler: prompts longer than the positional table are truncated
    /// to their first `max_seq` tokens. The pre-chunking step loop used
    /// to silently clamp the positional index deep inside decode when a
    /// prompt overran the table (garbage numerics, and a reallocating KV
    /// cache); the chunked forward now debug-asserts on overrun — loud
    /// where it used to be silent, while release builds keep the clamp,
    /// mirroring the out-of-vocab token contract — so oversized prompts
    /// are resolved here, once, at admission, where the caller can still
    /// see the whole request.
    pub fn admit_prompt<'a>(&self, prompt: &'a [u32]) -> &'a [u32] {
        &prompt[..prompt.len().min(self.config.max_seq)]
    }

    /// Greedy generation: prefill `prompt` in one chunked pass, then
    /// decode `max_new` tokens. Oversized prompts are truncated at
    /// admission ([`Engine::admit_prompt`]); output tokens are identical
    /// to feeding the prompt through `step()` one token at a time.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let prompt = self.admit_prompt(prompt);
        let mut cache = self.new_cache();
        let mut logits = vec![0f32; self.config.vocab];
        if !prompt.is_empty() {
            logits = self
                .prefill_batch(&[prompt], std::slice::from_mut(&mut cache))
                .pop()
                .expect("one lane yields one logit vector");
        }
        let mut out = Vec::with_capacity(max_new);
        for i in 0..max_new {
            let next = argmax(&logits) as u32;
            out.push(next);
            // Stop *before* stepping once the budget or the positional
            // table is exhausted — the final token's logits would be
            // discarded, so computing them is pure waste (the batched
            // server never does; keeping the schedulers step-identical
            // keeps their benchmark comparison fair).
            if i + 1 == max_new || cache.len >= self.config.max_seq {
                break;
            }
            logits = self.step(next, &mut cache);
        }
        out
    }

    /// Mean next-token NLL of one evaluation window, computed straight
    /// off the engine's (packed or dense) weights in a single chunked
    /// forward — the engine-path twin of `transformer::loss_only`, used
    /// by `eval::perplexity_packed` to evaluate without densifying. The
    /// cross-entropy mirrors `loss_only` exactly (f64 accumulation,
    /// max-subtracted softmax, targets wrapped mod vocab); the logits
    /// come from the engine's numeric path (f32 attention dots where the
    /// training forward uses f64), so the two paths agree to rounding,
    /// not bit-for-bit — see DESIGN.md §Prefill/decode split.
    pub fn window_nll(&self, tokens: &[u32], targets: &[u32]) -> f64 {
        assert_eq!(tokens.len(), targets.len(), "one target per window position");
        assert!(!tokens.is_empty(), "empty evaluation window");
        assert!(
            tokens.len() <= self.config.max_seq,
            "window {} longer than positional table {}",
            tokens.len(),
            self.config.max_seq
        );
        let mut cache = self.new_cache();
        let row_off = [0, tokens.len()];
        let xs = self.forward_chunk(&[tokens], std::slice::from_mut(&mut cache), &row_off);
        let v = self.config.vocab;
        // Per-position logits via the tied head, then CE. Positions are
        // independent; parallelize across them and reduce in position
        // order (deterministic).
        let nlls: Vec<f64> = parallel_map(xs.len(), 1, |r| {
            let z = ln_vec(&xs[r], &self.lnf_g, &self.lnf_b);
            let mut row = vec![0f32; v];
            for (vi, lr) in row.iter_mut().enumerate() {
                *lr = z.iter().zip(self.embed.row(vi)).map(|(&a, &w)| a * w).sum();
            }
            let maxv = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0f64;
            for &x in &row {
                denom += ((x - maxv) as f64).exp();
            }
            let tgt = targets[r] as usize % v;
            -((row[tgt] - maxv) as f64 - denom.ln())
        });
        nlls.iter().sum::<f64>() / nlls.len() as f64
    }
}

/// Advance every lane's KV clock by its chunk length — the one place a
/// forward commits its appended rows. Runs once per forward, after ALL
/// layers (pipeline stages included) have appended: `cache.len` must
/// mean "fully materialized prefix" at every layer, both for attention
/// windows and for the scheduler's `truncate_to(pre_len)` rollback rule
/// (rows past `len` are dangling and reclaimable).
pub(crate) fn advance_clock(chunks: &[&[u32]], caches: &mut [KvCache]) {
    for (chunk, cache) in chunks.iter().zip(caches.iter_mut()) {
        cache.len += chunk.len();
    }
}

/// Prefix sums of chunk lengths: lane `b`'s rows in a flattened
/// lane-major chunk batch are `row_off[b]..row_off[b + 1]`.
pub(crate) fn row_offsets(chunks: &[&[u32]]) -> Vec<usize> {
    let mut off = Vec::with_capacity(chunks.len() + 1);
    let mut acc = 0usize;
    off.push(0);
    for c in chunks {
        acc += c.len();
        off.push(acc);
    }
    off
}

/// Index of the maximum element (first wins on ties) — the greedy
/// decoding rule shared by `generate`, the server, and speculative
/// verification.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::infer::kv::KvQuantSpec;
    use crate::quant::activations::ActScalePolicy;
    use crate::model::transformer;
    use crate::util::rng::Rng;

    fn tiny_weights(seed: u64) -> Weights {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 12 };
        let mut rng = Rng::new(seed);
        Weights::init_training(cfg, &mut rng)
    }

    #[test]
    fn dense_engine_matches_batch_forward() {
        // The decode engine must reproduce the training-path forward
        // logits exactly (same math, different code path).
        let w = tiny_weights(181);
        let mut rng = Rng::new(182);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let cache_fwd = transformer::forward(&w, &toks, 1, 8);
        let logits_fwd = transformer::logits(&w, &cache_fwd.z);

        let engine = Engine::from_dense(&w);
        let mut kv = engine.new_cache();
        for (i, &t) in toks.iter().enumerate() {
            let logits = engine.step(t, &mut kv);
            for v in 0..w.config.vocab {
                let a = logits[v];
                let b = logits_fwd.get(i, v);
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "pos {i} vocab {v}: engine {a} vs forward {b}"
                );
            }
        }
    }

    #[test]
    fn quantized_engine_matches_dequantized_dense_engine() {
        let w = tiny_weights(183);
        let qm = rtn_quantize_model(&w, 6, 8);
        let eq = Engine::from_quantized(&qm);
        let ed = Engine::from_dense(&qm.to_weights());
        let mut rng = Rng::new(184);
        let toks: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let mut kv_q = eq.new_cache();
        let mut kv_d = ed.new_cache();
        for &t in &toks {
            let lq = eq.step(t, &mut kv_q);
            let ld = ed.step(t, &mut kv_d);
            for (a, b) in lq.iter().zip(&ld) {
                assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let w = tiny_weights(185);
        let engine = Engine::from_dense(&w);
        let out1 = engine.generate(&[1, 2, 3], 5);
        let out2 = engine.generate(&[1, 2, 3], 5);
        assert_eq!(out1, out2);
        assert!(out1.len() <= 5);
        assert!(out1.iter().all(|&t| t < 32));
    }

    #[test]
    fn kv_cache_footprint_tracks_sequence_length() {
        // The seed eagerly reserved max_seq·dim per layer even for short
        // lanes; the paged cache must allocate nothing up front and grow
        // page by page with the decoded length.
        let w = tiny_weights(186);
        let engine = Engine::from_dense(&w)
            .with_kv_config(KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() });
        let mut kv = engine.new_cache();
        assert_eq!(kv.layers(), w.config.layers);
        assert_eq!(kv.allocated_bytes(), 0, "fresh cache must not pre-reserve");
        let full = crate::infer::kv::lane_cost_bytes(
            &w.config,
            engine.kv_config(),
            w.config.max_seq,
        );
        let mut prev = 0usize;
        for t in 0..w.config.max_seq as u32 {
            engine.step(t % 32, &mut kv);
            assert!(kv.allocated_bytes() >= prev, "footprint must be monotone");
            prev = kv.allocated_bytes();
            // Vec::with_capacity guarantees "at least" the request, so
            // allow a 2x allocator margin over the exact page accounting.
            let bound =
                2 * crate::infer::kv::lane_cost_bytes(&w.config, engine.kv_config(), kv.len);
            assert!(
                kv.allocated_bytes() <= bound,
                "footprint {} exceeds worst-case accounting {bound} at len {}",
                kv.allocated_bytes(),
                kv.len
            );
        }
        assert!(prev <= 2 * full, "full lane must fit the max_seq accounting (2x margin)");
        // A 3-token lane occupies one page tier, far below max_seq.
        let mut short = engine.new_cache();
        for t in 0..3u32 {
            engine.step(t, &mut short);
        }
        assert!(short.allocated_bytes() < full / 2, "short lane must undercut max_seq");
    }

    #[test]
    fn step_batch_is_bit_identical_to_sequential_steps() {
        // Batching must not perturb any sequence's numerics: run three
        // sequences of different lengths via step(), then compare a joint
        // step_batch() against three more independent step() calls.
        let w = tiny_weights(187);
        for engine in [
            Engine::from_dense(&w),
            Engine::from_quantized(&rtn_quantize_model(&w, 5, 8)),
        ] {
            let prompts: [&[u32]; 3] = [&[1, 2, 3], &[7], &[4, 9, 11, 30]];
            let mut caches: Vec<KvCache> = prompts.iter().map(|_| engine.new_cache()).collect();
            for (p, cache) in prompts.iter().zip(caches.iter_mut()) {
                for &t in *p {
                    engine.step(t, cache);
                }
            }
            let mut caches_solo = caches.clone();
            let next = [5u32, 8, 2];
            let batched = engine.step_batch(&next, &mut caches);
            for b in 0..3 {
                let solo = engine.step(next[b], &mut caches_solo[b]);
                assert_eq!(batched[b], solo, "lane {b}: batched logits differ");
                assert_eq!(caches[b].len, caches_solo[b].len);
                for li in 0..w.config.layers {
                    assert_eq!(caches[b].k_flat(li), caches_solo[b].k_flat(li), "lane {b} K cache");
                    assert_eq!(caches[b].v_flat(li), caches_solo[b].v_flat(li), "lane {b} V cache");
                }
            }
        }
    }

    #[test]
    fn step_batch_empty_is_noop() {
        let w = tiny_weights(188);
        let engine = Engine::from_dense(&w);
        assert!(engine.step_batch(&[], &mut []).is_empty());
    }

    #[test]
    fn step_batch_masked_skips_logits_without_perturbing_lanes() {
        let w = tiny_weights(190);
        let engine = Engine::from_dense(&w);
        let mut caches_masked = vec![engine.new_cache(), engine.new_cache()];
        let mut caches_full = caches_masked.clone();
        let masked =
            engine.step_batch_masked(&[3, 4], &mut caches_masked, Some(&[true, false]));
        let full = engine.step_batch(&[3, 4], &mut caches_full);
        // Emitting lane: identical logits. Masked lane: no logits, but
        // its KV cache must advance identically.
        assert_eq!(masked[0], full[0]);
        assert!(masked[1].is_empty());
        for li in 0..w.config.layers {
            assert_eq!(caches_masked[1].k_flat(li), caches_full[1].k_flat(li));
            assert_eq!(caches_masked[1].v_flat(li), caches_full[1].v_flat(li));
        }
        assert_eq!(caches_masked[1].len, caches_full[1].len);
    }

    #[test]
    fn prefill_batch_is_bit_identical_to_step_loop() {
        // The tentpole invariant: one chunked pass over a prompt must
        // reproduce the sequential step() loop exactly — logits AND
        // cache contents — for dense and packed engines alike.
        let w = tiny_weights(191);
        for engine in [
            Engine::from_dense(&w),
            Engine::from_quantized(&rtn_quantize_model(&w, 5, 8)),
        ] {
            let chunks: [&[u32]; 3] = [&[1, 2, 3, 4, 5, 6, 7], &[9], &[4, 9, 11, 30, 2]];
            let mut caches: Vec<KvCache> = chunks.iter().map(|_| engine.new_cache()).collect();
            let batched = engine.prefill_batch(&chunks, &mut caches);
            for (b, chunk) in chunks.iter().enumerate() {
                let mut solo_cache = engine.new_cache();
                let mut solo = Vec::new();
                for &t in *chunk {
                    solo = engine.step(t, &mut solo_cache);
                }
                assert_eq!(batched[b], solo, "lane {b}: prefill logits differ from step loop");
                assert_eq!(caches[b].len, solo_cache.len);
                for li in 0..w.config.layers {
                    assert_eq!(caches[b].k_flat(li), solo_cache.k_flat(li), "lane {b} K cache");
                    assert_eq!(caches[b].v_flat(li), solo_cache.v_flat(li), "lane {b} V cache");
                }
            }
        }
    }

    #[test]
    fn prefill_positions_matches_step_loop_at_every_position() {
        // The verify primitive: per-position logits from one chunked
        // forward must equal the sequential step() loop's logits at
        // every position (not just the last), dense and packed alike,
        // and the final entry must equal prefill_batch's output.
        let w = tiny_weights(197);
        for engine in [
            Engine::from_dense(&w),
            Engine::from_quantized(&rtn_quantize_model(&w, 5, 8)),
        ] {
            let chunk: &[u32] = &[3, 1, 4, 1, 5, 9, 2];
            let mut cache = engine.new_cache();
            let all = engine
                .prefill_positions(&[chunk], std::slice::from_mut(&mut cache))
                .pop()
                .unwrap();
            assert_eq!(all.len(), chunk.len());
            let mut solo_cache = engine.new_cache();
            for (p, &t) in chunk.iter().enumerate() {
                let step = engine.step(t, &mut solo_cache);
                assert_eq!(all[p], step, "position {p} diverged from step loop");
            }
            assert_eq!(cache.len, solo_cache.len);
            let mut batch_cache = engine.new_cache();
            let last = engine
                .prefill_batch(&[chunk], std::slice::from_mut(&mut batch_cache))
                .pop()
                .unwrap();
            assert_eq!(all.last().unwrap(), &last, "tied-head paths diverged");
            // Empty chunks yield empty logit lists and untouched caches.
            let mut caches = vec![engine.new_cache(), engine.new_cache()];
            let chunks: [&[u32]; 2] = [&[], &[7, 8]];
            let out = engine.prefill_positions(&chunks, &mut caches);
            assert!(out[0].is_empty());
            assert_eq!(out[1].len(), 2);
            assert_eq!(caches[0].len, 0);
        }
    }

    #[test]
    fn prefill_crossing_row_tile_boundary_matches_step_loop() {
        // A chunk longer than GEMM_ROW_TILE spans multiple GEMM row
        // tiles; tile boundaries must not perturb any position.
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 40 };
        let mut rng = Rng::new(195);
        let w = Weights::init_training(cfg, &mut rng);
        let prompt: Vec<u32> = (0..37).map(|i| (i * 7 + 3) % 32).collect();
        assert!(prompt.len() > crate::infer::matvec::GEMM_ROW_TILE);
        for engine in [
            Engine::from_dense(&w),
            Engine::from_quantized(&rtn_quantize_model(&w, 4, 8)),
        ] {
            let mut cache = engine.new_cache();
            let chunked = engine.prefill_batch(&[&prompt], std::slice::from_mut(&mut cache));
            let mut solo_cache = engine.new_cache();
            let mut solo = Vec::new();
            for &t in &prompt {
                solo = engine.step(t, &mut solo_cache);
            }
            assert_eq!(chunked[0], solo, "tile-boundary prefill diverged from step loop");
            for li in 0..cfg.layers {
                assert_eq!(cache.k_flat(li), solo_cache.k_flat(li));
                assert_eq!(cache.v_flat(li), solo_cache.v_flat(li));
            }
        }
    }

    #[test]
    fn split_prefill_chunks_match_single_chunk() {
        // Chunk-budget scheduling splits prompts arbitrarily; the split
        // point must not change anything.
        let w = tiny_weights(192);
        let engine = Engine::from_quantized(&rtn_quantize_model(&w, 4, 8));
        let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3];
        let mut c_all = engine.new_cache();
        let all = engine.prefill_batch(&[&prompt], std::slice::from_mut(&mut c_all));
        let mut c_split = engine.new_cache();
        engine.prefill_batch(&[&prompt[..4]], std::slice::from_mut(&mut c_split));
        let split = engine.prefill_batch(&[&prompt[4..]], std::slice::from_mut(&mut c_split));
        assert_eq!(all, split, "split prefill diverged from single-chunk prefill");
        assert_eq!(c_all.len, c_split.len);
        for li in 0..w.config.layers {
            assert_eq!(c_all.k_flat(li), c_split.k_flat(li));
            assert_eq!(c_all.v_flat(li), c_split.v_flat(li));
        }
    }

    #[test]
    fn prefill_empty_chunk_lane_is_untouched() {
        let w = tiny_weights(194);
        let engine = Engine::from_dense(&w);
        let mut caches = vec![engine.new_cache(), engine.new_cache()];
        let chunks: [&[u32]; 2] = [&[1, 2, 3], &[]];
        let out = engine.prefill_batch(&chunks, &mut caches);
        assert!(out[1].is_empty(), "idle lane must return no logits");
        assert_eq!(caches[1].len, 0);
        assert!(caches[1].k_flat(0).is_empty());
        // The active lane is unaffected by the idle one.
        let mut solo_cache = engine.new_cache();
        let chunk: &[u32] = &[1, 2, 3];
        let solo = engine.prefill_batch(&[chunk], std::slice::from_mut(&mut solo_cache));
        assert_eq!(out[0], solo[0]);
    }

    #[test]
    fn prefill_masked_skips_logits_but_advances_cache() {
        let w = tiny_weights(196);
        let engine = Engine::from_dense(&w);
        let chunks: [&[u32]; 2] = [&[3, 4, 5], &[7, 8]];
        let mut caches_masked = vec![engine.new_cache(), engine.new_cache()];
        let mut caches_full = caches_masked.clone();
        let masked = engine.prefill_batch_masked(&chunks, &mut caches_masked, Some(&[false, true]));
        let full = engine.prefill_batch(&chunks, &mut caches_full);
        assert!(masked[0].is_empty());
        assert_eq!(masked[1], full[1]);
        for li in 0..w.config.layers {
            assert_eq!(caches_masked[0].k_flat(li), caches_full[0].k_flat(li));
            assert_eq!(caches_masked[0].v_flat(li), caches_full[0].v_flat(li));
        }
        assert_eq!(caches_masked[0].len, caches_full[0].len);
    }

    #[test]
    fn prefill_crossing_kv_page_boundary_matches_step_loop() {
        // The paged-dense bit-identity contract at the engine level: with
        // pages much smaller than the prompt, chunked prefill and the
        // sequential step loop must still agree exactly — logits AND
        // logical cache contents — and both must agree with a single-page
        // (flat-layout) cache. Splits land mid-page and on boundaries.
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 24 };
        let mut rng = Rng::new(198);
        let w = Weights::init_training(cfg, &mut rng);
        let prompt: Vec<u32> = (0..19).map(|i| (i * 5 + 2) % 32).collect();
        for base in [Engine::from_dense(&w), Engine::from_quantized(&rtn_quantize_model(&w, 5, 8))]
        {
            // page_rows = max_seq is literally the seed's flat layout.
            let flat_engine = base.with_kv_config(KvCacheConfig {
                page_rows: cfg.max_seq,
                ..KvCacheConfig::dense()
            });
            let mut flat_cache = flat_engine.new_cache();
            let flat =
                flat_engine.prefill_batch(&[&prompt], std::slice::from_mut(&mut flat_cache));
            let paged_engine = flat_engine
                .with_kv_config(KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() });
            // One chunked pass across 4-row pages.
            let mut paged_cache = paged_engine.new_cache();
            let paged =
                paged_engine.prefill_batch(&[&prompt], std::slice::from_mut(&mut paged_cache));
            assert_eq!(paged, flat, "paged dense diverged from flat layout");
            // Step loop over the same pages, then mid-page + boundary
            // chunk splits (7 is mid-page, 8 lands on a page boundary).
            let mut step_cache = paged_engine.new_cache();
            let mut step = Vec::new();
            for &t in &prompt {
                step = paged_engine.step(t, &mut step_cache);
            }
            assert_eq!(paged[0], step, "paged prefill diverged from step loop");
            let mut split_cache = paged_engine.new_cache();
            paged_engine.prefill_batch(&[&prompt[..7]], std::slice::from_mut(&mut split_cache));
            paged_engine.prefill_batch(&[&prompt[7..8]], std::slice::from_mut(&mut split_cache));
            let split =
                paged_engine.prefill_batch(&[&prompt[8..]], std::slice::from_mut(&mut split_cache));
            assert_eq!(split[0], step, "split chunks diverged across page boundaries");
            for li in 0..cfg.layers {
                assert_eq!(paged_cache.k_flat(li), flat_cache.k_flat(li), "K layer {li}");
                assert_eq!(paged_cache.v_flat(li), flat_cache.v_flat(li), "V layer {li}");
                assert_eq!(split_cache.k_flat(li), step_cache.k_flat(li));
                assert_eq!(split_cache.v_flat(li), step_cache.v_flat(li));
            }
        }
    }

    #[test]
    fn quantized_kv_tracks_dense_kv_logits() {
        // Quantized pages change numerics (by design); at 8 bits the
        // drift must stay within a tight relative tolerance of the dense
        // cache, and decode must remain deterministic.
        let w = tiny_weights(199);
        let spec = KvQuantSpec::uniform(w.config.layers, 8, 1.0, 0.0);
        let dense = Engine::from_dense(&w);
        let toks: Vec<u32> = vec![1, 7, 3, 2, 9, 4];
        let mut dense_cache = dense.new_cache();
        let mut want = Vec::new();
        for &t in &toks {
            want = dense.step(t, &mut dense_cache);
        }
        let quant = Engine::from_dense(&w).with_kv_config(KvCacheConfig {
            page_rows: 4,
            quant: Some(spec),
            flat_reserve: false,
        });
        let mut qc = quant.new_cache();
        assert!(qc.is_quantized());
        let mut got = Vec::new();
        for &t in &toks {
            got = quant.step(t, &mut qc);
        }
        assert_eq!(qc.len, dense_cache.len);
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() < 5e-2 * b.abs().max(1.0),
                "8-bit KV drifted too far: {a} vs {b}"
            );
        }
        // Determinism: same engine, same tokens, same logits and tokens.
        assert_eq!(quant.generate(&toks, 4), quant.generate(&toks, 4));
    }

    #[test]
    fn act_quantized_engine_tracks_f32_activations_and_is_deterministic() {
        // The W·A tentpole at the engine level: with every packed linear's
        // input quantized to 8 bits (per-token scales), decode logits must
        // stay within a tight relative tolerance of the f32-activation
        // engine over the SAME packed weights, prefill must stay
        // bit-identical to the step loop (per-row scales make chunking
        // invisible), and generation must be deterministic.
        let w = tiny_weights(201);
        let qm = rtn_quantize_model(&w, 6, 8); // Uniform mode → integer path
        let ids: Vec<MatId> = qm.packed.iter().map(|(id, _)| *id).collect();
        let spec = ActQuantSpec::uniform(&ids, 8, ActScalePolicy::PerToken, 1.0);
        let f32_engine = Engine::from_quantized(&qm);
        let int_engine = Engine::from_quantized(&qm).with_act_quant(&spec);
        let toks: Vec<u32> = vec![1, 7, 3, 2, 9, 4];
        let mut fc = f32_engine.new_cache();
        let mut ic = int_engine.new_cache();
        let (mut want, mut got) = (Vec::new(), Vec::new());
        for &t in &toks {
            want = f32_engine.step(t, &mut fc);
            got = int_engine.step(t, &mut ic);
        }
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() < 5e-2 * b.abs().max(1.0),
                "8-bit activations drifted too far: {a} vs {b}"
            );
        }
        // Chunked prefill == step loop, exactly, even with quantized
        // inputs: per-token scales are per-row, so tiling can't leak
        // across positions.
        let mut pc = int_engine.new_cache();
        let chunked = int_engine.prefill_batch(&[&toks], std::slice::from_mut(&mut pc));
        assert_eq!(chunked[0], got, "act-quant prefill diverged from step loop");
        assert_eq!(int_engine.generate(&toks, 4), int_engine.generate(&toks, 4));
    }

    #[test]
    fn persisted_act_spec_is_applied_automatically() {
        // A container carrying an ActQuantSpec must serve integer W·A
        // without any caller opt-in — from_quantized(qm with spec) must
        // behave exactly like an explicit with_act_quant over the same
        // weights.
        let w = tiny_weights(202);
        let base = rtn_quantize_model(&w, 6, 8);
        let ids: Vec<MatId> = base.packed.iter().map(|(id, _)| *id).collect();
        let spec = ActQuantSpec::uniform(&ids, 8, ActScalePolicy::PerToken, 1.0);
        let manual = Engine::from_quantized(&base).with_act_quant(&spec);
        let mut qm = rtn_quantize_model(&w, 6, 8);
        qm.act_quant = Some(spec);
        let auto = Engine::from_quantized(&qm);
        let toks: Vec<u32> = vec![2, 5, 1, 8];
        let mut mc = manual.new_cache();
        let mut ac = auto.new_cache();
        for &t in &toks {
            assert_eq!(manual.step(t, &mut mc), auto.step(t, &mut ac));
        }
        assert_eq!(auto.generate(&toks, 4), manual.generate(&toks, 4));
    }

    #[test]
    fn mixed_precision_act_spec_quantizes_only_listed_matrices() {
        // Matrices without a spec entry (and bits-0 entries) keep the f32
        // input path bit-for-bit; only listed layers change numerics.
        let w = tiny_weights(203);
        let qm = rtn_quantize_model(&w, 6, 8);
        let ids: Vec<MatId> = qm.packed.iter().map(|(id, _)| *id).collect();
        let toks: Vec<u32> = vec![4, 1, 6, 3, 2];
        let baseline = Engine::from_quantized(&qm);
        let mut bc = baseline.new_cache();
        let mut want = Vec::new();
        for &t in &toks {
            want = baseline.step(t, &mut bc);
        }
        // An all-full-precision spec is a no-op: identical bits out.
        let fp_spec = ActQuantSpec::uniform(&ids, 0, ActScalePolicy::PerToken, 1.0);
        let fp_engine = Engine::from_quantized(&qm).with_act_quant(&fp_spec);
        let mut fc = fp_engine.new_cache();
        let mut fp_got = Vec::new();
        for &t in &toks {
            fp_got = fp_engine.step(t, &mut fc);
        }
        assert_eq!(fp_got, want, "bits-0 spec must leave the f32 path untouched");
        // Layer-0-only spec: still close to baseline, still deterministic,
        // and the layer-1 linears run the identical f32 path internally.
        let l0_ids: Vec<MatId> = ids.iter().filter(|id| id.layer == 0).copied().collect();
        assert!(!l0_ids.is_empty() && l0_ids.len() < ids.len());
        let l0_spec = ActQuantSpec::uniform(&l0_ids, 8, ActScalePolicy::PerToken, 1.0);
        let mixed = Engine::from_quantized(&qm).with_act_quant(&l0_spec);
        let mut mc = mixed.new_cache();
        let mut got = Vec::new();
        for &t in &toks {
            got = mixed.step(t, &mut mc);
        }
        for (a, b) in got.iter().zip(&want) {
            assert!(
                (a - b).abs() < 5e-2 * b.abs().max(1.0),
                "mixed-precision drift too large: {a} vs {b}"
            );
        }
        assert_eq!(mixed.generate(&toks, 3), mixed.generate(&toks, 3));
    }

    #[test]
    fn generate_truncates_oversized_prompts_at_admission() {
        let w = tiny_weights(193);
        let engine = Engine::from_dense(&w);
        let max_seq = engine.config.max_seq;
        // Boundary: a prompt exactly filling the positional table still
        // yields one token (from the final prompt logits), cleanly.
        let exact: Vec<u32> = (0..max_seq as u32).map(|i| i % 32).collect();
        let out = engine.generate(&exact, 4);
        assert_eq!(out.len(), 1);
        // Past the boundary: truncation at admission, no deep panic, and
        // the result equals generating from the truncated prompt.
        let long: Vec<u32> = (0..max_seq as u32 + 5).map(|i| i % 32).collect();
        assert_eq!(engine.admit_prompt(&long).len(), max_seq);
        assert_eq!(engine.generate(&long, 4), engine.generate(&long[..max_seq], 4));
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of vocab")]
    fn step_rejects_out_of_vocab_tokens_in_debug() {
        let w = tiny_weights(189);
        let engine = Engine::from_dense(&w);
        let mut kv = engine.new_cache();
        engine.step(999, &mut kv);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn step_clamps_out_of_vocab_tokens_in_release() {
        let w = tiny_weights(189);
        let engine = Engine::from_dense(&w);
        let mut kv_bad = engine.new_cache();
        let mut kv_ref = engine.new_cache();
        let bad = engine.step(999, &mut kv_bad);
        let clamped = engine.step(31, &mut kv_ref);
        assert_eq!(bad, clamped, "release builds must clamp, not wrap");
    }
}
