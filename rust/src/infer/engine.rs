//! Quantized autoregressive inference engine: KV-cached decode running
//! every transformer-block matmul straight off the packed bitstreams via
//! the mixed-precision kernels. A dense-f32 engine over the same code
//! path provides the FP baseline (Table 7's comparison and the serving
//! example's control arm).
//!
//! The hot entry point is [`Engine::step_batch`]: one forward step for B
//! independent sequences that decodes each weight column's code stream
//! once for the whole batch (see [`crate::infer::matvec::MatvecPlan::matmul`]).
//! [`Engine::step`] is the batch-of-one wrapper, so single-request and
//! batched serving share one numeric path — results are bit-identical
//! regardless of what else is co-scheduled in the batch, which is the
//! invariant the continuous-batching server's determinism tests pin down.

use crate::infer::matvec::{dense_matmul, split_rows, MatvecPlan, SendMut};
use crate::model::config::ModelConfig;
use crate::model::tensor::Tensor;
use crate::model::weights::{Role, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::format::QuantizedModel;
use crate::util::threadpool::parallel_for_chunks;

const LN_EPS: f32 = 1e-5;

/// One linear layer: dense or packed-quantized.
enum Linear {
    Dense(Tensor),
    Quant { pm: PackedMatrix, plan: MatvecPlan },
}

impl Linear {
    /// Batched apply: decode once, transform all B activation vectors.
    fn apply_batch(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        match self {
            Linear::Dense(w) => dense_matmul(w, xs),
            Linear::Quant { pm, plan } => plan.matmul(pm, xs),
        }
    }
}

struct EngineLayer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Linear,
    bq: Vec<f32>,
    wk: Linear,
    bk: Vec<f32>,
    wv: Linear,
    bv: Vec<f32>,
    wo: Linear,
    bo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Linear,
    b1: Vec<f32>,
    w2: Linear,
    b2: Vec<f32>,
}

/// The decode engine.
pub struct Engine {
    pub config: ModelConfig,
    embed: Tensor,
    pos: Tensor,
    layers: Vec<EngineLayer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

/// Per-sequence attention cache: cached K and V per layer, (t×E) grown
/// one row per decoded token. Construction pre-reserves the full
/// `max_seq · dim` per layer so decode never reallocates mid-stream.
#[derive(Clone)]
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pub len: usize,
}

impl KvCache {
    pub fn new(cfg: &ModelConfig) -> KvCache {
        let cap = cfg.max_seq * cfg.dim;
        KvCache {
            k: (0..cfg.layers).map(|_| Vec::with_capacity(cap)).collect(),
            v: (0..cfg.layers).map(|_| Vec::with_capacity(cap)).collect(),
            len: 0,
        }
    }
}

fn ln_vec(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let e = x.len();
    let mu = x.iter().sum::<f32>() / e as f32;
    let var = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / e as f32;
    let rs = 1.0 / (var + LN_EPS).sqrt();
    x.iter()
        .zip(g.iter().zip(b))
        .map(|(&v, (&gv, &bv))| gv * (v - mu) * rs + bv)
        .collect()
}

#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

impl Engine {
    /// Build a quantized engine (weights stay packed; decode runs the
    /// mixed-precision kernel).
    pub fn from_quantized(qm: &QuantizedModel) -> Engine {
        let w = &qm.base;
        let mut layers = Vec::with_capacity(w.layers.len());
        let find = |layer: usize, role: Role| -> Linear {
            let pm = qm
                .packed
                .iter()
                .find(|(id, _)| id.layer == layer && id.role == role)
                .map(|(_, p)| p.clone())
                .expect("missing packed matrix");
            let plan = MatvecPlan::new(&pm);
            Linear::Quant { pm, plan }
        };
        for (li, l) in w.layers.iter().enumerate() {
            layers.push(EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: find(li, Role::Q),
                bq: l.bq.clone(),
                wk: find(li, Role::K),
                bk: l.bk.clone(),
                wv: find(li, Role::V),
                bv: l.bv.clone(),
                wo: find(li, Role::O),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: find(li, Role::Up),
                b1: l.b1.clone(),
                w2: find(li, Role::Down),
                b2: l.b2.clone(),
            });
        }
        Engine {
            config: w.config,
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// Dense-f32 engine (the FP baseline arm).
    pub fn from_dense(w: &Weights) -> Engine {
        let layers = w
            .layers
            .iter()
            .map(|l| EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: Linear::Dense(l.wq.clone()),
                bq: l.bq.clone(),
                wk: Linear::Dense(l.wk.clone()),
                bk: l.bk.clone(),
                wv: Linear::Dense(l.wv.clone()),
                bv: l.bv.clone(),
                wo: Linear::Dense(l.wo.clone()),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: Linear::Dense(l.w1.clone()),
                b1: l.b1.clone(),
                w2: Linear::Dense(l.w2.clone()),
                b2: l.b2.clone(),
            })
            .collect();
        Engine {
            config: w.config,
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// Fresh cache sized for this engine's model.
    pub fn new_cache(&self) -> KvCache {
        KvCache::new(&self.config)
    }

    /// Decode one token for one sequence. Batch-of-one wrapper around
    /// [`Engine::step_batch`] — see there for the token contract.
    pub fn step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        self.step_batch(&[token], std::slice::from_mut(cache))
            .pop()
            .expect("batch of one yields one logit vector")
    }

    /// Decode one token for each of B independent sequences, appending to
    /// each sequence's KV cache and returning per-sequence logits.
    ///
    /// Every per-layer linear runs through the batch-amortized GEMM, so
    /// the packed code streams are decoded once per layer per *step*
    /// rather than once per layer per *sequence*; the tied-head logits
    /// parallelize across the vocabulary.
    ///
    /// Token contract: callers must pass `token < config.vocab`. Debug
    /// builds assert; release builds clamp to the last vocab entry rather
    /// than silently wrapping (the seed's `token % vocab` hid caller
    /// bugs by aliasing distinct tokens).
    pub fn step_batch(&self, tokens: &[u32], caches: &mut [KvCache]) -> Vec<Vec<f32>> {
        self.step_batch_masked(tokens, caches, None)
    }

    /// [`Engine::step_batch`] with an optional per-lane emit mask: lanes
    /// whose flag is `false` still run the full transformer step (their
    /// KV caches must advance) but skip the tied-head logits — the
    /// dominant cost on small models — and get an empty vector back. The
    /// continuous-batching server uses this to avoid paying the head for
    /// lanes that are still prefilling their prompt.
    pub fn step_batch_masked(
        &self,
        tokens: &[u32],
        caches: &mut [KvCache],
        emit: Option<&[bool]>,
    ) -> Vec<Vec<f32>> {
        let bn = tokens.len();
        assert_eq!(bn, caches.len(), "one KV cache per sequence");
        if let Some(m) = emit {
            assert_eq!(bn, m.len(), "one emit flag per sequence");
        }
        if bn == 0 {
            return Vec::new();
        }
        let emits = |b: usize| emit.map_or(true, |m| m[b]);
        let cfg = &self.config;
        let (e, hds, dh) = (cfg.dim, cfg.heads, cfg.head_dim());

        let mut xs: Vec<Vec<f32>> = tokens
            .iter()
            .zip(caches.iter())
            .map(|(&t, cache)| {
                debug_assert!(
                    (t as usize) < cfg.vocab,
                    "token {t} out of vocab (vocab size {})",
                    cfg.vocab
                );
                let tok = (t as usize).min(cfg.vocab - 1);
                let pos_idx = cache.len.min(cfg.max_seq - 1);
                self.embed
                    .row(tok)
                    .iter()
                    .zip(self.pos.row(pos_idx))
                    .map(|(&a, &b)| a + b)
                    .collect()
            })
            .collect();

        for (li, l) in self.layers.iter().enumerate() {
            let a: Vec<Vec<f32>> = xs.iter().map(|x| ln_vec(x, &l.ln1_g, &l.ln1_b)).collect();
            let mut q = l.wq.apply_batch(&a);
            let k = {
                let mut k = l.wk.apply_batch(&a);
                for kb in k.iter_mut() {
                    for (kv, &b) in kb.iter_mut().zip(&l.bk) {
                        *kv += b;
                    }
                }
                k
            };
            let v = {
                let mut v = l.wv.apply_batch(&a);
                for vb in v.iter_mut() {
                    for (vv, &b) in vb.iter_mut().zip(&l.bv) {
                        *vv += b;
                    }
                }
                v
            };
            for qb in q.iter_mut() {
                for (qv, &b) in qb.iter_mut().zip(&l.bq) {
                    *qv += b;
                }
            }
            for (b, cache) in caches.iter_mut().enumerate() {
                cache.k[li].extend_from_slice(&k[b]);
                cache.v[li].extend_from_slice(&v[b]);
            }

            // Attention per sequence over its own cache, per head.
            let mut ctx_all: Vec<Vec<f32>> = Vec::with_capacity(bn);
            for (b, cache) in caches.iter().enumerate() {
                let t = cache.k[li].len() / e;
                let mut ctx = vec![0f32; e];
                let scale = 1.0 / (dh as f32).sqrt();
                for h in 0..hds {
                    let qh = &q[b][h * dh..(h + 1) * dh];
                    // Scores against all cached keys.
                    let mut scores = Vec::with_capacity(t);
                    let mut maxs = f32::NEG_INFINITY;
                    for ti in 0..t {
                        let kh = &cache.k[li][ti * e + h * dh..ti * e + (h + 1) * dh];
                        let s: f32 =
                            qh.iter().zip(kh).map(|(&a2, &b2)| a2 * b2).sum::<f32>() * scale;
                        scores.push(s);
                        maxs = maxs.max(s);
                    }
                    let mut denom = 0f32;
                    for s in scores.iter_mut() {
                        *s = (*s - maxs).exp();
                        denom += *s;
                    }
                    let ctx_h = &mut ctx[h * dh..(h + 1) * dh];
                    for ti in 0..t {
                        let p = scores[ti] / denom;
                        let vh = &cache.v[li][ti * e + h * dh..ti * e + (h + 1) * dh];
                        for (c, &vv) in ctx_h.iter_mut().zip(vh) {
                            *c += p * vv;
                        }
                    }
                }
                ctx_all.push(ctx);
            }

            let attn = l.wo.apply_batch(&ctx_all);
            for (b, x) in xs.iter_mut().enumerate() {
                for ((xv, &av), &bias) in x.iter_mut().zip(&attn[b]).zip(&l.bo) {
                    *xv += av + bias;
                }
            }

            let bnorm: Vec<Vec<f32>> = xs.iter().map(|x| ln_vec(x, &l.ln2_g, &l.ln2_b)).collect();
            let mut u = l.w1.apply_batch(&bnorm);
            for ub in u.iter_mut() {
                for (uv, &b) in ub.iter_mut().zip(&l.b1) {
                    *uv = gelu(*uv + b);
                }
            }
            let mm = l.w2.apply_batch(&u);
            for (b, x) in xs.iter_mut().enumerate() {
                for ((xv, &mv), &bias) in x.iter_mut().zip(&mm[b]).zip(&l.b2) {
                    *xv += mv + bias;
                }
            }
        }
        for cache in caches.iter_mut() {
            cache.len += 1;
        }

        let zs: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| ln_vec(x, &self.lnf_g, &self.lnf_b))
            .collect();
        // Tied head: logits[b][v] = z_b · embed[v]. The vocab × dim dot
        // products dominate small-model steps; chunk them across the pool
        // into one flat lane-major buffer with disjoint writes (per-(v, b)
        // dot order is fixed, so results stay deterministic). Masked
        // lanes skip the dots entirely.
        let mut logits_flat = vec![0f32; bn * cfg.vocab];
        let out_ptr = SendMut(logits_flat.as_mut_ptr());
        parallel_for_chunks(cfg.vocab, 64, |c0, c1| {
            let out_ptr = out_ptr;
            for vi in c0..c1 {
                let row = self.embed.row(vi);
                for (b, z) in zs.iter().enumerate() {
                    if !emits(b) {
                        continue;
                    }
                    let dot: f32 = z.iter().zip(row).map(|(&a, &w)| a * w).sum();
                    // SAFETY: vocab chunks are disjoint, so each
                    // (b, vi) slot is written by exactly one lane.
                    unsafe { *out_ptr.0.add(b * cfg.vocab + vi) = dot };
                }
            }
        });
        split_rows(logits_flat, bn)
            .into_iter()
            .enumerate()
            .map(|(b, row)| if emits(b) { row } else { Vec::new() })
            .collect()
    }

    /// Greedy generation: feed `prompt`, then decode `max_new` tokens.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut cache = self.new_cache();
        let mut logits = vec![0f32; self.config.vocab];
        for &t in prompt {
            logits = self.step(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for i in 0..max_new {
            let next = argmax(&logits) as u32;
            out.push(next);
            // Stop *before* stepping once the budget or the positional
            // table is exhausted — the final token's logits would be
            // discarded, so computing them is pure waste (the batched
            // server never does; keeping the schedulers step-identical
            // keeps their benchmark comparison fair).
            if i + 1 == max_new || cache.len >= self.config.max_seq {
                break;
            }
            logits = self.step(next, &mut cache);
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::model::transformer;
    use crate::util::rng::Rng;

    fn tiny_weights(seed: u64) -> Weights {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 12 };
        let mut rng = Rng::new(seed);
        Weights::init_training(cfg, &mut rng)
    }

    #[test]
    fn dense_engine_matches_batch_forward() {
        // The decode engine must reproduce the training-path forward
        // logits exactly (same math, different code path).
        let w = tiny_weights(181);
        let mut rng = Rng::new(182);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let cache_fwd = transformer::forward(&w, &toks, 1, 8);
        let logits_fwd = transformer::logits(&w, &cache_fwd.z);

        let engine = Engine::from_dense(&w);
        let mut kv = KvCache::new(&w.config);
        for (i, &t) in toks.iter().enumerate() {
            let logits = engine.step(t, &mut kv);
            for v in 0..w.config.vocab {
                let a = logits[v];
                let b = logits_fwd.get(i, v);
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "pos {i} vocab {v}: engine {a} vs forward {b}"
                );
            }
        }
    }

    #[test]
    fn quantized_engine_matches_dequantized_dense_engine() {
        let w = tiny_weights(183);
        let qm = rtn_quantize_model(&w, 6, 8);
        let eq = Engine::from_quantized(&qm);
        let ed = Engine::from_dense(&qm.to_weights());
        let mut rng = Rng::new(184);
        let toks: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let mut kv_q = eq.new_cache();
        let mut kv_d = ed.new_cache();
        for &t in &toks {
            let lq = eq.step(t, &mut kv_q);
            let ld = ed.step(t, &mut kv_d);
            for (a, b) in lq.iter().zip(&ld) {
                assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let w = tiny_weights(185);
        let engine = Engine::from_dense(&w);
        let out1 = engine.generate(&[1, 2, 3], 5);
        let out2 = engine.generate(&[1, 2, 3], 5);
        assert_eq!(out1, out2);
        assert!(out1.len() <= 5);
        assert!(out1.iter().all(|&t| t < 32));
    }

    #[test]
    fn kv_cache_preallocates_full_sequence() {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 3, mlp: 32, max_seq: 12 };
        let kv = KvCache::new(&cfg);
        assert_eq!(kv.k.len(), cfg.layers);
        assert_eq!(kv.v.len(), cfg.layers);
        for l in 0..cfg.layers {
            assert!(kv.k[l].capacity() >= cfg.max_seq * cfg.dim);
            assert!(kv.v[l].capacity() >= cfg.max_seq * cfg.dim);
        }
        // Decoding to max_seq must never exceed the reservation (i.e.
        // never reallocate).
        let w = tiny_weights(186);
        let engine = Engine::from_dense(&w);
        let mut kv = engine.new_cache();
        let cap0: Vec<usize> = kv.k.iter().map(|k| k.capacity()).collect();
        for t in 0..cfg.max_seq as u32 {
            engine.step(t % 32, &mut kv);
        }
        let cap1: Vec<usize> = kv.k.iter().map(|k| k.capacity()).collect();
        assert_eq!(cap0, cap1, "KV cache reallocated during decode");
    }

    #[test]
    fn step_batch_is_bit_identical_to_sequential_steps() {
        // Batching must not perturb any sequence's numerics: run three
        // sequences of different lengths via step(), then compare a joint
        // step_batch() against three more independent step() calls.
        let w = tiny_weights(187);
        for engine in [
            Engine::from_dense(&w),
            Engine::from_quantized(&rtn_quantize_model(&w, 5, 8)),
        ] {
            let prompts: [&[u32]; 3] = [&[1, 2, 3], &[7], &[4, 9, 11, 30]];
            let mut caches: Vec<KvCache> = prompts.iter().map(|_| engine.new_cache()).collect();
            for (p, cache) in prompts.iter().zip(caches.iter_mut()) {
                for &t in *p {
                    engine.step(t, cache);
                }
            }
            let mut caches_solo = caches.clone();
            let next = [5u32, 8, 2];
            let batched = engine.step_batch(&next, &mut caches);
            for b in 0..3 {
                let solo = engine.step(next[b], &mut caches_solo[b]);
                assert_eq!(batched[b], solo, "lane {b}: batched logits differ");
                assert_eq!(caches[b].len, caches_solo[b].len);
                for li in 0..w.config.layers {
                    assert_eq!(caches[b].k[li], caches_solo[b].k[li], "lane {b} K cache");
                    assert_eq!(caches[b].v[li], caches_solo[b].v[li], "lane {b} V cache");
                }
            }
        }
    }

    #[test]
    fn step_batch_empty_is_noop() {
        let w = tiny_weights(188);
        let engine = Engine::from_dense(&w);
        assert!(engine.step_batch(&[], &mut []).is_empty());
    }

    #[test]
    fn step_batch_masked_skips_logits_without_perturbing_lanes() {
        let w = tiny_weights(190);
        let engine = Engine::from_dense(&w);
        let mut caches_masked = vec![engine.new_cache(), engine.new_cache()];
        let mut caches_full = caches_masked.clone();
        let masked =
            engine.step_batch_masked(&[3, 4], &mut caches_masked, Some(&[true, false]));
        let full = engine.step_batch(&[3, 4], &mut caches_full);
        // Emitting lane: identical logits. Masked lane: no logits, but
        // its KV cache must advance identically.
        assert_eq!(masked[0], full[0]);
        assert!(masked[1].is_empty());
        for li in 0..w.config.layers {
            assert_eq!(caches_masked[1].k[li], caches_full[1].k[li]);
            assert_eq!(caches_masked[1].v[li], caches_full[1].v[li]);
        }
        assert_eq!(caches_masked[1].len, caches_full[1].len);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "out of vocab")]
    fn step_rejects_out_of_vocab_tokens_in_debug() {
        let w = tiny_weights(189);
        let engine = Engine::from_dense(&w);
        let mut kv = engine.new_cache();
        engine.step(999, &mut kv);
    }

    #[cfg(not(debug_assertions))]
    #[test]
    fn step_clamps_out_of_vocab_tokens_in_release() {
        let w = tiny_weights(189);
        let engine = Engine::from_dense(&w);
        let mut kv_bad = engine.new_cache();
        let mut kv_ref = engine.new_cache();
        let bad = engine.step(999, &mut kv_bad);
        let clamped = engine.step(31, &mut kv_ref);
        assert_eq!(bad, clamped, "release builds must clamp, not wrap");
    }
}
