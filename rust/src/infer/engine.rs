//! Quantized autoregressive inference engine: single-token decode with a
//! KV cache, running every transformer-block matmul straight off the
//! packed bitstreams via the mixed-precision matvec kernel. A dense-f32
//! engine over the same code path provides the FP baseline (Table 7's
//! comparison and the serving example's control arm).

use crate::infer::matvec::{dense_matvec, MatvecPlan};
use crate::model::config::ModelConfig;
use crate::model::tensor::Tensor;
use crate::model::weights::{Role, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::format::QuantizedModel;

const LN_EPS: f32 = 1e-5;

/// One linear layer: dense or packed-quantized.
enum Linear {
    Dense(Tensor),
    Quant { pm: PackedMatrix, plan: MatvecPlan },
}

impl Linear {
    fn apply(&self, x: &[f32]) -> Vec<f32> {
        match self {
            Linear::Dense(w) => dense_matvec(w, x),
            Linear::Quant { pm, plan } => plan.matvec(pm, x),
        }
    }
}

struct EngineLayer {
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    wq: Linear,
    bq: Vec<f32>,
    wk: Linear,
    bk: Vec<f32>,
    wv: Linear,
    bv: Vec<f32>,
    wo: Linear,
    bo: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    w1: Linear,
    b1: Vec<f32>,
    w2: Linear,
    b2: Vec<f32>,
}

/// The decode engine.
pub struct Engine {
    pub config: ModelConfig,
    embed: Tensor,
    pos: Tensor,
    layers: Vec<EngineLayer>,
    lnf_g: Vec<f32>,
    lnf_b: Vec<f32>,
}

/// Per-sequence attention cache: cached K and V per layer, (t×E) grown
/// one row per decoded token.
pub struct KvCache {
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    pub len: usize,
}

impl KvCache {
    pub fn new(layers: usize) -> KvCache {
        KvCache { k: vec![Vec::new(); layers], v: vec![Vec::new(); layers], len: 0 }
    }
}

fn ln_vec(x: &[f32], g: &[f32], b: &[f32]) -> Vec<f32> {
    let e = x.len();
    let mu = x.iter().sum::<f32>() / e as f32;
    let var = x.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / e as f32;
    let rs = 1.0 / (var + LN_EPS).sqrt();
    x.iter()
        .zip(g.iter().zip(b))
        .map(|(&v, (&gv, &bv))| gv * (v - mu) * rs + bv)
        .collect()
}

#[inline]
fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.797_884_6 * (x + 0.044_715 * x * x * x)).tanh())
}

impl Engine {
    /// Build a quantized engine (weights stay packed; decode runs the
    /// mixed-precision kernel).
    pub fn from_quantized(qm: &QuantizedModel) -> Engine {
        let w = &qm.base;
        let mut layers = Vec::with_capacity(w.layers.len());
        let find = |layer: usize, role: Role| -> Linear {
            let pm = qm
                .packed
                .iter()
                .find(|(id, _)| id.layer == layer && id.role == role)
                .map(|(_, p)| p.clone())
                .expect("missing packed matrix");
            let plan = MatvecPlan::new(&pm);
            Linear::Quant { pm, plan }
        };
        for (li, l) in w.layers.iter().enumerate() {
            layers.push(EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: find(li, Role::Q),
                bq: l.bq.clone(),
                wk: find(li, Role::K),
                bk: l.bk.clone(),
                wv: find(li, Role::V),
                bv: l.bv.clone(),
                wo: find(li, Role::O),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: find(li, Role::Up),
                b1: l.b1.clone(),
                w2: find(li, Role::Down),
                b2: l.b2.clone(),
            });
        }
        Engine {
            config: w.config,
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// Dense-f32 engine (the FP baseline arm).
    pub fn from_dense(w: &Weights) -> Engine {
        let layers = w
            .layers
            .iter()
            .map(|l| EngineLayer {
                ln1_g: l.ln1_g.clone(),
                ln1_b: l.ln1_b.clone(),
                wq: Linear::Dense(l.wq.clone()),
                bq: l.bq.clone(),
                wk: Linear::Dense(l.wk.clone()),
                bk: l.bk.clone(),
                wv: Linear::Dense(l.wv.clone()),
                bv: l.bv.clone(),
                wo: Linear::Dense(l.wo.clone()),
                bo: l.bo.clone(),
                ln2_g: l.ln2_g.clone(),
                ln2_b: l.ln2_b.clone(),
                w1: Linear::Dense(l.w1.clone()),
                b1: l.b1.clone(),
                w2: Linear::Dense(l.w2.clone()),
                b2: l.b2.clone(),
            })
            .collect();
        Engine {
            config: w.config,
            embed: w.embed.clone(),
            pos: w.pos.clone(),
            layers,
            lnf_g: w.lnf_g.clone(),
            lnf_b: w.lnf_b.clone(),
        }
    }

    /// Decode one token: append to the KV cache and return the logits.
    pub fn step(&self, token: u32, cache: &mut KvCache) -> Vec<f32> {
        let cfg = &self.config;
        let (e, hds, dh) = (cfg.dim, cfg.heads, cfg.head_dim());
        let pos_idx = cache.len.min(cfg.max_seq - 1);
        let mut x: Vec<f32> = self
            .embed
            .row(token as usize % cfg.vocab)
            .iter()
            .zip(self.pos.row(pos_idx))
            .map(|(&a, &b)| a + b)
            .collect();

        for (li, l) in self.layers.iter().enumerate() {
            let a = ln_vec(&x, &l.ln1_g, &l.ln1_b);
            let mut q = l.wq.apply(&a);
            let mut k = l.wk.apply(&a);
            let mut v = l.wv.apply(&a);
            for (qv, &b) in q.iter_mut().zip(&l.bq) {
                *qv += b;
            }
            for (kv, &b) in k.iter_mut().zip(&l.bk) {
                *kv += b;
            }
            for (vv, &b) in v.iter_mut().zip(&l.bv) {
                *vv += b;
            }
            cache.k[li].extend_from_slice(&k);
            cache.v[li].extend_from_slice(&v);
            let t = cache.k[li].len() / e;

            // Attention over the cache, per head.
            let mut ctx = vec![0f32; e];
            let scale = 1.0 / (dh as f32).sqrt();
            for h in 0..hds {
                let qh = &q[h * dh..(h + 1) * dh];
                // Scores against all cached keys.
                let mut scores = Vec::with_capacity(t);
                let mut maxs = f32::NEG_INFINITY;
                for ti in 0..t {
                    let kh = &cache.k[li][ti * e + h * dh..ti * e + (h + 1) * dh];
                    let s: f32 = qh.iter().zip(kh).map(|(&a2, &b2)| a2 * b2).sum::<f32>() * scale;
                    scores.push(s);
                    maxs = maxs.max(s);
                }
                let mut denom = 0f32;
                for s in scores.iter_mut() {
                    *s = (*s - maxs).exp();
                    denom += *s;
                }
                let ctx_h = &mut ctx[h * dh..(h + 1) * dh];
                for ti in 0..t {
                    let p = scores[ti] / denom;
                    let vh = &cache.v[li][ti * e + h * dh..ti * e + (h + 1) * dh];
                    for (c, &vv) in ctx_h.iter_mut().zip(vh) {
                        *c += p * vv;
                    }
                }
            }
            let mut attn = l.wo.apply(&ctx);
            for ((xv, av), &b) in x.iter_mut().zip(attn.iter_mut()).zip(&l.bo) {
                *xv += *av + b;
            }

            let bn = ln_vec(&x, &l.ln2_g, &l.ln2_b);
            let mut u = l.w1.apply(&bn);
            for (uv, &b) in u.iter_mut().zip(&l.b1) {
                *uv = gelu(*uv + b);
            }
            let m = l.w2.apply(&u);
            for ((xv, &mv), &b) in x.iter_mut().zip(&m).zip(&l.b2) {
                *xv += mv + b;
            }
        }
        cache.len += 1;

        let z = ln_vec(&x, &self.lnf_g, &self.lnf_b);
        // Tied head: logits[v] = z · embed[v].
        let mut logits = vec![0f32; cfg.vocab];
        for (vi, lv) in logits.iter_mut().enumerate() {
            *lv = z.iter().zip(self.embed.row(vi)).map(|(&a, &b)| a * b).sum();
        }
        logits
    }

    /// Greedy generation: feed `prompt`, then decode `max_new` tokens.
    pub fn generate(&self, prompt: &[u32], max_new: usize) -> Vec<u32> {
        let mut cache = KvCache::new(self.config.layers);
        let mut logits = vec![0f32; self.config.vocab];
        for &t in prompt {
            logits = self.step(t, &mut cache);
        }
        let mut out = Vec::with_capacity(max_new);
        for _ in 0..max_new {
            let next = argmax(&logits) as u32;
            out.push(next);
            if cache.len >= self.config.max_seq {
                break;
            }
            logits = self.step(next, &mut cache);
        }
        out
    }
}

pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::model::transformer;
    use crate::util::rng::Rng;

    fn tiny_weights(seed: u64) -> Weights {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 12 };
        let mut rng = Rng::new(seed);
        Weights::init_training(cfg, &mut rng)
    }

    #[test]
    fn dense_engine_matches_batch_forward() {
        // The decode engine must reproduce the training-path forward
        // logits exactly (same math, different code path).
        let w = tiny_weights(181);
        let mut rng = Rng::new(182);
        let toks: Vec<u32> = (0..8).map(|_| rng.below(32) as u32).collect();
        let cache_fwd = transformer::forward(&w, &toks, 1, 8);
        let logits_fwd = transformer::logits(&w, &cache_fwd.z);

        let engine = Engine::from_dense(&w);
        let mut kv = KvCache::new(w.config.layers);
        for (i, &t) in toks.iter().enumerate() {
            let logits = engine.step(t, &mut kv);
            for v in 0..w.config.vocab {
                let a = logits[v];
                let b = logits_fwd.get(i, v);
                assert!(
                    (a - b).abs() < 1e-3 * b.abs().max(1.0),
                    "pos {i} vocab {v}: engine {a} vs forward {b}"
                );
            }
        }
    }

    #[test]
    fn quantized_engine_matches_dequantized_dense_engine() {
        let w = tiny_weights(183);
        let qm = rtn_quantize_model(&w, 6, 8);
        let eq = Engine::from_quantized(&qm);
        let ed = Engine::from_dense(&qm.to_weights());
        let mut rng = Rng::new(184);
        let toks: Vec<u32> = (0..6).map(|_| rng.below(32) as u32).collect();
        let mut kv_q = KvCache::new(w.config.layers);
        let mut kv_d = KvCache::new(w.config.layers);
        for &t in &toks {
            let lq = eq.step(t, &mut kv_q);
            let ld = ed.step(t, &mut kv_d);
            for (a, b) in lq.iter().zip(&ld) {
                assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_bounded() {
        let w = tiny_weights(185);
        let engine = Engine::from_dense(&w);
        let out1 = engine.generate(&[1, 2, 3], 5);
        let out2 = engine.generate(&[1, 2, 3], 5);
        assert_eq!(out1, out2);
        assert!(out1.len() <= 5);
        assert!(out1.iter().all(|&t| t < 32));
    }
}
