//! Self-speculative decoding off the rate ladder: draft with a low-rate
//! allocation of the model, verify with the high-rate target — both
//! packed from ONE calibration artifact (`coordinator::ladder`), so the
//! paper's "family of operating points" becomes a wall-clock knob, not
//! just a size/accuracy one.
//!
//! The loop is standard greedy speculative decoding:
//!
//! 1. **Draft**: the low-rate engine proposes up to `spec_k` tokens
//!    autoregressively (cheap — its bitstreams are a fraction of the
//!    target's, and decode is bitstream-bound at batch 1).
//! 2. **Verify**: the target scores ALL proposals in ONE chunked forward
//!    ([`Engine::prefill_positions`] — the PR-3 GEMM path, so k draft
//!    positions cost ~one amortized pass, not k sequential steps).
//! 3. **Accept**: the longest prefix of proposals matching the target's
//!    greedy argmax is kept, plus one token the target computed itself
//!    (the correction on mismatch, the natural next token on full
//!    acceptance). Rejected suffix rows are rolled back with
//!    [`KvCache::truncate_to`] — whole pages freed, remaining contents
//!    bit-identical to a never-extended cache.
//!
//! **Token identity by construction.** Every emitted token is the argmax
//! of target logits over exactly the fed prefix a sequential
//! [`Engine::generate`] would have used: accepted proposals equal the
//! target's own argmax (that is the acceptance test), verify forwards
//! are bit-identical to step loops (the chunked-prefill invariant), and
//! rollback restores the cache bit-for-bit (the truncate contract). So
//! `generate_speculative` == `generate` for every `(spec_k, draft)`
//! configuration — speculation changes wall-clock, never output — and a
//! test pins it. `spec_k = 0` degenerates to a plain verify-only step
//! loop through the same code path (the bench's baseline arm).
//!
//! The draft lags the target by design: it catches up on accepted
//! corrections lazily, as the leading chunk of its next draft pass (one
//! GEMM-amortized prefill), so a rejected burst never costs dedicated
//! draft work. When the draft rate is too low its proposals stop
//! matching, acceptance collapses, and every round degrades to
//! one-token-per-verify — see DESIGN.md §Speculative decoding for the
//! collapse regime and `eval::draft_agreement` for qualifying a draft
//! rate before serving with it.
//!
//! Speculation composes with the execution backends (`infer::backend`)
//! for free: draft and verify both ride `Engine::forward_chunk`, which
//! routes through whatever backend each engine carries, and every
//! backend is bit-identical by contract — so a column-sharded or
//! layer-pipelined target verifies the exact tokens the single path
//! would, and acceptance rates are backend-independent.
//!
//! Prefix sharing (`infer::prefix`) composes too: a served target cache
//! may begin with attached shared pages covering part of the prompt.
//! Round rollbacks are safe against that run because `truncate_to`
//! never cuts below the prompt rows — every rollback target is ≥ the
//! fed prompt length, which is ≥ the shared row count — and the draft
//! engine never shares pages at all (its cache is built from its own
//! numerics via the lazy catch-up prefill above), so acceptance is
//! identical with and without a prefix hit.

use crate::infer::engine::{argmax, Engine};
use crate::infer::kv::KvCache;

/// Aggregate speculation counters for one generation (or one served
/// lane; the server sums them into `ServeStats`).
#[derive(Clone, Copy, Debug, Default)]
pub struct SpecStats {
    /// Draft tokens proposed across all rounds.
    pub proposed: usize,
    /// Proposals accepted by target verification.
    pub accepted: usize,
    /// Draft/verify rounds executed.
    pub rounds: usize,
}

impl SpecStats {
    /// Fraction of proposals accepted (0 when nothing was proposed).
    pub fn acceptance(&self) -> f64 {
        if self.proposed == 0 {
            0.0
        } else {
            self.accepted as f64 / self.proposed as f64
        }
    }
}

/// Outcome of one draft/verify round.
#[derive(Clone, Debug)]
pub struct SpecRound {
    /// Tokens emitted this round: the accepted proposal prefix plus one
    /// target-computed token (correction or natural continuation).
    /// Always non-empty.
    pub emitted: Vec<u32>,
    /// Draft tokens proposed this round (≤ `spec_k`; clamped by the
    /// remaining generation budget and the positional table).
    pub proposed: usize,
    /// Proposals accepted (`accepted < proposed` means the round ended
    /// on a correction).
    pub accepted: usize,
}

impl Engine {
    /// One speculative round: draft up to `spec_k` tokens with `draft`,
    /// verify them against `self` (the target) in one chunked forward,
    /// accept the longest matching prefix, and roll back rejected rows.
    ///
    /// State contract: `tokens` is the full token stream (prompt +
    /// everything emitted), whose last element is *pending* — emitted
    /// but not yet fed — so `target_cache.len + 1 == tokens.len()`.
    /// `draft_cache` holds a prefix of the same stream (it may lag; the
    /// round feeds it the gap as draft-prefill). `remaining` is how many
    /// tokens the caller still wants (≥ 1); the round emits at most
    /// `remaining` and never overruns the positional table. On return
    /// the emitted tokens have been appended to `tokens` and the new
    /// last element is pending again.
    ///
    /// Both engines must share one model shape (same tokenizer, same
    /// positional table) — the self-speculative setting.
    pub fn step_speculative(
        &self,
        draft: &Engine,
        tokens: &mut Vec<u32>,
        target_cache: &mut KvCache,
        draft_cache: &mut KvCache,
        spec_k: usize,
        remaining: usize,
    ) -> SpecRound {
        assert_eq!(
            self.config, draft.config,
            "draft and target must share one model shape (self-speculative)"
        );
        assert!(remaining >= 1, "a round must be allowed to emit");
        assert!(!tokens.is_empty(), "no pending token to feed");
        debug_assert_eq!(
            target_cache.len + 1,
            tokens.len(),
            "exactly the last token may be pending"
        );
        let max_seq = self.config.max_seq;
        assert!(target_cache.len < max_seq, "positional table exhausted");

        // Proposal budget: spec_k, but never more than the remaining
        // emission budget leaves useful (each round emits accepted + 1)
        // and never past the positional table (the verify chunk feeds
        // m + 1 tokens).
        let m = spec_k.min(remaining - 1).min(max_seq - target_cache.len - 1);
        let pending = *tokens.last().expect("tokens checked non-empty");

        // Draft phase: catch the draft up on everything it has not seen
        // (lagging corrections + the pending token) in one prefill, then
        // step out the remaining proposals. Skipped entirely at m = 0 —
        // the draft's lag is repaid only when it earns proposals.
        let mut proposals: Vec<u32> = Vec::with_capacity(m);
        if m > 0 {
            let catchup: Vec<u32> = tokens[draft_cache.len..].to_vec();
            let mut dl = draft
                .prefill_batch(&[&catchup], std::slice::from_mut(draft_cache))
                .pop()
                .expect("one lane yields one logit vector");
            loop {
                let q = argmax(&dl) as u32;
                proposals.push(q);
                if proposals.len() == m {
                    break;
                }
                dl = draft.step(q, draft_cache);
            }
        }

        // Verify phase: ONE target forward over [pending, proposals…]
        // scores every draft position (PR-3 chunked prefill).
        let mut chunk: Vec<u32> = Vec::with_capacity(m + 1);
        chunk.push(pending);
        chunk.extend_from_slice(&proposals);
        let before = target_cache.len;
        let logits = self
            .prefill_positions(&[&chunk], std::slice::from_mut(target_cache))
            .pop()
            .expect("one lane yields one logit list");

        // Greedy longest-prefix acceptance: proposal j survives iff it
        // IS the target's argmax after the accepted prefix.
        let mut j = 0usize;
        while j < proposals.len() && argmax(&logits[j]) as u32 == proposals[j] {
            j += 1;
        }
        // logits[j] always exists (the chunk had m + 1 positions): on
        // full acceptance it is the target's natural next token, on
        // mismatch it is the correction — either way exactly what a
        // sequential generate() would emit here.
        let next = argmax(&logits[j]) as u32;
        let mut emitted = proposals[..j].to_vec();
        emitted.push(next);
        tokens.extend_from_slice(&emitted);

        // Roll back the rejected suffix; the draft also drops anything
        // past the accepted prefix (it will re-sync next round).
        let keep = before + 1 + j;
        target_cache.truncate_to(keep);
        if draft_cache.len > keep {
            draft_cache.truncate_to(keep);
        }
        SpecRound { emitted, proposed: m, accepted: j }
    }

    /// Greedy generation with self-speculative decoding: token-identical
    /// to [`Engine::generate`] on `self` for every `(spec_k, draft)`
    /// configuration (tested), but drafted at the `draft` engine's rate
    /// and verified in chunked target forwards. Returns the generated
    /// tokens plus acceptance statistics — the number to watch: wall
    /// clock improves only while `draft` stays cheap *and* its proposals
    /// keep matching (`SpecStats::acceptance`).
    ///
    /// `spec_k = 0` runs the same loop without ever touching `draft`
    /// (pure verify steps) — the baseline arm `bench_spec` measures
    /// speedup against.
    pub fn generate_speculative(
        &self,
        draft: &Engine,
        prompt: &[u32],
        max_new: usize,
        spec_k: usize,
    ) -> (Vec<u32>, SpecStats) {
        let mut stats = SpecStats::default();
        if max_new == 0 {
            return (Vec::new(), stats);
        }
        let prompt = self.admit_prompt(prompt);
        let mut target_cache = self.new_cache();
        let mut draft_cache = draft.new_cache();
        let mut logits = vec![0f32; self.config.vocab];
        if !prompt.is_empty() {
            logits = self
                .prefill_batch(&[prompt], std::slice::from_mut(&mut target_cache))
                .pop()
                .expect("one lane yields one logit vector");
        }
        let first = argmax(&logits) as u32;
        let mut tokens: Vec<u32> = prompt.to_vec();
        tokens.push(first);
        let mut out = vec![first];
        // Same stopping rule as generate(): stop once the budget or the
        // positional table is exhausted, with the final token emitted
        // from the last in-budget logits.
        while out.len() < max_new && target_cache.len < self.config.max_seq {
            let round = self.step_speculative(
                draft,
                &mut tokens,
                &mut target_cache,
                &mut draft_cache,
                spec_k,
                max_new - out.len(),
            );
            out.extend_from_slice(&round.emitted);
            stats.proposed += round.proposed;
            stats.accepted += round.accepted;
            stats.rounds += 1;
        }
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::infer::kv::{KvCacheConfig, KvQuantSpec};
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn tiny_weights(seed: u64) -> Weights {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 24 };
        let mut rng = Rng::new(seed);
        Weights::init_training(cfg, &mut rng)
    }

    #[test]
    fn speculative_is_token_identical_to_generate() {
        // The acceptance criterion: for every (spec_k, draft-rate)
        // configuration — including a garbage 1-bit draft — the emitted
        // tokens equal a plain generate() on the target.
        let w = tiny_weights(401);
        let target = Engine::from_quantized(&rtn_quantize_model(&w, 6, 8));
        let drafts = [
            Engine::from_quantized(&rtn_quantize_model(&w, 1, 8)),
            Engine::from_quantized(&rtn_quantize_model(&w, 2, 8)),
            Engine::from_quantized(&rtn_quantize_model(&w, 4, 8)),
            Engine::from_dense(&w),
        ];
        let prompts: [&[u32]; 3] = [&[1, 2, 3], &[7], &[4, 9, 11, 30, 2, 5]];
        for draft in &drafts {
            for prompt in prompts {
                for max_new in [1usize, 2, 5, 12] {
                    let want = target.generate(prompt, max_new);
                    for k in [0usize, 1, 2, 3, 8] {
                        let (got, stats) =
                            target.generate_speculative(draft, prompt, max_new, k);
                        assert_eq!(
                            got, want,
                            "spec_k={k} max_new={max_new} diverged from generate()"
                        );
                        assert!(stats.accepted <= stats.proposed);
                    }
                }
            }
        }
    }

    #[test]
    fn self_draft_accepts_every_proposal() {
        // Draft == target weights ⇒ proposals are the target's own
        // argmaxes ⇒ acceptance is exactly 100%.
        let w = tiny_weights(402);
        let target = Engine::from_dense(&w);
        let draft = Engine::from_dense(&w);
        let (out, stats) = target.generate_speculative(&draft, &[3, 1, 4], 12, 4);
        assert_eq!(out, target.generate(&[3, 1, 4], 12));
        assert!(stats.proposed > 0, "long generation must draft");
        assert_eq!(stats.accepted, stats.proposed, "self-draft must fully accept");
        assert_eq!(stats.acceptance(), 1.0);
    }

    #[test]
    fn speculative_matches_generate_across_kv_configs() {
        // Rollback must compose with paged AND quantized KV backings:
        // tokens equal the same engine's generate() (which shares the
        // KV config) with pages far smaller than the verify chunks.
        let w = tiny_weights(403);
        let small_pages = KvCacheConfig { page_rows: 3, ..KvCacheConfig::dense() };
        let quant_kv = KvCacheConfig {
            page_rows: 3,
            ..KvCacheConfig::quantized(KvQuantSpec::uniform(w.config.layers, 6, 1.0, 0.0))
        };
        for kv in [small_pages, quant_kv] {
            let target =
                Engine::from_quantized(&rtn_quantize_model(&w, 6, 8)).with_kv_config(kv.clone());
            let draft =
                Engine::from_quantized(&rtn_quantize_model(&w, 3, 8)).with_kv_config(kv.clone());
            let prompt: &[u32] = &[2, 7, 1, 8];
            let want = target.generate(prompt, 15);
            let (got, _) = target.generate_speculative(&draft, prompt, 15, 4);
            assert_eq!(got, want, "kv config {kv:?} diverged");
        }
    }

    #[test]
    fn speculative_respects_budget_and_positional_table() {
        let w = tiny_weights(404);
        let target = Engine::from_dense(&w);
        let draft = Engine::from_dense(&w);
        // max_new = 0 emits nothing; an empty prompt mirrors generate's
        // all-zero-logits start; a long budget stops at the table.
        assert!(target.generate_speculative(&draft, &[1], 0, 4).0.is_empty());
        assert_eq!(
            target.generate_speculative(&draft, &[], 5, 4).0,
            target.generate(&[], 5)
        );
        let max_seq = target.config.max_seq;
        let long = target.generate(&[1, 2], 3 * max_seq);
        let (spec_long, _) = target.generate_speculative(&draft, &[1, 2], 3 * max_seq, 4);
        assert_eq!(spec_long, long, "table-limited generation diverged");
        // Prompt exactly filling the table still emits one token.
        let exact: Vec<u32> = (0..max_seq as u32).map(|i| i % 32).collect();
        let (one, stats) = target.generate_speculative(&draft, &exact, 6, 4);
        assert_eq!(one, target.generate(&exact, 6));
        assert_eq!(one.len(), 1);
        assert_eq!(stats.rounds, 0, "no room to draft past a full table");
    }

    #[test]
    fn spec_k_zero_never_touches_the_draft() {
        // spec_k = 0 must behave like a plain verify-step loop: same
        // tokens, zero proposals, and a draft cache that never grows.
        let w = tiny_weights(405);
        let target = Engine::from_dense(&w);
        // A deliberately mismatched-weights draft: if it were consulted,
        // tokens could diverge.
        let draft = Engine::from_dense(&tiny_weights(406));
        let (out, stats) = target.generate_speculative(&draft, &[5, 6], 8, 0);
        assert_eq!(out, target.generate(&[5, 6], 8));
        assert_eq!(stats.proposed, 0);
        assert_eq!(stats.accepted, 0);
        assert!(stats.rounds > 0);
    }
}
