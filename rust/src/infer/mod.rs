//! Quantized inference: the mixed-precision bit-packed matvec kernel
//! (paper Appendix A, CPU adaptation), the KV-cached decode engine, and
//! the batched request server.

pub mod engine;
pub mod matvec;
pub mod server;

pub use engine::{Engine, KvCache};
pub use matvec::{dense_matvec, MatvecPlan, QuantMatvec};
pub use server::{serve, Request, Response, ServeStats};
