//! Quantized inference: the mixed-precision bit-packed matvec/GEMM
//! kernels (paper Appendix A, CPU adaptation), the KV-cached batched
//! decode engine, and the continuous-batching request server.

pub mod engine;
pub mod matvec;
pub mod server;

pub use engine::{Engine, KvCache};
pub use matvec::{dense_matmul, dense_matvec, MatvecPlan, QuantMatvec};
pub use server::{serve, serve_threaded, Request, Response, ServeStats};
