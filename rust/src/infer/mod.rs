//! Quantized inference: the mixed-precision bit-packed matvec/GEMM
//! kernels (paper Appendix A, CPU adaptation), the paged
//! (optionally-quantized) KV cache with pool-budget admission
//! accounting, the KV-cached batched decode engine with chunked prefill,
//! the execution backends (single-thread / column-sharded /
//! layer-pipeline) behind the engine, the continuous-batching
//! request server with an admission router for multi-replica serving,
//! and a cross-request prefix cache that shares immutable KV page runs
//! between lanes with common prompt prefixes.

/// Execution backends: single-thread, column-sharded, layer-pipeline.
pub mod backend;
/// The KV-cached batched decode engine with chunked prefill.
pub mod engine;
/// Paged, optionally-quantized KV cache + pool-budget accounting.
pub mod kv;
/// Mixed-precision bit-packed matvec/GEMM kernels.
pub mod matvec;
/// Cross-request radix-tree prefix cache over shared KV page runs.
pub mod prefix;
/// Admission router: continuous batching across engine replicas.
pub mod router;
/// Continuous-batching request server (plain and speculative).
pub mod server;
/// Self-speculative decoding: draft at a low rate, verify at the target.
pub mod speculative;

pub use backend::{Backend, ColumnSharded, LayerPipeline, SingleThread};
pub use engine::Engine;
pub use kv::{
    lane_cost_bytes, lane_cost_bytes_shared, page_set_bytes, KvCache, KvCacheConfig, KvLayerQuant,
    KvPageSet, KvPool, KvQuantParams, KvQuantSpec, KV_PAGE_ROWS,
};
pub use matvec::{dense_matmul, dense_matvec, MatvecPlan, QuantMatvec, GEMM_ROW_TILE};
pub use prefix::PrefixCache;
pub use router::{route, serve_replicated, RouterConfig, RouterStats};
pub use server::{
    serve, serve_ladder, serve_ladder_mapped, serve_speculative, serve_threaded, serve_with,
    Request, Response, ServeConfig, ServeStats,
};
pub use speculative::{SpecRound, SpecStats};
