//! Quantized inference: the mixed-precision bit-packed matvec/GEMM
//! kernels (paper Appendix A, CPU adaptation), the KV-cached batched
//! decode engine with chunked prefill, and the continuous-batching
//! request server with budgeted prefill scheduling.

pub mod engine;
pub mod matvec;
pub mod server;

pub use engine::{Engine, KvCache};
pub use matvec::{dense_matmul, dense_matvec, MatvecPlan, QuantMatvec, GEMM_ROW_TILE};
pub use server::{serve, serve_threaded, serve_with, Request, Response, ServeConfig, ServeStats};
