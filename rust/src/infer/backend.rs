//! Execution backends: how one logical forward pass maps onto OS
//! threads. The [`Backend`] trait is the seam that lets single-thread,
//! column-sharded (tensor-parallel), layer-pipeline, and (later)
//! PJRT/XLA execution coexist behind one [`Engine`] — the serving
//! scheduler, speculative decoding, and `generate` all call
//! `Engine::forward_chunk`, which routes here.
//!
//! # The backend contract
//!
//! Implementors must guarantee, for every worker count and micro-batch
//! shape:
//!
//! 1. **Bit-identity.** The returned hidden rows and all KV-cache side
//!    effects are bit-for-bit equal to
//!    [`SingleThread`]'s. Concretely: never introduce a
//!    floating-point reduction whose operand order depends on the worker
//!    count. The column-sharded backend satisfies this by construction —
//!    each output column is decoded whole by exactly one worker through
//!    the same per-column kernel the pooled sweep uses, and per-worker
//!    ranges are stitched by concatenation (a memcpy, not an FP op).
//!    The pipeline backend satisfies it because micro-batching is just
//!    batching, and per-lane results are batch-composition-independent
//!    (the engine's oldest invariant).
//! 2. **Rollback discipline.** K/V rows may be appended eagerly per
//!    layer, but lane clocks (`KvCache::len`) advance only after the
//!    WHOLE forward succeeds (via the engine's crate-internal
//!    `advance_clock`).
//!    On a panic mid-forward, appended rows must be left *dangling past
//!    `len`* so the serving scheduler's `truncate_to(pre_len)` rollback
//!    reclaims them — never half-commit a clock.
//! 3. **Panic transparency.** A worker panic must propagate to the
//!    caller with its **original payload** (use
//!    [`crate::util::threadpool::scoped_map`] or equivalent), so the
//!    scheduler's fault containment retires only the affected lanes as
//!    `LaneFault` with a detail message naming the real site — not
//!    `std::thread::scope`'s generic "a scoped thread panicked".
//!
//! Under that contract, backend choice affects wall-clock only: serving
//! on any backend stays token-identical to single-engine
//! [`Engine::generate`], which the sharding test suite pins for
//! W ∈ {1, 2, 4} on both shard axes. See `docs/SERVING.md` for how to
//! pick a topology and size W.

use crate::infer::engine::{advance_clock, row_offsets, Engine, GemmMode};
use crate::infer::kv::KvCache;
use crate::quant::format::ShardPlan;
use std::sync::mpsc;

/// One logical forward pass, mapped onto an execution topology.
///
/// See the [module docs](self) for the three-part contract
/// (bit-identity, rollback discipline, panic transparency) every
/// implementor must uphold.
pub trait Backend: Send + Sync {
    /// Run the shared transformer body for `chunks` against `caches`,
    /// returning all N = ΣT hidden rows (lane-major, pre-final-LN).
    /// `row_off` is `row_offsets(chunks)`, passed in so callers index
    /// the result with the exact layout used here. Must append each
    /// lane's K/V rows per layer and advance lane clocks once at the
    /// end — bit-identical to [`SingleThread`] in both outputs and
    /// cache state.
    fn forward_chunk(
        &self,
        engine: &Engine,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
    ) -> Vec<Vec<f32>>;

    /// Short stable name for diagnostics and benches.
    fn name(&self) -> &'static str;
}

/// The classic path: one forward on the calling thread, GEMMs chunked
/// across the shared persistent threadpool. Default for every
/// constructor; the reference numerics all other backends must match.
#[derive(Clone, Copy, Debug, Default)]
pub struct SingleThread;

impl Backend for SingleThread {
    fn forward_chunk(
        &self,
        engine: &Engine,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
    ) -> Vec<Vec<f32>> {
        engine.forward_chunk_mode(chunks, caches, row_off, GemmMode::Full)
    }

    fn name(&self) -> &'static str {
        "single"
    }
}

/// Tensor parallelism along the axis the decoder already iterates:
/// every linear's output columns are split into `workers` contiguous
/// ranges and each range is decoded by its own scoped worker, straight
/// off the shared packed bitstreams (workers of one process share the
/// mmap'd container — no weight duplication).
///
/// Bit-identity for every W: the split points (`i·cols/W`) are fixed by
/// W alone, each output column is computed whole by one worker through
/// the per-column kernel the pooled sweep shares
/// ([`crate::infer::matvec::MatvecPlan::matmul_cols`]), and stitching
/// is pure concatenation — there is no cross-worker floating-point
/// reduction to order. Attention and layer norms run un-sharded on the
/// calling thread, unchanged.
///
/// Scaling shape: decode cost per linear is ~`payload_bits / W` per
/// worker, so W should track physical cores not already consumed by the
/// shared pool (see `docs/SERVING.md` §Sizing).
#[derive(Clone, Copy, Debug)]
pub struct ColumnSharded {
    /// Worker count W (clamped to ≥ 1; a width-`cols` linear uses at
    /// most `cols` workers).
    pub workers: usize,
}

impl ColumnSharded {
    /// Backend with `workers` column shards. `ColumnSharded { workers: 1 }`
    /// is numerically AND operationally the single path (no threads are
    /// spawned).
    pub fn new(workers: usize) -> ColumnSharded {
        ColumnSharded { workers }
    }
}

impl Backend for ColumnSharded {
    fn forward_chunk(
        &self,
        engine: &Engine,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
    ) -> Vec<Vec<f32>> {
        engine.forward_chunk_mode(chunks, caches, row_off, GemmMode::Sharded(self.workers.max(1)))
    }

    fn name(&self) -> &'static str {
        "column-sharded"
    }
}

/// One in-flight micro-batch: a contiguous lane group with its own
/// cache sub-slice and lane-rebased row bookkeeping, flowing
/// stage-to-stage through the pipeline's channels.
struct MicroBatch<'a> {
    idx: usize,
    caches: &'a mut [KvCache],
    row_off: Vec<usize>,
    row_win: Vec<(usize, usize)>,
    xs: Vec<Vec<f32>>,
}

/// Pipeline parallelism across the layer axis: the transformer blocks
/// are partitioned into `stages` contiguous spans, each owned by one
/// scoped worker; lanes are grouped into micro-batches of
/// [`LayerPipeline::micro_batch`] lanes that flow stage → stage through
/// channels, so up to `stages` micro-batches are in flight at once —
/// riding the same chunked-prefill structure the scheduler already
/// feeds.
///
/// Bit-identity: a micro-batch is just a smaller batch, and per-lane
/// results are batch-composition-independent (the engine's oldest
/// invariant); every lane still sees all layers in order against its
/// own cache sub-slice (disjoint by construction), and lane clocks
/// advance once after the whole forward — so outputs and cache state
/// match [`SingleThread`] exactly.
///
/// Failure semantics: a stage panic disconnects the pipeline's
/// channels, the remaining stages drain and exit cleanly, and the
/// ORIGINAL panic payload is re-raised to the caller — so the serving
/// scheduler sees the same rollback picture as a single-thread panic
/// (appended rows dangling past un-advanced clocks) and retires only
/// the affected lanes as `LaneFault`.
#[derive(Clone, Debug)]
pub struct LayerPipeline {
    /// Stage count (clamped to the model's layer count at run time).
    pub stages: usize,
    /// Lanes per micro-batch. Smaller = more overlap across stages but
    /// less GEMM amortization within each; 4 is a reasonable default
    /// for serving batch sizes (see `docs/SERVING.md` §Sizing).
    pub micro_batch: usize,
    /// Optional payload-balanced stage bounds from
    /// [`ShardPlan`] (`bounds.len() == stages + 1`); `None` = even
    /// layer split.
    bounds: Option<Vec<usize>>,
}

impl LayerPipeline {
    /// Pipeline with `stages` even layer spans and the default
    /// micro-batch of 4 lanes.
    pub fn new(stages: usize) -> LayerPipeline {
        LayerPipeline { stages, micro_batch: 4, bounds: None }
    }

    /// Pipeline whose stage bounds come from a payload-balanced
    /// [`ShardPlan`] (built over the container's section table, so
    /// stages carry near-equal packed bits rather than equal layer
    /// counts). Bounds that don't match the engine's layer count fall
    /// back to an even split at run time.
    pub fn with_plan(plan: &ShardPlan) -> LayerPipeline {
        LayerPipeline {
            stages: plan.workers,
            micro_batch: 4,
            bounds: Some(plan.stage_bounds.clone()),
        }
    }

    /// Builder: lanes per micro-batch (clamped to ≥ 1).
    pub fn micro_batch(mut self, lanes: usize) -> LayerPipeline {
        self.micro_batch = lanes.max(1);
        self
    }

    /// Stage bounds for `nl` layers: the plan's if it covers exactly
    /// `0..nl` with `stages + 1` monotone cut points, else an even
    /// split.
    fn stage_bounds(&self, stages: usize, nl: usize) -> Vec<usize> {
        if let Some(b) = &self.bounds {
            let monotone = b.windows(2).all(|w| w[0] <= w[1]);
            if b.len() == stages + 1 && b.first() == Some(&0) && b.last() == Some(&nl) && monotone
            {
                return b.clone();
            }
        }
        (0..=stages).map(|i| i * nl / stages).collect()
    }
}

impl Backend for LayerPipeline {
    fn forward_chunk(
        &self,
        engine: &Engine,
        chunks: &[&[u32]],
        caches: &mut [KvCache],
        row_off: &[usize],
    ) -> Vec<Vec<f32>> {
        debug_assert_eq!(row_off, row_offsets(chunks).as_slice());
        let n = *row_off.last().unwrap_or(&0);
        if n == 0 {
            return Vec::new();
        }
        let nl = engine.num_layers();
        let stages = self.stages.clamp(1, nl.max(1));
        if stages <= 1 {
            return engine.forward_chunk_mode(chunks, caches, row_off, GemmMode::Full);
        }
        let bounds = self.stage_bounds(stages, nl);
        let micro = self.micro_batch.max(1);

        // Carve lanes into micro-batches: contiguous lane groups, each
        // owning a disjoint &mut sub-slice of the caches. Embedding
        // happens up front (it reads cache clocks, which are stable
        // until advance_clock) so stages only run layer spans.
        let mut batches: Vec<MicroBatch> = Vec::new();
        let mut rest: &mut [KvCache] = &mut *caches;
        for (idx, group) in chunks.chunks(micro).enumerate() {
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(group.len());
            rest = tail;
            let row_off_g = row_offsets(group);
            let (xs, row_win) = engine.embed_rows(group, head);
            batches.push(MicroBatch { idx, caches: head, row_off: row_off_g, row_win, xs });
        }
        let nmb = batches.len();

        let mut results: Vec<(usize, Vec<Vec<f32>>)> = std::thread::scope(|s| {
            let (tx0, rx0) = mpsc::channel::<MicroBatch>();
            let mut prev_rx = rx0;
            let mut handles = Vec::with_capacity(stages);
            for t in 0..stages {
                let (tx, rx) = mpsc::channel::<MicroBatch>();
                let rx_in = std::mem::replace(&mut prev_rx, rx);
                let (lo, hi) = (bounds[t], bounds[t + 1]);
                handles.push(s.spawn(move || {
                    // Drain until the upstream sender hangs up (all
                    // micro-batches done, or an upstream stage died).
                    while let Ok(mut mb) = rx_in.recv() {
                        mb.xs = engine.run_layers(
                            lo,
                            hi,
                            std::mem::take(&mut mb.xs),
                            &mb.row_win,
                            mb.caches,
                            &mb.row_off,
                            GemmMode::Full,
                        );
                        if tx.send(mb).is_err() {
                            // Downstream died: exit cleanly — ITS panic
                            // is the one the join below re-raises.
                            break;
                        }
                    }
                }));
            }
            // Feed in lane order; the channel chain preserves it, so no
            // reordering can happen (results still carry idx for
            // robustness).
            for mb in batches.drain(..) {
                if tx0.send(mb).is_err() {
                    break; // first stage died; surfaced via join below
                }
            }
            drop(tx0);
            let mut out = Vec::with_capacity(nmb);
            while let Ok(mb) = prev_rx.recv() {
                out.push((mb.idx, mb.xs));
            }
            // Manual join so a stage panic re-raises its ORIGINAL
            // payload (scope's implicit join would replace it with "a
            // scoped thread panicked" and break LaneFault details).
            let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
            for h in handles {
                if let Err(p) = h.join() {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
            if let Some(p) = first_panic {
                std::panic::resume_unwind(p);
            }
            out
        });

        // Stitch hidden rows back into lane-major order and commit the
        // clocks — once, for the whole forward, exactly like the
        // single path.
        results.sort_by_key(|(idx, _)| *idx);
        let mut xs = Vec::with_capacity(n);
        for (_, part) in results {
            xs.extend(part);
        }
        advance_clock(chunks, caches);
        xs
    }

    fn name(&self) -> &'static str {
        "layer-pipeline"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn quad_engine(seed: u64) -> Engine {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 4, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(seed);
        let w = Weights::init_training(cfg, &mut rng);
        let qm = rtn_quantize_model(&w, 3, 64);
        Engine::from_quantized(&qm)
    }

    #[test]
    fn backends_agree_on_logits_bit_for_bit() {
        let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9];
        let base = quad_engine(7);
        let mut c0 = base.new_cache();
        let want = base.prefill_batch(&[&prompt], std::slice::from_mut(&mut c0));
        for w in [1usize, 2, 4] {
            let col = quad_engine(7).with_backend(ColumnSharded::new(w));
            let mut c = col.new_cache();
            let got = col.prefill_batch(&[&prompt], std::slice::from_mut(&mut c));
            assert_eq!(got, want, "column-sharded W={w}");
            let pipe = quad_engine(7).with_backend(LayerPipeline::new(w).micro_batch(1));
            let mut c = pipe.new_cache();
            let got = pipe.prefill_batch(&[&prompt], std::slice::from_mut(&mut c));
            assert_eq!(got, want, "layer-pipeline W={w}");
        }
    }

    #[test]
    fn pipeline_handles_empty_and_uneven_micro_batches() {
        let base = quad_engine(11);
        let pipe = quad_engine(11).with_backend(LayerPipeline::new(2).micro_batch(2));
        let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![], vec![7], vec![9, 9], vec![4]];
        let chunks: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let mut cb: Vec<_> = (0..5).map(|_| base.new_cache()).collect();
        let mut cp: Vec<_> = (0..5).map(|_| pipe.new_cache()).collect();
        let want = base.prefill_batch(&chunks, &mut cb);
        let got = pipe.prefill_batch(&chunks, &mut cp);
        assert_eq!(got, want);
        for (a, b) in cb.iter().zip(&cp) {
            assert_eq!(a.len, b.len, "clocks must advance identically");
        }
    }

    #[test]
    fn shard_plan_bounds_are_honored_and_bad_bounds_fall_back() {
        let pipe = LayerPipeline {
            stages: 2,
            micro_batch: 1,
            bounds: Some(vec![0, 3, 4]),
        };
        assert_eq!(pipe.stage_bounds(2, 4), vec![0, 3, 4]);
        // Wrong layer count → even split.
        assert_eq!(pipe.stage_bounds(2, 6), vec![0, 3, 6]);
        let even = LayerPipeline::new(3);
        assert_eq!(even.stage_bounds(3, 4), vec![0, 1, 2, 4]);
    }
}
