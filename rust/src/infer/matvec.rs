//! Mixed-precision quantized matrix–vector multiply — the paper's
//! Appendix-A CUDA kernel rethought for CPU (see DESIGN.md
//! §Hardware-Adaptation for the TPU/Pallas variant).
//!
//! The kernel computes `y[j] = Σ_i x[i]·W[i,j]` directly from the packed
//! code stream, never materializing the dense matrix:
//!
//! - codes stream sequentially per column (the packed layout is
//!   column-major), so memory traffic is `bits/32` of the FP32 baseline —
//!   the memory-bound speedup the paper's Table 7 measures;
//! - dequantization is one LUT lookup + FMA: `deq = mean + scale·lut[code]`;
//! - the per-group mean term factors out: `Σ_{i∈g} x_i·mean_g =
//!   mean_g·(Σ_{i∈g} x_i)`, and the per-sub-group partial sums of `x` are
//!   shared by *every* column, so they're computed once per call;
//! - depth changes only at sub-group boundaries (the CPU analogue of the
//!   CUDA kernel's divergence-free per-4-row depth schedule).

use crate::model::tensor::Tensor;
use crate::quant::activations::{dequantize_row, quantize_row, ActQuantParams};
use crate::quant::bitpack::{PackedMatrix, QuantMode};
use crate::util::threadpool::parallel_for_chunks;

/// Precomputed decode plan for repeated matvecs against one packed
/// matrix. Owns only derived data, so it can live beside the matrix in
/// an engine without self-referential borrows.
pub struct MatvecPlan {
    /// Dequant LUTs per bit depth (index 0 unused).
    luts: Vec<Vec<f32>>,
    /// group_rows flattened in sub order (matches the code stream order).
    flat_rows: Vec<u32>,
    /// Start of each sub-group in `flat_rows`.
    sub_offsets: Vec<usize>,
    /// Copy of the code words padded with one zero word, so the decoder
    /// can always load a full 128-bit window without bounds branches.
    padded_words: Vec<u64>,
    rows: usize,
    cols: usize,
}

/// Borrow-based convenience wrapper (plan + matrix).
pub struct QuantMatvec<'a> {
    pm: &'a PackedMatrix,
    plan: MatvecPlan,
}

impl MatvecPlan {
    /// Precompute the decode plan (LUTs, row permutation, padded words)
    /// for one packed matrix.
    pub fn new(pm: &PackedMatrix) -> MatvecPlan {
        let luts: Vec<Vec<f32>> = (0..=8u8).map(|b| pm.mode.base_lut(b)).collect();
        let mut flat_rows = Vec::with_capacity(pm.rows);
        let mut sub_offsets = Vec::with_capacity(pm.grouping.m + 1);
        let mut is_fp = vec![false; pm.rows];
        for (r, _) in &pm.fp_rows {
            is_fp[*r as usize] = true;
        }
        for sub in 0..pm.grouping.m {
            sub_offsets.push(flat_rows.len());
            for &r in &pm.grouping.group_rows[sub] {
                if !is_fp[r as usize] {
                    flat_rows.push(r);
                }
            }
        }
        sub_offsets.push(flat_rows.len());
        let mut padded_words = pm.words.clone();
        padded_words.push(0);
        padded_words.push(0);
        MatvecPlan { luts, flat_rows, sub_offsets, padded_words, rows: pm.rows, cols: pm.cols }
    }

    /// y[j] = Σ_i x[i]·W[i,j], decoding from the packed stream. `pm` must
    /// be the matrix this plan was built from.
    ///
    /// §Perf hot path. The inner loop uses a *bin-accumulation* identity:
    /// `Σ_i x_i·lut[c_i] = Σ_c lut[c]·(Σ_{i: c_i=c} x_i)` — per weight it
    /// costs one bit-extract and one add into a 2^B-entry L1-resident bin
    /// array, deferring all LUT multiplies to 2^B FMAs per group. The
    /// gathered x values are pre-permuted once per call into code-stream
    /// order, so the per-column loop is fully sequential.
    pub fn matvec(&self, pm: &PackedMatrix, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        assert_eq!(x.len(), pm.rows);
        let m = pm.grouping.m;
        // Permute x into code-stream order (and fold the AWQ row scale),
        // once per call, shared by all columns.
        let mut x_perm = vec![0f32; self.flat_rows.len()];
        match &pm.row_scale {
            Some(s) => {
                for (dst, &r) in x_perm.iter_mut().zip(&self.flat_rows) {
                    *dst = x[r as usize] / s[r as usize];
                }
            }
            None => {
                for (dst, &r) in x_perm.iter_mut().zip(&self.flat_rows) {
                    *dst = x[r as usize];
                }
            }
        }
        // Per-sub-group partial sums of x (for the mean term).
        let mut sum_x = vec![0f32; m];
        for sub in 0..m {
            sum_x[sub] = x_perm[self.sub_offsets[sub]..self.sub_offsets[sub + 1]]
                .iter()
                .sum();
        }

        let mut y = vec![0f32; pm.cols];
        let y_ptr = SendMut(y.as_mut_ptr());
        let words = &self.padded_words;
        #[cfg(target_arch = "x86_64")]
        let simd_ok = std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
        parallel_for_chunks(pm.cols, 128, |c0, c1| {
            let y_ptr = y_ptr;
            for col in c0..c1 {
                let mut pos = pm.col_bit_offset[col];
                let mut acc = 0f32;
                for sub in 0..m {
                    let gm = pm.meta[col * m + sub];
                    if gm.bits == 0 {
                        continue; // pruned: contributes nothing
                    }
                    let xs = &x_perm[self.sub_offsets[sub]..self.sub_offsets[sub + 1]];
                    let bits = gm.bits as usize;
                    let mask = ((1u64 << bits) - 1) as u128;
                    let lut = &self.luts[bits][..];
                    // AVX2 fast path: for B ≤ 3 the whole LUT fits one YMM
                    // register and `vpermps` performs 8 dequantizations per
                    // instruction — the CPU analogue of the CUDA kernel's
                    // shared-memory LUT.
                    #[cfg(target_arch = "x86_64")]
                    if bits >= 1 && bits <= 3 && simd_ok && xs.len() >= 16 {
                        let (dot, npos) =
                            unsafe { dot_avx2_small_lut(words, pos, xs, bits, lut) };
                        pos = npos;
                        acc += gm.scale * dot + gm.mean * sum_x[sub];
                        continue;
                    }
                    // Window decode: one 128-bit load yields k = 64/bits
                    // codes with *independent* shifts (no serial cursor
                    // dependency); 4 accumulators keep FMA ports busy.
                    let k = 64 / bits;
                    let (mut d0, mut d1, mut d2, mut d3) = (0f32, 0f32, 0f32, 0f32);
                    let mut i = 0usize;
                    while i + k <= xs.len() {
                        let wi = pos >> 6;
                        let off = pos & 63;
                        // SAFETY: padded_words has 2 spare words.
                        let lo = unsafe { *words.get_unchecked(wi) } as u128;
                        let hi = unsafe { *words.get_unchecked(wi + 1) } as u128;
                        let win = (lo | (hi << 64)) >> off;
                        let mut j = 0;
                        while j + 4 <= k {
                            let c0i = ((win >> (j * bits)) & mask) as usize;
                            let c1i = ((win >> ((j + 1) * bits)) & mask) as usize;
                            let c2i = ((win >> ((j + 2) * bits)) & mask) as usize;
                            let c3i = ((win >> ((j + 3) * bits)) & mask) as usize;
                            // SAFETY: codes are < 2^bits = lut.len().
                            unsafe {
                                d0 += xs.get_unchecked(i + j) * lut.get_unchecked(c0i);
                                d1 += xs.get_unchecked(i + j + 1) * lut.get_unchecked(c1i);
                                d2 += xs.get_unchecked(i + j + 2) * lut.get_unchecked(c2i);
                                d3 += xs.get_unchecked(i + j + 3) * lut.get_unchecked(c3i);
                            }
                            j += 4;
                        }
                        while j < k {
                            let c = ((win >> (j * bits)) & mask) as usize;
                            unsafe {
                                d0 += xs.get_unchecked(i + j) * lut.get_unchecked(c);
                            }
                            j += 1;
                        }
                        pos += k * bits;
                        i += k;
                    }
                    // Tail.
                    let mut cur = Cursor::new(words, pos);
                    while i < xs.len() {
                        let c = cur.next(gm.bits as u32, mask as u64);
                        d0 += xs[i] * lut[c];
                        i += 1;
                    }
                    pos = cur.pos;
                    let dot = (d0 + d1) + (d2 + d3);
                    acc += gm.scale * dot + gm.mean * sum_x[sub];
                }
                // SAFETY: disjoint column ranges.
                unsafe { *y_ptr.0.add(col) = acc };
            }
        });
        // FP16 exception rows: dense contribution with the ORIGINAL x.
        for (r, vals) in &pm.fp_rows {
            let xv = x[*r as usize];
            if xv == 0.0 {
                continue;
            }
            for (j, &wv) in vals.iter().enumerate() {
                y[j] += xv * wv;
            }
        }
        y
    }
}

impl MatvecPlan {
    /// Batch-amortized GEMM: `ys[b][j] = Σ_i xs[b][i]·W[i,j]`, decoding
    /// each column's code stream **once** and applying every dequantized
    /// weight to all B activation vectors. Decode cost is O(1) in batch
    /// size — the amortization that makes continuous batching pay off —
    /// while FLOPs scale with B as they must.
    ///
    /// Layout: activations are pre-permuted into code-stream order and
    /// interleaved weight-major/batch-minor (`xp[i·B + b]`), so the inner
    /// per-weight loop is a contiguous length-B AXPY that vectorizes.
    ///
    /// Determinism contract: for a fixed sequence `b`, the floating-point
    /// operation order is independent of the batch size and of the other
    /// sequences (one accumulator per lane, no fused multiply-add in the
    /// batched inner loop), so `matmul(&[x])[0] == matmul(xs)[b]` bit for
    /// bit whenever `xs[b] == x`. The engine and server lean on this for
    /// their token-identical batching guarantee. Note the *per-vector*
    /// [`MatvecPlan::matvec`] uses a different accumulation order (4-way
    /// unroll / bin tricks) and agrees only to rounding tolerance.
    pub fn matmul(&self, pm: &PackedMatrix, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let bn = xs.len();
        if bn == 0 {
            return Vec::new();
        }
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        for x in xs {
            assert_eq!(x.len(), pm.rows);
        }
        let (xp, sum_x) = self.prepare_f32(pm, xs);

        // Output, column-major × batch-minor; columns are chunked across
        // the pool with disjoint writes.
        let mut yflat = vec![0f32; pm.cols * bn];
        let y_ptr = SendMut(yflat.as_mut_ptr());
        let simd_ok = simd_avx2_fma();
        // Per-column work scales with B, so shrink the minimum chunk as
        // the batch grows (chunking never affects numerics — each column
        // is computed whole by one lane).
        let min_cols = (128 / bn).max(8);
        parallel_for_chunks(pm.cols, min_cols, |c0, c1| {
            let y_ptr = y_ptr;
            let mut colacc = vec![0f32; bn];
            let mut dotacc = vec![0f32; bn];
            for col in c0..c1 {
                self.gemm_col(pm, col, &xp, &sum_x, bn, simd_ok, &mut colacc, &mut dotacc);
                for (b, &v) in colacc.iter().enumerate() {
                    // SAFETY: disjoint column ranges across chunks.
                    unsafe { *y_ptr.0.add(col * bn + b) = v };
                }
            }
        });
        // De-interleave into per-sequence outputs.
        let mut ys: Vec<Vec<f32>> = (0..bn)
            .map(|b| (0..pm.cols).map(|col| yflat[col * bn + b]).collect())
            .collect();
        // FP16 exception rows: dense contribution with the ORIGINAL x
        // (same skip rule and row order as the per-vector kernel).
        for (r, vals) in &pm.fp_rows {
            for (b, x) in xs.iter().enumerate() {
                let xv = x[*r as usize];
                if xv == 0.0 {
                    continue;
                }
                for (yj, &wv) in ys[b].iter_mut().zip(vals) {
                    *yj += xv * wv;
                }
            }
        }
        ys
    }

    /// Column-range variant of [`MatvecPlan::matmul`] — the tensor-parallel
    /// serving seam: computes only columns `c0..c1`, returning per-lane
    /// vectors of length `c1 − c0`.
    ///
    /// Bit-identity contract: `matmul_cols(pm, xs, c0, c1)[b][j]` equals
    /// `matmul(pm, xs)[b][c0 + j]` bit for bit, because every output
    /// column is computed whole by [`MatvecPlan::gemm_col`] — the one
    /// per-column kernel both entry points share — and the FP16
    /// exception-row pass visits the same rows in the same order over the
    /// `c0..c1` slice of each row. Concatenating the per-worker ranges of
    /// a column-sharded GEMM is therefore a pure memcpy, never a
    /// cross-worker floating-point reduction, which is what keeps sharded
    /// serving logits independent of the worker count W.
    pub fn matmul_cols(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        c0: usize,
        c1: usize,
    ) -> Vec<Vec<f32>> {
        let bn = xs.len();
        if bn == 0 {
            return Vec::new();
        }
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        assert!(c0 <= c1 && c1 <= pm.cols, "column range {c0}..{c1} out of 0..{}", pm.cols);
        for x in xs {
            assert_eq!(x.len(), pm.rows);
        }
        if c0 == c1 {
            return vec![Vec::new(); bn];
        }
        let (xp, sum_x) = self.prepare_f32(pm, xs);
        let simd_ok = simd_avx2_fma();
        // Serial over the range: the workers sharing this matrix ARE the
        // parallelism, and each column's op order is internal to
        // `gemm_col` either way.
        let mut ys: Vec<Vec<f32>> = vec![vec![0f32; c1 - c0]; bn];
        let mut colacc = vec![0f32; bn];
        let mut dotacc = vec![0f32; bn];
        for col in c0..c1 {
            self.gemm_col(pm, col, &xp, &sum_x, bn, simd_ok, &mut colacc, &mut dotacc);
            for (b, &v) in colacc.iter().enumerate() {
                ys[b][col - c0] = v;
            }
        }
        // FP16 exception rows, restricted to this range's column slice
        // (same row order and zero-skip as the full-width pass).
        for (r, vals) in &pm.fp_rows {
            for (b, x) in xs.iter().enumerate() {
                let xv = x[*r as usize];
                if xv == 0.0 {
                    continue;
                }
                for (yj, &wv) in ys[b].iter_mut().zip(&vals[c0..c1]) {
                    *yj += xv * wv;
                }
            }
        }
        ys
    }

    /// Permute all B activations into code-stream order (folding the AWQ
    /// row scale), interleaved batch-minor (`xp[i·B + b]`), plus the
    /// per-(sub-group, lane) partial sums for the factored mean term —
    /// the column-independent preamble shared by [`MatvecPlan::matmul`]
    /// and [`MatvecPlan::matmul_cols`]. Column-sharded workers each
    /// recompute it; the values (and their op order) never depend on
    /// which columns a worker owns.
    fn prepare_f32(&self, pm: &PackedMatrix, xs: &[Vec<f32>]) -> (Vec<f32>, Vec<f32>) {
        let bn = xs.len();
        let m = pm.grouping.m;
        let flat = self.flat_rows.len();
        let mut xp = vec![0f32; flat * bn];
        match &pm.row_scale {
            Some(s) => {
                for (i, &r) in self.flat_rows.iter().enumerate() {
                    let inv = 1.0 / s[r as usize];
                    for (b, x) in xs.iter().enumerate() {
                        xp[i * bn + b] = x[r as usize] * inv;
                    }
                }
            }
            None => {
                for (i, &r) in self.flat_rows.iter().enumerate() {
                    for (b, x) in xs.iter().enumerate() {
                        xp[i * bn + b] = x[r as usize];
                    }
                }
            }
        }
        let mut sum_x = vec![0f32; m * bn];
        for sub in 0..m {
            let acc = &mut sum_x[sub * bn..(sub + 1) * bn];
            for i in self.sub_offsets[sub]..self.sub_offsets[sub + 1] {
                let row = &xp[i * bn..(i + 1) * bn];
                for (a, &v) in acc.iter_mut().zip(row) {
                    *a += v;
                }
            }
        }
        (xp, sum_x)
    }

    /// Decode ONE column's code stream against the prepared activations:
    /// `colacc[b] = Σ_sub (scale·dot_b + mean·sum_x_b)`. This is THE
    /// per-column f32 kernel — the pooled full-width sweep (`matmul`)
    /// and the worker-sharded range sweep (`matmul_cols`) both call it,
    /// which is what makes column sharding bit-identical: an output
    /// column's FP op order lives entirely inside this function and
    /// never depends on which worker, chunk, or range computed it.
    #[allow(unused_variables)] // simd_ok is read on x86_64 only
    fn gemm_col(
        &self,
        pm: &PackedMatrix,
        col: usize,
        xp: &[f32],
        sum_x: &[f32],
        bn: usize,
        simd_ok: bool,
        colacc: &mut [f32],
        dotacc: &mut [f32],
    ) {
        let m = pm.grouping.m;
        let words = &self.padded_words;
        let mut pos = pm.col_bit_offset[col];
        colacc.iter_mut().for_each(|v| *v = 0.0);
        for sub in 0..m {
            let gm = pm.meta[col * m + sub];
            if gm.bits == 0 {
                continue; // pruned: contributes nothing
            }
            let start = self.sub_offsets[sub];
            let end = self.sub_offsets[sub + 1];
            let glen = end - start;
            let bits = gm.bits as usize;
            let lut = &self.luts[bits][..];
            dotacc.iter_mut().for_each(|v| *v = 0.0);
            let group_x = &xp[start * bn..end * bn];
            // Widened AVX2 small-LUT path: decode 8 codes per
            // `vpermps`, then broadcast each dequantized weight
            // against all B lanes (unfused mul+add, preserving
            // the scalar op order per lane). The decode side is
            // lane-count independent, so this runs at every
            // batch size — B < 8 just uses the scalar lane tail.
            #[cfg(target_arch = "x86_64")]
            if bits <= 3 && simd_ok && glen >= 8 {
                pos = unsafe {
                    gemm_avx2_small_lut(words, pos, group_x, bn, bits, lut, dotacc)
                };
                for b in 0..bn {
                    colacc[b] += gm.scale * dotacc[b] + gm.mean * sum_x[sub * bn + b];
                }
                continue;
            }
            // Generic path: 128-bit window decode (k = 64/bits
            // codes per load) + one length-B AXPY per weight.
            let mask = ((1u64 << bits) - 1) as u128;
            let k = 64 / bits;
            let mut i = 0usize;
            while i + k <= glen {
                let wi = pos >> 6;
                let off = pos & 63;
                // SAFETY: padded_words has 2 spare words.
                let lo = unsafe { *words.get_unchecked(wi) } as u128;
                let hi = unsafe { *words.get_unchecked(wi + 1) } as u128;
                let win = (lo | (hi << 64)) >> off;
                for j in 0..k {
                    let c = ((win >> (j * bits)) & mask) as usize;
                    // SAFETY: codes are < 2^bits = lut.len().
                    let wv = unsafe { *lut.get_unchecked(c) };
                    if bn == 1 {
                        // Batch-1 specialization: same multiply-add
                        // in the same order, minus the per-weight
                        // slice bookkeeping.
                        // SAFETY: i + j < glen and group_x has
                        // glen elements when bn == 1.
                        dotacc[0] += wv * unsafe { *group_x.get_unchecked(i + j) };
                    } else {
                        let row = &group_x[(i + j) * bn..(i + j + 1) * bn];
                        for (a, &x) in dotacc.iter_mut().zip(row) {
                            *a += wv * x;
                        }
                    }
                }
                pos += k * bits;
                i += k;
            }
            // Tail.
            let mut cur = Cursor::new(words, pos);
            while i < glen {
                let c = cur.next(gm.bits as u32, mask as u64);
                let wv = lut[c];
                let row = &group_x[i * bn..(i + 1) * bn];
                for (a, &x) in dotacc.iter_mut().zip(row) {
                    *a += wv * x;
                }
                i += 1;
            }
            pos = cur.pos;
            for b in 0..bn {
                colacc[b] += gm.scale * dotacc[b] + gm.mean * sum_x[sub * bn + b];
            }
        }
    }
}

/// Runtime AVX2+FMA detection shared by the f32 GEMM entry points (the
/// sharded and pooled sweeps must agree on the kernel they pick — they
/// do by construction: detection is a pure function of the host).
#[inline]
fn simd_avx2_fma() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Runtime AVX2 detection for the integer W·A kernel (no FMA needed —
/// and irrelevant to numerics either way, since `int_axpy`'s vector and
/// scalar variants are exactly equal).
#[inline]
fn simd_avx2() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::arch::is_x86_feature_detected!("avx2")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

/// Row-tile width for [`MatvecPlan::matgem`]: how many activation rows
/// share one pass over a column's code stream. Large enough that decode
/// cost per row is negligible (the acceptance bar is amortization at
/// chunk ≥ 8), small enough that the permuted activation tile
/// (`rows × GEMM_ROW_TILE` floats) and the per-lane accumulators stay
/// cache-resident while a worker streams every column against them.
pub const GEMM_ROW_TILE: usize = 32;

impl MatvecPlan {
    /// Sequence-parallel GEMM (chunked prefill): `ys[r][j] = Σ_i
    /// xs[r][i]·W[i,j]` for N = B·T activation rows — prompt positions ×
    /// batch lanes flattened into one row axis. Generalizes
    /// [`MatvecPlan::matmul`]'s batch amortization to the sequence axis:
    /// rows are processed in tiles of [`GEMM_ROW_TILE`], and within a
    /// tile each packed column's code stream is decoded **once** (via the
    /// same widened AVX2 small-LUT path) and applied to every row of the
    /// tile, so decode cost is O(N / GEMM_ROW_TILE) instead of O(N).
    ///
    /// Tiling is purely a working-set bound: an un-tiled call over a long
    /// chunk would keep re-streaming an N-row permuted activation buffer
    /// (too big for L2 at prefill lengths) past every column, while a
    /// tile stays cache-resident for the whole column sweep.
    ///
    /// Determinism contract: inherited from `matmul` — each row's FP op
    /// order depends only on that row's values, never on the tile
    /// composition or N, so `matgem(xs)[r]` is bit-identical to
    /// `matmul(&[xs[r]])[0]`. Chunked prefill therefore reproduces
    /// token-by-token stepping exactly; the engine's bit-identity tests
    /// pin this down.
    pub fn matgem(&self, pm: &PackedMatrix, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let mut ys = Vec::with_capacity(xs.len());
        for tile in xs.chunks(GEMM_ROW_TILE) {
            ys.append(&mut self.matmul(pm, tile));
        }
        ys
    }

    /// Column-range variant of [`MatvecPlan::matgem`]: rows are tiled by
    /// [`GEMM_ROW_TILE`] exactly as in the full-width sweep (tiling and
    /// column range are independent axes), each tile computed over
    /// `c0..c1` via [`MatvecPlan::matmul_cols`]. Bit-identical to the
    /// `c0..c1` slice of `matgem`'s output.
    pub fn matgem_cols(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        c0: usize,
        c1: usize,
    ) -> Vec<Vec<f32>> {
        let mut ys = Vec::with_capacity(xs.len());
        for tile in xs.chunks(GEMM_ROW_TILE) {
            ys.append(&mut self.matmul_cols(pm, tile, c0, c1));
        }
        ys
    }
}

// -------------------------------------------------- integer W·A hot path

impl MatvecPlan {
    /// Fully-integer batched GEMM: quantize each activation row on the
    /// fly to symmetric signed codes (`quant::activations::quantize_row`),
    /// multiply the packed **weight codes** against the **activation
    /// codes** with i32 accumulation, and apply the combined dequant
    /// scale once per output element.
    ///
    /// Exactness rests on the Uniform LUT being affine in the code:
    /// `deq = mean + scale·(c − off + 0.5)` with `off = 2^(B−1)`, so for
    /// a quantized row `x̂_i = s_x·xc_i`:
    ///
    /// ```text
    /// Σ_i ŵ_i·x̂_i = s_x·[ scale·(D − (off − 0.5)·S) + mean·S ]
    ///   where D = Σ_i c_i·xc_i and S = Σ_i xc_i   (both exact in i32)
    /// ```
    ///
    /// Per weight the hot loop is one bit-extract plus one integer
    /// multiply-add — no LUT gather, no f32 FMA — and the f32 work
    /// (two multiplies, one add per *group*, one multiply per output
    /// element) is O(1) in the group length. `S` is shared by every
    /// column, computed once per call like `matmul`'s `sum_x`.
    ///
    /// Requires `pm.mode == QuantMode::Uniform` (the companded LUT is
    /// non-affine in the code, so no integer dot can absorb it — use
    /// [`MatvecPlan::matmul_act`], which falls back to fake-quantized
    /// f32 for companded matrices). With an AWQ `row_scale`, activations
    /// are quantized *after* the per-row fold (the fold is per input
    /// row, so it cannot be deferred past the dot product). FP16
    /// exception rows contribute densely with the ORIGINAL f32 `x`
    /// (outlier rows stay full precision, as in `matmul`).
    ///
    /// Determinism contract: each lane's codes and scale depend only on
    /// that lane's values, integer accumulation is exact, and the f32
    /// combine runs in a fixed per-column order, so `matmul_int(xs)[b]`
    /// is bit-identical to `matmul_int(&[xs[b]])[0]` — the same
    /// batch-invariance `matmul` guarantees.
    pub fn matmul_int(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
    ) -> Vec<Vec<f32>> {
        let bn = xs.len();
        if bn == 0 {
            return Vec::new();
        }
        assert_eq!(
            pm.mode,
            QuantMode::Uniform,
            "matmul_int requires an affine (Uniform) code LUT"
        );
        assert!(act.bits >= 2, "matmul_int called with a full-precision act spec");
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        for x in xs {
            assert_eq!(x.len(), pm.rows);
        }
        let (xq, s_x, sum_xc) = self.prepare_int(pm, xs, act);

        let mut yflat = vec![0f32; pm.cols * bn];
        let y_ptr = SendMut(yflat.as_mut_ptr());
        let simd = simd_avx2();
        let min_cols = (128 / bn).max(8);
        parallel_for_chunks(pm.cols, min_cols, |c0, c1| {
            let y_ptr = y_ptr;
            let mut colacc = vec![0f32; bn];
            let mut dotacc = vec![0i32; bn];
            for col in c0..c1 {
                self.gemm_int_col(pm, col, &xq, &sum_xc, bn, simd, &mut colacc, &mut dotacc);
                for (b, &v) in colacc.iter().enumerate() {
                    // SAFETY: disjoint column ranges across chunks.
                    unsafe { *y_ptr.0.add(col * bn + b) = v * s_x[b] };
                }
            }
        });
        let mut ys: Vec<Vec<f32>> = (0..bn)
            .map(|b| (0..pm.cols).map(|col| yflat[col * bn + b]).collect())
            .collect();
        // FP16 exception rows: dense contribution with the ORIGINAL f32 x.
        for (r, vals) in &pm.fp_rows {
            for (b, x) in xs.iter().enumerate() {
                let xv = x[*r as usize];
                if xv == 0.0 {
                    continue;
                }
                for (yj, &wv) in ys[b].iter_mut().zip(vals) {
                    *yj += xv * wv;
                }
            }
        }
        ys
    }

    /// Column-range variant of [`MatvecPlan::matmul_int`] — bit-identical
    /// to the `c0..c1` slice of the full-width result for the same reason
    /// as [`MatvecPlan::matmul_cols`]: activation quantization and the
    /// factored code sums are column-independent (and exact integer),
    /// and each output column runs whole through
    /// [`MatvecPlan::gemm_int_col`], the kernel shared with the pooled
    /// sweep.
    pub fn matmul_int_cols(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
        c0: usize,
        c1: usize,
    ) -> Vec<Vec<f32>> {
        let bn = xs.len();
        if bn == 0 {
            return Vec::new();
        }
        assert_eq!(
            pm.mode,
            QuantMode::Uniform,
            "matmul_int_cols requires an affine (Uniform) code LUT"
        );
        assert!(act.bits >= 2, "matmul_int_cols called with a full-precision act spec");
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        assert!(c0 <= c1 && c1 <= pm.cols, "column range {c0}..{c1} out of 0..{}", pm.cols);
        for x in xs {
            assert_eq!(x.len(), pm.rows);
        }
        if c0 == c1 {
            return vec![Vec::new(); bn];
        }
        let (xq, s_x, sum_xc) = self.prepare_int(pm, xs, act);
        let simd = simd_avx2();
        let mut ys: Vec<Vec<f32>> = vec![vec![0f32; c1 - c0]; bn];
        let mut colacc = vec![0f32; bn];
        let mut dotacc = vec![0i32; bn];
        for col in c0..c1 {
            self.gemm_int_col(pm, col, &xq, &sum_xc, bn, simd, &mut colacc, &mut dotacc);
            for (b, &v) in colacc.iter().enumerate() {
                ys[b][col - c0] = v * s_x[b];
            }
        }
        // FP16 exception rows over this range's column slice, with the
        // ORIGINAL f32 x (same order as the full-width pass).
        for (r, vals) in &pm.fp_rows {
            for (b, x) in xs.iter().enumerate() {
                let xv = x[*r as usize];
                if xv == 0.0 {
                    continue;
                }
                for (yj, &wv) in ys[b].iter_mut().zip(&vals[c0..c1]) {
                    *yj += xv * wv;
                }
            }
        }
        ys
    }

    /// Quantize every lane's (AWQ-folded, code-stream-permuted) row and
    /// compute the per-(sub-group, lane) integer code sums — the
    /// column-independent preamble shared by [`MatvecPlan::matmul_int`]
    /// and [`MatvecPlan::matmul_int_cols`]. Returns `(xq, s_x, sum_xc)`:
    /// batch-minor i32 codes, per-lane dequant scales, and the factored
    /// mean/offset sums (all exact, so worker-recomputation is free of
    /// rounding concerns by construction).
    fn prepare_int(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
    ) -> (Vec<i32>, Vec<f32>, Vec<i32>) {
        let bn = xs.len();
        let m = pm.grouping.m;
        let flat = self.flat_rows.len();
        let qmax = act.qmax();
        // Worst case per product: (2^8 − 1)·qmax; i32 accumulation is
        // exact while flat·255·qmax fits (rows up to ~66k at 8-bit acts).
        debug_assert!(
            (flat as u64) * 255 * qmax as u64 <= i32::MAX as u64,
            "activation row too long for exact i32 accumulation"
        );
        // Fold the AWQ row scale, permute into code-stream order, and
        // quantize each lane's row; codes are interleaved batch-minor
        // like matmul's xp.
        let mut xq = vec![0i32; flat * bn];
        let mut s_x = vec![0f32; bn];
        let mut folded = vec![0f32; flat];
        for (b, x) in xs.iter().enumerate() {
            match &pm.row_scale {
                Some(s) => {
                    for (dst, &r) in folded.iter_mut().zip(&self.flat_rows) {
                        *dst = x[r as usize] / s[r as usize];
                    }
                }
                None => {
                    for (dst, &r) in folded.iter_mut().zip(&self.flat_rows) {
                        *dst = x[r as usize];
                    }
                }
            }
            let (codes, s) = quantize_row(&folded, act);
            s_x[b] = s;
            for (i, &c) in codes.iter().enumerate() {
                xq[i * bn + b] = c as i32;
            }
        }
        // Per-(sub-group, lane) integer code sums for the factored
        // mean/offset terms (exact; shared by every column).
        let mut sum_xc = vec![0i32; m * bn];
        for sub in 0..m {
            let acc = &mut sum_xc[sub * bn..(sub + 1) * bn];
            for i in self.sub_offsets[sub]..self.sub_offsets[sub + 1] {
                let row = &xq[i * bn..(i + 1) * bn];
                for (a, &v) in acc.iter_mut().zip(row) {
                    *a += v;
                }
            }
        }
        (xq, s_x, sum_xc)
    }

    /// Decode ONE column's code stream against the quantized activations
    /// (integer dot + one f32 combine per group) — the per-column kernel
    /// shared by the pooled and sharded integer sweeps, mirroring
    /// [`MatvecPlan::gemm_col`]. `colacc` holds the un-scaled result
    /// (caller applies the per-lane `s_x[b]`).
    #[allow(clippy::too_many_arguments)]
    fn gemm_int_col(
        &self,
        pm: &PackedMatrix,
        col: usize,
        xq: &[i32],
        sum_xc: &[i32],
        bn: usize,
        simd: bool,
        colacc: &mut [f32],
        dotacc: &mut [i32],
    ) {
        let m = pm.grouping.m;
        let words = &self.padded_words;
        let mut pos = pm.col_bit_offset[col];
        colacc.iter_mut().for_each(|v| *v = 0.0);
        for sub in 0..m {
            let gm = pm.meta[col * m + sub];
            if gm.bits == 0 {
                continue; // pruned: contributes nothing
            }
            let start = self.sub_offsets[sub];
            let end = self.sub_offsets[sub + 1];
            let glen = end - start;
            let bits = gm.bits as usize;
            dotacc.iter_mut().for_each(|v| *v = 0);
            let group_x = &xq[start * bn..end * bn];
            // 128-bit window decode (k = 64/bits codes per load),
            // then one length-B integer AXPY per weight code.
            let mask = ((1u64 << bits) - 1) as u128;
            let k = 64 / bits;
            let mut i = 0usize;
            while i + k <= glen {
                let wi = pos >> 6;
                let off = pos & 63;
                // SAFETY: padded_words has 2 spare words.
                let lo = unsafe { *words.get_unchecked(wi) } as u128;
                let hi = unsafe { *words.get_unchecked(wi + 1) } as u128;
                let win = (lo | (hi << 64)) >> off;
                for j in 0..k {
                    let c = ((win >> (j * bits)) & mask) as i32;
                    if bn == 1 {
                        // SAFETY: i + j < glen = group_x.len().
                        dotacc[0] += c * unsafe { *group_x.get_unchecked(i + j) };
                    } else {
                        let row = &group_x[(i + j) * bn..(i + j + 1) * bn];
                        int_axpy(c, row, dotacc, simd);
                    }
                }
                pos += k * bits;
                i += k;
            }
            // Tail.
            let mut cur = Cursor::new(words, pos);
            while i < glen {
                let c = cur.next(gm.bits as u32, mask as u64) as i32;
                let row = &group_x[i * bn..(i + 1) * bn];
                int_axpy(c, row, dotacc, simd);
                i += 1;
            }
            pos = cur.pos;
            // One f32 combine per (group, lane): the Uniform LUT
            // offset off − 0.5 = 2^(B−1) − 0.5.
            let offm = (1i64 << (bits - 1)) as f32 - 0.5;
            for b in 0..bn {
                let d = dotacc[b] as f32;
                let s = sum_xc[sub * bn + b] as f32;
                colacc[b] += gm.scale * (d - offm * s) + gm.mean * s;
            }
        }
    }

    /// Sequence-parallel integer GEMM: [`MatvecPlan::matgem`] with the
    /// integer tile kernel. Rows are tiled by [`GEMM_ROW_TILE`] and each
    /// tile's column code streams are decoded once; per-row results are
    /// tile-position independent (inherited from `matmul_int`'s
    /// batch-invariance), so chunked prefill reproduces token-by-token
    /// stepping exactly.
    pub fn matgem_int(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
    ) -> Vec<Vec<f32>> {
        let mut ys = Vec::with_capacity(xs.len());
        for tile in xs.chunks(GEMM_ROW_TILE) {
            ys.append(&mut self.matmul_int(pm, tile, act));
        }
        ys
    }

    /// Activation-quantized batched GEMM with automatic routing:
    ///
    /// - `act.bits == 0` (allocator left this input at full precision):
    ///   the plain f32 [`MatvecPlan::matmul`];
    /// - Uniform weight matrices: the fully-integer
    ///   [`MatvecPlan::matmul_int`];
    /// - Companded matrices: *fake-quantize* each row (quantize →
    ///   dequantize at the same rate, so the numerics and perplexity
    ///   impact match the integer path) and run the f32 LUT kernel —
    ///   the companded LUT is non-affine in the code, so the integer
    ///   dot does not apply. OWQ exception rows are restored to their
    ///   original f32 values first (outlier rows stay full precision on
    ///   every path).
    pub fn matmul_act(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
    ) -> Vec<Vec<f32>> {
        if act.bits == 0 {
            return self.matmul(pm, xs);
        }
        if pm.mode == QuantMode::Uniform {
            return self.matmul_int(pm, xs, act);
        }
        let xf: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                let (codes, s) = quantize_row(x, act);
                let mut xq = dequantize_row(&codes, s);
                for (r, _) in &pm.fp_rows {
                    xq[*r as usize] = x[*r as usize];
                }
                xq
            })
            .collect();
        self.matmul(pm, &xf)
    }

    /// Sequence-parallel [`MatvecPlan::matmul_act`] (same routing, tiled
    /// by [`GEMM_ROW_TILE`]).
    pub fn matgem_act(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
    ) -> Vec<Vec<f32>> {
        if act.bits == 0 {
            return self.matgem(pm, xs);
        }
        let mut ys = Vec::with_capacity(xs.len());
        for tile in xs.chunks(GEMM_ROW_TILE) {
            ys.append(&mut self.matmul_act(pm, tile, act));
        }
        ys
    }

    /// Column-range variant of [`MatvecPlan::matmul_act`]: identical
    /// routing (f32 / fully-integer / fake-quantized f32), each leg
    /// dispatched to its `_cols` form. The fake-quantize step for
    /// companded matrices is per-row and column-independent, so it
    /// commutes with the range restriction.
    pub fn matmul_act_cols(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
        c0: usize,
        c1: usize,
    ) -> Vec<Vec<f32>> {
        if act.bits == 0 {
            return self.matmul_cols(pm, xs, c0, c1);
        }
        if pm.mode == QuantMode::Uniform {
            return self.matmul_int_cols(pm, xs, act, c0, c1);
        }
        let xf: Vec<Vec<f32>> = xs
            .iter()
            .map(|x| {
                let (codes, s) = quantize_row(x, act);
                let mut xq = dequantize_row(&codes, s);
                for (r, _) in &pm.fp_rows {
                    xq[*r as usize] = x[*r as usize];
                }
                xq
            })
            .collect();
        self.matmul_cols(pm, &xf, c0, c1)
    }

    /// Column-range variant of [`MatvecPlan::matgem_act`] (same routing,
    /// tiled by [`GEMM_ROW_TILE`] exactly as the full-width sweep). This
    /// is the entry point a column-sharded worker calls per projection:
    /// bit-identical to the `c0..c1` slice of `matgem_act`'s output.
    pub fn matgem_act_cols(
        &self,
        pm: &PackedMatrix,
        xs: &[Vec<f32>],
        act: ActQuantParams,
        c0: usize,
        c1: usize,
    ) -> Vec<Vec<f32>> {
        if act.bits == 0 {
            return self.matgem_cols(pm, xs, c0, c1);
        }
        let mut ys = Vec::with_capacity(xs.len());
        for tile in xs.chunks(GEMM_ROW_TILE) {
            ys.append(&mut self.matmul_act_cols(pm, tile, act, c0, c1));
        }
        ys
    }
}

/// Integer AXPY for the W·A kernel: `acc[l] += c · row[l]` across all
/// batch lanes. The AVX2 variant (`vpmulld` + `vpaddd`) and the scalar
/// loop are exactly equal — integer arithmetic has no rounding — which
/// is what keeps `matmul_int` bit-stable across ISAs (pinned by the
/// scalar-vs-AVX2 parity test).
#[inline(always)]
#[allow(unused_variables)]
fn int_axpy(c: i32, row: &[i32], acc: &mut [i32], simd: bool) {
    debug_assert_eq!(row.len(), acc.len());
    #[cfg(target_arch = "x86_64")]
    if simd && row.len() >= 8 {
        // SAFETY: AVX2 presence checked by the caller's feature detect.
        unsafe { int_axpy_avx2(c, row, acc) };
        return;
    }
    for (a, &x) in acc.iter_mut().zip(row) {
        *a += c * x;
    }
}

/// AVX2 lane-vectorized integer multiply-accumulate (8 lanes per
/// `vpmulld`). Exact — see [`int_axpy`].
///
/// # Safety
/// Caller must guarantee AVX2 (feature detection) and
/// `row.len() == acc.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn int_axpy_avx2(c: i32, row: &[i32], acc: &mut [i32]) {
    use std::arch::x86_64::*;
    let n = row.len();
    let cb = _mm256_set1_epi32(c);
    let rptr = row.as_ptr();
    let aptr = acc.as_mut_ptr();
    let mut lane = 0usize;
    while lane + 8 <= n {
        let av = _mm256_loadu_si256(aptr.add(lane) as *const __m256i);
        let xv = _mm256_loadu_si256(rptr.add(lane) as *const __m256i);
        let sum = _mm256_add_epi32(av, _mm256_mullo_epi32(cb, xv));
        _mm256_storeu_si256(aptr.add(lane) as *mut __m256i, sum);
        lane += 8;
    }
    while lane < n {
        *aptr.add(lane) += c * *rptr.add(lane);
        lane += 1;
    }
}

impl<'a> QuantMatvec<'a> {
    /// Plan the borrowed matrix for decoding.
    pub fn new(pm: &'a PackedMatrix) -> QuantMatvec<'a> {
        QuantMatvec { pm, plan: MatvecPlan::new(pm) }
    }

    /// `W·x` straight off the packed stream ([`MatvecPlan::matvec`]).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.plan.matvec(self.pm, x)
    }

    /// Batched `W·xᵢ` for all vectors ([`MatvecPlan::matmul`]).
    pub fn matmul(&self, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
        self.plan.matmul(self.pm, xs)
    }
}

/// AVX2 dot product for B ≤ 3 bit groups: per 8 weights, broadcast a
/// 32-bit code window into a YMM register, variable-shift each lane into
/// place (`vpsrlvd`), mask, and dequantize all 8 via one `vpermps` LUT
/// permute, then FMA against the activations. Returns (dot, new bit pos).
///
/// # Safety
/// Caller must guarantee AVX2+FMA, `lut.len() >= 8`… wait — lut has
/// 2^bits ≤ 8 entries; it is padded to 8 below. `words` must be the
/// zero-padded plan copy (2 spare words).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2_small_lut(
    words: &[u64],
    mut pos: usize,
    xs: &[f32],
    bits: usize,
    lut: &[f32],
) -> (f32, usize) {
    use std::arch::x86_64::*;
    debug_assert!(bits >= 1 && bits <= 3);
    let mut lut8 = [0f32; 8];
    lut8[..lut.len()].copy_from_slice(lut);
    let lutv = _mm256_loadu_ps(lut8.as_ptr());
    let b = bits as i32;
    let shifts = _mm256_setr_epi32(0, b, 2 * b, 3 * b, 4 * b, 5 * b, 6 * b, 7 * b);
    let maskv = _mm256_set1_epi32(((1u32 << bits) - 1) as i32);
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let step = 8 * bits;
    let mut i = 0usize;
    // 16 weights per iteration (two independent FMA chains).
    while i + 16 <= xs.len() {
        let w0 = load_window32(words, pos);
        let w1 = load_window32(words, pos + step);
        let idx0 = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w0 as i32), shifts), maskv);
        let idx1 = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w1 as i32), shifts), maskv);
        let wv0 = _mm256_permutevar8x32_ps(lutv, idx0);
        let wv1 = _mm256_permutevar8x32_ps(lutv, idx1);
        let xv0 = _mm256_loadu_ps(xs.as_ptr().add(i));
        let xv1 = _mm256_loadu_ps(xs.as_ptr().add(i + 8));
        acc0 = _mm256_fmadd_ps(xv0, wv0, acc0);
        acc1 = _mm256_fmadd_ps(xv1, wv1, acc1);
        pos += 2 * step;
        i += 16;
    }
    // Horizontal sum.
    let accv = _mm256_add_ps(acc0, acc1);
    let hi = _mm256_extractf128_ps(accv, 1);
    let lo = _mm256_castps256_ps128(accv);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_hadd_ps(s, s);
    let s = _mm_hadd_ps(s, s);
    let mut dot = _mm_cvtss_f32(s);
    // Scalar tail.
    let mask = (1u64 << bits) - 1;
    let mut cur = Cursor::new(words, pos);
    while i < xs.len() {
        let c = cur.next(bits as u32, mask);
        dot += xs[i] * lut[c];
        i += 1;
    }
    (dot, cur.pos)
}

/// Widened (batched) AVX2 small-LUT kernel for B ≤ 3-bit groups: decode
/// 8 codes per 32-bit window with one `vpermps`, then broadcast each
/// dequantized weight and accumulate it into all `bn` per-lane partial
/// dots. Uses separate multiply and add (NOT `vfmadd`) so each lane's
/// rounding matches the scalar generic path exactly — the batched
/// decode must be bit-identical to the batch-1 decode.
///
/// `group_x` is the weight-major/batch-minor slice for this sub-group
/// (`glen × bn`), `dotacc` has `bn` entries. Works at any `bn ≥ 1`: the
/// vectorized lane loop covers multiples of 8, the scalar tail the rest
/// (for `bn < 8` the win is the `vpermps` code-stream decode itself).
/// Returns the new bit position.
///
/// # Safety
/// Caller must guarantee AVX2+FMA (feature detection), `bn >= 1`,
/// `group_x.len() == glen·bn`, and `words` must be the zero-padded plan
/// copy (2 spare words).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn gemm_avx2_small_lut(
    words: &[u64],
    mut pos: usize,
    group_x: &[f32],
    bn: usize,
    bits: usize,
    lut: &[f32],
    dotacc: &mut [f32],
) -> usize {
    use std::arch::x86_64::*;
    debug_assert!(bits >= 1 && bits <= 3);
    debug_assert!(bn >= 1);
    debug_assert_eq!(group_x.len() % bn, 0);
    debug_assert_eq!(dotacc.len(), bn);
    let glen = group_x.len() / bn;
    let mut lut8 = [0f32; 8];
    lut8[..lut.len()].copy_from_slice(lut);
    let lutv = _mm256_loadu_ps(lut8.as_ptr());
    let b = bits as i32;
    let shifts = _mm256_setr_epi32(0, b, 2 * b, 3 * b, 4 * b, 5 * b, 6 * b, 7 * b);
    let maskv = _mm256_set1_epi32(((1u32 << bits) - 1) as i32);
    let step = 8 * bits;
    let xptr = group_x.as_ptr();
    let aptr = dotacc.as_mut_ptr();
    let mut i = 0usize;
    while i + 8 <= glen {
        let w32 = load_window32(words, pos);
        let idx = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w32 as i32), shifts), maskv);
        let wv = _mm256_permutevar8x32_ps(lutv, idx);
        let mut wv8 = [0f32; 8];
        _mm256_storeu_ps(wv8.as_mut_ptr(), wv);
        for (j, &w) in wv8.iter().enumerate() {
            let row = xptr.add((i + j) * bn);
            let wb = _mm256_set1_ps(w);
            let mut lane = 0usize;
            while lane + 8 <= bn {
                let acc = _mm256_loadu_ps(aptr.add(lane));
                let xv = _mm256_loadu_ps(row.add(lane));
                let acc = _mm256_add_ps(acc, _mm256_mul_ps(wb, xv));
                _mm256_storeu_ps(aptr.add(lane), acc);
                lane += 8;
            }
            while lane < bn {
                *aptr.add(lane) += w * *row.add(lane);
                lane += 1;
            }
        }
        pos += step;
        i += 8;
    }
    // Scalar tail over the remaining codes.
    let mask = (1u64 << bits) - 1;
    let mut cur = Cursor::new(words, pos);
    while i < glen {
        let c = cur.next(bits as u32, mask);
        let w = lut[c];
        let row = &group_x[i * bn..(i + 1) * bn];
        for (a, &x) in dotacc.iter_mut().zip(row) {
            *a += w * x;
        }
        i += 1;
    }
    cur.pos
}

/// Load 32 bits of code stream starting at bit `pos` (words are padded).
#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn load_window32(words: &[u64], pos: usize) -> u32 {
    let wi = pos >> 6;
    let off = pos & 63;
    let lo = *words.get_unchecked(wi);
    if off == 0 {
        lo as u32
    } else {
        let hi = *words.get_unchecked(wi + 1);
        ((lo >> off) | (hi << (64 - off))) as u32
    }
}

/// Minimal LSB-first bit cursor for the decode hot loop (inlined; the
/// cross-word branch predicts near-perfectly for fixed-depth runs).
struct Cursor<'a> {
    words: &'a [u64],
    pos: usize,
}

impl<'a> Cursor<'a> {
    #[inline(always)]
    fn new(words: &'a [u64], pos: usize) -> Self {
        Cursor { words, pos }
    }

    #[inline(always)]
    fn next(&mut self, bits: u32, mask: u64) -> usize {
        let wi = self.pos >> 6;
        let off = (self.pos & 63) as u32;
        let mut v = unsafe { *self.words.get_unchecked(wi) } >> off;
        if off + bits > 64 {
            v |= unsafe { *self.words.get_unchecked(wi + 1) } << (64 - off);
        }
        self.pos += bits as usize;
        (v & mask) as usize
    }
}

/// Split a flat row-major buffer into `rows` equally sized owned vectors
/// (shared by the batched kernels and the engine's tied head). `rows`
/// must be nonzero and divide `flat.len()`.
pub(crate) fn split_rows(flat: Vec<f32>, rows: usize) -> Vec<Vec<f32>> {
    debug_assert!(rows > 0);
    debug_assert_eq!(flat.len() % rows, 0);
    let row_len = flat.len() / rows;
    if row_len == 0 {
        return vec![Vec::new(); rows];
    }
    // One linear pass, each row right-sized (split_off would re-copy the
    // shrinking tail on every iteration).
    flat.chunks_exact(row_len).map(<[f32]>::to_vec).collect()
}

/// Send/Sync raw-pointer wrapper for disjoint parallel writes (shared
/// with the engine's tied-head kernel).
pub(crate) struct SendMut<T>(pub(crate) *mut T);
impl<T> Clone for SendMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}
unsafe impl<T> Sync for SendMut<T> {}

/// Dense f32 matvec baseline (the paper's FP16/cuBLAS stand-in):
/// y[j] = Σ_i x[i]·W[i,j], streaming W row-by-row.
pub fn dense_matvec(w: &Tensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.rows);
    let mut y = vec![0f32; w.cols];
    let y_ptr = SendMut(y.as_mut_ptr());
    // Parallelize over column blocks to match the quantized kernel's
    // threading (fair Table 7 comparison).
    parallel_for_chunks(w.cols, 256, |c0, c1| {
        let y_ptr = y_ptr;
        let yslice = unsafe { std::slice::from_raw_parts_mut(y_ptr.0.add(c0), c1 - c0) };
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w.row(i)[c0..c1];
            for (yj, &wv) in yslice.iter_mut().zip(row) {
                *yj += xv * wv;
            }
        }
    });
    y
}

/// Dense f32 batched GEMM counterpart: `ys[b][j] = Σ_i xs[b][i]·W[i,j]`,
/// streaming W row-by-row exactly once for the whole batch. Per lane the
/// op order matches [`dense_matvec`] (including the zero-activation skip),
/// so `dense_matmul(w, &[x])[0] == dense_matvec(w, x)` bit for bit.
pub fn dense_matmul(w: &Tensor, xs: &[Vec<f32>]) -> Vec<Vec<f32>> {
    let bn = xs.len();
    if bn == 0 {
        return Vec::new();
    }
    for x in xs {
        assert_eq!(x.len(), w.rows);
    }
    // Per-sequence contiguous output rows: yflat[b·cols + j].
    let mut yflat = vec![0f32; bn * w.cols];
    let y_ptr = SendMut(yflat.as_mut_ptr());
    let min_cols = (256 / bn).max(16);
    parallel_for_chunks(w.cols, min_cols, |c0, c1| {
        let y_ptr = y_ptr;
        for (b, x) in xs.iter().enumerate() {
            // SAFETY: disjoint column ranges per chunk; lanes b are
            // disjoint output rows.
            let yslice = unsafe {
                std::slice::from_raw_parts_mut(y_ptr.0.add(b * w.cols + c0), c1 - c0)
            };
            for (i, &xv) in x.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let row = &w.row(i)[c0..c1];
                for (yj, &wv) in yslice.iter_mut().zip(row) {
                    *yj += xv * wv;
                }
            }
        }
    });
    split_rows(yflat, bn)
}

/// Column-range variant of [`dense_matmul`]: computes only columns
/// `c0..c1` of the dense GEMM, serially (the sharded workers calling it
/// are the parallelism). Bit-identical to the `c0..c1` slice of
/// `dense_matmul`'s output: the per-lane row loop, zero-activation skip,
/// and per-element multiply-add order over `w.row(i)[c0..c1]` are exactly
/// the pooled sweep's — the pool's column chunking was already
/// numerics-free, so restricting the range changes nothing.
pub fn dense_matmul_cols(w: &Tensor, xs: &[Vec<f32>], c0: usize, c1: usize) -> Vec<Vec<f32>> {
    let bn = xs.len();
    if bn == 0 {
        return Vec::new();
    }
    assert!(c0 <= c1 && c1 <= w.cols, "column range {c0}..{c1} out of 0..{}", w.cols);
    for x in xs {
        assert_eq!(x.len(), w.rows);
    }
    let mut ys: Vec<Vec<f32>> = vec![vec![0f32; c1 - c0]; bn];
    for (b, x) in xs.iter().enumerate() {
        let yslice = &mut ys[b][..];
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w.row(i)[c0..c1];
            for (yj, &wv) in yslice.iter_mut().zip(row) {
                *yj += xv * wv;
            }
        }
    }
    ys
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grouping::Grouping;
    use crate::quant::{quantize_matrix, QuantMode, ScaleRule};
    use crate::util::rng::Rng;

    fn random_packed(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        bits: u8,
        mode: QuantMode,
    ) -> (Tensor, PackedMatrix) {
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.05, 0.5);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, (rows / 4).max(1), &scores);
        // Mixed depths across groups to exercise the mixed-precision path.
        let bvec: Vec<u8> = (0..grouping.num_groups())
            .map(|i| match i % 4 {
                0 => bits,
                1 => bits.saturating_sub(1).max(1),
                2 => (bits + 1).min(8),
                _ => bits,
            })
            .collect();
        let pm = quantize_matrix(&w, &grouping, &bvec, mode, ScaleRule::Range);
        (w, pm)
    }

    #[test]
    fn quantized_matvec_matches_unpacked_dense() {
        let mut rng = Rng::new(171);
        for mode in [QuantMode::Companded, QuantMode::Uniform] {
            let (_, pm) = random_packed(&mut rng, 96, 40, 3, mode);
            let mut x = vec![0f32; 96];
            rng.fill_gauss(&mut x, 0.0, 1.0);
            let qmv = QuantMatvec::new(&pm);
            let y_kernel = qmv.matvec(&x);
            let y_ref = dense_matvec(&pm.unpack(), &x);
            for (a, b) in y_kernel.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{mode:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dense_matvec_matches_naive() {
        let mut rng = Rng::new(172);
        let (rows, cols) = (33, 17);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = dense_matvec(&w, &x);
        for j in 0..cols {
            let want: f32 = (0..rows).map(|i| x[i] * w.get(i, j)).sum();
            assert!((y[j] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn kernel_handles_pruned_groups() {
        let mut rng = Rng::new(173);
        let (rows, cols) = (32, 8);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]);
        let mut bvec = vec![3u8; grouping.num_groups()];
        for (i, b) in bvec.iter_mut().enumerate() {
            if i % 3 == 0 {
                *b = 0;
            }
        }
        let pm = quantize_matrix(&w, &grouping, &bvec, QuantMode::Companded, ScaleRule::Range);
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = QuantMatvec::new(&pm).matvec(&x);
        let y_ref = dense_matvec(&pm.unpack(), &x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn kernel_handles_row_scale_and_fp_rows() {
        let mut rng = Rng::new(174);
        let (rows, cols) = (24, 10);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.4);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]);
        let metas: Vec<crate::quant::GroupMeta> = (0..grouping.num_groups())
            .map(|gi| {
                let col = gi / grouping.m;
                let sub = gi % grouping.m;
                let vals = grouping.gather(&w, col, sub);
                crate::quant::group_meta(&vals, 3, QuantMode::Uniform, ScaleRule::Range)
            })
            .collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.5 + rng.uniform_f32()).collect();
        let fp = vec![2u32, 11, 17];
        let pm = crate::quant::bitpack::PackedMatrix::pack_full(
            &w,
            &grouping,
            &metas,
            QuantMode::Uniform,
            Some(scale),
            &fp,
        );
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = QuantMatvec::new(&pm).matvec(&x);
        let y_ref = dense_matvec(&pm.unpack(), &x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }

    fn random_batch(rng: &mut Rng, bn: usize, rows: usize) -> Vec<Vec<f32>> {
        (0..bn)
            .map(|_| {
                let mut x = vec![0f32; rows];
                rng.fill_gauss(&mut x, 0.0, 1.0);
                x
            })
            .collect()
    }

    #[test]
    fn matmul_matches_matvec_per_vector() {
        let mut rng = Rng::new(175);
        for mode in [QuantMode::Companded, QuantMode::Uniform] {
            for bits in [2u8, 4] {
                let (_, pm) = random_packed(&mut rng, 96, 40, bits, mode);
                let xs = random_batch(&mut rng, 5, 96);
                let qmv = QuantMatvec::new(&pm);
                let ys = qmv.matmul(&xs);
                assert_eq!(ys.len(), xs.len());
                let dense = pm.unpack();
                for (b, x) in xs.iter().enumerate() {
                    let y_mv = qmv.matvec(x);
                    let y_ref = dense_matvec(&dense, x);
                    for j in 0..pm.cols {
                        let g = ys[b][j];
                        assert!(
                            (g - y_mv[j]).abs() < 1e-3 * y_mv[j].abs().max(1.0),
                            "{mode:?}/{bits}b lane {b} col {j}: gemm {g} vs matvec {}",
                            y_mv[j]
                        );
                        assert!(
                            (g - y_ref[j]).abs() < 2e-3 * y_ref[j].abs().max(1.0),
                            "{mode:?}/{bits}b lane {b} col {j}: gemm {g} vs dense {}",
                            y_ref[j]
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_batched_is_bit_identical_to_batch_of_one() {
        // The determinism contract behind token-identical batching: a
        // lane's result must not depend on batch size (B = 16 exercises
        // the widened AVX2 path, B = 1 the generic path).
        let mut rng = Rng::new(176);
        for bits in [2u8, 3, 5] {
            let (_, pm) = random_packed(&mut rng, 128, 24, bits, QuantMode::Companded);
            let plan = MatvecPlan::new(&pm);
            for bn in [2usize, 8, 16] {
                let xs = random_batch(&mut rng, bn, 128);
                let batched = plan.matmul(&pm, &xs);
                for (b, x) in xs.iter().enumerate() {
                    let single = plan.matmul(&pm, std::slice::from_ref(x));
                    assert_eq!(
                        batched[b], single[0],
                        "{bits}b B={bn} lane {b}: batched result differs from batch-1"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_handles_pruned_row_scale_and_fp_rows() {
        let mut rng = Rng::new(177);
        let (rows, cols) = (48, 10);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.4);
        let grouping = Grouping::build(rows, cols, 12, &vec![0.0; rows]);
        let metas: Vec<crate::quant::GroupMeta> = (0..grouping.num_groups())
            .map(|gi| {
                let col = gi / grouping.m;
                let sub = gi % grouping.m;
                let vals = grouping.gather(&w, col, sub);
                let mut gm =
                    crate::quant::group_meta(&vals, 3, QuantMode::Uniform, ScaleRule::Range);
                if gi % 5 == 0 {
                    gm.bits = 0; // pruned groups in the mix
                }
                gm
            })
            .collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.5 + rng.uniform_f32()).collect();
        let fp = vec![1u32, 20, 33];
        let pm = crate::quant::bitpack::PackedMatrix::pack_full(
            &w,
            &grouping,
            &metas,
            QuantMode::Uniform,
            Some(scale),
            &fp,
        );
        let plan = MatvecPlan::new(&pm);
        let xs = random_batch(&mut rng, 9, rows);
        let ys = plan.matmul(&pm, &xs);
        let dense = pm.unpack();
        for (b, x) in xs.iter().enumerate() {
            let y_ref = dense_matvec(&dense, x);
            for (a, r) in ys[b].iter().zip(&y_ref) {
                assert!((a - r).abs() < 2e-3 * r.abs().max(1.0), "lane {b}: {a} vs {r}");
            }
            let single = plan.matmul(&pm, std::slice::from_ref(x));
            assert_eq!(ys[b], single[0], "lane {b}: batch dependence");
        }
    }

    #[test]
    fn matgem_is_bit_identical_to_per_row_matmul() {
        // The sequence-axis determinism contract: a row's result must not
        // depend on how many rows ride in the chunk or where tile
        // boundaries fall. 2·GEMM_ROW_TILE + 7 rows exercises full tiles
        // plus a ragged tail.
        let mut rng = Rng::new(179);
        for bits in [2u8, 4] {
            let (_, pm) = random_packed(&mut rng, 96, 24, bits, QuantMode::Companded);
            let plan = MatvecPlan::new(&pm);
            let xs = random_batch(&mut rng, 2 * GEMM_ROW_TILE + 7, 96);
            let ys = plan.matgem(&pm, &xs);
            assert_eq!(ys.len(), xs.len());
            for (r, x) in xs.iter().enumerate() {
                let single = plan.matmul(&pm, std::slice::from_ref(x));
                assert_eq!(ys[r], single[0], "{bits}b row {r}: tile-position dependence");
            }
        }
    }

    #[test]
    fn matgem_handles_empty_and_small_chunks() {
        let mut rng = Rng::new(180);
        let (_, pm) = random_packed(&mut rng, 64, 12, 3, QuantMode::Uniform);
        let plan = MatvecPlan::new(&pm);
        assert!(plan.matgem(&pm, &[]).is_empty());
        let xs = random_batch(&mut rng, 3, 64);
        assert_eq!(plan.matgem(&pm, &xs), plan.matmul(&pm, &xs));
    }

    use crate::quant::activations::ActScalePolicy;

    /// Fake-quant reference for the integer kernel: fold the AWQ row
    /// scale, quantize-dequantize the folded row, un-fold, restore OWQ
    /// exception rows, and run the f32 LUT kernel. Agrees with
    /// `matmul_int` up to f32 rounding-order differences only.
    fn int_reference(
        plan: &MatvecPlan,
        pm: &PackedMatrix,
        x: &[f32],
        act: ActQuantParams,
    ) -> Vec<f32> {
        let folded: Vec<f32> = plan
            .flat_rows
            .iter()
            .map(|&r| match &pm.row_scale {
                Some(s) => x[r as usize] / s[r as usize],
                None => x[r as usize],
            })
            .collect();
        let (codes, s_x) = quantize_row(&folded, act);
        let mut xhat = x.to_vec();
        for (i, &r) in plan.flat_rows.iter().enumerate() {
            let deq = s_x * codes[i] as f32;
            xhat[r as usize] = match &pm.row_scale {
                Some(s) => deq * s[r as usize],
                None => deq,
            };
        }
        // fp rows keep the original x (both paths).
        plan.matmul(pm, std::slice::from_ref(&xhat)).remove(0)
    }

    #[test]
    fn matmul_int_matches_fake_quant_reference() {
        let mut rng = Rng::new(181);
        for wbits in [2u8, 3, 5, 8] {
            for abits in [4u8, 8] {
                let (_, pm) = random_packed(&mut rng, 96, 24, wbits, QuantMode::Uniform);
                let plan = MatvecPlan::new(&pm);
                let act = ActQuantParams::new(abits, ActScalePolicy::PerToken, 1.0);
                let xs = random_batch(&mut rng, 4, 96);
                let ys = plan.matmul_int(&pm, &xs, act);
                for (b, x) in xs.iter().enumerate() {
                    let y_ref = int_reference(&plan, &pm, x, act);
                    for (j, (a, r)) in ys[b].iter().zip(&y_ref).enumerate() {
                        assert!(
                            (a - r).abs() < 2e-3 * r.abs().max(1.0),
                            "w{wbits}/a{abits} lane {b} col {j}: int {a} vs ref {r}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_int_handles_row_scale_fp_rows_and_pruned_groups() {
        let mut rng = Rng::new(182);
        let (rows, cols) = (48, 10);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.4);
        let grouping = Grouping::build(rows, cols, 12, &vec![0.0; rows]);
        let metas: Vec<crate::quant::GroupMeta> = (0..grouping.num_groups())
            .map(|gi| {
                let col = gi / grouping.m;
                let sub = gi % grouping.m;
                let vals = grouping.gather(&w, col, sub);
                let mut gm =
                    crate::quant::group_meta(&vals, 3, QuantMode::Uniform, ScaleRule::Range);
                if gi % 5 == 0 {
                    gm.bits = 0; // pruned groups in the mix
                }
                gm
            })
            .collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.5 + rng.uniform_f32()).collect();
        let fp = vec![1u32, 20, 33];
        let pm = crate::quant::bitpack::PackedMatrix::pack_full(
            &w,
            &grouping,
            &metas,
            QuantMode::Uniform,
            Some(scale),
            &fp,
        );
        let plan = MatvecPlan::new(&pm);
        let act = ActQuantParams::new(8, ActScalePolicy::PerToken, 1.0);
        let xs = random_batch(&mut rng, 6, rows);
        let ys = plan.matmul_int(&pm, &xs, act);
        for (b, x) in xs.iter().enumerate() {
            let y_ref = int_reference(&plan, &pm, x, act);
            for (a, r) in ys[b].iter().zip(&y_ref) {
                assert!((a - r).abs() < 2e-3 * r.abs().max(1.0), "lane {b}: {a} vs {r}");
            }
        }
    }

    #[test]
    fn matmul_int_batched_is_bit_identical_to_batch_of_one() {
        // Same batch-invariance contract as the f32 kernel; B = 16
        // exercises the AVX2 integer AXPY (8-lane vpmulld), B = 2 the
        // scalar lane loop. Repeated calls are also bit-stable (same
        // input → same codes → same output).
        let mut rng = Rng::new(183);
        for wbits in [2u8, 4, 7] {
            let (_, pm) = random_packed(&mut rng, 128, 20, wbits, QuantMode::Uniform);
            let plan = MatvecPlan::new(&pm);
            for abits in [4u8, 8] {
                let act = ActQuantParams::new(abits, ActScalePolicy::PerToken, 1.0);
                for bn in [2usize, 8, 16] {
                    let xs = random_batch(&mut rng, bn, 128);
                    let batched = plan.matmul_int(&pm, &xs, act);
                    assert_eq!(batched, plan.matmul_int(&pm, &xs, act), "nondeterministic");
                    for (b, x) in xs.iter().enumerate() {
                        let single = plan.matmul_int(&pm, std::slice::from_ref(x), act);
                        assert_eq!(
                            batched[b], single[0],
                            "w{wbits}/a{abits} B={bn} lane {b}: batch dependence"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn matgem_int_is_bit_identical_to_per_row_matmul_int() {
        // Tile-boundary coverage: 2·GEMM_ROW_TILE + 7 rows gives two full
        // tiles plus a ragged tail, so rows straddle every boundary case.
        let mut rng = Rng::new(184);
        let (_, pm) = random_packed(&mut rng, 96, 24, 4, QuantMode::Uniform);
        let plan = MatvecPlan::new(&pm);
        let act = ActQuantParams::new(8, ActScalePolicy::PerToken, 1.0);
        let xs = random_batch(&mut rng, 2 * GEMM_ROW_TILE + 7, 96);
        let ys = plan.matgem_int(&pm, &xs, act);
        assert_eq!(ys.len(), xs.len());
        for (r, x) in xs.iter().enumerate() {
            let single = plan.matmul_int(&pm, std::slice::from_ref(x), act);
            assert_eq!(ys[r], single[0], "row {r}: tile-position dependence");
        }
        assert!(plan.matgem_int(&pm, &[], act).is_empty());
    }

    #[test]
    fn matmul_act_routes_by_mode_and_bits() {
        let mut rng = Rng::new(185);
        let xs = random_batch(&mut rng, 3, 96);
        // bits == 0: exact f32 path, bit-identical to plain matmul.
        let (_, pmu) = random_packed(&mut rng, 96, 16, 3, QuantMode::Uniform);
        let planu = MatvecPlan::new(&pmu);
        let full = ActQuantParams::full_precision();
        assert_eq!(planu.matmul_act(&pmu, &xs, full), planu.matmul(&pmu, &xs));
        assert_eq!(planu.matgem_act(&pmu, &xs, full), planu.matgem(&pmu, &xs));
        // Uniform: the integer path, bit for bit.
        let act = ActQuantParams::new(8, ActScalePolicy::PerToken, 1.0);
        assert_eq!(planu.matmul_act(&pmu, &xs, act), planu.matmul_int(&pmu, &xs, act));
        // Companded: fake-quant fallback — close to the unquantized
        // result at 8 bits, not identical (quantization happened).
        let (_, pmc) = random_packed(&mut rng, 96, 16, 3, QuantMode::Companded);
        let planc = MatvecPlan::new(&pmc);
        let yq = planc.matmul_act(&pmc, &xs, act);
        let yf = planc.matmul(&pmc, &xs);
        assert_ne!(yq, yf, "companded fallback should actually quantize");
        for (b, (qs, fs)) in yq.iter().zip(&yf).enumerate() {
            for (a, r) in qs.iter().zip(fs) {
                assert!((a - r).abs() < 2e-2 * r.abs().max(1.0), "lane {b}: {a} vs {r}");
            }
        }
    }

    #[test]
    fn int_axpy_avx2_matches_scalar_exactly() {
        // Scalar-vs-AVX2 parity: integer arithmetic is exact, so the two
        // must agree bit for bit at every lane count (tails included).
        let mut rng = Rng::new(186);
        #[cfg(target_arch = "x86_64")]
        let simd_ok = std::arch::is_x86_feature_detected!("avx2");
        #[cfg(not(target_arch = "x86_64"))]
        let simd_ok = false;
        for bn in [1usize, 3, 8, 11, 16, 29] {
            let row: Vec<i32> = (0..bn).map(|_| (rng.uniform() * 255.0) as i32 - 127).collect();
            for c in [0i32, 1, 7, 63, 255] {
                let mut a_scalar = vec![3i32; bn];
                let mut a_simd = vec![3i32; bn];
                int_axpy(c, &row, &mut a_scalar, false);
                int_axpy(c, &row, &mut a_simd, simd_ok);
                assert_eq!(a_scalar, a_simd, "bn={bn} c={c}");
            }
        }
    }

    #[test]
    fn dense_matmul_matches_dense_matvec_exactly() {
        let mut rng = Rng::new(178);
        let (rows, cols) = (40, 21);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        // Include exact zeros to exercise the skip rule both ways.
        w.data[7] = 0.0;
        let mut xs = random_batch(&mut rng, 6, rows);
        xs[2][5] = 0.0;
        let ys = dense_matmul(&w, &xs);
        for (b, x) in xs.iter().enumerate() {
            let y_ref = dense_matvec(&w, x);
            assert_eq!(ys[b], y_ref, "lane {b}");
        }
        assert!(dense_matmul(&w, &[]).is_empty());
    }

    /// Stitch `[0, b1), [b1, b2), [b2, cols)` range results back together.
    fn stitch(parts: Vec<Vec<Vec<f32>>>, bn: usize) -> Vec<Vec<f32>> {
        let mut ys: Vec<Vec<f32>> = vec![Vec::new(); bn];
        for part in parts {
            for (b, lane) in part.into_iter().enumerate() {
                ys[b].extend_from_slice(&lane);
            }
        }
        ys
    }

    #[test]
    fn matmul_cols_stitches_bit_identically() {
        // The column-sharding contract: concatenated range results equal
        // the full-width sweep EXACTLY, for both LUT modes, with AWQ
        // row-scale / fp-rows / pruned groups in play (seed 176 hits
        // those paths in random_packed), at uneven split points.
        let mut rng = Rng::new(191);
        for mode in [QuantMode::Companded, QuantMode::Uniform] {
            let (_, pm) = random_packed(&mut rng, 96, 40, 3, mode);
            let plan = MatvecPlan::new(&pm);
            let xs = random_batch(&mut rng, 5, 96);
            let full = plan.matmul(&pm, &xs);
            for bounds in [vec![0, 40], vec![0, 13, 40], vec![0, 7, 29, 40]] {
                let parts: Vec<_> = bounds
                    .windows(2)
                    .map(|wn| plan.matmul_cols(&pm, &xs, wn[0], wn[1]))
                    .collect();
                assert_eq!(stitch(parts, 5), full, "{mode:?} bounds {bounds:?}");
            }
            // Degenerate ranges.
            assert_eq!(plan.matmul_cols(&pm, &xs, 17, 17), vec![Vec::<f32>::new(); 5]);
            assert!(plan.matmul_cols(&pm, &[], 0, 40).is_empty());
        }
    }

    #[test]
    fn matgem_act_cols_stitches_bit_identically() {
        // Same contract through the act-quant router (the engine's
        // sharded entry point): integer leg on Uniform, fake-quant leg
        // on Companded, plain leg at bits == 0 — with enough rows to
        // cross a GEMM_ROW_TILE boundary.
        let mut rng = Rng::new(192);
        for (mode, bits) in [
            (QuantMode::Uniform, 8u8),
            (QuantMode::Companded, 8),
            (QuantMode::Uniform, 0),
        ] {
            let a = if bits == 0 {
                ActQuantParams::full_precision()
            } else {
                ActQuantParams::new(bits, ActScalePolicy::PerToken, 1.0)
            };
            let (_, pm) = random_packed(&mut rng, 64, 24, 3, mode);
            let plan = MatvecPlan::new(&pm);
            let xs = random_batch(&mut rng, GEMM_ROW_TILE + 3, 64);
            let full = plan.matgem_act(&pm, &xs, a);
            let parts = vec![
                plan.matgem_act_cols(&pm, &xs, a, 0, 9),
                plan.matgem_act_cols(&pm, &xs, a, 9, 24),
            ];
            assert_eq!(stitch(parts, xs.len()), full, "{mode:?} bits {bits}");
        }
    }

    #[test]
    fn dense_matmul_cols_stitches_bit_identically() {
        let mut rng = Rng::new(193);
        let (rows, cols) = (40, 21);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let mut xs = random_batch(&mut rng, 4, rows);
        xs[1][3] = 0.0; // exercise the zero-skip on the range path too
        let full = dense_matmul(&w, &xs);
        let parts = vec![
            dense_matmul_cols(&w, &xs, 0, 8),
            dense_matmul_cols(&w, &xs, 8, 8),
            dense_matmul_cols(&w, &xs, 8, 21),
        ];
        assert_eq!(stitch(parts, 4), full);
    }
}
