//! Mixed-precision quantized matrix–vector multiply — the paper's
//! Appendix-A CUDA kernel rethought for CPU (see DESIGN.md
//! §Hardware-Adaptation for the TPU/Pallas variant).
//!
//! The kernel computes `y[j] = Σ_i x[i]·W[i,j]` directly from the packed
//! code stream, never materializing the dense matrix:
//!
//! - codes stream sequentially per column (the packed layout is
//!   column-major), so memory traffic is `bits/32` of the FP32 baseline —
//!   the memory-bound speedup the paper's Table 7 measures;
//! - dequantization is one LUT lookup + FMA: `deq = mean + scale·lut[code]`;
//! - the per-group mean term factors out: `Σ_{i∈g} x_i·mean_g =
//!   mean_g·(Σ_{i∈g} x_i)`, and the per-sub-group partial sums of `x` are
//!   shared by *every* column, so they're computed once per call;
//! - depth changes only at sub-group boundaries (the CPU analogue of the
//!   CUDA kernel's divergence-free per-4-row depth schedule).

use crate::model::tensor::Tensor;
use crate::quant::bitpack::PackedMatrix;
use crate::util::threadpool::parallel_for_chunks;

/// Precomputed decode plan for repeated matvecs against one packed
/// matrix. Owns only derived data, so it can live beside the matrix in
/// an engine without self-referential borrows.
pub struct MatvecPlan {
    /// Dequant LUTs per bit depth (index 0 unused).
    luts: Vec<Vec<f32>>,
    /// group_rows flattened in sub order (matches the code stream order).
    flat_rows: Vec<u32>,
    /// Start of each sub-group in `flat_rows`.
    sub_offsets: Vec<usize>,
    /// Copy of the code words padded with one zero word, so the decoder
    /// can always load a full 128-bit window without bounds branches.
    padded_words: Vec<u64>,
    rows: usize,
    cols: usize,
}

/// Borrow-based convenience wrapper (plan + matrix).
pub struct QuantMatvec<'a> {
    pm: &'a PackedMatrix,
    plan: MatvecPlan,
}

impl MatvecPlan {
    pub fn new(pm: &PackedMatrix) -> MatvecPlan {
        let luts: Vec<Vec<f32>> = (0..=8u8).map(|b| pm.mode.base_lut(b)).collect();
        let mut flat_rows = Vec::with_capacity(pm.rows);
        let mut sub_offsets = Vec::with_capacity(pm.grouping.m + 1);
        let mut is_fp = vec![false; pm.rows];
        for (r, _) in &pm.fp_rows {
            is_fp[*r as usize] = true;
        }
        for sub in 0..pm.grouping.m {
            sub_offsets.push(flat_rows.len());
            for &r in &pm.grouping.group_rows[sub] {
                if !is_fp[r as usize] {
                    flat_rows.push(r);
                }
            }
        }
        sub_offsets.push(flat_rows.len());
        let mut padded_words = pm.words.clone();
        padded_words.push(0);
        padded_words.push(0);
        MatvecPlan { luts, flat_rows, sub_offsets, padded_words, rows: pm.rows, cols: pm.cols }
    }

    /// y[j] = Σ_i x[i]·W[i,j], decoding from the packed stream. `pm` must
    /// be the matrix this plan was built from.
    ///
    /// §Perf hot path. The inner loop uses a *bin-accumulation* identity:
    /// `Σ_i x_i·lut[c_i] = Σ_c lut[c]·(Σ_{i: c_i=c} x_i)` — per weight it
    /// costs one bit-extract and one add into a 2^B-entry L1-resident bin
    /// array, deferring all LUT multiplies to 2^B FMAs per group. The
    /// gathered x values are pre-permuted once per call into code-stream
    /// order, so the per-column loop is fully sequential.
    pub fn matvec(&self, pm: &PackedMatrix, x: &[f32]) -> Vec<f32> {
        debug_assert_eq!(pm.rows, self.rows);
        debug_assert_eq!(pm.cols, self.cols);
        assert_eq!(x.len(), pm.rows);
        let m = pm.grouping.m;
        // Permute x into code-stream order (and fold the AWQ row scale),
        // once per call, shared by all columns.
        let mut x_perm = vec![0f32; self.flat_rows.len()];
        match &pm.row_scale {
            Some(s) => {
                for (dst, &r) in x_perm.iter_mut().zip(&self.flat_rows) {
                    *dst = x[r as usize] / s[r as usize];
                }
            }
            None => {
                for (dst, &r) in x_perm.iter_mut().zip(&self.flat_rows) {
                    *dst = x[r as usize];
                }
            }
        }
        // Per-sub-group partial sums of x (for the mean term).
        let mut sum_x = vec![0f32; m];
        for sub in 0..m {
            sum_x[sub] = x_perm[self.sub_offsets[sub]..self.sub_offsets[sub + 1]]
                .iter()
                .sum();
        }

        let mut y = vec![0f32; pm.cols];
        let y_ptr = SendMut(y.as_mut_ptr());
        let words = &self.padded_words;
        #[cfg(target_arch = "x86_64")]
        let simd_ok = std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma");
        parallel_for_chunks(pm.cols, 128, |c0, c1| {
            let y_ptr = y_ptr;
            for col in c0..c1 {
                let mut pos = pm.col_bit_offset[col];
                let mut acc = 0f32;
                for sub in 0..m {
                    let gm = pm.meta[col * m + sub];
                    if gm.bits == 0 {
                        continue; // pruned: contributes nothing
                    }
                    let xs = &x_perm[self.sub_offsets[sub]..self.sub_offsets[sub + 1]];
                    let bits = gm.bits as usize;
                    let mask = ((1u64 << bits) - 1) as u128;
                    let lut = &self.luts[bits][..];
                    // AVX2 fast path: for B ≤ 3 the whole LUT fits one YMM
                    // register and `vpermps` performs 8 dequantizations per
                    // instruction — the CPU analogue of the CUDA kernel's
                    // shared-memory LUT.
                    #[cfg(target_arch = "x86_64")]
                    if bits >= 1 && bits <= 3 && simd_ok && xs.len() >= 16 {
                        let (dot, npos) =
                            unsafe { dot_avx2_small_lut(words, pos, xs, bits, lut) };
                        pos = npos;
                        acc += gm.scale * dot + gm.mean * sum_x[sub];
                        continue;
                    }
                    // Window decode: one 128-bit load yields k = 64/bits
                    // codes with *independent* shifts (no serial cursor
                    // dependency); 4 accumulators keep FMA ports busy.
                    let k = 64 / bits;
                    let (mut d0, mut d1, mut d2, mut d3) = (0f32, 0f32, 0f32, 0f32);
                    let mut i = 0usize;
                    while i + k <= xs.len() {
                        let wi = pos >> 6;
                        let off = pos & 63;
                        // SAFETY: padded_words has 2 spare words.
                        let lo = unsafe { *words.get_unchecked(wi) } as u128;
                        let hi = unsafe { *words.get_unchecked(wi + 1) } as u128;
                        let win = (lo | (hi << 64)) >> off;
                        let mut j = 0;
                        while j + 4 <= k {
                            let c0i = ((win >> (j * bits)) & mask) as usize;
                            let c1i = ((win >> ((j + 1) * bits)) & mask) as usize;
                            let c2i = ((win >> ((j + 2) * bits)) & mask) as usize;
                            let c3i = ((win >> ((j + 3) * bits)) & mask) as usize;
                            // SAFETY: codes are < 2^bits = lut.len().
                            unsafe {
                                d0 += xs.get_unchecked(i + j) * lut.get_unchecked(c0i);
                                d1 += xs.get_unchecked(i + j + 1) * lut.get_unchecked(c1i);
                                d2 += xs.get_unchecked(i + j + 2) * lut.get_unchecked(c2i);
                                d3 += xs.get_unchecked(i + j + 3) * lut.get_unchecked(c3i);
                            }
                            j += 4;
                        }
                        while j < k {
                            let c = ((win >> (j * bits)) & mask) as usize;
                            unsafe {
                                d0 += xs.get_unchecked(i + j) * lut.get_unchecked(c);
                            }
                            j += 1;
                        }
                        pos += k * bits;
                        i += k;
                    }
                    // Tail.
                    let mut cur = Cursor::new(words, pos);
                    while i < xs.len() {
                        let c = cur.next(gm.bits as u32, mask as u64);
                        d0 += xs[i] * lut[c];
                        i += 1;
                    }
                    pos = cur.pos;
                    let dot = (d0 + d1) + (d2 + d3);
                    acc += gm.scale * dot + gm.mean * sum_x[sub];
                }
                // SAFETY: disjoint column ranges.
                unsafe { *y_ptr.0.add(col) = acc };
            }
        });
        // FP16 exception rows: dense contribution with the ORIGINAL x.
        for (r, vals) in &pm.fp_rows {
            let xv = x[*r as usize];
            if xv == 0.0 {
                continue;
            }
            for (j, &wv) in vals.iter().enumerate() {
                y[j] += xv * wv;
            }
        }
        y
    }
}

impl<'a> QuantMatvec<'a> {
    pub fn new(pm: &'a PackedMatrix) -> QuantMatvec<'a> {
        QuantMatvec { pm, plan: MatvecPlan::new(pm) }
    }

    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        self.plan.matvec(self.pm, x)
    }
}

/// AVX2 dot product for B ≤ 3 bit groups: per 8 weights, broadcast a
/// 32-bit code window into a YMM register, variable-shift each lane into
/// place (`vpsrlvd`), mask, and dequantize all 8 via one `vpermps` LUT
/// permute, then FMA against the activations. Returns (dot, new bit pos).
///
/// # Safety
/// Caller must guarantee AVX2+FMA, `lut.len() >= 8`… wait — lut has
/// 2^bits ≤ 8 entries; it is padded to 8 below. `words` must be the
/// zero-padded plan copy (2 spare words).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn dot_avx2_small_lut(
    words: &[u64],
    mut pos: usize,
    xs: &[f32],
    bits: usize,
    lut: &[f32],
) -> (f32, usize) {
    use std::arch::x86_64::*;
    debug_assert!(bits >= 1 && bits <= 3);
    let mut lut8 = [0f32; 8];
    lut8[..lut.len()].copy_from_slice(lut);
    let lutv = _mm256_loadu_ps(lut8.as_ptr());
    let b = bits as i32;
    let shifts = _mm256_setr_epi32(0, b, 2 * b, 3 * b, 4 * b, 5 * b, 6 * b, 7 * b);
    let maskv = _mm256_set1_epi32(((1u32 << bits) - 1) as i32);
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let step = 8 * bits;
    let mut i = 0usize;
    // 16 weights per iteration (two independent FMA chains).
    while i + 16 <= xs.len() {
        let w0 = load_window32(words, pos);
        let w1 = load_window32(words, pos + step);
        let idx0 = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w0 as i32), shifts), maskv);
        let idx1 = _mm256_and_si256(_mm256_srlv_epi32(_mm256_set1_epi32(w1 as i32), shifts), maskv);
        let wv0 = _mm256_permutevar8x32_ps(lutv, idx0);
        let wv1 = _mm256_permutevar8x32_ps(lutv, idx1);
        let xv0 = _mm256_loadu_ps(xs.as_ptr().add(i));
        let xv1 = _mm256_loadu_ps(xs.as_ptr().add(i + 8));
        acc0 = _mm256_fmadd_ps(xv0, wv0, acc0);
        acc1 = _mm256_fmadd_ps(xv1, wv1, acc1);
        pos += 2 * step;
        i += 16;
    }
    // Horizontal sum.
    let accv = _mm256_add_ps(acc0, acc1);
    let hi = _mm256_extractf128_ps(accv, 1);
    let lo = _mm256_castps256_ps128(accv);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_hadd_ps(s, s);
    let s = _mm_hadd_ps(s, s);
    let mut dot = _mm_cvtss_f32(s);
    // Scalar tail.
    let mask = (1u64 << bits) - 1;
    let mut cur = Cursor::new(words, pos);
    while i < xs.len() {
        let c = cur.next(bits as u32, mask);
        dot += xs[i] * lut[c];
        i += 1;
    }
    (dot, cur.pos)
}

/// Load 32 bits of code stream starting at bit `pos` (words are padded).
#[cfg(target_arch = "x86_64")]
#[inline(always)]
unsafe fn load_window32(words: &[u64], pos: usize) -> u32 {
    let wi = pos >> 6;
    let off = pos & 63;
    let lo = *words.get_unchecked(wi);
    if off == 0 {
        lo as u32
    } else {
        let hi = *words.get_unchecked(wi + 1);
        ((lo >> off) | (hi << (64 - off))) as u32
    }
}

/// Minimal LSB-first bit cursor for the decode hot loop (inlined; the
/// cross-word branch predicts near-perfectly for fixed-depth runs).
struct Cursor<'a> {
    words: &'a [u64],
    pos: usize,
}

impl<'a> Cursor<'a> {
    #[inline(always)]
    fn new(words: &'a [u64], pos: usize) -> Self {
        Cursor { words, pos }
    }

    #[inline(always)]
    fn next(&mut self, bits: u32, mask: u64) -> usize {
        let wi = self.pos >> 6;
        let off = (self.pos & 63) as u32;
        let mut v = unsafe { *self.words.get_unchecked(wi) } >> off;
        if off + bits > 64 {
            v |= unsafe { *self.words.get_unchecked(wi + 1) } << (64 - off);
        }
        self.pos += bits as usize;
        (v & mask) as usize
    }
}

struct SendMut<T>(*mut T);
impl<T> Clone for SendMut<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendMut<T> {}
unsafe impl<T> Send for SendMut<T> {}
unsafe impl<T> Sync for SendMut<T> {}

/// Dense f32 matvec baseline (the paper's FP16/cuBLAS stand-in):
/// y[j] = Σ_i x[i]·W[i,j], streaming W row-by-row.
pub fn dense_matvec(w: &Tensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), w.rows);
    let mut y = vec![0f32; w.cols];
    let y_ptr = SendMut(y.as_mut_ptr());
    // Parallelize over column blocks to match the quantized kernel's
    // threading (fair Table 7 comparison).
    parallel_for_chunks(w.cols, 256, |c0, c1| {
        let y_ptr = y_ptr;
        let yslice = unsafe { std::slice::from_raw_parts_mut(y_ptr.0.add(c0), c1 - c0) };
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &w.row(i)[c0..c1];
            for (yj, &wv) in yslice.iter_mut().zip(row) {
                *yj += xv * wv;
            }
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::grouping::Grouping;
    use crate::quant::{quantize_matrix, QuantMode, ScaleRule};
    use crate::util::rng::Rng;

    fn random_packed(
        rng: &mut Rng,
        rows: usize,
        cols: usize,
        bits: u8,
        mode: QuantMode,
    ) -> (Tensor, PackedMatrix) {
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.05, 0.5);
        let scores: Vec<f64> = (0..rows).map(|_| rng.uniform()).collect();
        let grouping = Grouping::build(rows, cols, (rows / 4).max(1), &scores);
        // Mixed depths across groups to exercise the mixed-precision path.
        let bvec: Vec<u8> = (0..grouping.num_groups())
            .map(|i| match i % 4 {
                0 => bits,
                1 => bits.saturating_sub(1).max(1),
                2 => (bits + 1).min(8),
                _ => bits,
            })
            .collect();
        let pm = quantize_matrix(&w, &grouping, &bvec, mode, ScaleRule::Range);
        (w, pm)
    }

    #[test]
    fn quantized_matvec_matches_unpacked_dense() {
        let mut rng = Rng::new(171);
        for mode in [QuantMode::Companded, QuantMode::Uniform] {
            let (_, pm) = random_packed(&mut rng, 96, 40, 3, mode);
            let mut x = vec![0f32; 96];
            rng.fill_gauss(&mut x, 0.0, 1.0);
            let qmv = QuantMatvec::new(&pm);
            let y_kernel = qmv.matvec(&x);
            let y_ref = dense_matvec(&pm.unpack(), &x);
            for (a, b) in y_kernel.iter().zip(&y_ref) {
                assert!((a - b).abs() < 1e-3 * b.abs().max(1.0), "{mode:?}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn dense_matvec_matches_naive() {
        let mut rng = Rng::new(172);
        let (rows, cols) = (33, 17);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = dense_matvec(&w, &x);
        for j in 0..cols {
            let want: f32 = (0..rows).map(|i| x[i] * w.get(i, j)).sum();
            assert!((y[j] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn kernel_handles_pruned_groups() {
        let mut rng = Rng::new(173);
        let (rows, cols) = (32, 8);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]);
        let mut bvec = vec![3u8; grouping.num_groups()];
        for (i, b) in bvec.iter_mut().enumerate() {
            if i % 3 == 0 {
                *b = 0;
            }
        }
        let pm = quantize_matrix(&w, &grouping, &bvec, QuantMode::Companded, ScaleRule::Range);
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = QuantMatvec::new(&pm).matvec(&x);
        let y_ref = dense_matvec(&pm.unpack(), &x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn kernel_handles_row_scale_and_fp_rows() {
        let mut rng = Rng::new(174);
        let (rows, cols) = (24, 10);
        let mut w = Tensor::zeros(rows, cols);
        rng.fill_laplace(&mut w.data, 0.0, 0.4);
        let grouping = Grouping::build(rows, cols, 8, &vec![0.0; rows]);
        let metas: Vec<crate::quant::GroupMeta> = (0..grouping.num_groups())
            .map(|gi| {
                let col = gi / grouping.m;
                let sub = gi % grouping.m;
                let vals = grouping.gather(&w, col, sub);
                crate::quant::group_meta(&vals, 3, QuantMode::Uniform, ScaleRule::Range)
            })
            .collect();
        let scale: Vec<f32> = (0..rows).map(|_| 0.5 + rng.uniform_f32()).collect();
        let fp = vec![2u32, 11, 17];
        let pm = crate::quant::bitpack::PackedMatrix::pack_full(
            &w,
            &grouping,
            &metas,
            QuantMode::Uniform,
            Some(scale),
            &fp,
        );
        let mut x = vec![0f32; rows];
        rng.fill_gauss(&mut x, 0.0, 1.0);
        let y = QuantMatvec::new(&pm).matvec(&x);
        let y_ref = dense_matvec(&pm.unpack(), &x);
        for (a, b) in y.iter().zip(&y_ref) {
            assert!((a - b).abs() < 2e-3 * b.abs().max(1.0), "{a} vs {b}");
        }
    }
}
