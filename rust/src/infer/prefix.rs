//! Cross-request prefix cache: a radix tree over *page-sized* token
//! chunks whose nodes hold immutable, refcounted runs of KV pages
//! ([`KvPageSet`]). Real request streams are Zipf-shared — system
//! prompts and few-shot preambles repeat across most requests — so the
//! scheduler (`infer::server`) amortizes their prefill across requests:
//!
//! - **Insert-on-retire**: a lane whose whole prompt was fed publishes
//!   its prompt's full pages here. Pages are exported once
//!   ([`KvCache::export_page_set`]) and charged ONCE against the shared
//!   [`KvPool`], however many lanes later attach them.
//! - **Lookup-on-admit**: admission walks the tree for the longest
//!   cached page path matching the new prompt, attaches it to the
//!   lane's fresh cache ([`KvCache::attach_prefix`]), and skips that
//!   part of prefill entirely — the TTFT win. The lane reserves only
//!   its non-shared remainder (`lane_cost_bytes_shared`).
//! - **Refcounted eviction**: [`PrefixCache::acquire`]/[`PrefixCache::release`]
//!   pin a path for the lifetime of each attached lane; under pool
//!   pressure [`PrefixCache::evict_lru`] frees the least-recently-used
//!   *unreferenced leaf* back to the pool. Interior nodes are protected
//!   by construction (children hold longer prefixes of the same pages'
//!   run and always outlive them in LRU order — a run evicts
//!   tail-first), and a run with live references is never touched.
//!
//! Keying is page-granular on purpose: a node exists only for a *full*
//! page of prompt tokens, so every cached page is immutable and
//! complete, and the divergence point inside a partially-matching page
//! is handled by the lane's own COW copy, not by the tree. Token
//! identity is unaffected by any of this — attention reads rows through
//! `KvRows` views that are backing-independent (see DESIGN.md §Prefix
//! caching).

use crate::infer::kv::{KvCache, KvPageSet, KvPool};
use std::sync::Arc;

/// One radix node: a full page of prompt tokens and the KV pages their
/// prefill produced.
#[derive(Debug)]
struct Node {
    /// Exactly `page_rows` prompt tokens — the edge label from `parent`.
    chunk: Vec<u32>,
    /// The immutable page set those tokens produced (one full page per
    /// (layer, K|V) store).
    pages: Arc<KvPageSet>,
    parent: Option<usize>,
    children: Vec<usize>,
    /// Lanes currently attached through this node. Eviction never
    /// touches a node with live references.
    refs: usize,
    /// LRU clock value at the last lookup/insert touch.
    last_used: u64,
    /// Pool bytes charged (once) for `pages`.
    cost: usize,
}

/// The cross-request prefix cache. One instance per scheduler call
/// (`serve_replicated` gives each replica its own); entries hold
/// reservations against the scheduler's [`KvPool`], so the scheduler
/// drains the cache back into the pool before returning.
#[derive(Debug)]
pub struct PrefixCache {
    page_rows: usize,
    /// Slot-map of nodes; `None` slots are free-listed.
    nodes: Vec<Option<Node>>,
    free: Vec<usize>,
    /// First-chunk nodes (depth 0).
    roots: Vec<usize>,
    clock: u64,
    reserved: usize,
}

impl PrefixCache {
    /// Empty cache keyed on `page_rows`-token chunks (must match the
    /// engine's KV page geometry).
    pub fn new(page_rows: usize) -> PrefixCache {
        PrefixCache {
            page_rows: page_rows.max(1),
            nodes: Vec::new(),
            free: Vec::new(),
            roots: Vec::new(),
            clock: 0,
            reserved: 0,
        }
    }

    /// Live cached nodes (page sets).
    pub fn len(&self) -> usize {
        self.nodes.iter().flatten().count()
    }

    /// Whether the cache holds no nodes.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pool bytes currently reserved by cached page sets. The scheduler
    /// subtracts this when deciding whether deferring an admission could
    /// ever succeed (a pool holding only cache reservations frees
    /// nothing by waiting for retirements).
    pub fn reserved_bytes(&self) -> usize {
        self.reserved
    }

    fn node(&self, id: usize) -> &Node {
        self.nodes[id].as_ref().expect("live prefix node")
    }

    fn node_mut(&mut self, id: usize) -> &mut Node {
        self.nodes[id].as_mut().expect("live prefix node")
    }

    fn touch(&mut self, id: usize) {
        self.clock += 1;
        let clock = self.clock;
        self.node_mut(id).last_used = clock;
    }

    /// Longest cached page path matching `prompt`'s whole-page chunks,
    /// root-first. Matched nodes are LRU-touched. The caller decides how
    /// many of the path's pages to actually attach (it may cap sharing
    /// below the full match, e.g. to keep at least one prompt token to
    /// feed).
    pub fn lookup(&mut self, prompt: &[u32]) -> Vec<usize> {
        let r = self.page_rows;
        let mut path = Vec::new();
        let mut level = self.roots.clone();
        let mut depth = 0usize;
        while (depth + 1) * r <= prompt.len() {
            let chunk = &prompt[depth * r..(depth + 1) * r];
            let hit = level.iter().copied().find(|&id| self.node(id).chunk.as_slice() == chunk);
            let Some(id) = hit else { break };
            self.touch(id);
            path.push(id);
            level = self.node(id).children.clone();
            depth += 1;
        }
        path
    }

    /// Page-set handles for a looked-up path, in path order — what
    /// [`KvCache::attach_prefix`] consumes.
    pub fn pages(&self, path: &[usize]) -> Vec<Arc<KvPageSet>> {
        path.iter().map(|&id| Arc::clone(&self.node(id).pages)).collect()
    }

    /// Pin every node on `path` against eviction — one call per lane
    /// that attaches (or is about to attach) the path.
    pub fn acquire(&mut self, path: &[usize]) {
        for &id in path {
            self.node_mut(id).refs += 1;
        }
    }

    /// Drop a lane's pins (at retirement, or when a deferred admission
    /// gives the path back before re-queuing).
    pub fn release(&mut self, path: &[usize]) {
        for &id in path {
            let n = self.node_mut(id);
            debug_assert!(n.refs > 0, "release without matching acquire");
            n.refs = n.refs.saturating_sub(1);
        }
    }

    /// Publish the whole-page prefix of `prompt`, exporting pages from a
    /// retired lane's cache. Chunks already cached are deduplicated (and
    /// LRU-touched); each NEW node's bytes are reserved against `pool`
    /// — this is the single place shared pages are ever charged. Under
    /// pressure, unreferenced LRU runs are evicted to make room; if
    /// nothing more can be freed, insertion stops early (the cache is
    /// opportunistic). Returns `(nodes_inserted, nodes_evicted)`.
    pub fn insert(&mut self, prompt: &[u32], cache: &KvCache, pool: &mut KvPool) -> (usize, usize) {
        let r = self.page_rows;
        let full = prompt.len() / r;
        let mut parent: Option<usize> = None;
        // Hold the path while inserting so eviction can't free an
        // ancestor out from under the nodes we are still adding.
        let mut held: Vec<usize> = Vec::new();
        let (mut inserted, mut evicted) = (0usize, 0usize);
        'pages: for pi in 0..full {
            let chunk = &prompt[pi * r..(pi + 1) * r];
            let level = match parent {
                None => self.roots.clone(),
                Some(p) => self.node(p).children.clone(),
            };
            let hit = level.iter().copied().find(|&id| self.node(id).chunk.as_slice() == chunk);
            if let Some(id) = hit {
                self.touch(id);
                self.node_mut(id).refs += 1;
                held.push(id);
                parent = Some(id);
                continue;
            }
            let set = cache.export_page_set(pi);
            let cost = set.cost_bytes();
            while !pool.try_reserve(cost) {
                if !self.evict_lru(pool) {
                    break 'pages;
                }
                evicted += 1;
            }
            self.clock += 1;
            let node = Node {
                chunk: chunk.to_vec(),
                pages: Arc::new(set),
                parent,
                children: Vec::new(),
                refs: 1, // held below until the insert completes
                last_used: self.clock,
                cost,
            };
            let id = match self.free.pop() {
                Some(slot) => {
                    self.nodes[slot] = Some(node);
                    slot
                }
                None => {
                    self.nodes.push(Some(node));
                    self.nodes.len() - 1
                }
            };
            match parent {
                None => self.roots.push(id),
                Some(p) => self.node_mut(p).children.push(id),
            }
            self.reserved += cost;
            held.push(id);
            inserted += 1;
            parent = Some(id);
        }
        self.release(&held);
        (inserted, evicted)
    }

    /// Evict the least-recently-used unreferenced *leaf* and release its
    /// bytes to `pool`. Interior nodes are protected by their children
    /// (a cached run evicts tail-first); nodes with live references are
    /// never touched. Returns `false` when nothing is evictable.
    pub fn evict_lru(&mut self, pool: &mut KvPool) -> bool {
        let mut victim: Option<(usize, u64)> = None;
        for (id, slot) in self.nodes.iter().enumerate() {
            let Some(n) = slot else { continue };
            if n.refs == 0 && n.children.is_empty() {
                let older = match victim {
                    None => true,
                    Some((_, lu)) => n.last_used < lu,
                };
                if older {
                    victim = Some((id, n.last_used));
                }
            }
        }
        let Some((id, _)) = victim else { return false };
        let n = self.nodes[id].take().expect("victim is live");
        match n.parent {
            None => self.roots.retain(|&c| c != id),
            Some(p) => self.node_mut(p).children.retain(|&c| c != id),
        }
        pool.release(n.cost);
        self.reserved -= n.cost;
        self.free.push(id);
        true
    }

    /// Evict everything evictable, returning the number of nodes freed.
    /// The scheduler calls this on exit — every lane has retired, so no
    /// node is pinned and the pool's reservation count returns to zero.
    pub fn drain(&mut self, pool: &mut KvPool) -> usize {
        let mut n = 0usize;
        while self.evict_lru(pool) {
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::kv::{lane_cost_bytes, page_set_bytes, KvCacheConfig, KvQuantSpec};
    use crate::model::config::ModelConfig;
    use crate::util::rng::Rng;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig { vocab: 32, dim: 8, heads: 2, layers: 1, mlp: 16, max_seq: 24 }
    }

    /// A retired lane's cache holding `prompt.len()` rows derived
    /// deterministically from the prompt tokens, so equal prompts export
    /// byte-identical pages and different prompts don't.
    fn cache_for(prompt: &[u32], cfg: &ModelConfig, kvcfg: &KvCacheConfig) -> KvCache {
        let mut cache = KvCache::new(cfg, kvcfg);
        let rows: Vec<Vec<f32>> = prompt
            .iter()
            .map(|&t| {
                let mut r = vec![0f32; cfg.dim];
                let mut rng = Rng::new(1000 + t as u64);
                rng.fill_gauss(&mut r, 0.0, 1.0);
                r
            })
            .collect();
        for li in 0..cfg.layers {
            cache.append_chunk(li, &rows, &rows);
        }
        cache.len = prompt.len();
        cache
    }

    fn prompt(tokens: &[u32]) -> Vec<u32> {
        tokens.to_vec()
    }

    #[test]
    fn insert_then_lookup_longest_match() {
        let cfg = tiny_cfg();
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let mut pc = PrefixCache::new(4);
        let mut pool = KvPool::new(None);
        let p = prompt(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10]);
        let cache = cache_for(&p, &cfg, &kvcfg);
        let (ins, ev) = pc.insert(&p, &cache, &mut pool);
        assert_eq!((ins, ev), (2, 0), "10 tokens cache 2 full pages, partial tail skipped");
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.reserved_bytes(), 2 * page_set_bytes(&cfg, &kvcfg));
        assert_eq!(pool.reserved(), pc.reserved_bytes());

        // Full match on both pages; sharing structure at every depth.
        assert_eq!(pc.lookup(&p).len(), 2);
        assert_eq!(pc.lookup(&[1, 2, 3, 4, 5, 6, 7, 8]).len(), 2);
        assert_eq!(pc.lookup(&[1, 2, 3, 4, 99, 98, 97, 96]).len(), 1, "diverges in page 2");
        assert_eq!(pc.lookup(&[1, 2, 3, 4, 5, 6, 7]).len(), 1, "partial page 2 can't match");
        assert_eq!(pc.lookup(&[9, 9, 9, 9]).len(), 0);
        // The attached pages round-trip the donor's bytes.
        let path = pc.lookup(&p);
        let mut lane = KvCache::new(&cfg, &kvcfg);
        lane.attach_prefix(&pc.pages(&path), 8);
        assert_eq!(lane.k_flat(0), cache.k_flat(0)[..8 * cfg.dim]);

        // Re-inserting the same prompt dedups; a sibling prompt shares
        // the first page and adds one node.
        let (ins, _) = pc.insert(&p, &cache, &mut pool);
        assert_eq!(ins, 0, "identical prompt inserts nothing");
        let q = prompt(&[1, 2, 3, 4, 50, 51, 52, 53]);
        let qc = cache_for(&q, &cfg, &kvcfg);
        let (ins, _) = pc.insert(&q, &qc, &mut pool);
        assert_eq!(ins, 1, "shared first page dedups, divergent second inserts");
        assert_eq!(pc.len(), 3);
        pc.drain(&mut pool);
        assert_eq!(pool.reserved(), 0, "drain returns every cached byte");
        assert!(pc.is_empty());
    }

    #[test]
    fn eviction_is_lru_leaf_only_and_respects_refs() {
        let cfg = tiny_cfg();
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let ps = page_set_bytes(&cfg, &kvcfg);
        let mut pc = PrefixCache::new(4);
        let mut pool = KvPool::new(Some(4 * ps));
        let a = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]); // 2 nodes
        let b = prompt(&[20, 21, 22, 23]); // 1 node
        let ca = cache_for(&a, &cfg, &kvcfg);
        let cb = cache_for(&b, &cfg, &kvcfg);
        pc.insert(&a, &ca, &mut pool);
        pc.insert(&b, &cb, &mut pool);
        assert_eq!(pc.len(), 3);

        // Pin a's path: only b is evictable even though a is older.
        let pa = pc.lookup(&a);
        // (lookup touched a — re-touch b's recency below it for the test)
        let pb = pc.lookup(&b);
        pc.acquire(&pa);
        let freed = pc.evict_lru(&mut pool);
        assert!(freed);
        assert_eq!(pc.lookup(&b).len(), 0, "unpinned b evicted despite newer recency");
        assert_eq!(pc.lookup(&a).len(), 2, "pinned run untouched");
        assert!(!pc.evict_lru(&mut pool), "every remaining node is pinned");
        drop(pb);

        // Released runs evict tail-first (leaf before its parent), LRU
        // across roots.
        pc.release(&pa);
        assert!(pc.evict_lru(&mut pool), "leaf of a's run");
        assert_eq!(pc.lookup(&a).len(), 1, "interior node survives its child");
        assert!(pc.evict_lru(&mut pool));
        assert_eq!(pool.reserved(), 0, "drop-to-zero returns all bytes to the pool");
        assert_eq!(pc.reserved_bytes(), 0);
    }

    #[test]
    fn insert_under_pressure_evicts_then_stops_gracefully() {
        let cfg = tiny_cfg();
        let kvcfg = KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() };
        let ps = page_set_bytes(&cfg, &kvcfg);
        // Room for exactly two page sets.
        let mut pc = PrefixCache::new(4);
        let mut pool = KvPool::new(Some(2 * ps));
        let a = prompt(&[1, 2, 3, 4]);
        let b = prompt(&[5, 6, 7, 8]);
        let c = prompt(&[9, 10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20]);
        pc.insert(&a, &cache_for(&a, &cfg, &kvcfg), &mut pool);
        pc.insert(&b, &cache_for(&b, &cfg, &kvcfg), &mut pool);
        assert_eq!(pc.len(), 2);
        // c wants 3 nodes: evicts a then b (LRU order), caches 2 of its
        // 3 pages, and stops early without panicking or over-reserving.
        let (ins, ev) = pc.insert(&c, &cache_for(&c, &cfg, &kvcfg), &mut pool);
        assert_eq!(ev, 2, "both unreferenced sets evicted");
        assert_eq!(ins, 2, "c's run is capped by the budget");
        assert_eq!(pc.lookup(&a).len(), 0);
        assert_eq!(pc.lookup(&c).len(), 2);
        assert!(pool.reserved() <= 2 * ps);
        // A fully-pinned cache rejects further inserts without evicting.
        let par = pc.lookup(&c);
        pc.acquire(&par);
        let d = prompt(&[30, 31, 32, 33]);
        let (ins, ev) = pc.insert(&d, &cache_for(&d, &cfg, &kvcfg), &mut pool);
        assert_eq!((ins, ev), (0, 0), "nothing evictable, nothing inserted");
        pc.release(&par);
        pc.drain(&mut pool);
        assert_eq!(pool.reserved(), 0);
    }

    #[test]
    fn quantized_page_sets_cache_and_cost_correctly() {
        let cfg = tiny_cfg();
        let kvcfg = KvCacheConfig {
            page_rows: 4,
            ..KvCacheConfig::quantized(KvQuantSpec::uniform(1, 5, 1.0, 0.1))
        };
        let ps = page_set_bytes(&cfg, &kvcfg);
        assert_eq!(ps, lane_cost_bytes(&cfg, &kvcfg, 4));
        let mut pc = PrefixCache::new(4);
        let mut pool = KvPool::new(None);
        let p = prompt(&[1, 2, 3, 4, 5, 6, 7, 8]);
        let cache = cache_for(&p, &cfg, &kvcfg);
        let (ins, _) = pc.insert(&p, &cache, &mut pool);
        assert_eq!(ins, 2);
        assert_eq!(pool.reserved(), 2 * ps, "quant sets charge quant bytes");
        let path = pc.lookup(&p);
        let mut lane = KvCache::new(&cfg, &kvcfg);
        lane.attach_prefix(&pc.pages(&path), 8);
        assert!(lane.is_quantized());
        assert_eq!(lane.k_flat(0), cache.k_flat(0)[..8 * cfg.dim]);
        pc.drain(&mut pool);
        assert_eq!(pool.reserved(), 0);
    }
}
