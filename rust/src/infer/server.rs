//! Serving front-end over the decode engine — the L3 "request path"
//! exercised by `examples/serve_quantized.rs`, pure Rust end to end.
//!
//! [`serve_with`] is an **iteration-level continuous-batching scheduler
//! with chunked prefill** (the vLLM scheduling discipline at laptop
//! scale): one driver thread owns the engine and, each iteration, feeds
//! every resident sequence through ONE [`Engine::prefill_batch_masked`]
//! call — decode lanes contribute their single next token, prefilling
//! lanes contribute a *chunk* of their remaining prompt under a
//! configurable per-iteration token budget ([`ServeConfig`]), so long
//! prompts are absorbed at GEMM speed without stalling resident decode
//! lanes. Because the chunked engine decodes each weight column's code
//! stream once per row tile, a T-token prompt costs ~T/tile decode
//! passes instead of T (the seed's thread-per-request design, kept as
//! [`serve_threaded`] for baseline comparisons, paid the full decode per
//! token per request).
//!
//! Determinism: per-position numerics are independent of co-scheduled
//! lanes AND of chunk boundaries (see `Engine::prefill_batch`), so
//! `serve`/`serve_with` reproduce `Engine::generate` token for token no
//! matter how requests interleave or how the budget slices their
//! prompts.
//!
//! [`serve_speculative`] layers self-speculative decoding on the same
//! scheduler: decode lanes draft `spec_k` tokens with a low-rate engine
//! and verify them in one chunked target forward per round
//! (`infer::speculative`), per lane, composing with the KV pool (each
//! lane reserves BOTH caches' worst cases at admission) — still token-
//! identical to `generate`. [`serve_ladder`] picks the draft/target pair
//! straight off a `RateLadder` container.
//!
//! **Fault containment**: every engine call a lane participates in runs
//! under `catch_unwind`. A panicking lane is rolled back (paged-KV
//! `truncate_to` to its pre-iteration length), retired with a typed
//! [`RadioError::LaneFault`] response, and its pool reservation (and,
//! in the speculative scheduler, its draft cache) released — while the
//! surviving lanes of the batch re-run solo and keep decoding
//! token-identically to `generate()`. [`ServeConfig::max_queued`] and
//! [`ServeConfig::deadline_steps`] bound queueing and residency with
//! typed [`RadioError::Shed`] / [`RadioError::DeadlineExceeded`]
//! responses, and a degradation ladder sheds optimism before it sheds
//! work: sustained KV-pool deferral halves the effective prefill chunk,
//! and collapsed speculative acceptance turns speculation off. Neither
//! degradation can change a single emitted token (chunking and
//! speculation are both token-neutral by construction).
//!
//! **Scaling out**: the engine this scheduler drives can itself run a
//! sharded execution backend (`infer::backend` — column-sharded or
//! layer-pipeline, both bit-identical to the single path, so nothing
//! here changes), and `infer::router::serve_replicated` runs R
//! independent copies of THIS scheduler over route-partitioned request
//! streams, each with its own KV budget and containment ladder. See
//! `docs/SERVING.md` for topology choice and sizing.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::RadioError;
use crate::infer::engine::{argmax, Engine};
use crate::infer::kv::{lane_cost_bytes, lane_cost_bytes_shared, KvCache, KvPool};
use crate::infer::matvec::GEMM_ROW_TILE;
use crate::infer::prefix::PrefixCache;
use crate::util::failpoint;

/// One generation request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen id; responses are returned sorted by it.
    pub id: usize,
    /// Prompt tokens (truncated to the positional table at admission).
    pub prompt: Vec<u32>,
    /// Maximum tokens to generate.
    pub max_new: usize,
}

/// One completed request.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: usize,
    /// Generated tokens (identical to `Engine::generate` on the prompt;
    /// a *prefix* of it when the request was retired early by a
    /// deadline or an isolated lane fault — see [`Response::error`]).
    pub tokens: Vec<u32>,
    /// Completion latency measured from scheduler entry (queueing counts).
    pub latency: Duration,
    /// Time to first token, measured like `latency` from call entry. For
    /// requests that generate nothing (`max_new == 0` or shed at
    /// admission) this equals the completion latency.
    pub ttft: Duration,
    /// `None` for a clean completion; otherwise why the request ended
    /// early ([`RadioError::Shed`], [`RadioError::DeadlineExceeded`], or
    /// [`RadioError::LaneFault`]). Tokens decoded before the fault are
    /// kept in [`Response::tokens`]. Every admitted request gets exactly
    /// one response, faulted or not.
    pub error: Option<RadioError>,
}

/// Scheduling knobs for [`serve_with`].
#[derive(Clone, Copy, Debug)]
pub struct ServeConfig {
    /// Maximum resident sequences (batch slots).
    pub max_batch: usize,
    /// Maximum prompt tokens fed per lane per iteration. 1 reproduces
    /// the pre-chunking token-by-token prefill; the default is the GEMM
    /// row tile, past which a longer per-lane chunk buys no further
    /// decode amortization within the tile.
    pub prefill_chunk: usize,
    /// Maximum total prompt tokens across all lanes per iteration — the
    /// chunked-prefill fairness knob. Each iteration's engine call costs
    /// roughly (decode lanes + prompt tokens fed), so this bounds how
    /// long resident decode lanes can be stalled behind prompt bursts.
    /// Lanes that don't fit the budget simply idle for the iteration
    /// (their chunk is empty); decode tokens never count against it.
    pub chunk_budget: usize,
    /// Total KV page-pool budget in bytes (`None` = unbounded). Before
    /// admitting a request the scheduler reserves its *worst-case* KV
    /// footprint (`infer::kv::lane_cost_bytes` over the rows it can ever
    /// occupy, under the engine's KV cache configuration) against this
    /// budget; requests that don't fit wait in the queue until a
    /// retiring lane releases its reservation — admission is deferred,
    /// never revoked, so no lane is ever evicted mid-decode. A request
    /// whose worst case alone exceeds the whole budget is admitted when
    /// the pool is empty (running it solo is the only way to make
    /// progress). The KV cache *mode* (page size, quantized bit widths)
    /// lives on the `Engine`, keeping serve == generate token-identical.
    pub kv_budget_bytes: Option<usize>,
    /// Draft tokens per speculative round (0 = speculation off). Read by
    /// [`serve_speculative`] / [`serve_ladder`]; [`serve_with`] has no
    /// draft engine and ignores it. Speculation is per-lane and never
    /// changes tokens — only wall clock.
    pub spec_k: usize,
    /// Which rate-ladder point [`serve_ladder`] drafts from, as a target
    /// bits/weight (nearest point wins; `None` = the ladder's lowest
    /// rate). Ignored by the other entry points, which take their draft
    /// engine explicitly.
    pub draft_bits: Option<f64>,
    /// Retire any request still resident after this many scheduler
    /// iterations with a typed [`RadioError::DeadlineExceeded`]
    /// response carrying the tokens decoded so far (always a prefix of
    /// the `generate()` output). `None` = no deadline. Clean completion
    /// on the deadline iteration wins the tie.
    pub deadline_steps: Option<usize>,
    /// Bounded admission: requests beyond this queue depth are refused
    /// at scheduler entry with a typed [`RadioError::Shed`] response
    /// (the oldest `max_queued` requests keep their FIFO service
    /// order; the newest are shed). `None` = accept everything.
    pub max_queued: Option<usize>,
    /// Cross-request prefix caching (`infer::prefix`): retiring lanes
    /// publish their prompts' full KV pages into a per-scheduler radix
    /// cache; admissions attach the longest cached prefix, skip that
    /// part of prefill (the TTFT win), and reserve only the non-shared
    /// remainder of their worst case — shared pages are charged against
    /// [`ServeConfig::kv_budget_bytes`] ONCE, by the cache, with
    /// refcounted release and LRU eviction of unreferenced runs under
    /// pool pressure. Token-neutral by construction: attention reads
    /// rows through backing-independent `KvRows` views, so served
    /// tokens stay identical to `generate()` (see DESIGN.md §Prefix
    /// caching). Off by default.
    pub prefix_cache: bool,
}

impl ServeConfig {
    /// Default schedule for `max_batch` slots: tile-sized prefill
    /// chunks, a two-tile budget, no KV bound, speculation off, no
    /// deadline, unbounded queue.
    pub fn new(max_batch: usize) -> ServeConfig {
        ServeConfig {
            max_batch,
            prefill_chunk: GEMM_ROW_TILE,
            chunk_budget: 2 * GEMM_ROW_TILE,
            kv_budget_bytes: None,
            spec_k: 0,
            draft_bits: None,
            deadline_steps: None,
            max_queued: None,
            prefix_cache: false,
        }
    }
}

/// Degradation ladder: consecutive scheduler iterations with a KV-pool
/// admission deferral before the effective prefill chunk is halved.
/// Smaller chunks bound each iteration's GEMM cost, so resident lanes
/// retire (and release pool budget) after less wall clock — the
/// scheduler trades prompt-absorption bandwidth for drain latency
/// instead of stalling the queue head behind full-size chunks.
const DEFER_SHRINK_AFTER: usize = 4;

/// Degradation-ladder recovery: consecutive deferral-free scheduler
/// iterations before a shrunken prefill chunk re-doubles toward the
/// configured value. Recovery is deliberately slower than degradation
/// (8 clear iterations per doubling vs 4 deferred ones per halving) so
/// a pool oscillating near its admission limit settles at a small chunk
/// instead of thrashing between sizes. Re-chunking never changes tokens
/// (the prefill bit-identity contract), so the ladder is free to move
/// in both directions mid-call.
const DEFER_REGROW_AFTER: usize = 8;

/// One degradation-ladder update for the effective-prefill-chunk knob,
/// shared by `serve_with` and `serve_speculative` so the two schedulers
/// cannot drift apart: sustained admission deferral halves the chunk
/// (bounding per-iteration GEMM cost so resident lanes retire sooner);
/// sustained deferral-free running re-doubles it back toward
/// `configured` (restoring prompt-absorption bandwidth once pressure
/// clears — the seed ladder only ever shrank, so one burst of pressure
/// degraded TTFT for the rest of the call). Any deferral resets the
/// recovery streak.
fn update_chunk_ladder(
    deferred_now: bool,
    prefill_chunk: &mut usize,
    configured: usize,
    defer_streak: &mut usize,
    clear_streak: &mut usize,
    robust: &mut RobustCounters,
) {
    if deferred_now {
        *defer_streak += 1;
        *clear_streak = 0;
        if *defer_streak >= DEFER_SHRINK_AFTER && *prefill_chunk > 1 {
            *prefill_chunk = (*prefill_chunk / 2).max(1);
            robust.chunk_shrinks += 1;
            *defer_streak = 0;
        }
    } else {
        *defer_streak = 0;
        if *prefill_chunk < configured {
            *clear_streak += 1;
            if *clear_streak >= DEFER_REGROW_AFTER {
                *prefill_chunk = (*prefill_chunk * 2).min(configured);
                robust.chunk_regrows += 1;
                *clear_streak = 0;
            }
        } else {
            *clear_streak = 0;
        }
    }
}

/// Degradation ladder: proposals per acceptance-measurement window for
/// the speculative schedulers. Windows are disjoint; the decision uses
/// whole windows so one unlucky round cannot disable speculation.
const SPEC_WINDOW: usize = 64;

/// Degradation ladder: windowed acceptance below this fraction turns
/// speculation off for the rest of the call (drafting then costs more
/// engine work than it saves; emitted tokens are unaffected either way).
const SPEC_MIN_ACCEPTANCE: f64 = 0.20;

/// Degradation-ladder decision: should a full measurement window with
/// this acceptance turn speculation off?
fn spec_should_disable(win_proposed: usize, win_accepted: usize) -> bool {
    win_proposed >= SPEC_WINDOW
        && (win_accepted as f64) < SPEC_MIN_ACCEPTANCE * win_proposed as f64
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig::new(8)
    }
}

/// Aggregate serving statistics.
#[derive(Clone, Debug)]
pub struct ServeStats {
    /// Requests that finished cleanly (their [`Response::error`] is
    /// `None`). Shed, timed-out, and faulted requests are counted by
    /// their own fields below; [`ServeStats::accounted`] sums all four.
    pub completed: usize,
    /// Generated tokens across all responses (prompt tokens excluded).
    pub total_tokens: usize,
    /// Prompt tokens fed through the engine (post-admission-truncation).
    pub prompt_tokens: usize,
    /// Wall clock for the whole batch of requests.
    pub wall: Duration,
    /// Median completion latency.
    pub p50: Duration,
    /// 95th-percentile completion latency.
    pub p95: Duration,
    /// Median time to first token — the latency chunked prefill exists
    /// to move.
    pub ttft_p50: Duration,
    /// 95th-percentile time to first token.
    pub ttft_p95: Duration,
    /// Generated tokens per second of wall clock.
    pub throughput_tps: f64,
    /// Prompt tokens per second of wall clock.
    pub prompt_tps: f64,
    /// Tokens *fed through the engine* per second (prompt + generated − 1
    /// per request: the final token is emitted, never fed) — the number
    /// that scales with batch amortization.
    pub engine_tps: f64,
    /// Engine iterations executed (0 for the threaded baseline, which
    /// steps inside `generate`).
    pub steps: usize,
    /// Mean tokens fed per iteration — how full the batch ran (with
    /// chunked prefill this can exceed the slot count).
    pub mean_batch_occupancy: f64,
    /// Most lanes resident in any single iteration — the number a KV
    /// memory budget caps (0 for the threaded baseline).
    pub peak_lanes: usize,
    /// Admissions deferred because the KV pool was exhausted (a request
    /// can defer repeatedly; this counts deferral events).
    pub kv_deferrals: usize,
    /// Draft tokens proposed across all speculative rounds (0 when
    /// speculation is off or the scheduler has no draft engine).
    pub spec_proposed: usize,
    /// Draft proposals accepted by target verification.
    pub spec_accepted: usize,
    /// Requests refused at admission under [`ServeConfig::max_queued`],
    /// each answered with a [`RadioError::Shed`] response.
    pub shed: usize,
    /// Requests retired at [`ServeConfig::deadline_steps`] with partial
    /// tokens and a [`RadioError::DeadlineExceeded`] response.
    pub timed_out: usize,
    /// Lanes that panicked mid-forward and were isolated
    /// ([`RadioError::LaneFault`]): the batch survived, the lane's KV
    /// (and draft) state was rolled back, its reservation released.
    pub lane_faults: usize,
    /// Times the degradation ladder halved the effective prefill chunk
    /// under sustained KV-pool admission deferral.
    pub chunk_shrinks: usize,
    /// Times the ladder re-doubled a shrunken prefill chunk back toward
    /// the configured value after sustained deferral-free running — the
    /// recovery side of `chunk_shrinks` (never exceeds it: the chunk
    /// can only regrow what deferral shrank).
    pub chunk_regrows: usize,
    /// Times the degradation ladder disabled speculation after a full
    /// acceptance window collapsed (at most once per serve call).
    pub spec_disables: usize,
    /// Ladder sections dropped at load time because their payload failed
    /// its CRC or parse check ([`serve_ladder_mapped`] only): the serve
    /// ran degraded, falling back to the nearest surviving rate point.
    /// Always 0 for eager loads, which refuse corrupt containers.
    pub degraded_sections: usize,
    /// Admissions that attached a cached prefix run
    /// ([`ServeConfig::prefix_cache`]; 0 with the cache off).
    pub prefix_hits: usize,
    /// Prompt tokens served from shared pages instead of being
    /// prefilled — the engine work the prefix cache saved.
    pub prefix_tokens_reused: usize,
    /// Cached prefix page sets LRU-evicted under KV-pool pressure (the
    /// exit-time drain is bookkeeping, not pressure, and is not
    /// counted).
    pub prefix_evictions: usize,
    /// Most bytes reserved against the KV pool in any single iteration:
    /// admitted lanes' worst-case remainders plus cached prefix pages
    /// (each charged once, however many lanes share them) — the number
    /// `bench_prefix` compares across its cache-on/off arms.
    pub peak_kv_bytes: usize,
}

impl ServeStats {
    /// Fraction of draft proposals accepted (0 when nothing was
    /// proposed) — the number that decides whether a draft rate pays.
    pub fn spec_acceptance(&self) -> f64 {
        if self.spec_proposed == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_proposed as f64
        }
    }

    /// Responses produced for any reason: `completed + shed + timed_out
    /// + lane_faults`. The scheduler answers every submitted request
    /// exactly once, so this equals the request count — the accounting
    /// invariant the fault-injection suite pins.
    pub fn accounted(&self) -> usize {
        self.completed + self.shed + self.timed_out + self.lane_faults
    }
}

/// Fault/degradation tallies threaded from a scheduler loop into
/// [`finalize_stats`].
#[derive(Clone, Copy, Default)]
struct RobustCounters {
    shed: usize,
    timed_out: usize,
    lane_faults: usize,
    chunk_shrinks: usize,
    chunk_regrows: usize,
    spec_disables: usize,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests, {} tokens in {:.2?}: p50 {:.2?}, p95 {:.2?}, ttft p50 {:.2?}/p95 \
             {:.2?}, {:.1} gen tok/s, {:.1} prompt tok/s, {:.1} engine tok/s",
            self.completed,
            self.total_tokens,
            self.wall,
            self.p50,
            self.p95,
            self.ttft_p50,
            self.ttft_p95,
            self.throughput_tps,
            self.prompt_tps,
            self.engine_tps
        )?;
        if self.steps > 0 {
            write!(
                f,
                ", batch occupancy {:.2} over {} steps (peak {} lanes)",
                self.mean_batch_occupancy, self.steps, self.peak_lanes
            )?;
        }
        if self.kv_deferrals > 0 {
            write!(f, ", {} KV-pool deferrals", self.kv_deferrals)?;
        }
        if self.prefix_hits > 0 || self.prefix_evictions > 0 {
            write!(
                f,
                ", prefix cache: {} hits / {} tokens reused / {} evictions",
                self.prefix_hits, self.prefix_tokens_reused, self.prefix_evictions
            )?;
        }
        if self.spec_proposed > 0 {
            write!(
                f,
                ", spec acceptance {:.0}% ({}/{})",
                100.0 * self.spec_acceptance(),
                self.spec_accepted,
                self.spec_proposed
            )?;
        }
        if self.shed + self.timed_out + self.lane_faults > 0 {
            write!(
                f,
                ", faults: {} shed / {} timed out / {} lane faults",
                self.shed, self.timed_out, self.lane_faults
            )?;
        }
        if self.chunk_shrinks > 0 {
            write!(f, ", {} prefill-chunk shrinks", self.chunk_shrinks)?;
        }
        if self.chunk_regrows > 0 {
            write!(f, ", {} prefill-chunk regrows", self.chunk_regrows)?;
        }
        if self.spec_disables > 0 {
            write!(f, ", speculation disabled mid-call")?;
        }
        if self.degraded_sections > 0 {
            write!(f, ", {} ladder sections dropped (degraded load)", self.degraded_sections)?;
        }
        Ok(())
    }
}

fn percentile(lats: &mut [Duration], q: f64) -> Duration {
    if lats.is_empty() {
        return Duration::ZERO;
    }
    lats.sort_unstable();
    lats[((lats.len() - 1) as f64 * q).round() as usize]
}

fn finalize_stats(
    responses: &[Response],
    wall: Duration,
    engine_tokens: usize,
    prompt_tokens: usize,
    steps: usize,
    peak_lanes: usize,
    kv_deferrals: usize,
    spec: (usize, usize),
    robust: RobustCounters,
    prefix: (usize, usize, usize),
    peak_kv_bytes: usize,
) -> ServeStats {
    let mut lats: Vec<Duration> = responses.iter().map(|r| r.latency).collect();
    // TTFT percentiles cover only responses that produced a token:
    // max_new = 0 requests would contribute pure queueing time and skew
    // the metric chunked prefill exists to report.
    let mut ttfts: Vec<Duration> = responses
        .iter()
        .filter(|r| !r.tokens.is_empty())
        .map(|r| r.ttft)
        .collect();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let completed = responses.iter().filter(|r| r.error.is_none()).count();
    // Exact accounting: every request's response is either clean or
    // carries exactly one of the three fault reasons.
    debug_assert_eq!(
        completed + robust.shed + robust.timed_out + robust.lane_faults,
        responses.len(),
        "every request must be accounted exactly once"
    );
    let secs = wall.as_secs_f64().max(1e-9);
    ServeStats {
        completed,
        total_tokens,
        prompt_tokens,
        wall,
        p50: percentile(&mut lats, 0.5),
        p95: percentile(&mut lats, 0.95),
        ttft_p50: percentile(&mut ttfts, 0.5),
        ttft_p95: percentile(&mut ttfts, 0.95),
        throughput_tps: total_tokens as f64 / secs,
        prompt_tps: prompt_tokens as f64 / secs,
        engine_tps: engine_tokens as f64 / secs,
        steps,
        mean_batch_occupancy: if steps == 0 {
            0.0
        } else {
            engine_tokens as f64 / steps as f64
        },
        peak_lanes,
        kv_deferrals,
        spec_proposed: spec.0,
        spec_accepted: spec.1,
        shed: robust.shed,
        timed_out: robust.timed_out,
        lane_faults: robust.lane_faults,
        chunk_shrinks: robust.chunk_shrinks,
        chunk_regrows: robust.chunk_regrows,
        spec_disables: robust.spec_disables,
        degraded_sections: 0,
        prefix_hits: prefix.0,
        prefix_tokens_reused: prefix.1,
        prefix_evictions: prefix.2,
        peak_kv_bytes,
    }
}

/// Bounded admission ([`ServeConfig::max_queued`]) applied at scheduler
/// entry: requests beyond the bound are answered immediately with a
/// typed [`RadioError::Shed`] response, newest first, so the oldest
/// `max_queued` requests keep their FIFO service order. Returns the
/// number shed.
fn shed_overload(
    queue: &mut VecDeque<Request>,
    max_queued: Option<usize>,
    responses: &mut Vec<Response>,
    t0: Instant,
) -> usize {
    let Some(bound) = max_queued else { return 0 };
    let mut shed = 0usize;
    while queue.len() > bound {
        let req = queue.pop_back().expect("len > bound implies non-empty");
        let now = t0.elapsed();
        responses.push(Response {
            id: req.id,
            tokens: Vec::new(),
            latency: now,
            ttft: now,
            error: Some(RadioError::Shed { queued: bound }),
        });
        shed += 1;
    }
    shed
}

/// Render a `catch_unwind` payload for a [`RadioError::LaneFault`].
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "panic payload of unknown type"
    }
}

/// One resident sequence in the continuous batch. Its KV cache lives in a
/// parallel `Vec<KvCache>` (kept index-aligned) so the scheduler can hand
/// the engine one contiguous `&mut [KvCache]` per step.
struct ActiveSeq {
    id: usize,
    /// Admission-truncated prompt (≤ `max_seq` tokens).
    prompt: Vec<u32>,
    /// Prompt tokens already fed to the engine.
    fed: usize,
    max_new: usize,
    out: Vec<u32>,
    ttft: Option<Duration>,
    /// Worst-case KV bytes reserved against the pool at admission,
    /// released verbatim at retirement. With a prefix-cache hit this is
    /// only the non-shared remainder (`lane_cost_bytes_shared`).
    kv_cost: usize,
    /// Prefix-cache nodes this lane holds pinned (empty without a hit);
    /// released at retirement so eviction can reclaim the run.
    prefix_path: Vec<usize>,
    /// Scheduler iterations this lane has been resident — the clock
    /// [`ServeConfig::deadline_steps`] is measured on.
    steps_resident: usize,
}

impl ActiveSeq {
    /// Mirror of `Engine::generate`'s stopping rule, applied after a
    /// token has been pushed: stop at `max_new`, or once the KV cache has
    /// reached the positional table (one final token is still emitted
    /// from the last in-budget logits, exactly like `generate`).
    fn is_done(&self, cache_len: usize, max_seq: usize) -> bool {
        self.out.len() >= self.max_new || cache_len >= max_seq
    }
}

/// [`serve_with`] under the default chunked-prefill schedule — the
/// drop-in entry point (`max_batch` slots, default chunk budget).
pub fn serve(
    engine: &Engine,
    requests: Vec<Request>,
    max_batch: usize,
) -> (Vec<Response>, ServeStats) {
    serve_with(engine, requests, ServeConfig::new(max_batch))
}

/// Serve `requests` through one engine with **iteration-level continuous
/// batching and chunked prefill**: up to `cfg.max_batch` sequences are
/// resident at once; waiting requests are admitted the moment a slot
/// frees (prompts truncated to the positional table at admission, the
/// [`Engine::admit_prompt`] rule); each iteration feeds decode lanes
/// their next token and prefilling lanes a prompt chunk under
/// `cfg.chunk_budget`. Returns per-request responses (sorted by id) and
/// aggregate stats. Latency is measured from call entry (all requests
/// "arrive" together), so it includes queueing — the honest number for a
/// loaded server.
///
/// Output tokens are identical to calling `engine.generate(&prompt,
/// max_new)` per request, for every budget/chunk configuration.
pub fn serve_with(
    engine: &Engine,
    requests: Vec<Request>,
    cfg: ServeConfig,
) -> (Vec<Response>, ServeStats) {
    let t0 = Instant::now();
    let max_batch = cfg.max_batch.max(1);
    let chunk_budget = cfg.chunk_budget.max(1);
    let max_seq = engine.config.max_seq;
    let mut queue: VecDeque<Request> = requests.into_iter().collect();
    let mut pool = KvPool::new(cfg.kv_budget_bytes);
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut caches: Vec<KvCache> = Vec::new(); // index-aligned with `active`
    let mut responses: Vec<Response> = Vec::new();
    let mut steps = 0usize;
    let mut engine_tokens = 0usize;
    let mut prompt_tokens = 0usize;
    let mut peak_lanes = 0usize;
    let mut kv_deferrals = 0usize;
    let mut robust = RobustCounters::default();
    // Degradation ladder: the effective prefill chunk starts at the
    // configured value and halves after DEFER_SHRINK_AFTER consecutive
    // deferral iterations. Chunking never changes tokens, so the ladder
    // is free to move this knob mid-call.
    let mut prefill_chunk = cfg.prefill_chunk.max(1);
    let mut defer_streak = 0usize;
    let mut clear_streak = 0usize;
    // Counts deferral EPISODES (one per request that had to wait), not
    // wait iterations — the head request re-checks the pool every
    // iteration and would otherwise inflate the stat by decode length.
    let mut last_deferred: Option<usize> = None;
    // Cross-request prefix cache (one per scheduler call;
    // serve_replicated gives each replica its own). Cached page sets
    // hold pool reservations, so the cache is drained back into the
    // pool before exit.
    let page_rows = engine.kv_config().page_rows.max(1);
    let mut prefix = cfg.prefix_cache.then(|| PrefixCache::new(page_rows));
    let (mut prefix_hits, mut prefix_reused, mut prefix_evictions) = (0usize, 0usize, 0usize);
    let mut peak_kv = 0usize;
    robust.shed = shed_overload(&mut queue, cfg.max_queued, &mut responses, t0);

    loop {
        // Admission: fill free slots from the queue, in arrival order,
        // reserving each lane's worst-case KV footprint against the pool
        // first. A request the pool can't hold waits (admission is
        // deferred, never reordered past — FIFO keeps it deterministic
        // and starvation-free) until retirements release budget; the
        // sole exception is a request too big for the whole budget,
        // which is admitted alone rather than deadlocking the queue.
        let mut deferred_now = false;
        while active.len() < max_batch {
            let Some(req) = queue.pop_front() else { break };
            // One source of truth for the admission rule: whatever
            // Engine::admit_prompt keeps is what this scheduler feeds.
            let keep = engine.admit_prompt(&req.prompt).len();
            // Worst-case cache rows this lane can ever occupy: the
            // prompt plus every decode step that feeds a token (the
            // final generated token is emitted, never fed), clamped to
            // the positional table — `generate`'s stopping rule.
            let rows_worst = (keep + req.max_new.saturating_sub(1)).min(max_seq);
            // Prefix lookup before reserving: whole pages matched in the
            // cache are already charged (once) by it, so the lane
            // reserves only its non-shared remainder. At least one
            // prompt token is always fed — the lane needs logits to
            // emit from — capping sharing at keep − 1; a cap landing
            // mid-page becomes a lane-owned COW tail at attach.
            let mut path: Vec<usize> = Vec::new();
            let mut shared = 0usize;
            if req.max_new > 0 && keep > 0 {
                if let Some(pc) = prefix.as_mut() {
                    path = pc.lookup(&req.prompt[..keep]);
                    shared = (path.len() * page_rows).min(keep - 1);
                    if shared == 0 {
                        path.clear();
                    } else {
                        pc.acquire(&path); // pin against eviction
                    }
                }
            }
            let kv_cost = if req.max_new == 0 {
                0 // completes at admission; never builds a cache
            } else {
                lane_cost_bytes_shared(
                    &engine.config,
                    engine.kv_config(),
                    rows_worst,
                    shared / page_rows,
                )
            };
            let mut admitted = pool.try_reserve(kv_cost);
            if !admitted {
                // Pool pressure: LRU-evict unreferenced cached runs
                // before deferring — the cache is opportunistic,
                // admissions are not.
                if let Some(pc) = prefix.as_mut() {
                    while pc.evict_lru(&mut pool) {
                        prefix_evictions += 1;
                        if pool.try_reserve(kv_cost) {
                            admitted = true;
                            break;
                        }
                    }
                }
            }
            if !admitted {
                let cache_held = prefix.as_ref().map_or(0, PrefixCache::reserved_bytes);
                if active.is_empty() && pool.reserved() == cache_held {
                    // Solo progress guarantee: every remaining reserved
                    // byte is the cache's own (this lane's pinned path
                    // included) — no retirement can ever free budget,
                    // so deferring would deadlock the queue.
                    pool.reserve_unchecked(kv_cost);
                } else {
                    deferred_now = true;
                    if last_deferred != Some(req.id) {
                        kv_deferrals += 1;
                        last_deferred = Some(req.id);
                    }
                    if let Some(pc) = prefix.as_mut() {
                        pc.release(&path); // re-looked-up on retry
                    }
                    queue.push_front(req);
                    break;
                }
            }
            if shared > 0 {
                prefix_hits += 1;
                prefix_reused += shared;
            }
            let mut prompt = req.prompt;
            prompt.truncate(keep);
            let mut seq = ActiveSeq {
                id: req.id,
                prompt,
                fed: shared,
                max_new: req.max_new,
                out: Vec::new(),
                ttft: None,
                kv_cost,
                prefix_path: path,
                steps_resident: 0,
            };
            if seq.max_new == 0 {
                let now = t0.elapsed();
                responses.push(Response {
                    id: seq.id,
                    tokens: seq.out,
                    latency: now,
                    ttft: now,
                    error: None,
                });
                continue;
            }
            if seq.prompt.is_empty() {
                // `generate` starts from all-zero logits: argmax is 0.
                seq.out.push(0);
                seq.ttft = Some(t0.elapsed());
                if seq.is_done(0, max_seq) {
                    let now = t0.elapsed();
                    let ttft = seq.ttft.unwrap();
                    responses.push(Response {
                        id: seq.id,
                        tokens: seq.out,
                        latency: now,
                        ttft,
                        error: None,
                    });
                    pool.release(seq.kv_cost);
                    continue;
                }
            }
            // A hit lane starts from the cached pages (its cache clock
            // already at `shared`), so prefill resumes mid-prompt
            // exactly like a resumed lane — skipping the shared rows'
            // engine work entirely.
            let cache = if shared > 0 {
                let pc = prefix.as_ref().expect("prefix hit implies a cache");
                engine.new_cache_with_prefix(&pc.pages(&seq.prefix_path), shared)
            } else {
                engine.new_cache()
            };
            active.push(seq);
            caches.push(cache);
        }
        if active.is_empty() {
            break;
        }
        // Degradation ladder: sustained pool exhaustion shrinks the
        // effective prefill chunk instead of letting the queue head
        // stall behind full-size prompt chunks; sustained deferral-free
        // running re-grows it toward the configured value.
        update_chunk_ladder(
            deferred_now,
            &mut prefill_chunk,
            cfg.prefill_chunk.max(1),
            &mut defer_streak,
            &mut clear_streak,
            &mut robust,
        );
        peak_lanes = peak_lanes.max(active.len());
        peak_kv = peak_kv.max(pool.reserved());
        for seq in active.iter_mut() {
            seq.steps_resident += 1;
        }

        // Plan this iteration's chunks: decode lanes always feed their
        // single next token (never budget-limited — starving decode is
        // what the budget exists to prevent); prefilling lanes take up
        // to `prefill_chunk` of their remaining prompt from the shared
        // budget, in lane order; lanes the budget can't reach idle this
        // iteration with an empty chunk. A lane emits logits once this
        // iteration's chunk finishes its prompt, or on any decode token.
        let mut budget = chunk_budget;
        let mut chunks: Vec<&[u32]> = Vec::with_capacity(active.len());
        let mut emit: Vec<bool> = Vec::with_capacity(active.len());
        let mut fed_now: Vec<usize> = Vec::with_capacity(active.len());
        for seq in active.iter() {
            if seq.fed < seq.prompt.len() {
                let c = (seq.prompt.len() - seq.fed).min(prefill_chunk).min(budget);
                budget -= c;
                chunks.push(&seq.prompt[seq.fed..seq.fed + c]);
                emit.push(c > 0 && seq.fed + c == seq.prompt.len());
                fed_now.push(c);
            } else {
                let last = seq.out.last().expect("decode phase implies a generated token");
                chunks.push(std::slice::from_ref(last));
                emit.push(true);
                fed_now.push(0);
            }
        }

        // Chunk lengths outlive `chunks` (which borrows `active`) for
        // the accounting below, where `active` is borrowed mutably.
        let chunk_lens: Vec<usize> = chunks.iter().map(|c| c.len()).collect();

        // Fault containment around the one batched engine call. The
        // failpoint "serve::lane" (tag = request id) is how the
        // fault-injection suite kills a specific lane here; a real
        // panic out of the engine (e.g. a corrupted KV page) takes the
        // same path. On unwind: every cache is rolled back to its
        // pre-iteration length (`forward_chunk` only advances `len`
        // after a fully successful forward, so appended rows beyond
        // `pre` are exactly the partial work), then each lane re-runs
        // solo — per-lane numeric independence makes the solo result
        // bit-identical to the batched one — and only the lane that
        // panics again is retired with a typed fault.
        let pre_lens: Vec<usize> = caches.iter().map(|c| c.len).collect();
        let ids: Vec<usize> = active.iter().map(|s| s.id).collect();
        let mut exit: Vec<Option<RadioError>> = vec![None; active.len()];
        let batched = catch_unwind(AssertUnwindSafe(|| {
            for (i, &id) in ids.iter().enumerate() {
                if !chunks[i].is_empty() {
                    failpoint::fire("serve::lane", id as u64);
                }
            }
            engine.prefill_batch_masked(&chunks, &mut caches, Some(&emit))
        }));
        let logits = match batched {
            Ok(l) => l,
            Err(_) => {
                for (c, &pre) in caches.iter_mut().zip(&pre_lens) {
                    c.truncate_to(pre);
                }
                let mut solo = vec![Vec::new(); ids.len()];
                for i in 0..ids.len() {
                    if chunks[i].is_empty() {
                        continue; // idle this iteration; nothing to redo
                    }
                    let one = catch_unwind(AssertUnwindSafe(|| {
                        failpoint::fire("serve::lane", ids[i] as u64);
                        engine.prefill_batch_masked(
                            &chunks[i..i + 1],
                            &mut caches[i..i + 1],
                            Some(&emit[i..i + 1]),
                        )
                    }));
                    match one {
                        Ok(mut l) => solo[i] = l.pop().unwrap_or_default(),
                        Err(payload) => {
                            caches[i].truncate_to(pre_lens[i]);
                            exit[i] = Some(RadioError::LaneFault {
                                detail: format!(
                                    "request {}: {}",
                                    ids[i],
                                    panic_message(payload.as_ref())
                                ),
                            });
                            robust.lane_faults += 1;
                        }
                    }
                }
                solo
            }
        };
        steps += 1;

        // Advance every lane first (stable indices into `logits`), then
        // compact out the finished ones. Faulted lanes were rolled back
        // — their chunk was never fed, so they contribute nothing to the
        // token accounting and retire with whatever they decoded before.
        let mut retired = vec![false; active.len()];
        for (i, seq) in active.iter_mut().enumerate() {
            if exit[i].is_some() {
                retired[i] = true;
                continue;
            }
            engine_tokens += chunk_lens[i];
            prompt_tokens += fed_now[i];
            seq.fed += fed_now[i];
            if emit[i] {
                let next = argmax(&logits[i]) as u32;
                seq.out.push(next);
                if seq.ttft.is_none() {
                    seq.ttft = Some(t0.elapsed());
                }
                retired[i] = seq.is_done(caches[i].len, max_seq);
            }
        }
        // Deadlines, after the iteration's work: clean completion on the
        // deadline iteration wins the tie; partial tokens are kept.
        if let Some(d) = cfg.deadline_steps {
            for (i, seq) in active.iter().enumerate() {
                if !retired[i] && seq.steps_resident >= d.max(1) {
                    retired[i] = true;
                    exit[i] = Some(RadioError::DeadlineExceeded { steps: seq.steps_resident });
                    robust.timed_out += 1;
                }
            }
        }
        // Back-to-front so swap_remove never disturbs an index still to
        // be visited (lanes are numerically independent, so batch order
        // is free to change between steps). `exit` gets the identical
        // swap_remove so it stays element-aligned with `active`.
        for i in (0..active.len()).rev() {
            if retired[i] {
                let done = active.swap_remove(i);
                let cache = caches.swap_remove(i);
                let error = exit.swap_remove(i);
                pool.release(done.kv_cost);
                if let Some(pc) = prefix.as_mut() {
                    pc.release(&done.prefix_path);
                    // Insert-on-retire: a lane whose whole prompt made
                    // it into the cache (fed or attached) publishes its
                    // full prompt pages for later admissions. Faulted
                    // lanes rolled back mid-prompt publish nothing.
                    if done.fed == done.prompt.len() && !done.prompt.is_empty() {
                        let (_, ev) = pc.insert(&done.prompt, &cache, &mut pool);
                        prefix_evictions += ev;
                    }
                }
                let now = t0.elapsed();
                // A lane faulted or expired before its first token has
                // no TTFT; report completion time so percentiles stay
                // defined (such responses carry an error and no tokens).
                let ttft = done.ttft.unwrap_or(now);
                responses.push(Response {
                    id: done.id,
                    tokens: done.out,
                    latency: now,
                    ttft,
                    error,
                });
            }
        }
    }

    if let Some(pc) = prefix.as_mut() {
        // Every lane has retired, so nothing is pinned: drain the
        // cache's reservations back into the pool (bookkeeping, not
        // pressure — deliberately not counted as evictions).
        pc.drain(&mut pool);
    }
    debug_assert_eq!(
        pool.reserved(),
        0,
        "KV pool must drain to zero at scheduler exit (reservation leak)"
    );
    responses.sort_by_key(|r| r.id);
    let stats = finalize_stats(
        &responses,
        t0.elapsed(),
        engine_tokens,
        prompt_tokens,
        steps,
        peak_lanes,
        kv_deferrals,
        (0, 0),
        robust,
        (prefix_hits, prefix_reused, prefix_evictions),
        peak_kv,
    );
    (responses, stats)
}

/// One resident sequence of the speculative scheduler: the serve_with
/// bookkeeping plus the speculative round state (the full token stream
/// whose last element is pending). Target and draft caches live in two
/// parallel `Vec<KvCache>`s, index-aligned with `active`.
struct SpecSeq {
    id: usize,
    prompt: Vec<u32>,
    fed: usize,
    max_new: usize,
    out: Vec<u32>,
    ttft: Option<Duration>,
    kv_cost: usize,
    /// prompt + emitted tokens; built when the first token is emitted.
    /// The last element is always pending (emitted, not yet fed) — the
    /// `Engine::step_speculative` state contract.
    tokens: Vec<u32>,
    /// Prefix-cache nodes pinned for this lane's TARGET cache (the
    /// draft cache never shares: its pages come from draft-engine
    /// numerics, which cached target pages cannot reproduce).
    prefix_path: Vec<usize>,
    /// Scheduler iterations resident (the `deadline_steps` clock).
    steps_resident: usize,
}

/// [`serve_with`]'s scheduler with **per-lane self-speculative decoding**:
/// prompts are absorbed through the same budgeted chunked prefill on the
/// target engine; once a lane reaches decode it runs draft/verify rounds
/// ([`Engine::step_speculative`]) — `cfg.spec_k` draft tokens from the
/// low-rate `draft` engine, one chunked target verify, greedy
/// longest-prefix acceptance, paged-KV rollback of rejected rows.
///
/// Composition with admission control: each lane reserves the worst case
/// of BOTH its caches (target + draft) against the KV pool at admission;
/// the speculative round's provisional rows never exceed the same
/// `prompt + max_new − 1` row bound a plain decode lane has (the round
/// clamps its proposal budget), so `serve_with`'s deferral semantics
/// carry over unchanged. Output tokens are identical to
/// `engine.generate(&prompt, max_new)` per request for every `(spec_k,
/// draft)` configuration — speculation moves wall clock only.
/// `ServeStats` reports the proposal/acceptance counters.
pub fn serve_speculative(
    engine: &Engine,
    draft: &Engine,
    requests: Vec<Request>,
    cfg: ServeConfig,
) -> (Vec<Response>, ServeStats) {
    assert_eq!(
        engine.config, draft.config,
        "draft and target must share one model shape (self-speculative)"
    );
    let t0 = Instant::now();
    let max_batch = cfg.max_batch.max(1);
    let chunk_budget = cfg.chunk_budget.max(1);
    let max_seq = engine.config.max_seq;
    let mut queue: VecDeque<Request> = requests.into_iter().collect();
    let mut pool = KvPool::new(cfg.kv_budget_bytes);
    let mut active: Vec<SpecSeq> = Vec::new();
    let mut caches: Vec<KvCache> = Vec::new(); // target caches
    let mut draft_caches: Vec<KvCache> = Vec::new();
    let mut responses: Vec<Response> = Vec::new();
    let (mut steps, mut engine_tokens, mut prompt_tokens) = (0usize, 0usize, 0usize);
    let (mut peak_lanes, mut kv_deferrals) = (0usize, 0usize);
    let (mut spec_proposed, mut spec_accepted) = (0usize, 0usize);
    let mut robust = RobustCounters::default();
    // Degradation ladder state (see serve_with for the chunk ladder):
    // speculation is additionally disabled for the rest of the call
    // once a full window of proposals collapses below the acceptance
    // floor — drafting then burns more engine work than it saves, and
    // turning it off never changes a token (speculation is
    // token-neutral by the greedy-verification contract).
    let mut prefill_chunk = cfg.prefill_chunk.max(1);
    let mut defer_streak = 0usize;
    let mut clear_streak = 0usize;
    let mut spec_enabled = true;
    let (mut win_proposed, mut win_accepted) = (0usize, 0usize);
    let mut last_deferred: Option<usize> = None;
    // Prefix cache over TARGET pages only (see SpecSeq::prefix_path);
    // same lifecycle as in serve_with.
    let page_rows = engine.kv_config().page_rows.max(1);
    let mut prefix = cfg.prefix_cache.then(|| PrefixCache::new(page_rows));
    let (mut prefix_hits, mut prefix_reused, mut prefix_evictions) = (0usize, 0usize, 0usize);
    let mut peak_kv = 0usize;
    robust.shed = shed_overload(&mut queue, cfg.max_queued, &mut responses, t0);

    loop {
        // Admission: serve_with's rule, with the lane's worst case
        // covering BOTH caches. The draft cache always trails the target
        // cache, so the same row bound covers it. A prefix hit discounts
        // the TARGET side only — the draft must still prefill the whole
        // prompt with its own (low-rate) numerics, so its worst case is
        // undiminished.
        let mut deferred_now = false;
        while active.len() < max_batch {
            let Some(req) = queue.pop_front() else { break };
            let keep = engine.admit_prompt(&req.prompt).len();
            let rows_worst = (keep + req.max_new.saturating_sub(1)).min(max_seq);
            let mut path: Vec<usize> = Vec::new();
            let mut shared = 0usize;
            if req.max_new > 0 && keep > 0 {
                if let Some(pc) = prefix.as_mut() {
                    path = pc.lookup(&req.prompt[..keep]);
                    shared = (path.len() * page_rows).min(keep - 1);
                    if shared == 0 {
                        path.clear();
                    } else {
                        pc.acquire(&path);
                    }
                }
            }
            let kv_cost = if req.max_new == 0 {
                0
            } else {
                lane_cost_bytes_shared(
                    &engine.config,
                    engine.kv_config(),
                    rows_worst,
                    shared / page_rows,
                ) + lane_cost_bytes(&draft.config, draft.kv_config(), rows_worst)
            };
            let mut admitted = pool.try_reserve(kv_cost);
            if !admitted {
                if let Some(pc) = prefix.as_mut() {
                    while pc.evict_lru(&mut pool) {
                        prefix_evictions += 1;
                        if pool.try_reserve(kv_cost) {
                            admitted = true;
                            break;
                        }
                    }
                }
            }
            if !admitted {
                let cache_held = prefix.as_ref().map_or(0, PrefixCache::reserved_bytes);
                if active.is_empty() && pool.reserved() == cache_held {
                    pool.reserve_unchecked(kv_cost); // solo over-budget lane
                } else {
                    deferred_now = true;
                    if last_deferred != Some(req.id) {
                        kv_deferrals += 1;
                        last_deferred = Some(req.id);
                    }
                    if let Some(pc) = prefix.as_mut() {
                        pc.release(&path);
                    }
                    queue.push_front(req);
                    break;
                }
            }
            if shared > 0 {
                prefix_hits += 1;
                prefix_reused += shared;
            }
            let mut prompt = req.prompt;
            prompt.truncate(keep);
            let mut seq = SpecSeq {
                id: req.id,
                prompt,
                fed: shared,
                max_new: req.max_new,
                out: Vec::new(),
                ttft: None,
                kv_cost,
                tokens: Vec::new(),
                prefix_path: path,
                steps_resident: 0,
            };
            if seq.max_new == 0 {
                let now = t0.elapsed();
                responses.push(Response {
                    id: seq.id,
                    tokens: seq.out,
                    latency: now,
                    ttft: now,
                    error: None,
                });
                continue;
            }
            if seq.prompt.is_empty() {
                // `generate` starts from all-zero logits: argmax is 0.
                seq.out.push(0);
                seq.tokens = vec![0];
                seq.ttft = Some(t0.elapsed());
                if seq.out.len() >= seq.max_new {
                    let now = t0.elapsed();
                    let ttft = seq.ttft.unwrap();
                    responses.push(Response {
                        id: seq.id,
                        tokens: seq.out,
                        latency: now,
                        ttft,
                        error: None,
                    });
                    pool.release(seq.kv_cost);
                    continue;
                }
            }
            // Target cache starts from the cached prefix pages; the
            // draft cache always starts fresh and catches up inside the
            // first speculative round's catch-up prefill (its rows must
            // come from draft-engine numerics for acceptance to mean
            // anything).
            let cache = if shared > 0 {
                let pc = prefix.as_ref().expect("prefix hit implies a cache");
                engine.new_cache_with_prefix(&pc.pages(&seq.prefix_path), shared)
            } else {
                engine.new_cache()
            };
            active.push(seq);
            caches.push(cache);
            draft_caches.push(draft.new_cache());
        }
        if active.is_empty() {
            break;
        }
        update_chunk_ladder(
            deferred_now,
            &mut prefill_chunk,
            cfg.prefill_chunk.max(1),
            &mut defer_streak,
            &mut clear_streak,
            &mut robust,
        );
        peak_lanes = peak_lanes.max(active.len());
        peak_kv = peak_kv.max(pool.reserved());
        for seq in active.iter_mut() {
            seq.steps_resident += 1;
        }

        // Phase A — chunked prompt absorption on the target, exactly
        // serve_with's plan, except decode lanes contribute nothing here
        // (their work is the per-lane rounds below). Lanes decoding at
        // the START of the iteration are marked now; a lane finishing
        // its prompt this iteration starts drafting next iteration.
        let mut budget = chunk_budget;
        let mut chunks: Vec<&[u32]> = Vec::with_capacity(active.len());
        let mut emit: Vec<bool> = Vec::with_capacity(active.len());
        let mut fed_now: Vec<usize> = Vec::with_capacity(active.len());
        let mut decoding: Vec<bool> = Vec::with_capacity(active.len());
        for seq in active.iter() {
            if seq.fed < seq.prompt.len() {
                let c = (seq.prompt.len() - seq.fed).min(prefill_chunk).min(budget);
                budget -= c;
                chunks.push(&seq.prompt[seq.fed..seq.fed + c]);
                emit.push(c > 0 && seq.fed + c == seq.prompt.len());
                fed_now.push(c);
                decoding.push(false);
            } else {
                chunks.push(&[]);
                emit.push(false);
                fed_now.push(0);
                decoding.push(true);
            }
        }
        let mut retired = vec![false; active.len()];
        let mut exit: Vec<Option<RadioError>> = vec![None; active.len()];
        let fed_total: usize = fed_now.iter().sum();
        if fed_total > 0 {
            // Fault containment exactly as in serve_with: snapshot, one
            // batched call under catch_unwind, rollback + solo re-runs
            // on unwind, typed retirement for the lane that faults
            // again. Decode lanes have empty chunks here (their work is
            // Phase B), so they neither fire the failpoint nor re-run.
            let pre_lens: Vec<usize> = caches.iter().map(|c| c.len).collect();
            let ids: Vec<usize> = active.iter().map(|s| s.id).collect();
            let chunk_lens: Vec<usize> = chunks.iter().map(|c| c.len()).collect();
            let batched = catch_unwind(AssertUnwindSafe(|| {
                for (i, &id) in ids.iter().enumerate() {
                    if !chunks[i].is_empty() {
                        failpoint::fire("serve::lane", id as u64);
                    }
                }
                engine.prefill_batch_masked(&chunks, &mut caches, Some(&emit))
            }));
            let logits = match batched {
                Ok(l) => l,
                Err(_) => {
                    for (c, &pre) in caches.iter_mut().zip(&pre_lens) {
                        c.truncate_to(pre);
                    }
                    let mut solo = vec![Vec::new(); ids.len()];
                    for i in 0..ids.len() {
                        if chunks[i].is_empty() {
                            continue;
                        }
                        let one = catch_unwind(AssertUnwindSafe(|| {
                            failpoint::fire("serve::lane", ids[i] as u64);
                            engine.prefill_batch_masked(
                                &chunks[i..i + 1],
                                &mut caches[i..i + 1],
                                Some(&emit[i..i + 1]),
                            )
                        }));
                        match one {
                            Ok(mut l) => solo[i] = l.pop().unwrap_or_default(),
                            Err(payload) => {
                                caches[i].truncate_to(pre_lens[i]);
                                exit[i] = Some(RadioError::LaneFault {
                                    detail: format!(
                                        "request {}: {}",
                                        ids[i],
                                        panic_message(payload.as_ref())
                                    ),
                                });
                                robust.lane_faults += 1;
                            }
                        }
                    }
                    solo
                }
            };
            steps += 1;
            for (i, seq) in active.iter_mut().enumerate() {
                if exit[i].is_some() {
                    retired[i] = true;
                    continue;
                }
                engine_tokens += chunk_lens[i];
                prompt_tokens += fed_now[i];
                seq.fed += fed_now[i];
                if emit[i] {
                    let first = argmax(&logits[i]) as u32;
                    seq.out.push(first);
                    seq.tokens = seq.prompt.clone();
                    seq.tokens.push(first);
                    seq.ttft = Some(t0.elapsed());
                    // generate's stopping rule after the first token.
                    retired[i] = seq.out.len() >= seq.max_new || caches[i].len >= max_seq;
                }
            }
        }

        // Phase B — one speculative round per decode lane. Per-lane by
        // design (acceptance lengths desynchronize lanes); each round is
        // internally GEMM-amortized (draft catch-up prefill + one
        // chunked verify). Each round runs under catch_unwind: a panic
        // rolls BOTH caches back to their pre-round lengths (the round
        // never truncates below them, so the rollback target is always
        // valid), retires the lane with a typed fault, and — via the
        // retirement sweep — drops its draft cache and releases its
        // pool reservation. Surviving lanes are untouched: rounds are
        // per-lane, so there is nothing to re-run.
        for i in 0..active.len() {
            if !decoding[i] || retired[i] {
                continue;
            }
            let pre_t = caches[i].len;
            let pre_d = draft_caches[i].len;
            let eff_k = if spec_enabled { cfg.spec_k } else { 0 };
            let seq = &mut active[i];
            let id = seq.id;
            let left = seq.max_new - seq.out.len();
            let round = {
                let tokens = &mut seq.tokens;
                let tcache = &mut caches[i];
                let dcache = &mut draft_caches[i];
                catch_unwind(AssertUnwindSafe(|| {
                    failpoint::fire("serve::lane", id as u64);
                    engine.step_speculative(draft, tokens, tcache, dcache, eff_k, left)
                }))
            };
            match round {
                Ok(round) => {
                    seq.out.extend_from_slice(&round.emitted);
                    steps += 1;
                    engine_tokens += round.proposed + 1; // target-fed, incl. rejected
                    spec_proposed += round.proposed;
                    spec_accepted += round.accepted;
                    win_proposed += round.proposed;
                    win_accepted += round.accepted;
                    retired[i] = seq.out.len() >= seq.max_new || caches[i].len >= max_seq;
                }
                Err(payload) => {
                    caches[i].truncate_to(pre_t);
                    draft_caches[i].truncate_to(pre_d);
                    retired[i] = true;
                    exit[i] = Some(RadioError::LaneFault {
                        detail: format!("request {id}: {}", panic_message(payload.as_ref())),
                    });
                    robust.lane_faults += 1;
                }
            }
        }
        // Acceptance-collapse ladder, on disjoint whole windows.
        if spec_enabled && cfg.spec_k > 0 && win_proposed >= SPEC_WINDOW {
            if spec_should_disable(win_proposed, win_accepted) {
                spec_enabled = false;
                robust.spec_disables += 1;
            }
            win_proposed = 0;
            win_accepted = 0;
        }
        // Deadlines, after both phases (completion wins the tie).
        if let Some(d) = cfg.deadline_steps {
            for (i, seq) in active.iter().enumerate() {
                if !retired[i] && seq.steps_resident >= d.max(1) {
                    retired[i] = true;
                    exit[i] = Some(RadioError::DeadlineExceeded { steps: seq.steps_resident });
                    robust.timed_out += 1;
                }
            }
        }

        // Retirement sweep, back-to-front (as in serve_with). Dropping
        // the swap_removed draft cache IS the draft-release path for
        // faulted lanes.
        for i in (0..active.len()).rev() {
            if retired[i] {
                let done = active.swap_remove(i);
                let cache = caches.swap_remove(i);
                draft_caches.swap_remove(i);
                let error = exit.swap_remove(i);
                pool.release(done.kv_cost);
                if let Some(pc) = prefix.as_mut() {
                    pc.release(&done.prefix_path);
                    // Insert-on-retire publishes TARGET pages only; the
                    // draft cache is dropped with its lane.
                    if done.fed == done.prompt.len() && !done.prompt.is_empty() {
                        let (_, ev) = pc.insert(&done.prompt, &cache, &mut pool);
                        prefix_evictions += ev;
                    }
                }
                let now = t0.elapsed();
                let ttft = done.ttft.unwrap_or(now);
                responses.push(Response {
                    id: done.id,
                    tokens: done.out,
                    latency: now,
                    ttft,
                    error,
                });
            }
        }
    }

    if let Some(pc) = prefix.as_mut() {
        pc.drain(&mut pool);
    }
    debug_assert_eq!(
        pool.reserved(),
        0,
        "KV pool must drain to zero at scheduler exit (reservation leak)"
    );
    responses.sort_by_key(|r| r.id);
    let stats = finalize_stats(
        &responses,
        t0.elapsed(),
        engine_tokens,
        prompt_tokens,
        steps,
        peak_lanes,
        kv_deferrals,
        (spec_proposed, spec_accepted),
        robust,
        (prefix_hits, prefix_reused, prefix_evictions),
        peak_kv,
    );
    (responses, stats)
}

/// Two-point serving straight off a rate ladder: the **highest-rate
/// point serves as the target**; with `cfg.spec_k > 0` (and a ladder of
/// ≥ 2 points) the point nearest `cfg.draft_bits` (lowest point when
/// unset) drafts for it via [`serve_speculative`]. With speculation off
/// this is plain [`serve_with`] on the target point — one artifact, one
/// call, rate as a serving knob.
pub fn serve_ladder(
    ladder: &crate::coordinator::ladder::RateLadder,
    requests: Vec<Request>,
    cfg: ServeConfig,
) -> (Vec<Response>, ServeStats) {
    assert!(!ladder.points.is_empty(), "cannot serve an empty ladder");
    let target = ladder.engine(ladder.points.len() - 1);
    if cfg.spec_k == 0 || ladder.points.len() < 2 {
        return serve_with(&target, requests, cfg);
    }
    let draft_ix = match cfg.draft_bits {
        Some(bits) => ladder.nearest_point(bits),
        None => 0,
    };
    let draft = ladder.engine(draft_ix);
    serve_speculative(&target, &draft, requests, cfg)
}

/// [`serve_ladder`] off an integrity-checked lazy container load
/// ([`RateLadder::load_mapped`][crate::coordinator::ladder::RateLadder::load_mapped]):
/// non-essential rate points whose payload fails its CRC or parse check
/// are dropped instead of failing the load, the serve proceeds on the
/// surviving points, and [`ServeStats::degraded_sections`] reports how
/// many were lost. A corrupt top point, side section, or header is still
/// a hard error — there is nothing to degrade to.
pub fn serve_ladder_mapped(
    path: &std::path::Path,
    requests: Vec<Request>,
    cfg: ServeConfig,
) -> Result<(Vec<Response>, ServeStats), RadioError> {
    let (ladder, degraded) = crate::coordinator::ladder::RateLadder::load_mapped(path)?;
    let (responses, mut stats) = serve_ladder(&ladder, requests, cfg);
    stats.degraded_sections = degraded;
    Ok((responses, stats))
}

/// The seed's thread-per-request scheduler, kept as the un-amortized
/// baseline: `workers` threads each run `Engine::generate` on one request
/// at a time, so every resident request decodes the full bitstream
/// itself. `bench_serving` measures the continuous path against this.
/// `generate` is monolithic, so a response's TTFT here equals its
/// completion latency — the honest number for this scheduler.
pub fn serve_threaded(
    engine: &Engine,
    requests: Vec<Request>,
    workers: usize,
) -> (Vec<Response>, ServeStats) {
    let t0 = Instant::now();
    let queue: Arc<Mutex<VecDeque<Request>>> = Arc::new(Mutex::new(requests.into_iter().collect()));
    type Tally = Vec<(Response, usize, usize)>;
    let responses: Arc<Mutex<Tally>> = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            let queue = Arc::clone(&queue);
            let responses = Arc::clone(&responses);
            s.spawn(move || loop {
                let req = { queue.lock().unwrap().pop_front() };
                let Some(req) = req else { break };
                let plen = engine.admit_prompt(&req.prompt).len();
                let tokens = engine.generate(&req.prompt, req.max_new);
                // Same latency definition as `serve`: from call entry
                // (all requests arrive together), so queueing counts and
                // the two schedulers' percentiles are comparable.
                let latency = t0.elapsed();
                let engine_toks = plen + tokens.len().saturating_sub(1);
                responses.lock().unwrap().push((
                    Response { id: req.id, tokens, latency, ttft: latency, error: None },
                    engine_toks,
                    plen,
                ));
            });
        }
    });
    let done = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
    let engine_tokens: usize = done.iter().map(|(_, n, _)| n).sum();
    let prompt_tokens: usize = done.iter().map(|(_, _, p)| p).sum();
    let mut responses: Vec<Response> = done.into_iter().map(|(r, _, _)| r).collect();
    responses.sort_by_key(|r| r.id);
    let stats = finalize_stats(
        &responses,
        t0.elapsed(),
        engine_tokens,
        prompt_tokens,
        0,
        0,
        0,
        (0, 0),
        RobustCounters::default(),
        (0, 0, 0),
        0,
    );
    (responses, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn tiny_engine() -> Engine {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(191);
        Engine::from_dense(&Weights::init_training(cfg, &mut rng))
    }

    #[test]
    fn serves_all_requests_in_order() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..10)
            .map(|id| Request { id, prompt: vec![(id % 30) as u32, 2], max_new: 4 })
            .collect();
        let (resps, stats) = serve(&engine, reqs, 4);
        assert_eq!(resps.len(), 10);
        assert_eq!(stats.completed, 10);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(!r.tokens.is_empty());
            assert!(r.ttft <= r.latency, "first token cannot come after completion");
        }
        assert!(stats.p50 <= stats.p95);
        assert!(stats.ttft_p50 <= stats.ttft_p95);
        assert!(stats.ttft_p50 <= stats.p50);
        assert!(stats.throughput_tps > 0.0);
        assert!(stats.engine_tps >= stats.throughput_tps);
        assert_eq!(stats.prompt_tokens, 10 * 2, "every prompt token fed exactly once");
        assert!(stats.steps > 0);
        assert!(stats.mean_batch_occupancy > 1.0, "4-slot batch should run >1 resident");
    }

    #[test]
    fn serving_matches_direct_generation() {
        // Batching/routing must not change results (determinism
        // invariant): every request's tokens equal a solo `generate`.
        let engine = tiny_engine();
        let mut rng = Rng::new(192);
        let reqs: Vec<Request> = (0..8)
            .map(|id| {
                let plen = 1 + rng.below(5);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 2 + rng.below(7) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        for max_batch in [1usize, 3, 8] {
            let (resps, _) = serve(&engine, reqs.clone(), max_batch);
            for (r, want) in resps.iter().zip(&expected) {
                assert_eq!(
                    r.tokens, *want,
                    "request {} diverged from generate() at max_batch {max_batch}",
                    r.id
                );
            }
        }
    }

    #[test]
    fn chunked_prefill_schedule_matches_generate_for_every_budget() {
        // The chunk budget slices prompts differently every config; none
        // of it may change a single token (prefill bit-identity +
        // lane independence).
        let engine = tiny_engine();
        let mut rng = Rng::new(193);
        let reqs: Vec<Request> = (0..9)
            .map(|id| {
                // Mix long (up to max_seq-2 = 14) and short prompts.
                let plen = if id % 3 == 0 { 10 + rng.below(5) } else { 1 + rng.below(4) };
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 1 + rng.below(4) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        for (prefill_chunk, chunk_budget) in
            [(1usize, usize::MAX), (4, 8), (32, 64), (3, 5), (16, 1)]
        {
            let cfg = ServeConfig { prefill_chunk, chunk_budget, ..ServeConfig::new(4) };
            let (resps, stats) = serve_with(&engine, reqs.clone(), cfg);
            for (r, want) in resps.iter().zip(&expected) {
                assert_eq!(
                    r.tokens, *want,
                    "request {} diverged under prefill_chunk={prefill_chunk} \
                     chunk_budget={chunk_budget}",
                    r.id
                );
            }
            let total_prompt: usize = reqs.iter().map(|r| r.prompt.len()).sum();
            assert_eq!(stats.prompt_tokens, total_prompt);
        }
    }

    #[test]
    fn oversized_prompts_are_truncated_at_admission() {
        let engine = tiny_engine();
        let max_seq = engine.config.max_seq;
        let long: Vec<u32> = (0..max_seq as u32 + 7).map(|i| i % 32).collect();
        let reqs = vec![
            Request { id: 0, prompt: long.clone(), max_new: 3 },
            Request { id: 1, prompt: vec![2, 3], max_new: 3 },
        ];
        let (resps, stats) = serve(&engine, reqs, 2);
        // generate applies the same admission rule, so tokens must match.
        assert_eq!(resps[0].tokens, engine.generate(&long, 3));
        assert_eq!(resps[1].tokens, engine.generate(&[2, 3], 3));
        assert_eq!(stats.prompt_tokens, max_seq + 2, "truncated prompt feeds max_seq tokens");
    }

    #[test]
    fn threaded_baseline_matches_direct_generation() {
        let engine = tiny_engine();
        let prompt = vec![5u32, 7, 11];
        let direct = engine.generate(&prompt, 6);
        let (resps, _) = serve_threaded(
            &engine,
            vec![Request { id: 0, prompt: prompt.clone(), max_new: 6 }],
            3,
        );
        assert_eq!(resps[0].tokens, direct);
        assert_eq!(resps[0].ttft, resps[0].latency);
    }

    #[test]
    fn kv_budget_defers_admission_without_changing_tokens() {
        // The pool-exhaustion contract: a KV byte budget throttles how
        // many lanes run concurrently (peak_lanes) but every request
        // still completes with tokens identical to a solo generate() —
        // admission is deferred, never evicted, and scheduling stays
        // deterministic.
        let engine = tiny_engine();
        let mut rng = Rng::new(194);
        let reqs: Vec<Request> = (0..6)
            .map(|id| {
                let plen = 2 + rng.below(6);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 3 + rng.below(5) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        // Budget sized for roughly two worst-case lanes.
        let worst = crate::infer::kv::lane_cost_bytes(
            &engine.config,
            engine.kv_config(),
            engine.config.max_seq,
        );
        let open = serve_with(&engine, reqs.clone(), ServeConfig::new(6));
        let tight_cfg = ServeConfig { kv_budget_bytes: Some(2 * worst), ..ServeConfig::new(6) };
        let tight = serve_with(&engine, reqs.clone(), tight_cfg);
        for ((r, want), label) in tight.0.iter().zip(&expected).zip(std::iter::repeat("tight")) {
            assert_eq!(r.tokens, *want, "{label}: request {} diverged from generate()", r.id);
        }
        assert_eq!(tight.1.completed, 6);
        assert!(tight.1.peak_lanes <= 2, "budget for 2 lanes admitted {}", tight.1.peak_lanes);
        assert!(
            tight.1.peak_lanes < open.1.peak_lanes,
            "tight budget must cap concurrency below the open pool ({} vs {})",
            tight.1.peak_lanes,
            open.1.peak_lanes
        );
        assert!(tight.1.kv_deferrals > 0, "exhaustion must be visible in stats");
        assert_eq!(open.1.kv_deferrals, 0);
        // Determinism of the deferral schedule itself.
        let again = serve_with(&engine, reqs.clone(), tight_cfg);
        assert_eq!(again.1.peak_lanes, tight.1.peak_lanes);
        assert_eq!(again.1.steps, tight.1.steps);
        for (a, b) in again.0.iter().zip(&tight.0) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn oversized_lane_is_admitted_solo_rather_than_deadlocking() {
        // A single request whose worst case exceeds the whole budget
        // must still run (alone) — deferral forever would hang the queue.
        let engine = tiny_engine();
        let reqs = vec![
            Request { id: 0, prompt: vec![1, 2, 3], max_new: 10 },
            Request { id: 1, prompt: vec![4], max_new: 2 },
        ];
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let cfg = ServeConfig { kv_budget_bytes: Some(1), ..ServeConfig::new(4) };
        let (resps, stats) = serve_with(&engine, reqs, cfg);
        assert_eq!(stats.completed, 2);
        assert_eq!(stats.peak_lanes, 1, "1-byte budget must serialize lanes");
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "request {}", r.id);
        }
    }

    #[test]
    fn empty_queue_is_fine() {
        let engine = tiny_engine();
        let (resps, stats) = serve(&engine, vec![], 2);
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
        let (resps, stats) = serve_threaded(&engine, vec![], 2);
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn speculative_serving_matches_direct_generation() {
        // The speculative token-identity invariant at the scheduler
        // level: any (spec_k, draft-rate) configuration — including a
        // weak 2-bit draft — serves tokens identical to the TARGET's
        // generate(), and acceptance counters stay consistent.
        use crate::coordinator::pipeline::rtn_quantize_model;
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(501);
        let w = Weights::init_training(cfg, &mut rng);
        let target = Engine::from_quantized(&rtn_quantize_model(&w, 6, 8));
        let drafts = [
            Engine::from_quantized(&rtn_quantize_model(&w, 2, 8)),
            Engine::from_quantized(&rtn_quantize_model(&w, 6, 8)), // self-rate draft
        ];
        let mut rng = Rng::new(502);
        let reqs: Vec<Request> = (0..7)
            .map(|id| {
                let plen = if id % 3 == 0 { 8 + rng.below(4) } else { rng.below(4) };
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 1 + rng.below(6) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| target.generate(&r.prompt, r.max_new))
            .collect();
        for draft in &drafts {
            for spec_k in [0usize, 2, 4] {
                let cfg = ServeConfig { spec_k, ..ServeConfig::new(3) };
                let (resps, stats) = serve_speculative(&target, draft, reqs.clone(), cfg);
                assert_eq!(stats.completed, reqs.len());
                for (r, want) in resps.iter().zip(&expected) {
                    assert_eq!(
                        r.tokens, *want,
                        "request {} diverged from generate() at spec_k={spec_k}",
                        r.id
                    );
                    assert!(r.ttft <= r.latency);
                }
                assert!(stats.spec_accepted <= stats.spec_proposed);
                if spec_k == 0 {
                    assert_eq!(stats.spec_proposed, 0, "spec_k=0 must never draft");
                } else {
                    assert!(stats.spec_proposed > 0, "decode lanes must draft");
                    let a = stats.spec_acceptance();
                    assert!((0.0..=1.0).contains(&a));
                }
            }
        }
        // A self-weights draft accepts everything.
        let spec_cfg = ServeConfig { spec_k: 3, ..ServeConfig::new(4) };
        let (_, stats) = serve_speculative(&target, &drafts[1], reqs.clone(), spec_cfg);
        assert_eq!(stats.spec_accepted, stats.spec_proposed);
        assert_eq!(stats.spec_acceptance(), 1.0);
    }

    #[test]
    fn speculative_serving_composes_with_kv_budget() {
        // Both caches are reserved at admission; a tight pool must cap
        // concurrency (deferring, never evicting) without changing a
        // single token, deterministically.
        let engine = tiny_engine();
        let draft = tiny_engine(); // same seed -> same weights
        let mut rng = Rng::new(503);
        let reqs: Vec<Request> = (0..5)
            .map(|id| {
                let plen = 2 + rng.below(5);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 3 + rng.below(4) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        // Room for ~2 speculative lanes (each pays target + draft).
        let worst = 2 * crate::infer::kv::lane_cost_bytes(
            &engine.config,
            engine.kv_config(),
            engine.config.max_seq,
        );
        let cfg = ServeConfig {
            spec_k: 3,
            kv_budget_bytes: Some(2 * worst),
            ..ServeConfig::new(5)
        };
        let (resps, stats) = serve_speculative(&engine, &draft, reqs.clone(), cfg);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "request {} diverged under KV budget", r.id);
        }
        assert!(stats.peak_lanes <= 2, "budget for 2 lanes admitted {}", stats.peak_lanes);
        assert!(stats.kv_deferrals > 0, "exhaustion must be visible");
        let again = serve_speculative(&engine, &draft, reqs.clone(), cfg);
        assert_eq!(again.1.steps, stats.steps, "speculative schedule must be deterministic");
        for (a, b) in again.0.iter().zip(&resps) {
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn serve_ladder_picks_draft_and_target_points() {
        use crate::coordinator::ladder::RateLadder;
        use crate::coordinator::pipeline::rtn_quantize_model;
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(504);
        let w = Weights::init_training(cfg, &mut rng);
        let ladder = RateLadder::from_models(vec![
            (2.0, rtn_quantize_model(&w, 2, 8)),
            (6.0, rtn_quantize_model(&w, 6, 8)),
        ]);
        let target = ladder.engine(1);
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![(id + 1) as u32, 3], max_new: 5 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| target.generate(&r.prompt, r.max_new))
            .collect();
        // Speculation on: drafts from the 2-bit point, serves the 6-bit
        // target's tokens.
        let spec_cfg =
            ServeConfig { spec_k: 3, draft_bits: Some(2.0), ..ServeConfig::new(2) };
        let (resps, stats) = serve_ladder(&ladder, reqs.clone(), spec_cfg);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "ladder serving diverged from the target point");
        }
        assert!(stats.spec_proposed > 0);
        // Speculation off: plain serve_with on the target point.
        let plain_cfg = ServeConfig::new(2);
        let (plain, plain_stats) = serve_ladder(&ladder, reqs.clone(), plain_cfg);
        for (r, want) in plain.iter().zip(&expected) {
            assert_eq!(r.tokens, *want);
        }
        assert_eq!(plain_stats.spec_proposed, 0);
    }

    #[test]
    fn degenerate_requests_mirror_generate() {
        let engine = tiny_engine();
        // max_new = 0 and an empty prompt must reproduce generate()'s
        // edge-case behaviour through the scheduler.
        let reqs = vec![
            Request { id: 0, prompt: vec![3, 4], max_new: 0 },
            Request { id: 1, prompt: vec![], max_new: 3 },
            Request { id: 2, prompt: vec![1], max_new: 40 }, // hits max_seq
        ];
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let (resps, _) = serve(&engine, reqs, 2);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "request {}", r.id);
        }
    }

    #[test]
    fn overload_is_shed_with_typed_errors_and_exact_accounting() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..8)
            .map(|id| Request { id, prompt: vec![(id % 30) as u32, 1], max_new: 3 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let cfg = ServeConfig { max_queued: Some(5), ..ServeConfig::new(2) };
        let (resps, stats) = serve_with(&engine, reqs, cfg);
        assert_eq!(resps.len(), 8, "every request is answered exactly once");
        assert_eq!(stats.shed, 3);
        assert_eq!(stats.completed, 5);
        assert_eq!(stats.accounted(), 8);
        for r in &resps {
            if r.id >= 5 {
                // Newest requests are shed; the FIFO prefix is kept.
                assert_eq!(r.error, Some(RadioError::Shed { queued: 5 }));
                assert!(r.tokens.is_empty());
            } else {
                assert!(r.error.is_none());
                assert_eq!(r.tokens, expected[r.id], "served request {} must match", r.id);
            }
        }
    }

    #[test]
    fn deadlines_retire_lanes_with_partial_prefix_tokens() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![(id + 1) as u32, 2], max_new: 8 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let cfg = ServeConfig { deadline_steps: Some(3), ..ServeConfig::new(4) };
        let (resps, stats) = serve_with(&engine, reqs, cfg);
        assert_eq!(resps.len(), 4);
        assert_eq!(stats.timed_out, 4, "8 decode steps cannot fit a 3-step deadline");
        assert_eq!(stats.completed, 0);
        assert_eq!(stats.accounted(), 4);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.error, Some(RadioError::DeadlineExceeded { steps: 3 }));
            assert!(!r.tokens.is_empty(), "tokens decoded before the deadline are kept");
            assert!(r.tokens.len() < want.len());
            assert_eq!(
                r.tokens[..],
                want[..r.tokens.len()],
                "partial output must be a prefix of generate()"
            );
        }
        // A deadline wide enough for the whole request changes nothing.
        let reqs: Vec<Request> =
            (0..2).map(|id| Request { id, prompt: vec![(id + 1) as u32, 2], max_new: 4 }).collect();
        let lax = ServeConfig { deadline_steps: Some(64), ..ServeConfig::new(2) };
        let (resps, stats) = serve_with(&engine, reqs.clone(), lax);
        assert_eq!(stats.timed_out, 0);
        for (r, req) in resps.iter().zip(&reqs) {
            assert!(r.error.is_none());
            assert_eq!(r.tokens, engine.generate(&req.prompt, req.max_new));
        }
    }

    #[test]
    fn lane_panic_is_contained_and_survivors_match_generate() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![(id + 3) as u32, 2], max_new: 4 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let _s = crate::util::failpoint::scenario();
        // Second hit: request 2 survives the first iteration (emitting
        // one token), then panics inside the batched forward — and
        // again in its solo re-run, which is what retires it.
        crate::util::failpoint::arm("serve::lane", 2, 2);
        let (resps, stats) = serve(&engine, reqs, 4);
        assert_eq!(resps.len(), 4, "a lane fault must not lose any response");
        assert_eq!(stats.lane_faults, 1);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.accounted(), 4);
        for (r, want) in resps.iter().zip(&expected) {
            if r.id == 2 {
                assert!(
                    matches!(r.error, Some(RadioError::LaneFault { .. })),
                    "victim must retire with a typed lane fault, got {:?}",
                    r.error
                );
                assert_eq!(
                    r.tokens[..],
                    want[..r.tokens.len()],
                    "victim keeps a generate() prefix"
                );
                assert!(r.tokens.len() < want.len());
            } else {
                assert!(r.error.is_none(), "survivor {} must not see the fault", r.id);
                assert_eq!(r.tokens, *want, "survivor {} must match generate()", r.id);
            }
        }
    }

    #[test]
    fn speculative_lane_fault_is_contained_and_rolls_back_both_caches() {
        let engine = tiny_engine();
        let draft = tiny_engine(); // same seed -> same weights
        let reqs: Vec<Request> = (0..4)
            .map(|id| Request { id, prompt: vec![(id + 3) as u32, 2], max_new: 5 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let _s = crate::util::failpoint::scenario();
        // Hit 1 lands in Phase A (prompt absorption, survived); hit 2
        // lands inside the lane's Phase-B speculative round, exercising
        // the dual-cache rollback + draft-release path.
        crate::util::failpoint::arm("serve::lane", 1, 2);
        let cfg = ServeConfig { spec_k: 3, ..ServeConfig::new(4) };
        let (resps, stats) = serve_speculative(&engine, &draft, reqs, cfg);
        assert_eq!(resps.len(), 4);
        assert_eq!(stats.lane_faults, 1);
        assert_eq!(stats.completed, 3);
        assert_eq!(stats.accounted(), 4);
        assert!(stats.spec_proposed > 0, "surviving decode lanes must still draft");
        for (r, want) in resps.iter().zip(&expected) {
            if r.id == 1 {
                assert!(matches!(r.error, Some(RadioError::LaneFault { .. })));
                assert_eq!(r.tokens[..], want[..r.tokens.len()]);
                assert!(r.tokens.len() < want.len());
            } else {
                assert!(r.error.is_none());
                assert_eq!(r.tokens, *want, "survivor {} must match generate()", r.id);
            }
        }
    }

    #[test]
    fn sustained_kv_deferral_shrinks_prefill_chunks_without_changing_tokens() {
        let engine = tiny_engine();
        let prompt: Vec<u32> = (0..12).map(|i| ((i * 5 + 1) % 32) as u32).collect();
        let reqs = vec![
            Request { id: 0, prompt: prompt.clone(), max_new: 6 },
            Request { id: 1, prompt: prompt.clone(), max_new: 6 },
        ];
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        // Budget for exactly one worst-case lane: request 1 defers for
        // every iteration request 0 is resident — long enough to walk
        // the degradation ladder.
        let worst = crate::infer::kv::lane_cost_bytes(
            &engine.config,
            engine.kv_config(),
            engine.config.max_seq,
        );
        let cfg = ServeConfig { kv_budget_bytes: Some(worst), ..ServeConfig::new(4) };
        let (resps, stats) = serve_with(&engine, reqs, cfg);
        assert!(stats.kv_deferrals > 0);
        assert!(stats.chunk_shrinks >= 1, "sustained deferral must shrink the prefill chunk");
        // The recovery side can only undo what deferral shrank.
        assert!(stats.chunk_regrows <= stats.chunk_shrinks);
        assert_eq!(stats.completed, 2);
        for (r, want) in resps.iter().zip(&expected) {
            assert!(r.error.is_none());
            assert_eq!(r.tokens, *want, "degraded chunking must not change tokens");
        }
    }

    #[test]
    fn chunk_ladder_shrinks_then_regrows_toward_configured() {
        // The ladder's state machine, pinned directly (both schedulers
        // share this exact function).
        let mut chunk = 8usize;
        let (mut ds, mut cs) = (0usize, 0usize);
        let mut rc = RobustCounters::default();
        for _ in 0..DEFER_SHRINK_AFTER {
            update_chunk_ladder(true, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 4, "sustained deferral halves the chunk");
        assert_eq!(rc.chunk_shrinks, 1);
        // Keep the pressure on: the chunk floors at 1 and stays there.
        for _ in 0..3 * DEFER_SHRINK_AFTER {
            update_chunk_ladder(true, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 1, "the ladder floors at a 1-token chunk");
        let shrinks = rc.chunk_shrinks;
        // Recovery: one doubling per DEFER_REGROW_AFTER clear iterations,
        // back to the configured value and no further.
        for _ in 0..DEFER_REGROW_AFTER {
            update_chunk_ladder(false, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 2, "clear running must re-double the chunk");
        assert_eq!(rc.chunk_regrows, 1);
        for _ in 0..2 * DEFER_REGROW_AFTER {
            update_chunk_ladder(false, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 8, "recovery stops at the configured value");
        assert_eq!(rc.chunk_regrows, 3);
        // At the configured size the ladder is idle.
        for _ in 0..4 * DEFER_REGROW_AFTER {
            update_chunk_ladder(false, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 8);
        assert_eq!(rc.chunk_regrows, 3);
        assert_eq!(rc.chunk_shrinks, shrinks, "idle running never shrinks");
        // A deferral mid-recovery resets the clear streak: almost-enough
        // clear iterations, one deferral, one more clear → no regrow.
        for _ in 0..2 * DEFER_SHRINK_AFTER {
            update_chunk_ladder(true, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        assert_eq!(chunk, 2);
        for _ in 0..DEFER_REGROW_AFTER - 1 {
            update_chunk_ladder(false, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        }
        update_chunk_ladder(true, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        let rg = rc.chunk_regrows;
        update_chunk_ladder(false, &mut chunk, 8, &mut ds, &mut cs, &mut rc);
        assert_eq!(rc.chunk_regrows, rg, "deferral must reset the clear streak");
        assert_eq!(chunk, 2);
    }

    #[test]
    fn chunk_regrow_fires_after_pressure_clears_without_changing_tokens() {
        // End to end: a tight pool shrinks the chunk while lanes queue;
        // once the pool pressure clears, long decode tails give the
        // ladder enough deferral-free iterations to re-grow the chunk —
        // visible in stats, invisible in tokens.
        let engine = tiny_engine();
        let prompt: Vec<u32> = (0..12).map(|i| ((i * 5 + 1) % 32) as u32).collect();
        let reqs = vec![
            Request { id: 0, prompt: prompt.clone(), max_new: 4 },
            Request { id: 1, prompt: prompt.clone(), max_new: 4 },
            Request { id: 2, prompt: vec![3, 1, 4], max_new: 12 },
        ];
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let worst = crate::infer::kv::lane_cost_bytes(
            &engine.config,
            engine.kv_config(),
            engine.config.max_seq,
        );
        // Room for one full lane plus the small request: the two big
        // prompts serialize (sustained deferral → shrink), then the
        // 12-token decode tail runs pressure-free (regrow window).
        let cfg = ServeConfig { kv_budget_bytes: Some(worst + worst / 2), ..ServeConfig::new(4) };
        let (resps, stats) = serve_with(&engine, reqs, cfg);
        assert_eq!(stats.completed, 3);
        assert!(stats.chunk_regrows <= stats.chunk_shrinks);
        if stats.chunk_shrinks >= 1 {
            // Regrow needs DEFER_REGROW_AFTER clear iterations after the
            // last shrink; the long decode tail provides them whenever a
            // shrink happened at all.
            assert!(
                stats.chunk_regrows >= 1,
                "pressure cleared for {} iterations but the chunk never regrew",
                stats.steps
            );
        }
        for (r, want) in resps.iter().zip(&expected) {
            assert!(r.error.is_none());
            assert_eq!(r.tokens, *want, "regrown chunking must not change tokens");
        }
    }

    #[test]
    fn acceptance_collapse_disables_speculation_without_changing_tokens() {
        // The ladder's decision rule, pinned directly.
        assert!(!spec_should_disable(SPEC_WINDOW - 1, 0), "partial windows never decide");
        assert!(spec_should_disable(SPEC_WINDOW, 12), "12/64 < 20% must disable");
        assert!(!spec_should_disable(SPEC_WINDOW, 16), "16/64 >= 20% must keep drafting");
        // End to end with an adversarial draft: independently
        // initialized weights, so acceptance is poor. Whether or not
        // the ladder trips, tokens must equal the TARGET's generate().
        let target = tiny_engine();
        let cfg_m = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(977);
        let draft = Engine::from_dense(&Weights::init_training(cfg_m, &mut rng));
        let reqs: Vec<Request> = (0..8)
            .map(|id| Request { id, prompt: vec![(id % 30) as u32], max_new: 12 })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| target.generate(&r.prompt, r.max_new))
            .collect();
        let cfg = ServeConfig { spec_k: 4, ..ServeConfig::new(4) };
        let (resps, stats) = serve_speculative(&target, &draft, reqs, cfg);
        assert_eq!(stats.completed, 8);
        assert!(stats.spec_disables <= 1, "the ladder can trip at most once per call");
        if stats.spec_disables == 1 {
            assert!(stats.spec_proposed >= SPEC_WINDOW, "only a full window can trip it");
        }
        for (r, want) in resps.iter().zip(&expected) {
            assert!(r.error.is_none());
            assert_eq!(r.tokens, *want, "request {} must serve the target's tokens", r.id);
        }
    }

    /// Engine with 4-row KV pages so prefixes can share pages inside the
    /// tiny 16-row context (the default page spans the whole window,
    /// which would leave nothing page-aligned to cache).
    fn tiny_engine_paged(kv: crate::infer::kv::KvCacheConfig) -> Engine {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(191);
        Engine::from_dense(&Weights::init_training(cfg, &mut rng)).with_kv_config(kv)
    }

    #[test]
    fn prefix_cache_serving_matches_generate_and_reuses_pages() {
        // The tentpole invariant: turning the prefix cache on changes
        // TTFT economics (prompt tokens skipped, pages shared) but not
        // one output token, for dense and quantized pages and under
        // speculative decoding.
        use crate::infer::kv::{KvCacheConfig, KvQuantSpec};
        let kv_modes = [
            KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() },
            KvCacheConfig {
                page_rows: 4,
                ..KvCacheConfig::quantized(KvQuantSpec::uniform(1, 5, 1.0, 0.1))
            },
        ];
        for kv in kv_modes {
            let engine = tiny_engine_paged(kv);
            // Six requests share an 8-token base (two full pages) and
            // diverge at the ninth token.
            let base: Vec<u32> = (0..8).map(|t| (3 + t * 2) as u32).collect();
            let reqs: Vec<Request> = (0..6)
                .map(|id| {
                    let mut prompt = base.clone();
                    prompt.push((20 + id) as u32);
                    Request { id, prompt, max_new: 4 }
                })
                .collect();
            let expected: Vec<Vec<u32>> = reqs
                .iter()
                .map(|r| engine.generate(&r.prompt, r.max_new))
                .collect();
            let off_cfg = ServeConfig::new(2);
            let on_cfg = ServeConfig { prefix_cache: true, ..ServeConfig::new(2) };
            let (off_resps, off) = serve_with(&engine, reqs.clone(), off_cfg);
            let (on_resps, on) = serve_with(&engine, reqs.clone(), on_cfg);
            for ((r_on, r_off), want) in on_resps.iter().zip(&off_resps).zip(&expected) {
                assert_eq!(r_on.tokens, *want, "cache-on diverged from generate()");
                assert_eq!(r_on.tokens, r_off.tokens, "cache flipped a token");
            }
            // max_batch 2: requests 0/1 are cold, 2..=5 land after a
            // retirement has populated the cache — 4 hits × 8 tokens.
            assert_eq!(on.prefix_hits, 4, "four late requests must hit the cached base");
            assert_eq!(on.prefix_tokens_reused, 4 * 8);
            assert_eq!(off.prefix_hits, 0);
            assert_eq!(
                on.prompt_tokens + on.prefix_tokens_reused,
                off.prompt_tokens,
                "every reused token is a prompt token not re-fed"
            );
            assert_eq!(on.accounted(), 6);
            assert_eq!(off.accounted(), 6);
        }
        // Speculative arm: a self-rate draft over the dense paged engine;
        // draft lanes never share so this exercises the mixed reserve.
        let engine = tiny_engine_paged(KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() });
        let draft = tiny_engine_paged(KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() });
        let base: Vec<u32> = (0..8).map(|t| (3 + t * 2) as u32).collect();
        let reqs: Vec<Request> = (0..6)
            .map(|id| {
                let mut prompt = base.clone();
                prompt.push((20 + id) as u32);
                Request { id, prompt, max_new: 4 }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let spec_on =
            ServeConfig { spec_k: 3, prefix_cache: true, ..ServeConfig::new(2) };
        let (resps, stats) = serve_speculative(&engine, &draft, reqs, spec_on);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "speculative + prefix cache diverged from generate()");
        }
        assert!(stats.prefix_hits > 0, "late speculative lanes must hit the cache");
        assert!(stats.prefix_tokens_reused > 0);
        assert_eq!(stats.accounted(), 6);
    }

    #[test]
    fn prefix_hit_reserves_only_the_non_shared_remainder() {
        // The [bugfix] satellite: a prefix hit must charge the pool only
        // for the pages the lane actually owns. Three identical 9-token
        // prompts under a 4-page budget serialize without the cache
        // (3 pages each) but run concurrently with it (1 page each after
        // the first retires and donates its two full prefix pages).
        use crate::infer::kv::{lane_cost_bytes, KvCacheConfig};
        let engine = tiny_engine_paged(KvCacheConfig { page_rows: 4, ..KvCacheConfig::dense() });
        let prompt: Vec<u32> = (0..9).map(|t| (5 + t) as u32).collect();
        let reqs: Vec<Request> =
            (0..3).map(|id| Request { id, prompt: prompt.clone(), max_new: 3 }).collect();
        let expected = engine.generate(&prompt, 3);
        // One page's worth of lane cost; rows_worst = 11 → 3 pages/lane.
        let page = lane_cost_bytes(&engine.config, engine.kv_config(), 1);
        let budget = Some(4 * page);
        let off_cfg = ServeConfig { kv_budget_bytes: budget, ..ServeConfig::new(4) };
        let on_cfg =
            ServeConfig { kv_budget_bytes: budget, prefix_cache: true, ..ServeConfig::new(4) };
        let (off_resps, off) = serve_with(&engine, reqs.clone(), off_cfg);
        let (on_resps, on) = serve_with(&engine, reqs, on_cfg);
        for r in off_resps.iter().chain(&on_resps) {
            assert_eq!(r.tokens, expected, "budget pressure must never change tokens");
        }
        assert_eq!(off.peak_lanes, 1, "without the cache a 4-page budget serializes 3-page lanes");
        assert_eq!(on.prefix_hits, 2, "both followers must ride the retired leader's pages");
        assert!(
            on.peak_lanes >= 2,
            "prefix hits must shrink the reserve enough to overlap lanes (peak {})",
            on.peak_lanes
        );
        assert!(on.peak_kv_bytes <= 4 * page, "reserve may never exceed the budget");
    }
}
