//! A small batched serving front-end over the decode engine: a work queue
//! drained by worker threads, per-request latency tracking, and aggregate
//! throughput stats. This is the L3 "request path" exercised by
//! `examples/serve_quantized.rs` — pure Rust, no Python anywhere.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::infer::engine::Engine;

#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: usize,
    pub tokens: Vec<u32>,
    pub latency: Duration,
}

#[derive(Clone, Debug)]
pub struct ServeStats {
    pub completed: usize,
    pub total_tokens: usize,
    pub wall: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub throughput_tps: f64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests, {} tokens in {:.2?}: p50 {:.2?}, p95 {:.2?}, {:.1} tok/s",
            self.completed, self.total_tokens, self.wall, self.p50, self.p95, self.throughput_tps
        )
    }
}

/// Serve a batch of requests with `workers` threads sharing one engine.
/// Returns per-request responses (sorted by id) and aggregate stats.
pub fn serve(engine: &Engine, requests: Vec<Request>, workers: usize) -> (Vec<Response>, ServeStats) {
    let t0 = Instant::now();
    let queue: Arc<Mutex<VecDeque<Request>>> = Arc::new(Mutex::new(requests.into_iter().collect()));
    let responses: Arc<Mutex<Vec<Response>>> = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            let queue = Arc::clone(&queue);
            let responses = Arc::clone(&responses);
            s.spawn(move || loop {
                let req = { queue.lock().unwrap().pop_front() };
                let Some(req) = req else { break };
                let start = Instant::now();
                let tokens = engine.generate(&req.prompt, req.max_new);
                let latency = start.elapsed();
                responses.lock().unwrap().push(Response { id: req.id, tokens, latency });
            });
        }
    });
    let mut responses = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
    responses.sort_by_key(|r| r.id);
    let wall = t0.elapsed();
    let mut lats: Vec<Duration> = responses.iter().map(|r| r.latency).collect();
    lats.sort_unstable();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let pick = |q: f64| {
        if lats.is_empty() {
            Duration::ZERO
        } else {
            lats[((lats.len() - 1) as f64 * q).round() as usize]
        }
    };
    let stats = ServeStats {
        completed: responses.len(),
        total_tokens,
        wall,
        p50: pick(0.5),
        p95: pick(0.95),
        throughput_tps: total_tokens as f64 / wall.as_secs_f64().max(1e-9),
    };
    (responses, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn tiny_engine() -> Engine {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(191);
        Engine::from_dense(&Weights::init_training(cfg, &mut rng))
    }

    #[test]
    fn serves_all_requests_in_order() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..10)
            .map(|id| Request { id, prompt: vec![(id % 30) as u32, 2], max_new: 4 })
            .collect();
        let (resps, stats) = serve(&engine, reqs, 4);
        assert_eq!(resps.len(), 10);
        assert_eq!(stats.completed, 10);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(!r.tokens.is_empty());
        }
        assert!(stats.p50 <= stats.p95);
        assert!(stats.throughput_tps > 0.0);
    }

    #[test]
    fn serving_matches_direct_generation() {
        // Batching/routing must not change results (determinism invariant).
        let engine = tiny_engine();
        let prompt = vec![5u32, 7, 11];
        let direct = engine.generate(&prompt, 6);
        let (resps, _) = serve(
            &engine,
            vec![Request { id: 0, prompt: prompt.clone(), max_new: 6 }],
            3,
        );
        assert_eq!(resps[0].tokens, direct);
    }

    #[test]
    fn empty_queue_is_fine() {
        let engine = tiny_engine();
        let (resps, stats) = serve(&engine, vec![], 2);
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
    }
}
