//! Serving front-end over the decode engine — the L3 "request path"
//! exercised by `examples/serve_quantized.rs`, pure Rust end to end.
//!
//! [`serve`] is an **iteration-level continuous-batching scheduler** (the
//! vLLM scheduling discipline at laptop scale): one driver thread owns the
//! engine and, each step, feeds one token for every resident sequence via
//! [`Engine::step_batch`], admits waiting requests into free batch slots,
//! and retires finished sequences immediately — no head-of-line blocking
//! on long generations. Because the batched engine decodes each weight
//! column's code stream once per step for the whole batch, B resident
//! sequences cost ~one decode pass instead of B (the seed's
//! thread-per-request design, kept as [`serve_threaded`] for baseline
//! comparisons, paid the full decode per request).
//!
//! Determinism: per-sequence numerics are independent of co-scheduled
//! sequences (see `Engine::step_batch`), so `serve` reproduces
//! `Engine::generate` token for token no matter how requests interleave.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::infer::engine::{argmax, Engine, KvCache};

#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub max_new: usize,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: usize,
    pub tokens: Vec<u32>,
    pub latency: Duration,
}

#[derive(Clone, Debug)]
pub struct ServeStats {
    pub completed: usize,
    /// Generated tokens across all responses (prompt tokens excluded).
    pub total_tokens: usize,
    pub wall: Duration,
    pub p50: Duration,
    pub p95: Duration,
    /// Generated tokens per second of wall clock.
    pub throughput_tps: f64,
    /// Tokens *fed through the engine* per second (prompt + generated − 1
    /// per request: the final token is emitted, never fed) — the number
    /// that scales with batch amortization.
    pub engine_tps: f64,
    /// Engine steps executed (0 for the threaded baseline, which steps
    /// inside `generate`).
    pub steps: usize,
    /// Mean resident sequences per step — how full the batch ran.
    pub mean_batch_occupancy: f64,
}

impl std::fmt::Display for ServeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} requests, {} tokens in {:.2?}: p50 {:.2?}, p95 {:.2?}, {:.1} gen tok/s, \
             {:.1} engine tok/s",
            self.completed,
            self.total_tokens,
            self.wall,
            self.p50,
            self.p95,
            self.throughput_tps,
            self.engine_tps
        )?;
        if self.steps > 0 {
            write!(f, ", batch occupancy {:.2} over {} steps", self.mean_batch_occupancy, self.steps)?;
        }
        Ok(())
    }
}

fn percentile(lats: &mut [Duration], q: f64) -> Duration {
    if lats.is_empty() {
        return Duration::ZERO;
    }
    lats.sort_unstable();
    lats[((lats.len() - 1) as f64 * q).round() as usize]
}

fn finalize_stats(
    responses: &[Response],
    wall: Duration,
    engine_tokens: usize,
    steps: usize,
) -> ServeStats {
    let mut lats: Vec<Duration> = responses.iter().map(|r| r.latency).collect();
    let total_tokens: usize = responses.iter().map(|r| r.tokens.len()).sum();
    let secs = wall.as_secs_f64().max(1e-9);
    ServeStats {
        completed: responses.len(),
        total_tokens,
        wall,
        p50: percentile(&mut lats, 0.5),
        p95: percentile(&mut lats, 0.95),
        throughput_tps: total_tokens as f64 / secs,
        engine_tps: engine_tokens as f64 / secs,
        steps,
        mean_batch_occupancy: if steps == 0 {
            0.0
        } else {
            engine_tokens as f64 / steps as f64
        },
    }
}

/// One resident sequence in the continuous batch. Its KV cache lives in a
/// parallel `Vec<KvCache>` (kept index-aligned) so the scheduler can hand
/// the engine one contiguous `&mut [KvCache]` per step.
struct ActiveSeq {
    id: usize,
    prompt: Vec<u32>,
    /// Prompt tokens already fed to the engine.
    fed: usize,
    max_new: usize,
    out: Vec<u32>,
}

impl ActiveSeq {
    /// The token this sequence feeds on the next engine step.
    fn next_input(&self) -> u32 {
        if self.fed < self.prompt.len() {
            self.prompt[self.fed]
        } else {
            *self.out.last().expect("decode phase implies at least one generated token")
        }
    }

    /// Mirror of `Engine::generate`'s stopping rule, applied after a
    /// token has been pushed: stop at `max_new`, or once the KV cache has
    /// reached the positional table (one final token is still emitted
    /// from the last in-budget logits, exactly like `generate`).
    fn is_done(&self, cache_len: usize, max_seq: usize) -> bool {
        self.out.len() >= self.max_new || cache_len >= max_seq
    }
}

/// Serve `requests` through one engine with **iteration-level continuous
/// batching**: up to `max_batch` sequences are resident at once; waiting
/// requests are admitted the moment a slot frees. Returns per-request
/// responses (sorted by id) and aggregate stats. Latency is measured from
/// call entry (all requests "arrive" together), so it includes queueing —
/// the honest number for a loaded server.
///
/// Output tokens are identical to calling `engine.generate(&prompt,
/// max_new)` per request.
pub fn serve(engine: &Engine, requests: Vec<Request>, max_batch: usize) -> (Vec<Response>, ServeStats) {
    let t0 = Instant::now();
    let max_batch = max_batch.max(1);
    let max_seq = engine.config.max_seq;
    let mut queue: VecDeque<Request> = requests.into_iter().collect();
    let mut active: Vec<ActiveSeq> = Vec::new();
    let mut caches: Vec<KvCache> = Vec::new(); // index-aligned with `active`
    let mut responses: Vec<Response> = Vec::new();
    let mut steps = 0usize;
    let mut engine_tokens = 0usize;

    loop {
        // Admission: fill free slots from the queue.
        while active.len() < max_batch {
            let Some(req) = queue.pop_front() else { break };
            let mut seq = ActiveSeq {
                id: req.id,
                prompt: req.prompt,
                fed: 0,
                max_new: req.max_new,
                out: Vec::new(),
            };
            if seq.max_new == 0 {
                responses.push(Response { id: seq.id, tokens: seq.out, latency: t0.elapsed() });
                continue;
            }
            if seq.prompt.is_empty() {
                // `generate` starts from all-zero logits: argmax is 0.
                seq.out.push(0);
                if seq.is_done(0, max_seq) {
                    responses.push(Response { id: seq.id, tokens: seq.out, latency: t0.elapsed() });
                    continue;
                }
            }
            active.push(seq);
            caches.push(engine.new_cache());
        }
        if active.is_empty() {
            break;
        }

        // One engine step for the whole resident batch. Lanes still
        // prefilling skip the tied-head logits (computed only to be
        // discarded otherwise); a lane emits once this step feeds its
        // final prompt token or any generated one.
        let tokens: Vec<u32> = active.iter().map(ActiveSeq::next_input).collect();
        let emit: Vec<bool> = active.iter().map(|s| s.fed + 1 >= s.prompt.len()).collect();
        let logits = engine.step_batch_masked(&tokens, &mut caches, Some(&emit));
        steps += 1;
        engine_tokens += active.len();

        // Advance every lane first (stable indices into `logits`), then
        // compact out the finished ones.
        let mut retired = vec![false; active.len()];
        for (i, seq) in active.iter_mut().enumerate() {
            let was_prefill = seq.fed < seq.prompt.len();
            if was_prefill {
                seq.fed += 1;
            }
            // A lane emits once its whole prompt has been fed: either
            // this step consumed the final prompt token, or it fed a
            // previously generated one.
            if !was_prefill || seq.fed == seq.prompt.len() {
                let next = argmax(&logits[i]) as u32;
                seq.out.push(next);
                retired[i] = seq.is_done(caches[i].len, max_seq);
            }
        }
        // Back-to-front so swap_remove never disturbs an index still to
        // be visited (lanes are numerically independent, so batch order
        // is free to change between steps).
        for i in (0..active.len()).rev() {
            if retired[i] {
                let done = active.swap_remove(i);
                caches.swap_remove(i);
                responses.push(Response { id: done.id, tokens: done.out, latency: t0.elapsed() });
            }
        }
    }

    responses.sort_by_key(|r| r.id);
    let stats = finalize_stats(&responses, t0.elapsed(), engine_tokens, steps);
    (responses, stats)
}

/// The seed's thread-per-request scheduler, kept as the un-amortized
/// baseline: `workers` threads each run `Engine::generate` on one request
/// at a time, so every resident request decodes the full bitstream
/// itself. `bench_serving` measures the continuous path against this.
pub fn serve_threaded(
    engine: &Engine,
    requests: Vec<Request>,
    workers: usize,
) -> (Vec<Response>, ServeStats) {
    let t0 = Instant::now();
    let queue: Arc<Mutex<VecDeque<Request>>> = Arc::new(Mutex::new(requests.into_iter().collect()));
    let responses: Arc<Mutex<Vec<(Response, usize)>>> = Arc::new(Mutex::new(Vec::new()));
    std::thread::scope(|s| {
        for _ in 0..workers.max(1) {
            let queue = Arc::clone(&queue);
            let responses = Arc::clone(&responses);
            s.spawn(move || loop {
                let req = { queue.lock().unwrap().pop_front() };
                let Some(req) = req else { break };
                let tokens = engine.generate(&req.prompt, req.max_new);
                // Same latency definition as `serve`: from call entry
                // (all requests arrive together), so queueing counts and
                // the two schedulers' percentiles are comparable.
                let latency = t0.elapsed();
                let engine_toks = req.prompt.len() + tokens.len().saturating_sub(1);
                responses
                    .lock()
                    .unwrap()
                    .push((Response { id: req.id, tokens, latency }, engine_toks));
            });
        }
    });
    let done = Arc::try_unwrap(responses).unwrap().into_inner().unwrap();
    let engine_tokens: usize = done.iter().map(|(_, n)| n).sum();
    let mut responses: Vec<Response> = done.into_iter().map(|(r, _)| r).collect();
    responses.sort_by_key(|r| r.id);
    let stats = finalize_stats(&responses, t0.elapsed(), engine_tokens, 0);
    (responses, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::weights::Weights;
    use crate::util::rng::Rng;

    fn tiny_engine() -> Engine {
        let cfg = ModelConfig { vocab: 32, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(191);
        Engine::from_dense(&Weights::init_training(cfg, &mut rng))
    }

    #[test]
    fn serves_all_requests_in_order() {
        let engine = tiny_engine();
        let reqs: Vec<Request> = (0..10)
            .map(|id| Request { id, prompt: vec![(id % 30) as u32, 2], max_new: 4 })
            .collect();
        let (resps, stats) = serve(&engine, reqs, 4);
        assert_eq!(resps.len(), 10);
        assert_eq!(stats.completed, 10);
        for (i, r) in resps.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!(!r.tokens.is_empty());
        }
        assert!(stats.p50 <= stats.p95);
        assert!(stats.throughput_tps > 0.0);
        assert!(stats.engine_tps >= stats.throughput_tps);
        assert!(stats.steps > 0);
        assert!(stats.mean_batch_occupancy > 1.0, "4-slot batch should run >1 resident");
    }

    #[test]
    fn serving_matches_direct_generation() {
        // Batching/routing must not change results (determinism
        // invariant): every request's tokens equal a solo `generate`.
        let engine = tiny_engine();
        let mut rng = Rng::new(192);
        let reqs: Vec<Request> = (0..8)
            .map(|id| {
                let plen = 1 + rng.below(5);
                let prompt: Vec<u32> = (0..plen).map(|_| rng.below(32) as u32).collect();
                Request { id, prompt, max_new: 2 + rng.below(7) }
            })
            .collect();
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        for max_batch in [1usize, 3, 8] {
            let (resps, _) = serve(&engine, reqs.clone(), max_batch);
            for (r, want) in resps.iter().zip(&expected) {
                assert_eq!(
                    r.tokens, *want,
                    "request {} diverged from generate() at max_batch {max_batch}",
                    r.id
                );
            }
        }
    }

    #[test]
    fn threaded_baseline_matches_direct_generation() {
        let engine = tiny_engine();
        let prompt = vec![5u32, 7, 11];
        let direct = engine.generate(&prompt, 6);
        let (resps, _) = serve_threaded(
            &engine,
            vec![Request { id: 0, prompt: prompt.clone(), max_new: 6 }],
            3,
        );
        assert_eq!(resps[0].tokens, direct);
    }

    #[test]
    fn empty_queue_is_fine() {
        let engine = tiny_engine();
        let (resps, stats) = serve(&engine, vec![], 2);
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
        let (resps, stats) = serve_threaded(&engine, vec![], 2);
        assert!(resps.is_empty());
        assert_eq!(stats.completed, 0);
    }

    #[test]
    fn degenerate_requests_mirror_generate() {
        let engine = tiny_engine();
        // max_new = 0 and an empty prompt must reproduce generate()'s
        // edge-case behaviour through the scheduler.
        let reqs = vec![
            Request { id: 0, prompt: vec![3, 4], max_new: 0 },
            Request { id: 1, prompt: vec![], max_new: 3 },
            Request { id: 2, prompt: vec![1], max_new: 40 }, // hits max_seq
        ];
        let expected: Vec<Vec<u32>> = reqs
            .iter()
            .map(|r| engine.generate(&r.prompt, r.max_new))
            .collect();
        let (resps, _) = serve(&engine, reqs, 2);
        for (r, want) in resps.iter().zip(&expected) {
            assert_eq!(r.tokens, *want, "request {}", r.id);
        }
    }
}
