//! Shared experiment plumbing for the bench binaries and examples:
//! standard corpora, cached trained checkpoints, and the method grids the
//! paper's tables sweep. Keeping this in the library means every bench
//! regenerates a table with a few lines of code and identical settings.

use std::path::PathBuf;

use crate::baselines::awq::AwqConfig;
use crate::baselines::gptq::GptqConfig;
use crate::baselines::owq::OwqConfig;
use crate::coordinator::pipeline::Method;
use crate::coordinator::radio::RadioConfig;
use crate::model::corpus::{Corpus, Domain};
use crate::model::train::{train, TrainConfig};
use crate::model::weights::Weights;
use crate::model::ModelConfig;
use crate::util::rng::Rng;

/// Corpus sizes used across experiments.
pub const CORPUS_BYTES: usize = 256 * 1024;

/// The two evaluation corpora: "C4-like" (calibration domain) and
/// "WikiText-like" (shifted domain). Deterministic.
pub fn corpora() -> (Corpus, Corpus) {
    (
        Corpus::synthetic(0xC4, Domain::Calib, CORPUS_BYTES),
        Corpus::synthetic(0x21C1, Domain::Shifted, CORPUS_BYTES / 4),
    )
}

/// Cache directory for trained checkpoints.
fn cache_dir() -> PathBuf {
    let p = PathBuf::from(
        std::env::var("RADIO_CACHE_DIR").unwrap_or_else(|_| "artifacts/bench_cache".into()),
    );
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Get a trained checkpoint for `preset`, training (and caching) it on
/// first use. Training budget scales down for larger models so benches
/// stay minutes-scale; the *relative* quantization behaviour is what the
/// tables compare.
pub fn trained_model(preset: &str, steps: usize) -> Weights {
    let path = cache_dir().join(format!("{preset}_{steps}.weights"));
    if path.exists() {
        if let Ok(w) = Weights::load(&path) {
            return w;
        }
    }
    let cfg = ModelConfig::preset(preset).unwrap_or_else(|| panic!("unknown preset {preset}"));
    let (calib, _) = corpora();
    let (train_split, _, _) = calib.split();
    let mut rng = Rng::new(0x7EA1_u64 ^ preset.len() as u64);
    let mut w = Weights::init_training(cfg, &mut rng);
    let tcfg = TrainConfig { steps, ..Default::default() };
    crate::log_info!("training {preset} for {steps} steps (cached at {})", path.display());
    let report = train(&mut w, &train_split, &tcfg, 0x5EED);
    crate::log_info!("{preset}: final train loss {:.4} in {:.1}s", report.final_loss, report.seconds);
    let _ = w.save(&path);
    w
}

/// Default training budget per preset (keeps total bench time bounded).
pub fn default_steps(preset: &str) -> usize {
    match preset {
        "ropt-nano" => 300,
        "ropt-micro" => 250,
        "ropt-small" => 220,
        "ropt-med" => 150,
        "ropt-large" => 100,
        _ => 80,
    }
}

/// The paper's Table-1 method grid at a given bit depth / group size.
pub fn method_grid(bits: u8, group: usize, iters: usize) -> Vec<Method> {
    let mut grid = baseline_grid(bits, group);
    grid.push(Method::Radio(radio_cfg(bits as f64, group, iters)));
    grid
}

/// The baseline methods alone — for callers that run Radio through the
/// staged calibrate-once API instead of `run_method`.
pub fn baseline_grid(bits: u8, group: usize) -> Vec<Method> {
    vec![
        Method::Rtn { bits, rows_per_group: group },
        Method::Gptq(GptqConfig {
            bits,
            rows_per_group: group,
            calib_batches: 4,
            batch: 4,
            seq: 64,
            ..Default::default()
        }),
        Method::Awq(AwqConfig {
            bits,
            rows_per_group: group,
            calib_batches: 2,
            batch: 4,
            seq: 64,
            grid: 10,
            ..Default::default()
        }),
        Method::Owq(OwqConfig {
            bits,
            target_bits: bits as f64 + 0.01,
            rows_per_group: group,
            calib_batches: 2,
            batch: 4,
            seq: 64,
            ..Default::default()
        }),
    ]
}

/// Standard Radio configuration for experiments.
pub fn radio_cfg(target_bits: f64, group: usize, iters: usize) -> RadioConfig {
    RadioConfig {
        target_bits,
        rows_per_group: group,
        batch: 8,
        seq: 64,
        tokens_per_seq: 17,
        iters,
        pca_k: 8,
        ..Default::default()
    }
}

/// Quick perplexity evaluation settings shared by benches.
pub const EVAL_SEQ: usize = 64;
pub const EVAL_WINDOWS: usize = 48;

/// True when `RADIO_SMOKE` is set: examples shrink to tiny configs so
/// CI's examples-smoke job can execute every example end-to-end in
/// seconds. Smoke runs exercise the full code path (train → quantize →
/// eval → serve) with reduced budgets; the printed numbers are not
/// meaningful, only completion is.
pub fn smoke() -> bool {
    std::env::var("RADIO_SMOKE").is_ok()
}

/// `full` normally, `tiny` under `RADIO_SMOKE` — the examples' one-line
/// budget switch.
pub fn smoke_scaled(full: usize, tiny: usize) -> usize {
    if smoke() {
        tiny
    } else {
        full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpora_are_distinct_domains() {
        let (a, b) = corpora();
        assert_eq!(a.domain, Domain::Calib);
        assert_eq!(b.domain, Domain::Shifted);
    }

    #[test]
    fn method_grid_has_all_five() {
        let g = method_grid(3, 64, 8);
        assert_eq!(g.len(), 5);
        let names: Vec<String> = g.iter().map(|m| m.name()).collect();
        assert!(names.iter().any(|n| n.starts_with("RTN")));
        assert!(names.iter().any(|n| n.starts_with("Radio")));
        let b = baseline_grid(3, 64);
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|m| !m.name().starts_with("Radio")));
    }
}
