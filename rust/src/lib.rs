//! # Radio: Rate–Distortion Optimization for LLM Compression
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *Radio: Rate-Distortion Optimization for Large Language Model
//! Compression* (Sean I. Young, ICML 2025).
//!
//! - **L3 (this crate):** the coordinator — Algorithm 1's dual-ascent bit
//!   allocation, companded quantization, grouping/bit-packing, baselines
//!   (RTN/GPTQ/AWQ/OWQ), a transformer substrate with manual backprop, a
//!   mixed-precision quantized inference engine, and evaluation harnesses.
//! - **L2 (python/compile/model.py):** the same transformer in JAX,
//!   AOT-lowered to HLO text artifacts that L3 loads via PJRT.
//! - **L1 (python/compile/kernels/):** Pallas kernels for companded
//!   quantization and mixed-depth matvec, verified against `ref.py`.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

pub mod util;

pub mod stats;

pub mod model;

pub mod quant;

pub mod coordinator;

pub mod baselines;

pub mod infer;

pub mod eval;

pub mod runtime;

pub mod report;

pub mod exp;
