//! # Radio: Rate–Distortion Optimization for LLM Compression
//!
//! A three-layer (Rust + JAX + Pallas, AOT via PJRT) reproduction of
//! *Radio: Rate-Distortion Optimization for Large Language Model
//! Compression* (Sean I. Young, ICML 2025).
//!
//! - **L3 (this crate):** the coordinator — Algorithm 1's dual-ascent bit
//!   allocation, companded quantization, grouping/bit-packing, baselines
//!   (RTN/GPTQ/AWQ/OWQ), a transformer substrate with manual backprop, a
//!   mixed-precision quantized inference engine, and evaluation harnesses.
//! - **L2 (python/compile/model.py):** the same transformer in JAX,
//!   AOT-lowered to HLO text artifacts that L3 loads via PJRT.
//! - **L1 (python/compile/kernels/):** Pallas kernels for companded
//!   quantization and mixed-depth matvec, verified against `ref.py`.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

// Deliberate style choices for `cargo clippy -D warnings` (CI): index
// loops walk several parallel buffers in lockstep (iterator zips would
// obscure the disjoint-write safety arguments), kernel entry points take
// long flat argument lists (structs would cost a pack/unpack per call),
// and a few explicit lifetimes document borrow relationships the
// compiler could elide. Held crate-wide rather than per-module because
// the numeric style pervades the crate — transformer backprop, stats,
// quantizers, and baselines all use the same idiom, not just
// infer/matvec — so per-module allows would re-list most of the tree.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::needless_lifetimes,
    clippy::manual_memcpy,
    clippy::comparison_chain
)]

pub mod util;

// The typed error taxonomy for container loads and serving faults.
// Part of the documented API surface: `RadioError` rides inside
// `infer::Response` and is matched on by downstream tooling.
#[warn(missing_docs)]
pub mod error;

pub mod stats;

pub mod model;

pub mod quant;

// The user-facing API surface (coordinator, infer, eval, and the
// `.radio` container in quant::format) carries a rustdoc gate: every
// public item is documented, and CI's `cargo doc` job runs with
// `RUSTDOCFLAGS="-D warnings"` so regressions fail the build.
#[warn(missing_docs)]
pub mod coordinator;

pub mod baselines;

#[warn(missing_docs)]
pub mod infer;

#[warn(missing_docs)]
pub mod eval;

pub mod runtime;

pub mod report;

pub mod exp;
