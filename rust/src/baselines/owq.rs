//! OWQ (Lee et al., 2024) from scratch: outlier-aware weight quantization.
//! Input channels whose quantization hurts most — sensitivity
//! λ_i = H_ii · ‖ΔW_i‖² with H the input Hessian — are kept in FP16
//! ("weak columns"); everything else is quantized uniformly at the base
//! bit depth. The number of FP16 rows is chosen to hit a fractional
//! target rate such as 3.01 bits (Table 4a's 2.1–2.8-bit sweep).

use crate::model::corpus::Corpus;
use crate::model::tensor::Tensor;
use crate::model::transformer;
use crate::model::weights::{MatId, Role, SideParams, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::grouping::Grouping;
use crate::quant::{group_meta, QuantMode, ScaleRule};
use crate::stats::linalg;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct OwqConfig {
    /// Base bit depth for non-outlier weights.
    pub bits: u8,
    /// Target *average* bits incl. FP16 outliers (e.g. 3.01). The number
    /// of FP16 rows is derived from this.
    pub target_bits: f64,
    /// Scale-group size (input-dim rows per group); `usize::MAX` = none.
    pub rows_per_group: usize,
    pub calib_batches: usize,
    pub batch: usize,
    pub seq: usize,
    pub seed: u64,
}

impl Default for OwqConfig {
    fn default() -> Self {
        Self {
            bits: 3,
            target_bits: 3.01,
            rows_per_group: 64,
            calib_batches: 4,
            batch: 4,
            seq: 64,
            seed: 0x0_39,
        }
    }
}

/// Number of FP16 rows that brings `bits`-bit quantization up to the
/// fractional `target_bits` average: solve
/// (k·16 + (R−k)·bits) / R = target  ⇒  k = R(target−bits)/(16−bits).
pub fn outlier_rows_for_target(rows: usize, bits: u8, target_bits: f64) -> usize {
    let b = bits as f64;
    if target_bits <= b {
        return 0;
    }
    let k = (rows as f64 * (target_bits - b) / (16.0 - b)).round() as usize;
    k.min(rows)
}

/// Quantize one matrix with OWQ given the diagonal of its input Hessian.
pub fn owq_matrix(w: &Tensor, h_diag: &[f64], cfg: &OwqConfig) -> PackedMatrix {
    assert_eq!(h_diag.len(), w.rows);
    let k = outlier_rows_for_target(w.rows, cfg.bits, cfg.target_bits);
    // Sensitivity per input row: H_ii · ‖W_i‖² (the row's output impact).
    let mut sens: Vec<(f64, u32)> = (0..w.rows)
        .map(|r| {
            let norm2: f64 = w.row(r).iter().map(|&x| (x as f64) * (x as f64)).sum();
            (h_diag[r] * norm2, r as u32)
        })
        .collect();
    sens.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
    let mut fp_rows: Vec<u32> = sens[..k].iter().map(|&(_, r)| r).collect();
    fp_rows.sort_unstable();

    let rows_per_group = cfg.rows_per_group.min(w.rows);
    let grouping = Grouping::build(w.rows, w.cols, rows_per_group, &vec![0.0; w.rows]);
    // Metas computed from non-outlier members of each group.
    let is_fp: Vec<bool> = {
        let mut v = vec![false; w.rows];
        for &r in &fp_rows {
            v[r as usize] = true;
        }
        v
    };
    let mut metas = Vec::with_capacity(grouping.num_groups());
    for col in 0..grouping.cols {
        for sub in 0..grouping.m {
            let vals: Vec<f32> = grouping.group_rows[sub]
                .iter()
                .filter(|&&r| !is_fp[r as usize])
                .map(|&r| w.get(r as usize, col))
                .collect();
            if vals.is_empty() {
                metas.push(crate::quant::GroupMeta { bits: cfg.bits, scale: 1.0, mean: 0.0 });
            } else {
                metas.push(group_meta(&vals, cfg.bits, QuantMode::Uniform, ScaleRule::Mmse));
            }
        }
    }
    PackedMatrix::pack_full(w, &grouping, &metas, QuantMode::Uniform, None, &fp_rows)
}

/// Full-model OWQ.
pub fn owq_quantize(
    w: &Weights,
    corpus: &Corpus,
    cfg: &OwqConfig,
) -> crate::quant::format::QuantizedModel {
    let mut rng = Rng::new(cfg.seed);
    let ids = w.matrix_ids();
    let mut diags: Vec<Vec<f64>> = ids.iter().map(|&id| vec![0f64; w.matrix(id).rows]).collect();
    for _ in 0..cfg.calib_batches {
        let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
        let cache = transformer::forward(w, &toks, cfg.batch, cfg.seq);
        for (kk, &id) in ids.iter().enumerate() {
            let x = match id.role {
                Role::Q | Role::K | Role::V => &cache.layers[id.layer].a,
                Role::O => &cache.layers[id.layer].ctx,
                Role::Up => &cache.layers[id.layer].bn,
                Role::Down => &cache.layers[id.layer].h,
            };
            // Diagonal of XᵀX only.
            for r in 0..x.rows {
                let row = x.row(r);
                for (j, d) in diags[kk].iter_mut().enumerate() {
                    *d += (row[j] as f64) * (row[j] as f64);
                }
            }
        }
    }
    let _ = linalg::dot; // (diag-only: full Hessian not required)
    let packed: Vec<(MatId, PackedMatrix)> = ids
        .iter()
        .enumerate()
        .map(|(k, &id)| (id, owq_matrix(w.matrix(id), &diags[k], cfg)))
        .collect();
    let base = SideParams::from_weights(w);
    crate::quant::format::QuantizedModel { base, packed, act_quant: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;

    #[test]
    fn outlier_count_hits_fractional_rate() {
        // 512 rows at 3 bits, target 3.01 → k = 512·0.01/13 ≈ 0.4 → 0;
        // target 3.5 → k = 512·0.5/13 ≈ 20.
        assert_eq!(outlier_rows_for_target(512, 3, 3.0), 0);
        assert_eq!(outlier_rows_for_target(512, 3, 3.5), 20);
        assert_eq!(outlier_rows_for_target(512, 3, 16.0), 512);
    }

    #[test]
    fn owq_rate_close_to_target() {
        let mut rng = Rng::new(151);
        let (din, dout) = (128, 32);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_laplace(&mut w.data, 0.0, 0.5);
        let h: Vec<f64> = (0..din).map(|i| 1.0 + (i % 7) as f64).collect();
        let cfg = OwqConfig { bits: 2, target_bits: 2.4, rows_per_group: 32, ..Default::default() };
        let pm = owq_matrix(&w, &h, &cfg);
        assert!(
            (pm.avg_bits_per_weight() - 2.4).abs() < 0.15,
            "avg bits {}",
            pm.avg_bits_per_weight()
        );
    }

    #[test]
    fn owq_keeps_sensitive_rows_exact_to_fp16() {
        let mut rng = Rng::new(152);
        let (din, dout) = (32, 8);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let mut h = vec![1.0f64; din];
        h[5] = 1e6; // row 5 is hyper-sensitive
        let cfg = OwqConfig { bits: 2, target_bits: 4.0, rows_per_group: din, ..Default::default() };
        let pm = owq_matrix(&w, &h, &cfg);
        assert!(pm.fp_rows.iter().any(|(r, _)| *r == 5), "row 5 must be FP16");
        let deq = pm.unpack();
        for c in 0..dout {
            // FP16 precision, not 2-bit precision.
            assert!((deq.get(5, c) - w.get(5, c)).abs() < 1e-2);
        }
    }

    #[test]
    fn owq_beats_plain_rtn_at_same_rate() {
        // Give some rows huge sensitivity; OWQ protects them, RTN can't.
        let mut rng = Rng::new(153);
        let (din, dout) = (64, 24);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_laplace(&mut w.data, 0.0, 0.4);
        // Hot rows with larger magnitudes (hurt RTN's shared step).
        for &r in &[3usize, 31, 47] {
            for v in w.row_mut(r) {
                *v *= 10.0;
            }
        }
        let mut h = vec![1.0f64; din];
        for &r in &[3usize, 31, 47] {
            h[r] = 100.0;
        }
        let cfg = OwqConfig { bits: 2, target_bits: 2.7, rows_per_group: din, ..Default::default() };
        let pm_owq = owq_matrix(&w, &h, &cfg);
        let pm_rtn = crate::quant::rtn_quantize(&w, 3, din, ScaleRule::Mmse); // ~3 bits > 2.7
        let herr = |d: &Tensor| {
            let mut e = 0f64;
            for r in 0..din {
                for c in 0..dout {
                    e += h[r] * ((w.get(r, c) - d.get(r, c)) as f64).powi(2);
                }
            }
            e
        };
        let (eo, er) = (herr(&pm_owq.unpack()), herr(&pm_rtn.unpack()));
        assert!(
            eo < er,
            "owq at {:.2} bits ({eo:.4}) should beat rtn at 3 bits ({er:.4}) on H-weighted error",
            pm_owq.avg_bits_per_weight()
        );
    }

    #[test]
    fn owq_end_to_end_tiny() {
        let mcfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(154);
        let w = Weights::init_pretrained_like(mcfg, &mut rng);
        let corpus = Corpus::synthetic(155, Domain::Calib, 4 * 1024);
        let cfg = OwqConfig {
            bits: 3,
            target_bits: 3.4,
            rows_per_group: 8,
            calib_batches: 1,
            batch: 2,
            seq: 16,
            ..Default::default()
        };
        let qm = owq_quantize(&w, &corpus, &cfg);
        assert_eq!(qm.packed.len(), 6);
        // With 16–32-row matrices, outlier-count rounding is coarse: the
        // achieved rate sits between the base depth and the target.
        let avg = qm.avg_bits();
        assert!((3.0..=3.45).contains(&avg), "avg {avg}");
    }
}
