//! Baseline quantizers the paper compares against, implemented from
//! scratch: GPTQ (OBS error compensation), AWQ (activation-aware
//! scaling), OWQ (FP16 outlier rows). RTN lives in `quant::rtn`.

pub mod awq;
pub mod gptq;
pub mod owq;
