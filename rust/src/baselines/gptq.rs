//! GPTQ (Frantar et al., 2022) from scratch — the paper's main baseline
//! and, per its Appendix F framing, the OBS-lineage comparator.
//!
//! Layer-sequential variant (the reference implementation's behaviour):
//! for each transformer block in order, calibration inputs are collected
//! by running the *partially quantized* model forward, the per-matrix
//! Hessian H = XᵀX (+ damping) is accumulated, and each matrix is
//! quantized row-by-row with OBS error compensation:
//!
//! ```text
//! U = chol(H⁻¹, upper)             (so H⁻¹ = UᵀU)
//! for input row i:
//!     q_i   = quant(w_i)           (per-group uniform, MMSE steps)
//!     e_i   = (w_i − q_i) / U[i,i]
//!     w_k  += −U[i,k]·e_i  for k > i
//! ```
//!
//! In our `y = xW` convention, W is (d_in × d_out) and the Hessian runs
//! over input rows.

use crate::model::corpus::Corpus;
use crate::model::tensor::Tensor;
use crate::model::transformer;
use crate::model::weights::{MatId, Role, SideParams, Weights};
use crate::quant::bitpack::{GroupMeta, PackedMatrix};
use crate::quant::grouping::Grouping;
use crate::quant::{group_meta, QuantMode, ScaleRule};
use crate::stats::linalg;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct GptqConfig {
    pub bits: u8,
    /// Scale-group size along the input dimension (paper "GPTQ/256").
    pub rows_per_group: usize,
    /// Relative Hessian damping (reference uses 1%).
    pub damping: f64,
    /// Calibration batches (of `batch`×`seq` tokens each).
    pub calib_batches: usize,
    pub batch: usize,
    pub seq: usize,
    pub seed: u64,
}

impl Default for GptqConfig {
    fn default() -> Self {
        Self {
            bits: 4,
            rows_per_group: 64,
            damping: 0.01,
            calib_batches: 8,
            batch: 4,
            seq: 64,
            seed: 0x69_7074, // "gpt"
        }
    }
}

/// Quantize one matrix with GPTQ given its input Gram matrix `h`
/// (d_in×d_in, f64). Returns (packed, dense quantized weights).
pub fn gptq_matrix(
    w: &Tensor,
    h: &[f64],
    cfg: &GptqConfig,
) -> (PackedMatrix, Tensor) {
    let din = w.rows;
    let dout = w.cols;
    assert_eq!(h.len(), din * din);

    // Damped Hessian → upper Cholesky of its inverse.
    let mut hd = h.to_vec();
    let mean_diag = (0..din).map(|i| h[i * din + i]).sum::<f64>() / din as f64;
    let damp = (cfg.damping * mean_diag).max(1e-8);
    for i in 0..din {
        hd[i * din + i] += damp;
    }
    let u = linalg::cholesky_inverse_upper(&hd, din).unwrap_or_else(|_| {
        // Fall back to identity scaling (plain RTN ordering) if the
        // Hessian is irreparably singular.
        let mut id = vec![0f64; din * din];
        for i in 0..din {
            id[i * din + i] = 1.0;
        }
        id
    });

    // Contiguous row groups (GPTQ groups run along the input dim).
    let order_scores: Vec<f64> = (0..din).map(|r| r as f64).collect();
    let grouping = Grouping::build(din, dout, cfg.rows_per_group, &order_scores);

    let mut work = w.clone(); // updated in place by OBS compensation
    let mut quantized = Tensor::zeros(din, dout);
    // Metas are decided when the first row of each (col, sub) group is
    // reached, from the *current* (compensated) values — as in the
    // reference implementation.
    let mut metas: Vec<Option<GroupMeta>> = vec![None; grouping.num_groups()];

    for i in 0..din {
        let sub = grouping.row_to_group[i] as usize;
        let uii = u[i * din + i].max(1e-12);
        // Decide metas for any group whose first row this is.
        for col in 0..dout {
            let gi = grouping.group_index(col, sub);
            if metas[gi].is_none() {
                // Gather *current* values of this group's rows.
                let vals = grouping.gather(&work, col, sub);
                metas[gi] = Some(group_meta(&vals, cfg.bits, QuantMode::Uniform, ScaleRule::Mmse));
            }
        }
        // Quantize row i and compute compensation errors.
        let mut err = vec![0f32; dout];
        for col in 0..dout {
            let gi = grouping.group_index(col, sub);
            let gm = metas[gi].unwrap();
            let x = work.get(i, col);
            let code = crate::quant::rtn::quantize_code(x, gm.bits, gm.scale, gm.mean);
            let q = crate::quant::rtn::dequantize_code(code, gm.scale, gm.mean);
            quantized.set(i, col, q);
            err[col] = ((x - q) as f64 / uii) as f32;
        }
        // Propagate to remaining rows: w_k -= U[i,k]·err.
        for k in (i + 1)..din {
            let uik = u[i * din + k];
            if uik == 0.0 {
                continue;
            }
            let row = work.row_mut(k);
            for (col, e) in err.iter().enumerate() {
                row[col] -= (uik * *e as f64) as f32;
            }
        }
    }

    // Pack: the final values are exact dequant points of the chosen metas,
    // so packing the quantized tensor reproduces them bit-exactly.
    let metas: Vec<GroupMeta> = metas.into_iter().map(|m| m.unwrap()).collect();
    let packed = PackedMatrix::pack(&quantized, &grouping, &metas, QuantMode::Uniform);
    (packed, quantized)
}

/// Accumulate input Gram matrices for every matrix of one block by
/// running the (partially quantized) model on calibration batches.
fn block_grams(
    w: &Weights,
    corpus: &Corpus,
    layer: usize,
    cfg: &GptqConfig,
    rng: &mut Rng,
) -> Vec<(Role, Vec<f64>)> {
    let e = w.config.dim;
    let f = w.config.mlp;
    let mut grams: Vec<(Role, Vec<f64>)> = vec![
        (Role::Q, vec![0f64; e * e]),
        (Role::O, vec![0f64; e * e]),
        (Role::Up, vec![0f64; e * e]),
        (Role::Down, vec![0f64; f * f]),
    ];
    for _ in 0..cfg.calib_batches {
        let (toks, _) = corpus.sample_batch(rng, cfg.batch, cfg.seq);
        let cache = transformer::forward(w, &toks, cfg.batch, cfg.seq);
        let lc = &cache.layers[layer];
        for (role, g) in grams.iter_mut() {
            let x = match role {
                Role::Q | Role::K | Role::V => &lc.a,
                Role::O => &lc.ctx,
                Role::Up => &lc.bn,
                Role::Down => &lc.h,
            };
            let gx = linalg::gram(&x.data, x.rows, x.cols);
            for (a, b) in g.iter_mut().zip(&gx) {
                *a += b;
            }
        }
    }
    grams
}

/// Full-model GPTQ: layer-sequential, quantizing all six matrices per
/// block with inputs from the partially-quantized prefix.
pub fn gptq_quantize(
    w: &Weights,
    corpus: &Corpus,
    cfg: &GptqConfig,
) -> crate::quant::format::QuantizedModel {
    let mut rng = Rng::new(cfg.seed);
    let mut current = w.clone();
    let mut packed: Vec<(MatId, PackedMatrix)> = Vec::new();
    for layer in 0..w.config.layers {
        let grams = block_grams(&current, corpus, layer, cfg, &mut rng);
        let find = |role: Role| -> &Vec<f64> {
            &grams
                .iter()
                .find(|(r, _)| {
                    matches!(
                        (r, role),
                        (Role::Q, Role::Q | Role::K | Role::V)
                            | (Role::O, Role::O)
                            | (Role::Up, Role::Up)
                            | (Role::Down, Role::Down)
                    )
                })
                .unwrap()
                .1
        };
        for role in Role::ALL {
            let id = MatId { layer, role };
            let (pm, dense) = gptq_matrix(current.matrix(id), find(role), cfg);
            *current.matrix_mut(id) = dense;
            packed.push((id, pm));
        }
    }
    let base = SideParams::from_weights(&current);
    crate::quant::format::QuantizedModel { base, packed, act_quant: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;

    fn random_inputs(rng: &mut Rng, n: usize, d: usize) -> Tensor {
        let mut x = Tensor::zeros(n, d);
        rng.fill_gauss(&mut x.data, 0.0, 1.0);
        // Correlate the channels so the Hessian is non-trivial.
        for r in 0..n {
            let base = x.get(r, 0);
            for c in 1..d.min(4) {
                let v = x.get(r, c);
                x.set(r, c, 0.6 * base + 0.4 * v);
            }
        }
        x
    }

    #[test]
    fn gptq_beats_rtn_in_layer_output_mse() {
        // The whole point of OBS compensation: for the SAME quantizer,
        // output error ‖X(W−Wq)‖² is lower than direct RTN.
        let mut rng = Rng::new(131);
        let (n, din, dout) = (256, 24, 16);
        let x = random_inputs(&mut rng, n, din);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_laplace(&mut w.data, 0.0, 0.3);
        let h = linalg::gram(&x.data, n, din);
        let cfg = GptqConfig { bits: 3, rows_per_group: din, ..Default::default() };

        let (_, wq_gptq) = gptq_matrix(&w, &h, &cfg);
        let wq_rtn = crate::quant::rtn_quantize(&w, 3, din, ScaleRule::Mmse).unpack();

        let err = |wq: &Tensor| {
            let y0 = x.matmul(&w);
            let yq = x.matmul(wq);
            let mut e = 0f64;
            for (a, b) in y0.data.iter().zip(&yq.data) {
                e += ((a - b) as f64).powi(2);
            }
            e
        };
        let (eg, er) = (err(&wq_gptq), err(&wq_rtn));
        assert!(eg < er, "gptq {eg} should beat rtn {er}");
    }

    #[test]
    fn gptq_packed_matches_dense() {
        let mut rng = Rng::new(132);
        let (n, din, dout) = (128, 16, 8);
        let x = random_inputs(&mut rng, n, din);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_gauss(&mut w.data, 0.0, 0.5);
        let h = linalg::gram(&x.data, n, din);
        let cfg = GptqConfig { bits: 4, rows_per_group: 8, ..Default::default() };
        let (pm, dense) = gptq_matrix(&w, &h, &cfg);
        let unpacked = pm.unpack();
        for (a, b) in dense.data.iter().zip(&unpacked.data) {
            assert!((a - b).abs() < 2e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn gptq_end_to_end_on_tiny_model() {
        let mcfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(133);
        let w = Weights::init_pretrained_like(mcfg, &mut rng);
        let corpus = Corpus::synthetic(134, Domain::Calib, 8 * 1024);
        let cfg = GptqConfig {
            bits: 4,
            rows_per_group: 8,
            calib_batches: 2,
            batch: 2,
            seq: 16,
            ..Default::default()
        };
        let qm = gptq_quantize(&w, &corpus, &cfg);
        assert_eq!(qm.packed.len(), 12);
        assert!((qm.avg_bits() - 4.0).abs() < 1e-9);
    }
}
