//! AWQ (Lin et al., 2024) from scratch: activation-aware weight
//! quantization. Salient input channels (large average activation
//! magnitude) are protected by scaling them up before quantization and
//! down after — equivalently, quantization error on channel i is divided
//! by s_i. The per-matrix scale exponent α is grid-searched to minimize
//! the activation-weighted reconstruction error, exactly as in the
//! reference (`s_i = a_i^α`, α ∈ {0, 1/20, …, 1}).

use crate::model::corpus::Corpus;
use crate::model::tensor::Tensor;
use crate::model::transformer;
use crate::model::weights::{MatId, SideParams, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::grouping::Grouping;
use crate::quant::{group_meta, QuantMode, ScaleRule};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct AwqConfig {
    pub bits: u8,
    pub rows_per_group: usize,
    pub grid: usize,
    pub calib_batches: usize,
    pub batch: usize,
    pub seq: usize,
    pub seed: u64,
}

impl Default for AwqConfig {
    fn default() -> Self {
        Self {
            bits: 4,
            rows_per_group: 64,
            grid: 20,
            calib_batches: 4,
            batch: 4,
            seq: 64,
            seed: 0xA79,
        }
    }
}

/// Quantize one matrix given per-input-channel mean |activation| `act`.
pub fn awq_matrix(w: &Tensor, act: &[f32], cfg: &AwqConfig) -> PackedMatrix {
    assert_eq!(act.len(), w.rows);
    let grouping = Grouping::build(w.rows, w.cols, cfg.rows_per_group, &vec![0.0; w.rows]);

    // Normalize activations to geometric mean 1 for a stable grid.
    let logs: f64 = act.iter().map(|&a| (a.max(1e-6) as f64).ln()).sum::<f64>() / act.len() as f64;
    let norm: Vec<f32> = act.iter().map(|&a| (a.max(1e-6) as f64 / logs.exp()) as f32).collect();

    let mut best: Option<(f64, PackedMatrix)> = None;
    for gi in 0..=cfg.grid {
        let alpha = gi as f32 / cfg.grid as f32;
        let scale: Vec<f32> = norm.iter().map(|&a| a.powf(alpha).clamp(1e-4, 1e4)).collect();
        // Quantize the scaled weights.
        let mut scaled = w.clone();
        for r in 0..w.rows {
            let s = scale[r];
            for v in scaled.row_mut(r) {
                *v *= s;
            }
        }
        let mut metas = Vec::with_capacity(grouping.num_groups());
        for col in 0..grouping.cols {
            for sub in 0..grouping.m {
                let vals = grouping.gather(&scaled, col, sub);
                metas.push(group_meta(&vals, cfg.bits, QuantMode::Uniform, ScaleRule::Mmse));
            }
        }
        let pm = PackedMatrix::pack_full(
            w,
            &grouping,
            &metas,
            QuantMode::Uniform,
            Some(scale.clone()),
            &[],
        );
        // Activation-weighted reconstruction error ‖diag(a)(W − Wq)‖².
        let deq = pm.unpack();
        let mut err = 0f64;
        for r in 0..w.rows {
            let a2 = (act[r] as f64) * (act[r] as f64);
            for c in 0..w.cols {
                err += a2 * ((w.get(r, c) - deq.get(r, c)) as f64).powi(2);
            }
        }
        if best.as_ref().map(|(e, _)| err < *e).unwrap_or(true) {
            best = Some((err, pm));
        }
    }
    best.unwrap().1
}

/// Full-model AWQ: collect per-matrix mean |activation| from calibration
/// batches, then quantize every matrix independently.
pub fn awq_quantize(
    w: &Weights,
    corpus: &Corpus,
    cfg: &AwqConfig,
) -> crate::quant::format::QuantizedModel {
    let mut rng = Rng::new(cfg.seed);
    let ids = w.matrix_ids();
    // Accumulate mean |activation| per matrix input.
    let mut acts: Vec<Vec<f64>> = ids.iter().map(|&id| vec![0f64; w.matrix(id).rows]).collect();
    let mut count = 0usize;
    for _ in 0..cfg.calib_batches {
        let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
        let cache = transformer::forward(w, &toks, cfg.batch, cfg.seq);
        for (k, &id) in ids.iter().enumerate() {
            let x = match id.role {
                crate::model::weights::Role::Q
                | crate::model::weights::Role::K
                | crate::model::weights::Role::V => &cache.layers[id.layer].a,
                crate::model::weights::Role::O => &cache.layers[id.layer].ctx,
                crate::model::weights::Role::Up => &cache.layers[id.layer].bn,
                crate::model::weights::Role::Down => &cache.layers[id.layer].h,
            };
            for r in 0..x.rows {
                for (j, a) in acts[k].iter_mut().enumerate() {
                    *a += x.get(r, j).abs() as f64;
                }
            }
        }
        count += cfg.batch * cfg.seq;
    }
    let mut packed: Vec<(MatId, PackedMatrix)> = Vec::new();
    for (k, &id) in ids.iter().enumerate() {
        let act: Vec<f32> = acts[k].iter().map(|&a| (a / count as f64) as f32).collect();
        packed.push((id, awq_matrix(w.matrix(id), &act, cfg)));
    }
    let base = SideParams::from_weights(w);
    crate::quant::format::QuantizedModel { base, packed, act_quant: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;

    #[test]
    fn awq_protects_salient_channels() {
        // With one hot input channel, AWQ's activation-weighted error must
        // beat plain RTN's on that weighting.
        let mut rng = Rng::new(141);
        let (din, dout) = (32, 16);
        let mut w = Tensor::zeros(din, dout);
        rng.fill_laplace(&mut w.data, 0.0, 0.3);
        let mut act = vec![0.1f32; din];
        act[3] = 10.0;
        act[17] = 6.0;
        let cfg = AwqConfig { bits: 3, rows_per_group: din, ..Default::default() };
        let pm_awq = awq_matrix(&w, &act, &cfg);
        let pm_rtn = crate::quant::rtn_quantize(&w, 3, din, ScaleRule::Mmse);
        let werr = |pm: &PackedMatrix| {
            let d = pm.unpack();
            let mut e = 0f64;
            for r in 0..din {
                let a2 = (act[r] as f64).powi(2);
                for c in 0..dout {
                    e += a2 * ((w.get(r, c) - d.get(r, c)) as f64).powi(2);
                }
            }
            e
        };
        let (ea, er) = (werr(&pm_awq), werr(&pm_rtn));
        assert!(ea < er, "awq {ea} should beat rtn {er} on weighted error");
    }

    #[test]
    fn awq_rate_is_exact() {
        let mut rng = Rng::new(142);
        let mut w = Tensor::zeros(16, 8);
        rng.fill_gauss(&mut w.data, 0.0, 1.0);
        let act = vec![1.0f32; 16];
        let cfg = AwqConfig { bits: 4, rows_per_group: 16, ..Default::default() };
        let pm = awq_matrix(&w, &act, &cfg);
        assert!((pm.avg_bits_per_weight() - 4.0).abs() < 1e-9);
        // Row scales count as overhead.
        assert!(pm.overhead_bits() >= 16 * 16);
    }

    #[test]
    fn awq_end_to_end_tiny() {
        let mcfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 1, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(143);
        let w = Weights::init_pretrained_like(mcfg, &mut rng);
        let corpus = Corpus::synthetic(144, Domain::Calib, 4 * 1024);
        let cfg = AwqConfig {
            bits: 4,
            rows_per_group: 8,
            calib_batches: 1,
            batch: 2,
            seq: 16,
            grid: 8,
            ..Default::default()
        };
        let qm = awq_quantize(&w, &corpus, &cfg);
        assert_eq!(qm.packed.len(), 6);
        assert!((qm.avg_bits() - 4.0).abs() < 1e-9);
    }
}
