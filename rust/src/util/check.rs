//! Seeded property-testing helper ("proptest-lite": the offline registry
//! carries no proptest). Runs a property over many pseudo-random cases;
//! on failure it retries with progressively "smaller" generation sizes to
//! give a simpler counterexample, and always reports the failing seed so
//! a case can be replayed deterministically.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Checker {
    pub cases: usize,
    pub seed: u64,
    /// Maximum "size" hint passed to generators (e.g. max vector length).
    pub max_size: usize,
}

impl Default for Checker {
    fn default() -> Self {
        Self { cases: 64, seed: 0xC0FFEE, max_size: 256 }
    }
}

impl Checker {
    pub fn new(cases: usize, seed: u64) -> Self {
        Self { cases, seed, max_size: 256 }
    }

    /// Run `prop(rng, size)` for `cases` random cases. `size` ramps up from
    /// small to `max_size` so early failures are small. Panics with the
    /// failing seed/size on the first property violation.
    pub fn run<F>(&self, name: &str, mut prop: F)
    where
        F: FnMut(&mut Rng, usize) -> Result<(), String>,
    {
        for case in 0..self.cases {
            // Ramp size: first cases are tiny, later cases large.
            let size = 1 + (self.max_size - 1) * case / self.cases.max(1);
            let case_seed = self
                .seed
                .wrapping_add((case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let mut rng = Rng::new(case_seed);
            if let Err(msg) = prop(&mut rng, size) {
                // Attempt a smaller repro: rerun the same seed at smaller sizes.
                let mut minimal: Option<(usize, String)> = None;
                for s in 1..size {
                    let mut r2 = Rng::new(case_seed);
                    if let Err(m) = prop(&mut r2, s) {
                        minimal = Some((s, m));
                        break;
                    }
                }
                let (fsize, fmsg) = minimal.unwrap_or((size, msg));
                panic!(
                    "property `{name}` failed (case {case}, seed {case_seed:#x}, size {fsize}): {fmsg}"
                );
            }
        }
    }
}

/// Assert helper producing `Result` for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        Checker::new(32, 1).run("trivially-true", |_, _| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property `fails-on-big`")]
    fn failing_property_reports_seed() {
        Checker::new(32, 2).run("fails-on-big", |_, size| {
            if size > 10 {
                Err("too big".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrink_finds_smaller_size() {
        let result = std::panic::catch_unwind(|| {
            Checker::new(16, 3).run("gt5", |_, size| {
                if size > 5 {
                    Err("size>5".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // The shrinker should find size 6, the minimal failing size.
        assert!(msg.contains("size 6"), "got: {msg}");
    }
}
