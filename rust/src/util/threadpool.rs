//! A small data-parallel helper built on a **persistent worker pool** (no
//! rayon in the offline registry).
//!
//! The seed version forked `std::thread::scope` threads per call; that was
//! fine for coarse offline quantization loops, but the decode path issues
//! ~6 matvecs per layer per token, and at serving rates the spawn/join
//! cost dominated the kernels themselves. Workers are now spawned once,
//! lazily, on first use (`RADIO_THREADS`-tunable, snapshotted at pool
//! creation) and parked on a condvar between jobs, so a parallel region
//! costs one notify + one latch instead of N thread spawns.
//!
//! The public API is unchanged: [`parallel_for_chunks`],
//! [`parallel_for_dynamic`] and [`parallel_map`] accept borrowed
//! (non-`'static`) closures. Safety comes from the fork-join discipline:
//! the submitting thread never returns from a parallel call until every
//! worker has finished running the closure, so borrows stay live for the
//! whole region (the same argument rayon's `scope` makes).
//!
//! Reentrancy: a parallel call made from inside a parallel region (from a
//! pool worker or from the submitting thread) runs inline on the calling
//! thread. This keeps nested parallelism deadlock-free and means engine
//! code can parallelize freely without auditing its callees.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock};

/// Poison-tolerant lock: a panic that propagated out of a parallel
/// region may have poisoned pool mutexes while unwinding; the pool's
/// state is still consistent (all signalling is via atomics), so later
/// regions must keep working.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Number of worker threads to use. Reads `RADIO_THREADS` on every call;
/// note the persistent pool snapshots this at first parallel call, so
/// raising it later has no effect (lowering it to 1 still forces inline
/// execution, which is useful for deterministic debugging).
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("RADIO_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

thread_local! {
    /// True while this thread is executing inside a parallel region
    /// (always true on pool workers). Nested calls run inline.
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

fn in_parallel() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

/// Completion latch for one posted job.
struct JobDone {
    /// Spawned workers that have not yet finished running the closure.
    remaining: AtomicUsize,
    panicked: AtomicBool,
    /// First worker panic payload, kept so the submitter can re-raise it
    /// with the original message instead of a generic one (lane-fault
    /// reports downstream depend on that message).
    payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
    m: Mutex<()>,
    cv: Condvar,
}

/// Type-erased pointer to the borrowed broadcast closure plus its latch.
/// Valid only until `remaining` reaches zero — the submitter blocks until
/// then, keeping the referents alive.
#[derive(Clone, Copy)]
struct JobMsg {
    data: *const (),
    call: unsafe fn(*const ()),
    done: *const JobDone,
}

// SAFETY: the pointers are dereferenced only while the submitting thread
// is blocked in `broadcast`, which owns the referents on its stack.
unsafe impl Send for JobMsg {}

unsafe fn call_thunk<F: Fn() + Sync>(p: *const ()) {
    (*(p as *const F))();
}

struct Slot {
    epoch: u64,
    job: Option<JobMsg>,
}

struct Pool {
    /// Spawned workers (the submitter participates as the +1th lane).
    workers: usize,
    slot: Mutex<Slot>,
    cv: Condvar,
    /// Serializes broadcasts: one job in flight at a time.
    submit: Mutex<()>,
}

fn worker_loop(pool: &'static Pool) {
    // Pool threads are permanently "inside" a parallel region: any
    // parallel call they make must run inline.
    IN_PARALLEL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let msg = {
            let mut g = lock(&pool.slot);
            loop {
                if g.epoch != seen {
                    seen = g.epoch;
                    break g.job.expect("job posted with epoch bump");
                }
                g = pool.cv.wait(g).unwrap_or_else(|e| e.into_inner());
            }
        };
        let done = unsafe { &*msg.done };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| unsafe { (msg.call)(msg.data) })) {
            // Store the payload before the decrement critical section
            // below: the submitter only reads it after observing
            // `remaining == 0` under `done.m`.
            let mut slot = lock(&done.payload);
            if slot.is_none() {
                *slot = Some(p);
            }
            drop(slot);
            done.panicked.store(true, Ordering::Relaxed);
        }
        {
            // Decrement-and-notify under the latch mutex. The submitter
            // also reads `remaining` only under this mutex, so it cannot
            // observe 0 (and free the stack-local latch) until this
            // critical section — the worker's last touch of `done` — has
            // fully released.
            let _g = lock(&done.m);
            if done.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                done.cv.notify_all();
            }
        }
    }
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<&'static Pool> = OnceLock::new();
    POOL.get_or_init(|| {
        let workers = num_threads().saturating_sub(1);
        let p: &'static Pool = Box::leak(Box::new(Pool {
            workers,
            slot: Mutex::new(Slot { epoch: 0, job: None }),
            cv: Condvar::new(),
            submit: Mutex::new(()),
        }));
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("radio-pool-{i}"))
                .spawn(move || worker_loop(p))
                .expect("spawning pool worker");
        }
        p
    })
}

/// Restores the submitter's IN_PARALLEL flag even if the closure panics.
struct ParallelGuard;

impl ParallelGuard {
    fn enter() -> ParallelGuard {
        IN_PARALLEL.with(|c| c.set(true));
        ParallelGuard
    }
}

impl Drop for ParallelGuard {
    fn drop(&mut self) {
        IN_PARALLEL.with(|c| c.set(false));
    }
}

/// Run `f` once on every pool worker *and* on the calling thread, then
/// wait for all of them. `f` is typically a work-grabbing loop over an
/// atomic counter, so lane count never affects coverage.
fn broadcast<F: Fn() + Sync>(f: F) {
    let pool = pool();
    if pool.workers == 0 {
        let _guard = ParallelGuard::enter();
        f();
        return;
    }
    // One job in flight at a time. If another thread's region is already
    // running, don't idle waiting for the pool — run this region inline
    // on the calling thread so independent submitters (e.g. the
    // thread-per-request baseline) keep every core busy.
    let _submit = match pool.submit.try_lock() {
        Ok(g) => g,
        Err(std::sync::TryLockError::Poisoned(e)) => e.into_inner(),
        Err(std::sync::TryLockError::WouldBlock) => {
            let _guard = ParallelGuard::enter();
            f();
            return;
        }
    };
    let _guard = ParallelGuard::enter();
    let done = JobDone {
        remaining: AtomicUsize::new(pool.workers),
        panicked: AtomicBool::new(false),
        payload: Mutex::new(None),
        m: Mutex::new(()),
        cv: Condvar::new(),
    };
    let msg = JobMsg {
        data: &f as *const F as *const (),
        call: call_thunk::<F>,
        done: &done as *const JobDone,
    };
    {
        let mut g = lock(&pool.slot);
        g.epoch += 1;
        g.job = Some(msg);
    }
    pool.cv.notify_all();
    // The submitter is a full participant lane.
    let caller_panic = catch_unwind(AssertUnwindSafe(|| f())).err();
    // Block until every worker has finished touching `f` and `done`.
    // `remaining` is only read (and decremented) under `done.m`, which is
    // what makes dropping the stack-local latch safe on exit.
    {
        let mut g = lock(&done.m);
        while done.remaining.load(Ordering::Acquire) != 0 {
            g = done.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }
    // Drop the stale pointer from the slot (workers are all past it: each
    // decremented `remaining` after copying the message out).
    lock(&pool.slot).job = None;
    if let Some(p) = caller_panic {
        resume_unwind(p);
    }
    if done.panicked.load(Ordering::Relaxed) {
        // Re-raise the worker's own payload so panic messages (e.g.
        // failpoint names) survive the thread hop.
        if let Some(p) = lock(&done.payload).take() {
            resume_unwind(p);
        }
        panic!("worker thread panicked inside a parallel region");
    }
}

/// Run `f(start, end)` over disjoint chunks covering `0..n` in parallel.
/// `f` must be `Sync` (called concurrently with disjoint ranges).
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = num_threads();
    if threads <= 1 || n <= min_chunk || in_parallel() {
        f(0, n);
        return;
    }
    let chunks = threads.min(n.div_ceil(min_chunk.max(1)));
    let chunk = n.div_ceil(chunks);
    let next = AtomicUsize::new(0);
    broadcast(|| loop {
        let c = next.fetch_add(1, Ordering::Relaxed);
        let start = c * chunk;
        if start >= n {
            break;
        }
        f(start, (start + chunk).min(n));
    });
}

/// Dynamic work-stealing variant: lanes grab `grain`-sized blocks off a
/// shared counter. Better when per-item cost is highly skewed (e.g. GPTQ
/// columns, mixed-depth matvec rows).
pub fn parallel_for_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let grain = grain.max(1);
    if num_threads() <= 1 || n <= grain || in_parallel() {
        f(0, n);
        return;
    }
    let next = AtomicUsize::new(0);
    broadcast(|| loop {
        let start = next.fetch_add(grain, Ordering::Relaxed);
        if start >= n {
            break;
        }
        f(start, (start + grain).min(n));
    });
}

/// Map each index to a value in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(n, min_chunk, |start, end| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in start..end {
                // SAFETY: chunks are disjoint, so each index is written once
                // by exactly one thread; the Vec outlives the call (the
                // submitter blocks until all lanes finish).
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

/// Fork-join over `n` *dedicated* scoped threads: `f(i)` runs once per
/// worker index (index 0 on the calling thread), results are returned in
/// index order. This is the spawn primitive for the sharded serving
/// backends and the replica router — places that need N long-lived
/// peers running *concurrently* (each possibly submitting to the shared
/// pool themselves), which the single-job-in-flight broadcast pool
/// deliberately does not provide.
///
/// Panic contract: if any worker panics, every other worker is still
/// joined (no detached threads), and then the FIRST panic's original
/// payload is re-raised on the caller — not `std::thread::scope`'s
/// generic "a scoped thread panicked" — so serve-side `LaneFault`
/// details keep naming the real site (the same guarantee `broadcast`
/// makes for pool workers).
pub fn scoped_map<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![f(0)];
    }
    std::thread::scope(|s| {
        let fr = &f;
        let handles: Vec<_> = (1..n).map(|i| s.spawn(move || fr(i))).collect();
        // The caller participates as index 0; its panic is caught so the
        // spawned workers can be joined before anything unwinds.
        let first = catch_unwind(AssertUnwindSafe(|| f(0)));
        let mut results: Vec<Result<T, Box<dyn std::any::Any + Send>>> = Vec::with_capacity(n);
        results.push(first);
        for h in handles {
            results.push(h.join());
        }
        let mut out = Vec::with_capacity(n);
        let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
        for r in results {
            match r {
                Ok(v) => out.push(v),
                Err(p) => {
                    if first_panic.is_none() {
                        first_panic = Some(p);
                    }
                }
            }
        }
        if let Some(p) = first_panic {
            resume_unwind(p);
        }
        out
    })
}

struct SendPtr<T>(*mut T);
// Manual impls: `derive` would wrongly require `T: Copy`.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 10, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(777, 13, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(500, 7, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 1, |_, _| panic!("should not run"));
        let v: Vec<usize> = parallel_map(0, 1, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn nested_parallel_runs_inline_and_completes() {
        let hits: Vec<AtomicU64> = (0..256).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(16, 1, |s0, e0| {
            for outer in s0..e0 {
                // Nested region: must not deadlock; runs inline per lane.
                parallel_for_chunks(16, 1, |s1, e1| {
                    for inner in s1..e1 {
                        hits[outer * 16 + inner].fetch_add(1, Ordering::Relaxed);
                    }
                });
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        // Multiple non-pool threads racing to submit jobs must serialize
        // cleanly (this is the thread-per-request serving pattern).
        let totals: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|t| {
                    s.spawn(move || {
                        let acc = AtomicU64::new(0);
                        parallel_for_chunks(500, 8, |a, b| {
                            let mut local = 0u64;
                            for i in a..b {
                                local += (i as u64) + t as u64;
                            }
                            acc.fetch_add(local, Ordering::Relaxed);
                        });
                        acc.load(Ordering::Relaxed)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let base: u64 = (0..500u64).sum();
        for (t, total) in totals.iter().enumerate() {
            assert_eq!(*total, base + 500 * t as u64);
        }
    }

    #[test]
    fn pool_survives_a_panicking_region() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_for_chunks(64, 1, |s, _| {
                if s == 0 {
                    panic!("deliberate test panic");
                }
            });
        }));
        assert!(result.is_err(), "panic must propagate to the submitter");
        // The pool must still work afterwards.
        let hits: Vec<AtomicU64> = (0..100).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(100, 5, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn panic_in_reentrant_region_unwinds_through_both_levels() {
        // A nested (inline) parallel call that panics must unwind out
        // through the outer region to the submitter — and must not wedge
        // the pool for later callers.
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_for_chunks(8, 1, |s0, _| {
                parallel_for_dynamic(8, 1, |s1, _| {
                    if s0 == 0 && s1 == 0 {
                        panic!("deliberate nested panic");
                    }
                });
            });
        }));
        assert!(result.is_err(), "nested panic must reach the submitter");
        let v = parallel_map(64, 4, |i| i + 1);
        assert!(v.iter().enumerate().all(|(i, x)| *x == i + 1), "pool must keep working");
    }

    #[test]
    fn pool_is_reusable_after_repeated_poisoning() {
        // Each panicking region may poison pool/latch mutexes while
        // unwinding; the poison-tolerant locks must keep the pool fully
        // functional across many poison/recover cycles, with every
        // index still covered exactly once after each one.
        for round in 0..5u64 {
            let result = catch_unwind(AssertUnwindSafe(|| {
                parallel_for_dynamic(32, 1, |s, _| {
                    if s % 2 == 0 {
                        panic!("deliberate panic, round {round}");
                    }
                });
            }));
            assert!(result.is_err(), "round {round} must propagate the panic");
            let hits: Vec<AtomicU64> = (0..200).map(|_| AtomicU64::new(0)).collect();
            parallel_for_chunks(200, 3, |s, e| {
                for i in s..e {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "round {round}: coverage must be exact after recovery"
            );
        }
    }

    #[test]
    fn worker_panic_message_survives_to_submitter() {
        // The payload of a lane panic must reach the submitter verbatim;
        // serve-side fault reports turn this message into a LaneFault
        // detail, so a generic "worker thread panicked" stand-in is a
        // regression. Panic on every lane so the panicking lane is a pool
        // worker whenever the pool has one.
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_for_dynamic(64, 1, |s, _| {
                panic!("distinctive lane fault at index {s}");
            });
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("payload must be a string");
        assert!(
            msg.contains("distinctive lane fault at index"),
            "original message must survive, got: {msg}"
        );
        let v = parallel_map(16, 1, |i| i);
        assert!(v.iter().enumerate().all(|(i, x)| *x == i));
    }

    #[test]
    fn scoped_map_preserves_index_order() {
        let v = scoped_map(5, |i| i * 10);
        assert_eq!(v, vec![0, 10, 20, 30, 40]);
        let v1 = scoped_map(1, |i| i + 7);
        assert_eq!(v1, vec![7]);
        let v0: Vec<usize> = scoped_map(0, |i| i);
        assert!(v0.is_empty());
    }

    #[test]
    fn scoped_map_worker_panic_payload_survives() {
        // A worker panic must reach the caller with its ORIGINAL message
        // (LaneFault details depend on it), not thread::scope's generic
        // "a scoped thread panicked" stand-in.
        let result = catch_unwind(AssertUnwindSafe(|| {
            scoped_map(4, |i| {
                if i == 2 {
                    panic!("distinctive shard worker fault at index {i}");
                }
                i
            })
        }));
        let payload = result.expect_err("panic must propagate");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("payload must be a string");
        assert!(
            msg.contains("distinctive shard worker fault at index 2"),
            "original message must survive, got: {msg}"
        );
        // Scoped threads don't touch the pool's health.
        let v = scoped_map(3, |i| i);
        assert_eq!(v, vec![0, 1, 2]);
    }

    #[test]
    fn scoped_map_caller_lane_panic_joins_workers_first() {
        use std::sync::atomic::AtomicUsize;
        let finished = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            scoped_map(4, |i| {
                if i == 0 {
                    panic!("caller lane fault");
                }
                finished.fetch_add(1, Ordering::SeqCst);
            })
        }));
        assert!(result.is_err());
        assert_eq!(finished.load(Ordering::SeqCst), 3, "all workers joined before unwind");
    }

    #[test]
    fn panics_on_multiple_lanes_are_reported_once() {
        // Every lane panicking at once must still produce exactly one
        // propagated panic at the submitter (not an abort from a panic
        // escaping a worker thread), and the pool must survive.
        let result = catch_unwind(AssertUnwindSafe(|| {
            parallel_for_chunks(64, 1, |_, _| panic!("every lane panics"));
        }));
        assert!(result.is_err());
        let v = parallel_map(32, 2, |i| 2 * i);
        assert!(v.iter().enumerate().all(|(i, x)| *x == 2 * i));
    }
}
