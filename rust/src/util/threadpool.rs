//! A small scoped data-parallel helper (no rayon in the offline registry).
//!
//! `parallel_for_chunks` splits an index range into contiguous chunks and
//! runs a closure per chunk on `std::thread::scope` threads. Thread count
//! defaults to available parallelism and is tunable via `RADIO_THREADS`.
//! This is deliberately fork-join (no persistent pool): our hot loops are
//! coarse-grained (whole matrix rows), so spawn overhead is negligible
//! relative to work, and scoped borrows keep the API safe without `Arc`.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use.
pub fn num_threads() -> usize {
    if let Ok(s) = std::env::var("RADIO_THREADS") {
        if let Ok(n) = s.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Run `f(start, end)` over disjoint chunks covering `0..n` in parallel.
/// `f` must be `Sync` (called concurrently with disjoint ranges).
pub fn parallel_for_chunks<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = num_threads();
    if n == 0 {
        return;
    }
    if threads <= 1 || n <= min_chunk {
        f(0, n);
        return;
    }
    let chunks = threads.min(n.div_ceil(min_chunk.max(1)));
    let chunk = n.div_ceil(chunks);
    std::thread::scope(|s| {
        for c in 0..chunks {
            let start = c * chunk;
            let end = ((c + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let fref = &f;
            s.spawn(move || fref(start, end));
        }
    });
}

/// Dynamic work-stealing variant: workers grab `grain`-sized blocks off a
/// shared counter. Better when per-item cost is highly skewed (e.g. GPTQ
/// columns, mixed-depth matvec rows).
pub fn parallel_for_dynamic<F>(n: usize, grain: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = num_threads();
    if n == 0 {
        return;
    }
    if threads <= 1 || n <= grain {
        f(0, n);
        return;
    }
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let fref = &f;
            s.spawn(move || loop {
                let start = next.fetch_add(grain, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                fref(start, (start + grain).min(n));
            });
        }
    });
}

/// Map each index to a value in parallel, preserving order.
pub fn parallel_map<T, F>(n: usize, min_chunk: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_for_chunks(n, min_chunk, |start, end| {
            let p = out_ptr; // copy the Send wrapper into the closure
            for i in start..end {
                // SAFETY: chunks are disjoint, so each index is written once
                // by exactly one thread; the Vec outlives the scope.
                unsafe { *p.0.add(i) = f(i) };
            }
        });
    }
    out
}

struct SendPtr<T>(*mut T);
// Manual impls: `derive` would wrongly require `T: Copy`.
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_for_chunks(1000, 10, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn dynamic_covers_all_indices_once() {
        let hits: Vec<AtomicU64> = (0..777).map(|_| AtomicU64::new(0)).collect();
        parallel_for_dynamic(777, 13, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(500, 7, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn empty_range_ok() {
        parallel_for_chunks(0, 1, |_, _| panic!("should not run"));
        let v: Vec<usize> = parallel_map(0, 1, |i| i);
        assert!(v.is_empty());
    }
}
