//! Micro-benchmark harness (no criterion in the offline registry).
//!
//! Benches (`harness = false` binaries under rust/benches/) use
//! [`Bench::run`] to time closures with warmup, report median / p10 / p90,
//! and print table rows shaped like the paper's tables. A `black_box`
//! shim prevents the optimizer from deleting benchmarked work.

use std::time::{Duration, Instant};

/// Optimizer barrier (stable-Rust `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchStats {
    pub fn median_secs(&self) -> f64 {
        self.median.as_secs_f64()
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} median {:>10.3?}  p10 {:>10.3?}  p90 {:>10.3?}  ({} iters)",
            self.name, self.median, self.p10, self.p90, self.iters
        )
    }
}

/// Timing configuration.
#[derive(Clone, Copy, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    /// Stop once total measured time exceeds this budget.
    pub time_budget: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup_iters: 2,
            min_iters: 5,
            max_iters: 200,
            time_budget: Duration::from_secs(2),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 30,
            time_budget: Duration::from_millis(500),
        }
    }

    /// Time `f`, returning robust statistics.
    pub fn run<F: FnMut()>(&self, name: &str, mut f: F) -> BenchStats {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples: Vec<Duration> = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed() < self.time_budget)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed());
        }
        samples.sort_unstable();
        let n = samples.len();
        let pick = |q: f64| samples[((n - 1) as f64 * q).round() as usize];
        let mean = samples.iter().sum::<Duration>() / n as u32;
        BenchStats {
            name: name.to_string(),
            iters: n,
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            mean,
        }
    }
}

/// Simple fixed-width table printer used by the bench binaries to emit
/// paper-shaped rows.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let ncol = self.headers.len();
        let mut widths = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = h.len();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |sep: &str| {
            widths
                .iter()
                .map(|w| "-".repeat(w + 2))
                .collect::<Vec<_>>()
                .join(sep)
        };
        println!("+{}+", line("+"));
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!(" {:<w$} ", h, w = widths[i]))
            .collect();
        println!("|{}|", hdr.join("|"));
        println!("+{}+", line("+"));
        for row in &self.rows {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect();
            println!("|{}|", cells.join("|"));
        }
        println!("+{}+", line("+"));
    }

    /// Render as a markdown table (for results/*.md reports).
    pub fn to_markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("| ");
        s.push_str(&self.headers.join(" | "));
        s.push_str(" |\n|");
        for _ in &self.headers {
            s.push_str("---|");
        }
        s.push('\n');
        for row in &self.rows {
            s.push_str("| ");
            s.push_str(&row.join(" | "));
            s.push_str(" |\n");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_positive_time() {
        let stats = Bench::quick().run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = black_box(x.wrapping_add(i));
            }
        });
        assert!(stats.median > Duration::ZERO);
        assert!(stats.iters >= 3);
        assert!(stats.p10 <= stats.median && stats.median <= stats.p90);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["model", "ppl"]);
        t.row(vec!["ropt-small".into(), "12.34".into()]);
        let md = t.to_markdown();
        assert!(md.contains("ropt-small"));
        assert!(md.contains("| model | ppl |"));
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
