//! Deterministic pseudo-random number generation and samplers.
//!
//! The offline crate registry carries no `rand` crate, so we implement the
//! generators we need: SplitMix64 (seeding), xoshiro256++ (bulk), and
//! samplers for uniform, Gaussian (Box–Muller), Laplace (inverse CDF),
//! Zipf–Mandelbrot (alias-free CDF inversion) and categorical draws.

/// SplitMix64: used to expand a single `u64` seed into xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality 64-bit generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (SplitMix64-expanded).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Derive an independent stream for worker `i` (e.g. per-thread RNGs).
    pub fn fork(&mut self, i: u64) -> Rng {
        Rng::new(self.next_u64() ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        self.uniform() as f32
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free multiply-shift is fine for our sizes.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Laplace with mean `mu` and standard deviation `s`
    /// (scale b = s / sqrt(2)), by inverse-CDF.
    pub fn laplace(&mut self, mu: f64, s: f64) -> f64 {
        let b = s / std::f64::consts::SQRT_2;
        let u = self.uniform() - 0.5;
        mu - b * u.signum() * (1.0 - 2.0 * u.abs()).ln().max(f64::MIN) // ln(1-2|u|) <= 0
    }

    /// Fill a slice with N(0, std²) f32 samples.
    pub fn fill_gauss(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal(mean as f64, std as f64) as f32;
        }
    }

    /// Fill a slice with Laplace(mu, s) f32 samples.
    pub fn fill_laplace(&mut self, out: &mut [f32], mu: f32, s: f32) {
        for v in out.iter_mut() {
            *v = self.laplace(mu as f64, s as f64) as f32;
        }
    }

    /// Random ±1 vector (Rademacher), used for token-subsampling sketches.
    pub fn fill_sign(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from [0, n) (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Zipf–Mandelbrot sampler over {0, .., n-1}: p(k) ∝ 1/(k + q)^s.
/// Precomputes the CDF; used by the synthetic corpus generator.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64, q: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 0..n {
            acc += 1.0 / (k as f64 + 1.0 + q).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        // Binary search the CDF.
        match self
            .cdf
            .binary_search_by(|p| p.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_runs() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Rng::new(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut rng = Rng::new(3);
        for n in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(rng.below(n) < n);
            }
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.gauss();
            m += x;
            m2 += x * x;
        }
        m /= n as f64;
        m2 /= n as f64;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((m2 - 1.0).abs() < 0.03, "var {m2}");
    }

    #[test]
    fn laplace_moments() {
        let mut rng = Rng::new(13);
        let n = 200_000;
        let (mu, s) = (0.5, 2.0);
        let (mut m, mut m2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.laplace(mu, s);
            m += x;
            m2 += (x - mu) * (x - mu);
        }
        m /= n as f64;
        m2 /= n as f64;
        assert!((m - mu).abs() < 0.05, "mean {m}");
        assert!((m2 - s * s).abs() < 0.2, "var {m2}");
    }

    #[test]
    fn laplace_kurtosis_exceeds_gaussian() {
        // Laplace excess kurtosis = 3; Gaussian = 0. Sanity for the
        // distribution-fitting code downstream.
        let mut rng = Rng::new(17);
        let n = 100_000;
        let mut kurt = |f: &mut dyn FnMut(&mut Rng) -> f64| {
            let xs: Vec<f64> = (0..n).map(|_| f(&mut rng)).collect();
            let m = xs.iter().sum::<f64>() / n as f64;
            let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n as f64;
            xs.iter().map(|x| (x - m).powi(4)).sum::<f64>() / n as f64 / (v * v)
        };
        let kg = kurt(&mut |r| r.gauss());
        let kl = kurt(&mut |r| r.laplace(0.0, 1.0));
        assert!(kg < 3.5, "gaussian kurtosis {kg}");
        assert!(kl > 4.5, "laplace kurtosis {kl}");
    }

    #[test]
    fn zipf_is_monotone_decreasing() {
        let z = Zipf::new(50, 1.1, 2.0);
        let mut rng = Rng::new(5);
        let mut counts = vec![0usize; 50];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[10] > counts[40]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(21);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }
}
