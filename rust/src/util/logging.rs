//! Tiny leveled logger (no tracing/log crates offline).
//!
//! Level selected via `RADIO_LOG` = error|warn|info|debug|trace
//! (default info). Timestamps are seconds since process start.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let l = LEVEL.load(Ordering::Relaxed);
    if l != 255 {
        return l;
    }
    let parsed = match std::env::var("RADIO_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(parsed, Ordering::Relaxed);
    parsed
}

/// Override the log level programmatically (tests, CLI --verbose).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

#[doc(hidden)]
pub fn log(l: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let start = START.get_or_init(Instant::now);
    let t = start.elapsed().as_secs_f64();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{t:9.3}s {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
