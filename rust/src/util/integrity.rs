//! Integrity framing for on-disk containers: per-section CRC32 plus
//! total-length accounting, layered *behind* each container's existing
//! 8-byte magic so legacy (pre-checksum) files remain readable.
//!
//! Checked layout (all integers little-endian):
//!
//! ```text
//! u8[8]  container magic        # RADIOQM2 / RADIOQM3 / RADIOCS1
//! u8[8]  "RADIOCK1"             # integrity marker; absent = legacy file
//! ...    payload sections       # contiguous, exactly tiling the payload
//! # section table, at table_off:
//! u32    n_sections
//! # per section: u8 tag, u64 off (absolute), u64 len, u32 crc32
//! # trailer (final 20 bytes):
//! u64    table_off
//! u32    table_crc              # CRC32 of the section table bytes
//! u8[8]  "RADIOEND"
//! ```
//!
//! The trailing `RADIOEND` magic makes truncation at *any* byte —
//! including exactly at a section boundary — detectable before any
//! payload byte is parsed; the per-section CRCs localize bit flips to a
//! named section. Writers stream: [`SectionWriter`] checksums bytes as
//! they pass through, so `QuantizedModelWriter` never buffers a matrix
//! twice. Readers verify the whole frame up front ([`verify`]) and then
//! hand the body parser a plain byte slice, so every existing parser
//! runs unchanged on the checked payload.

use std::io::{self, Read, Write};

use crate::error::RadioError;

/// Marker written immediately after the container magic of every
/// checked container. A legacy container's body begins here instead;
/// no legacy body can alias it (a `RADIOQM2` matrix record starting
/// with these bytes would need role tag `b'O' = 0x4F`, which is
/// rejected, and a `RADIOCS1` body would need a ~1.2 GB config header).
pub const CHECK_MAGIC: &[u8; 8] = b"RADIOCK1";
/// Final 8 bytes of every checked container.
pub const END_MAGIC: &[u8; 8] = b"RADIOEND";
/// Container magic (8 bytes) plus [`CHECK_MAGIC`] (8 bytes).
pub const HEADER_LEN: usize = 16;
/// `table_off: u64` + `table_crc: u32` + [`END_MAGIC`].
const TRAILER_LEN: usize = 8 + 4 + 8;
/// Bytes per section-table record: tag u8, off u64, len u64, crc u32.
const RECORD_LEN: usize = 1 + 8 + 8 + 4;

/// Section tag: the packed-matrix record stream of a `RADIOQM2`.
pub const SEC_MATRICES: u8 = 1;
/// Section tag: a side-parameter block.
pub const SEC_SIDE: u8 = 2;
/// Section tag: a container's fixed-size scalar header.
pub const SEC_HEADER: u8 = 3;
/// Section tag: one rate point of a `RADIOQM3` ladder.
pub const SEC_POINT: u8 = 4;
/// Section tag: the per-matrix statistics block of a `RADIOCS1`.
pub const SEC_MATS: u8 = 5;
/// Section tag: the per-matrix activation-moment block of a `RADIOCS1`
/// (absent in pre-activation-quantization artifacts).
pub const SEC_ACTS: u8 = 6;
/// Section tag: the activation-quantization spec of a `RADIOQM2`
/// (absent in weight-only containers).
pub const SEC_ACTQ: u8 = 7;

/// Human-readable name of a section tag, for error messages.
pub fn section_name(tag: u8) -> &'static str {
    match tag {
        SEC_MATRICES => "matrix stream",
        SEC_SIDE => "side parameters",
        SEC_HEADER => "container header",
        SEC_POINT => "rate point",
        SEC_MATS => "calibration matrices",
        SEC_ACTS => "calibration activations",
        SEC_ACTQ => "activation quant spec",
        _ => "unknown section",
    }
}

/// Fill `buf` from `f`, or report a clean end-of-stream. `Ok(false)`
/// when EOF arrives before the first byte — the probe for *optional
/// trailing sections* (a container written before the section existed
/// simply ends here). A partial fill is an error like any truncation.
pub fn read_or_eof<R: Read>(f: &mut R, buf: &mut [u8]) -> io::Result<bool> {
    let mut filled = 0usize;
    while filled < buf.len() {
        let n = f.read(&mut buf[filled..])?;
        if n == 0 {
            if filled == 0 {
                return Ok(false);
            }
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "truncated optional trailing section",
            ));
        }
        filled += n;
    }
    Ok(true)
}

// ---------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected, polynomial 0xEDB88320)
// ---------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// Incremental CRC32 (IEEE), for checksumming streamed writes.
#[derive(Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    /// Start a fresh checksum.
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Checksum of everything folded in so far, without consuming the
    /// accumulator — the journal's running-stream checkpoint value.
    pub fn peek(&self) -> u32 {
        !self.state
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Finish and return the checksum value.
    pub fn finalize(&self) -> u32 {
        !self.state
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

/// One-shot CRC32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finalize()
}

// ---------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------

/// A `Write` adapter that checksums declared sections as bytes stream
/// through, then appends the section table and trailer on
/// [`SectionWriter::finish`].
///
/// The caller writes the 16-byte header (container magic +
/// [`CHECK_MAGIC`]) to the underlying writer first, then wraps it and
/// brackets every payload byte between [`begin`](Self::begin) /
/// [`end`](Self::end) calls. Sections must be contiguous — the first
/// begins at offset 16 and each subsequent one starts where the
/// previous ended — which holds by construction as long as every byte
/// is written inside a section.
pub struct SectionWriter<W: Write> {
    inner: W,
    /// Absolute file offset of the next byte (starts after the header).
    pos: u64,
    done: Vec<(u8, u64, u64, u32)>,
    open: Option<(u8, u64, Crc32)>,
}

impl<W: Write> SectionWriter<W> {
    /// Wrap `inner`, which must already have the 16-byte checked header
    /// written to it.
    pub fn new(inner: W) -> Self {
        SectionWriter { inner, pos: HEADER_LEN as u64, done: Vec::new(), open: None }
    }

    /// Reconstruct a writer whose *first* section is mid-write, for
    /// journaled resume after a crash: `inner` is positioned at absolute
    /// offset `pos`, and `crc` has already been fed the section bytes
    /// `[HEADER_LEN, pos)` (the caller re-reads and re-checksums the
    /// surviving staging file to produce it).
    pub fn resume_open(inner: W, tag: u8, pos: u64, crc: Crc32) -> Self {
        assert!(pos >= HEADER_LEN as u64, "resume position inside the header");
        SectionWriter { inner, pos, done: Vec::new(), open: Some((tag, HEADER_LEN as u64, crc)) }
    }

    /// Absolute file offset of the next byte to be written.
    pub fn position(&self) -> u64 {
        self.pos
    }

    /// Shared access to the wrapped writer (for durability syncs —
    /// checksummed positions are tracked here, but fsync lives below).
    pub fn get_ref(&self) -> &W {
        &self.inner
    }

    /// Running CRC32 of the currently open section — what the section's
    /// table entry would record if it were closed at this byte. Panics
    /// if no section is open.
    pub fn open_section_crc(&self) -> u32 {
        self.open.as_ref().expect("no open section").2.peek()
    }

    /// Open a new section with the given tag. Panics if one is open.
    pub fn begin(&mut self, tag: u8) {
        assert!(self.open.is_none(), "previous section not ended");
        self.open = Some((tag, self.pos, Crc32::new()));
    }

    /// Close the open section, recording its extent and checksum.
    pub fn end(&mut self) {
        let (tag, off, crc) = self.open.take().expect("no open section");
        self.done.push((tag, off, self.pos - off, crc.finalize()));
    }

    /// Write the section table and trailer, flush, and return the
    /// underlying writer.
    pub fn finish(mut self) -> io::Result<W> {
        assert!(self.open.is_none(), "section still open at finish");
        #[cfg(debug_assertions)]
        {
            let mut cursor = HEADER_LEN as u64;
            for s in &self.done {
                debug_assert_eq!(s.1, cursor, "sections must tile the payload contiguously");
                cursor += s.2;
            }
        }
        let table_off = self.pos;
        let mut table = Vec::with_capacity(4 + self.done.len() * RECORD_LEN);
        table.extend_from_slice(&(self.done.len() as u32).to_le_bytes());
        for &(tag, off, len, crc) in &self.done {
            table.push(tag);
            table.extend_from_slice(&off.to_le_bytes());
            table.extend_from_slice(&len.to_le_bytes());
            table.extend_from_slice(&crc.to_le_bytes());
        }
        self.inner.write_all(&table)?;
        self.inner.write_all(&table_off.to_le_bytes())?;
        self.inner.write_all(&crc32(&table).to_le_bytes())?;
        self.inner.write_all(END_MAGIC)?;
        self.inner.flush()?;
        Ok(self.inner)
    }
}

impl<W: Write> Write for SectionWriter<W> {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        let n = self.inner.write(buf)?;
        self.pos += n as u64;
        if let Some((_, _, crc)) = self.open.as_mut() {
            crc.update(&buf[..n]);
        }
        Ok(n)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.inner.flush()
    }
}

// ---------------------------------------------------------------------
// Reader
// ---------------------------------------------------------------------

/// Description of one verified section, as recorded in the table.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SectionInfo {
    /// Section tag (`SEC_*`).
    pub tag: u8,
    /// Absolute byte offset of the section's first byte.
    pub off: u64,
    /// Section length in bytes.
    pub len: u64,
    /// CRC32 of the section bytes.
    pub crc: u32,
}

/// A fully verified checked container.
pub struct CheckedContainer<'a> {
    /// The payload bytes (everything between the 16-byte header and the
    /// section table), ready for the format's body parser.
    pub payload: &'a [u8],
    /// The verified section table, in file order.
    pub sections: Vec<SectionInfo>,
}

fn u32_at(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap())
}

fn u64_at(bytes: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(bytes[off..off + 8].try_into().unwrap())
}

fn corrupt(section: &str, detail: impl Into<String>) -> RadioError {
    RadioError::Corrupt { section: section.to_string(), detail: detail.into() }
}

/// Verify the integrity frame of a container image (magic included).
///
/// Returns `Ok(None)` for legacy containers (no [`CHECK_MAGIC`] after
/// the format magic) — the caller should parse the body from offset 8
/// as before. Returns `Ok(Some(_))` once the trailer, section table,
/// payload tiling, and every per-section CRC have all been verified.
/// Any truncation or bit flip yields a typed [`RadioError`].
pub fn verify(bytes: &[u8]) -> Result<Option<CheckedContainer<'_>>, RadioError> {
    if bytes.len() < HEADER_LEN || &bytes[8..HEADER_LEN] != CHECK_MAGIC {
        return Ok(None);
    }
    // Trailer: the file must end in RADIOEND with room for the table.
    if bytes.len() < HEADER_LEN + 4 + TRAILER_LEN {
        return Err(RadioError::Truncated { section: "integrity trailer".into() });
    }
    if &bytes[bytes.len() - END_MAGIC.len()..] != END_MAGIC {
        return Err(RadioError::Truncated { section: "integrity trailer".into() });
    }
    let trailer = bytes.len() - TRAILER_LEN;
    let table_off = u64_at(bytes, trailer);
    let stored_table_crc = u32_at(bytes, trailer + 8);
    if table_off < HEADER_LEN as u64 || table_off + 4 > trailer as u64 {
        return Err(corrupt("integrity trailer", "section table offset out of range"));
    }
    let table_off = table_off as usize;
    let table = &bytes[table_off..trailer];
    let got_table_crc = crc32(table);
    if got_table_crc != stored_table_crc {
        return Err(RadioError::ChecksumMismatch {
            section: "section table".into(),
            expected: stored_table_crc,
            got: got_table_crc,
        });
    }
    let n = u32_at(table, 0) as usize;
    if table.len() != 4 + n * RECORD_LEN {
        return Err(corrupt("section table", "table length does not match entry count"));
    }
    let mut sections = Vec::with_capacity(n);
    for i in 0..n {
        let rec = 4 + i * RECORD_LEN;
        sections.push(SectionInfo {
            tag: table[rec],
            off: u64_at(table, rec + 1),
            len: u64_at(table, rec + 9),
            crc: u32_at(table, rec + 17),
        });
    }
    // Sections must exactly tile [HEADER_LEN, table_off).
    let mut cursor = HEADER_LEN as u64;
    for s in &sections {
        if s.off != cursor {
            return Err(corrupt("section table", "sections do not tile the payload"));
        }
        cursor = cursor
            .checked_add(s.len)
            .ok_or_else(|| corrupt("section table", "section length overflows"))?;
    }
    if cursor != table_off as u64 {
        return Err(corrupt("section table", "sections do not cover the payload"));
    }
    for s in &sections {
        let body = &bytes[s.off as usize..(s.off + s.len) as usize];
        let got = crc32(body);
        if got != s.crc {
            return Err(RadioError::ChecksumMismatch {
                section: section_name(s.tag).to_string(),
                expected: s.crc,
                got,
            });
        }
    }
    Ok(Some(CheckedContainer { payload: &bytes[HEADER_LEN..table_off], sections }))
}

// ---------------------------------------------------------------------
// Mapped (lazily verified) reader
// ---------------------------------------------------------------------

/// A checked container opened for *lazy* verification: the section
/// table, trailer, and payload tiling are verified eagerly on
/// [`open`](Self::open) (without touching a single payload byte), and
/// each section's CRC32 is verified on first read.
///
/// This is the serving-side counterpart of [`verify`]: a multi-GB
/// `.radio` container costs one header, one trailer, and one table read
/// to open, and pays per-section verification only for the rate points
/// actually served. Reads go through positioned I/O (`pread`) on the
/// kept-open file handle — the std-only stand-in for a read-only mmap —
/// so no resident copy of unread sections ever exists.
pub struct MappedContainer {
    file: std::fs::File,
    /// The container's leading 8-byte format magic, for dispatch.
    pub magic: [u8; 8],
    /// The verified section table, in file order.
    pub sections: Vec<SectionInfo>,
}

impl MappedContainer {
    /// Open `path` and eagerly verify its integrity frame (trailer,
    /// table CRC, payload tiling) without reading any payload bytes.
    ///
    /// Returns `Ok(None)` for legacy containers (no [`CHECK_MAGIC`]) —
    /// the caller should fall back to a resident load.
    pub fn open(path: &std::path::Path) -> Result<Option<MappedContainer>, RadioError> {
        use std::os::unix::fs::FileExt;
        let file = std::fs::File::open(path)?;
        let file_len = file.metadata()?.len();
        if file_len < HEADER_LEN as u64 {
            return Ok(None);
        }
        let mut header = [0u8; HEADER_LEN];
        file.read_exact_at(&mut header, 0)?;
        if &header[8..HEADER_LEN] != CHECK_MAGIC {
            return Ok(None);
        }
        if file_len < (HEADER_LEN + 4 + TRAILER_LEN) as u64 {
            return Err(RadioError::Truncated { section: "integrity trailer".into() });
        }
        let mut trailer = [0u8; TRAILER_LEN];
        let trailer_off = file_len - TRAILER_LEN as u64;
        file.read_exact_at(&mut trailer, trailer_off)?;
        if &trailer[12..] != END_MAGIC {
            return Err(RadioError::Truncated { section: "integrity trailer".into() });
        }
        let table_off = u64_at(&trailer, 0);
        let stored_table_crc = u32_at(&trailer, 8);
        if table_off < HEADER_LEN as u64 || table_off + 4 > trailer_off {
            return Err(corrupt("integrity trailer", "section table offset out of range"));
        }
        let mut table = vec![0u8; (trailer_off - table_off) as usize];
        file.read_exact_at(&mut table, table_off)?;
        let got_table_crc = crc32(&table);
        if got_table_crc != stored_table_crc {
            return Err(RadioError::ChecksumMismatch {
                section: "section table".into(),
                expected: stored_table_crc,
                got: got_table_crc,
            });
        }
        let n = u32_at(&table, 0) as usize;
        if table.len() != 4 + n * RECORD_LEN {
            return Err(corrupt("section table", "table length does not match entry count"));
        }
        let mut sections = Vec::with_capacity(n);
        for i in 0..n {
            let rec = 4 + i * RECORD_LEN;
            sections.push(SectionInfo {
                tag: table[rec],
                off: u64_at(&table, rec + 1),
                len: u64_at(&table, rec + 9),
                crc: u32_at(&table, rec + 17),
            });
        }
        let mut cursor = HEADER_LEN as u64;
        for s in &sections {
            if s.off != cursor {
                return Err(corrupt("section table", "sections do not tile the payload"));
            }
            cursor = cursor
                .checked_add(s.len)
                .ok_or_else(|| corrupt("section table", "section length overflows"))?;
        }
        if cursor != table_off {
            return Err(corrupt("section table", "sections do not cover the payload"));
        }
        let mut magic = [0u8; 8];
        magic.copy_from_slice(&header[..8]);
        Ok(Some(MappedContainer { file, magic, sections }))
    }

    /// Read and CRC-verify section `idx` (an index into
    /// [`sections`](Self::sections)). This is the lazy half of the
    /// verification contract: a bit flip in a section surfaces as a
    /// typed [`RadioError::ChecksumMismatch`] at first touch, and
    /// sections never touched are never read.
    pub fn read_section(&self, idx: usize) -> Result<Vec<u8>, RadioError> {
        use std::os::unix::fs::FileExt;
        let s = self.sections[idx];
        let mut body = vec![0u8; s.len as usize];
        self.file
            .read_exact_at(&mut body, s.off)
            .map_err(|e| RadioError::from(e).in_section(section_name(s.tag)))?;
        let got = crc32(&body);
        if got != s.crc {
            return Err(RadioError::ChecksumMismatch {
                section: section_name(s.tag).to_string(),
                expected: s.crc,
                got,
            });
        }
        Ok(body)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a checked container with the given magic and sections.
    fn build(magic: &[u8; 8], sections: &[(u8, &[u8])]) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(magic);
        buf.extend_from_slice(CHECK_MAGIC);
        let mut w = SectionWriter::new(buf);
        for &(tag, body) in sections {
            w.begin(tag);
            w.write_all(body).unwrap();
            w.end();
        }
        w.finish().unwrap()
    }

    #[test]
    fn crc32_matches_known_vector() {
        // The canonical IEEE CRC32 check value.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn roundtrip_verifies_and_recovers_payload() {
        let file = build(b"TESTMAG1", &[(SEC_HEADER, b"hdr"), (SEC_MATS, b"body bytes")]);
        let checked = verify(&file).unwrap().expect("marker present");
        assert_eq!(checked.payload, b"hdrbody bytes");
        assert_eq!(checked.sections.len(), 2);
        assert_eq!(checked.sections[0].tag, SEC_HEADER);
        assert_eq!(checked.sections[0].off, 16);
        assert_eq!(checked.sections[0].len, 3);
        assert_eq!(checked.sections[1].off, 19);
    }

    #[test]
    fn empty_sections_are_legal() {
        let file = build(b"TESTMAG1", &[(SEC_MATRICES, b"")]);
        let checked = verify(&file).unwrap().unwrap();
        assert_eq!(checked.payload, b"");
    }

    #[test]
    fn legacy_container_passes_through() {
        assert!(verify(b"RADIOQM2rest-of-a-legacy-body").unwrap().is_none());
        assert!(verify(b"short").unwrap().is_none());
    }

    #[test]
    fn every_truncation_is_rejected() {
        let file = build(b"TESTMAG1", &[(SEC_HEADER, b"hdr"), (SEC_MATS, b"body bytes")]);
        for cut in HEADER_LEN..file.len() {
            let err = verify(&file[..cut]).unwrap_err();
            assert!(
                matches!(
                    err,
                    RadioError::Truncated { .. } | RadioError::Corrupt { .. }
                ),
                "cut at {cut} gave {err:?}"
            );
        }
    }

    #[test]
    fn every_bit_flip_is_rejected() {
        let file = build(b"TESTMAG1", &[(SEC_HEADER, b"hdr"), (SEC_MATS, b"body bytes")]);
        // Flip one bit in every byte after the 16-byte header; each
        // must surface as a typed integrity error (flips inside the
        // header change the dispatch magic / downgrade to legacy, which
        // the *format* loaders reject — covered in their tests).
        for pos in HEADER_LEN..file.len() {
            let mut bad = file.clone();
            bad[pos] ^= 0x40;
            let r = verify(&bad);
            assert!(r.is_err(), "flip at {pos} was accepted: {:?}", r.as_ref().err());
        }
    }

    #[test]
    fn resumed_writer_matches_uninterrupted_writer() {
        // Write half a section, "crash", re-checksum the surviving
        // prefix, resume mid-section, and finish: the bytes must be
        // identical to a single uninterrupted write.
        let whole = build(b"TESTMAG1", &[(SEC_MATRICES, b"abcdefghij"), (SEC_SIDE, b"side")]);

        let mut buf = Vec::new();
        buf.extend_from_slice(b"TESTMAG1");
        buf.extend_from_slice(CHECK_MAGIC);
        let mut w = SectionWriter::new(buf);
        w.begin(SEC_MATRICES);
        w.write_all(b"abcde").unwrap();
        let pos = w.position();
        let crc_at_crash = w.open_section_crc();
        let survivor = w.inner; // simulated crash: keep the raw bytes

        let mut crc = Crc32::new();
        crc.update(&survivor[HEADER_LEN..pos as usize]);
        assert_eq!(crc.peek(), crc_at_crash);
        let mut w = SectionWriter::resume_open(survivor, SEC_MATRICES, pos, crc);
        w.write_all(b"fghij").unwrap();
        w.end();
        w.begin(SEC_SIDE);
        w.write_all(b"side").unwrap();
        w.end();
        let resumed = w.finish().unwrap();
        assert_eq!(whole, resumed);
    }

    #[test]
    fn mapped_open_verifies_frame_eagerly_and_payload_lazily() {
        let file = build(b"TESTMAG1", &[(SEC_HEADER, b"hdr"), (SEC_MATS, b"body bytes")]);
        let dir = std::env::temp_dir().join(format!("radio_integrity_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mapped.bin");
        std::fs::write(&path, &file).unwrap();

        let mc = MappedContainer::open(&path).unwrap().expect("checked container");
        assert_eq!(&mc.magic, b"TESTMAG1");
        assert_eq!(mc.sections.len(), 2);
        assert_eq!(mc.read_section(0).unwrap(), b"hdr");
        assert_eq!(mc.read_section(1).unwrap(), b"body bytes");

        // A payload bit flip passes open() (lazy) but fails first touch.
        let mut bad = file.clone();
        let body_off = mc.sections[1].off as usize;
        bad[body_off] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let mc = MappedContainer::open(&path).unwrap().expect("frame still intact");
        assert_eq!(mc.read_section(0).unwrap(), b"hdr");
        assert!(matches!(
            mc.read_section(1).unwrap_err(),
            RadioError::ChecksumMismatch { .. }
        ));

        // Truncations are caught eagerly, as in the resident verifier.
        std::fs::write(&path, &file[..file.len() - 3]).unwrap();
        assert!(MappedContainer::open(&path).is_err());

        // Legacy files fall through untouched.
        std::fs::write(&path, b"RADIOQM2legacy-body").unwrap();
        assert!(MappedContainer::open(&path).unwrap().is_none());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn writer_checksums_streamed_writes_incrementally() {
        // Many small writes must checksum identically to one big write.
        let one = build(b"TESTMAG1", &[(SEC_MATS, b"abcdefghij")]);
        let mut buf = Vec::new();
        buf.extend_from_slice(b"TESTMAG1");
        buf.extend_from_slice(CHECK_MAGIC);
        let mut w = SectionWriter::new(buf);
        w.begin(SEC_MATS);
        for chunk in [b"abc".as_slice(), b"defgh", b"ij"] {
            w.write_all(chunk).unwrap();
        }
        w.end();
        let many = w.finish().unwrap();
        assert_eq!(one, many);
    }
}
