//! Crash-safe file replacement: stage every byte into `<dest>.tmp`,
//! fsync, then rename over the destination.
//!
//! The rename is the commit point. Until [`AtomicFile::commit`] runs,
//! the destination path either does not exist or still holds the
//! previous, fully intact artifact — a crash mid-write can only ever
//! leave a stale `.tmp` beside it, never a torn final file. Commit
//! order is the classic three-step protocol: `fsync(tmp)` so the bytes
//! are durable before they become visible, `rename(tmp, dest)` which
//! POSIX guarantees is atomic within a filesystem, then `fsync(parent
//! dir)` so the directory entry itself survives power loss.
//!
//! A dropped (un-committed) `AtomicFile` deliberately leaves its `.tmp`
//! on disk: the journaled pack resume path
//! ([`crate::coordinator::Radio::pack_streaming`]) reopens exactly that
//! partial staging file and continues from the last durable checkpoint.
//! Callers that want no residue simply remove [`tmp_path`] themselves.

use std::fs::{self, File, OpenOptions};
use std::io::{self, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::util::failpoint;

/// Staging-path convention: `<dest>.tmp` (extension appended, not
/// replaced, so `model.radio` stages as `model.radio.tmp`).
pub fn tmp_path(dest: &Path) -> PathBuf {
    let mut os = dest.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// A file being written under the atomic-replace protocol. Implements
/// [`Write`]; call [`commit`](Self::commit) to publish, or drop to
/// abandon (the staging file is left for inspection / resume).
pub struct AtomicFile {
    file: File,
    dest: PathBuf,
    tmp: PathBuf,
}

impl AtomicFile {
    /// Begin staging a replacement for `dest`. Truncates any stale
    /// staging file from a previous crashed attempt.
    pub fn create(dest: &Path) -> io::Result<AtomicFile> {
        let tmp = tmp_path(dest);
        let file = File::create(&tmp)?;
        Ok(AtomicFile { file, dest: dest.to_path_buf(), tmp })
    }

    /// Reopen an existing staging file for `dest` to continue a crashed
    /// write: truncate it to `len` (discarding any bytes past the last
    /// durable checkpoint) and position the cursor at the end.
    pub fn resume(dest: &Path, len: u64) -> io::Result<AtomicFile> {
        let tmp = tmp_path(dest);
        let mut file = OpenOptions::new().read(true).write(true).open(&tmp)?;
        file.set_len(len)?;
        file.seek(SeekFrom::Start(len))?;
        Ok(AtomicFile { file, dest: dest.to_path_buf(), tmp })
    }

    /// Flush staged bytes to stable storage without committing — the
    /// durability barrier between a checkpoint's container bytes and
    /// its journal entry.
    pub fn sync_data(&self) -> io::Result<()> {
        self.file.sync_data()
    }

    /// Publish: fsync the staging file, rename it over the destination,
    /// and fsync the parent directory. After this returns, `dest` holds
    /// the complete new artifact; before it, `dest` is untouched.
    pub fn commit(self) -> io::Result<()> {
        failpoint::fire("atomic_io::commit", 0);
        self.file.sync_all()?;
        fs::rename(&self.tmp, &self.dest)?;
        // Durably record the rename in the directory itself. A parent
        // of "" means dest is relative to the cwd.
        let parent = self.dest.parent().filter(|p| !p.as_os_str().is_empty());
        if let Some(dir) = parent {
            File::open(dir)?.sync_all()?;
        }
        Ok(())
    }
}

impl Write for AtomicFile {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.file.write(buf)
    }

    fn flush(&mut self) -> io::Result<()> {
        self.file.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    fn tmp_dir() -> PathBuf {
        let d = std::env::temp_dir().join(format!("radio_atomic_io_{}", std::process::id()));
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn commit_replaces_destination_atomically() {
        let dest = tmp_dir().join("commit.bin");
        fs::write(&dest, b"old artifact").unwrap();
        let mut af = AtomicFile::create(&dest).unwrap();
        af.write_all(b"new artifact").unwrap();
        // Not yet committed: destination still holds the old bytes.
        assert_eq!(fs::read(&dest).unwrap(), b"old artifact");
        af.commit().unwrap();
        assert_eq!(fs::read(&dest).unwrap(), b"new artifact");
        assert!(!tmp_path(&dest).exists(), "staging file consumed by rename");
        fs::remove_file(&dest).unwrap();
    }

    #[test]
    fn abandoned_write_leaves_destination_intact_and_tmp_for_resume() {
        let dest = tmp_dir().join("abandon.bin");
        fs::write(&dest, b"previous").unwrap();
        {
            let mut af = AtomicFile::create(&dest).unwrap();
            af.write_all(b"half-writ").unwrap();
            // Dropped without commit: simulated crash.
        }
        assert_eq!(fs::read(&dest).unwrap(), b"previous");
        assert_eq!(fs::read(tmp_path(&dest)).unwrap(), b"half-writ");
        // Resume truncates to the requested checkpoint and appends.
        let mut af = AtomicFile::resume(&dest, 4).unwrap();
        af.write_all(b"-resumed").unwrap();
        af.commit().unwrap();
        assert_eq!(fs::read(&dest).unwrap(), b"half-resumed");
        fs::remove_file(&dest).unwrap();
    }

    #[test]
    fn crash_at_commit_failpoint_never_clobbers_destination() {
        let dest = tmp_dir().join("fp.bin");
        fs::write(&dest, b"survivor").unwrap();
        let _s = failpoint::scenario();
        failpoint::arm("atomic_io::commit", 0, 1);
        let r = catch_unwind(AssertUnwindSafe(|| {
            let mut af = AtomicFile::create(&dest).unwrap();
            af.write_all(b"doomed").unwrap();
            af.commit().unwrap();
        }));
        assert!(r.is_err(), "armed commit failpoint must fire");
        assert_eq!(fs::read(&dest).unwrap(), b"survivor");
        fs::remove_file(&dest).unwrap();
        let _ = fs::remove_file(tmp_path(&dest));
    }
}
