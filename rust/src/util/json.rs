//! Minimal JSON value, writer and parser (no serde in the offline registry).
//!
//! Supports the subset we need for configs, reports and artifact metadata:
//! objects, arrays, strings, f64 numbers, booleans, null. Parsing is strict
//! enough for round-tripping our own output plus `model_config.json`
//! emitted by the python AOT step.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    item.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other, self.pos)),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through).
                    let start = self.pos;
                    while !matches!(self.peek(), None | Some(b'"' | b'\\')) {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] (found {other:?})")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} (found {other:?})")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let v = Json::obj(vec![
            ("name", Json::str("ropt-small")),
            ("layers", Json::num(4)),
            ("lr", Json::num(0.001)),
            ("tags", Json::arr([Json::str("a"), Json::str("b")])),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
        ]);
        let s = v.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![("a", Json::arr([Json::num(1), Json::num(2)]))]);
        let back = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parses_external_style() {
        let s = r#"{"model": "tiny", "dims": [128, 256], "eps": 1e-5, "neg": -2.5}"#;
        let v = Json::parse(s).unwrap();
        assert_eq!(v.get("model").unwrap().as_str(), Some("tiny"));
        assert_eq!(v.get("dims").unwrap().as_arr().unwrap().len(), 2);
        assert!((v.get("eps").unwrap().as_f64().unwrap() - 1e-5).abs() < 1e-12);
        assert_eq!(v.get("neg").unwrap().as_f64(), Some(-2.5));
    }

    #[test]
    fn string_escapes() {
        let v = Json::str("line\n\"quoted\"\t\\slash");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} extra").is_err());
    }
}
