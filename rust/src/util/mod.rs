//! Self-built substrates: the offline crate registry only carries the
//! `xla` crate's dependency closure, so the pieces a production system
//! would normally pull from crates.io (PRNG, JSON, CLI, thread pool,
//! logging, bench harness, property testing) live here.

pub mod atomic_io;
pub mod bench;
pub mod check;
pub mod cli;
pub mod failpoint;
pub mod integrity;
pub mod json;
pub mod logging;
pub mod rng;
pub mod threadpool;
