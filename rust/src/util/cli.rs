//! Minimal CLI argument parser (no clap in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.
//! Subcommand dispatch is done by the binary itself.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let raw: Vec<String> = iter.into_iter().collect();
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    out.options
                        .insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {s:?}")))
            .unwrap_or(default)
    }

    /// First positional arg = subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed() {
        // Note: bare flags take the following token as a value unless it is
        // another option, so boolean flags go last or use `--flag=`.
        let a = parse(&[
            "quantize", "file.bin", "--bits", "3.0", "--group=256", "--verbose",
        ]);
        assert_eq!(a.subcommand(), Some("quantize"));
        assert_eq!(a.get_f64("bits", 4.0), 3.0);
        assert_eq!(a.get_usize("group", 0), 256);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional[1], "file.bin");
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["eval"]);
        assert_eq!(a.get_usize("steps", 64), 64);
        assert_eq!(a.get_or("model", "ropt-small"), "ropt-small");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["x", "--fast"]);
        assert!(a.flag("fast"));
    }
}
