//! Deterministic fault injection for the serving stack.
//!
//! A *failpoint* is a named site in production code (`fire("name",
//! tag)`) that does nothing unless a test has armed it. Tests arm a
//! site through a [`Scenario`] guard: `arm(name, tag, after)` makes the
//! `after`-th and every later hit of `(name, tag)` panic, which is how
//! the fault-injection suite kills a specific serving lane mid-decode
//! or interrupts a KV rollback between stores.
//!
//! Design constraints, in order:
//!
//! - **Zero cost when disabled.** The hot path is one relaxed atomic
//!   load of a global flag; the registry lock is only touched while a
//!   [`Scenario`] is alive. No site is ever compiled out, so release
//!   and test builds exercise identical code paths.
//! - **Deterministic.** Hit counts are keyed by `(site, tag)` and every
//!   site in this codebase fires from the scheduler thread, so the
//!   N-th hit is the same program point on every run.
//! - **Isolated.** [`scenario`] serializes failpoint tests behind a
//!   global mutex and clears all armed points when the guard drops
//!   (including on panic), so scenarios cannot leak into each other or
//!   into unrelated tests.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

/// Master switch: `fire` is a single relaxed load of this flag unless a
/// [`Scenario`] is alive.
static ENABLED: AtomicBool = AtomicBool::new(false);

struct Armed {
    /// Panic on the `after`-th hit (1-based) and on every hit after it,
    /// so a lane that re-runs solo after a batched fault faults again.
    after: usize,
    hits: usize,
}

type Registry = Mutex<HashMap<(String, u64), Armed>>;

fn registry() -> &'static Registry {
    static REG: OnceLock<Registry> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Serializes fault-injection tests: one scenario at a time.
fn scenario_lock() -> &'static Mutex<()> {
    static LOCK: Mutex<()> = Mutex::new(());
    &LOCK
}

/// RAII guard for one fault-injection scenario. While alive, failpoints
/// armed via [`arm`] are live; on drop (normal or panicking) every
/// armed point is cleared and injection is disabled again.
pub struct Scenario {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for Scenario {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
        registry().lock().unwrap_or_else(|p| p.into_inner()).clear();
    }
}

/// Begin a fault-injection scenario. Blocks until any other scenario
/// (possibly in another test thread) has finished, then enables the
/// global failpoint switch. Arm sites with [`arm`] after calling this.
pub fn scenario() -> Scenario {
    let serial = scenario_lock().lock().unwrap_or_else(|p| p.into_inner());
    registry().lock().unwrap_or_else(|p| p.into_inner()).clear();
    ENABLED.store(true, Ordering::SeqCst);
    Scenario { _serial: serial }
}

/// Arm the failpoint `name` for `tag`: the `after`-th hit (1-based) and
/// every subsequent hit of `fire(name, tag)` panic. Requires a live
/// [`Scenario`]; untagged sites fire with tag 0.
pub fn arm(name: &str, tag: u64, after: usize) {
    assert!(after >= 1, "failpoint trigger counts are 1-based");
    assert!(
        ENABLED.load(Ordering::SeqCst),
        "failpoint::arm called outside a failpoint::scenario()"
    );
    registry()
        .lock()
        .unwrap_or_else(|p| p.into_inner())
        .insert((name.to_string(), tag), Armed { after, hits: 0 });
}

/// A failpoint site. Free when no [`Scenario`] is alive (one relaxed
/// atomic load); under an armed scenario, panics once the hit count for
/// `(name, tag)` reaches the armed threshold.
#[inline]
pub fn fire(name: &str, tag: u64) {
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    fire_slow(name, tag);
}

#[cold]
fn fire_slow(name: &str, tag: u64) {
    let should_panic = {
        let mut reg = registry().lock().unwrap_or_else(|p| p.into_inner());
        match reg.get_mut(&(name.to_string(), tag)) {
            Some(armed) => {
                armed.hits += 1;
                armed.hits >= armed.after
            }
            None => false,
        }
    };
    if should_panic {
        panic!("failpoint '{name}' fired (tag {tag})");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn disabled_sites_are_inert() {
        // No scenario alive: firing any name/tag is a no-op.
        fire("nonexistent", 0);
        fire("nonexistent", 42);
    }

    #[test]
    fn fires_on_nth_hit_and_every_hit_after() {
        let _s = scenario();
        arm("test::nth", 7, 3);
        fire("test::nth", 7);
        fire("test::nth", 7);
        let r = catch_unwind(AssertUnwindSafe(|| fire("test::nth", 7)));
        assert!(r.is_err(), "third hit must panic");
        let r = catch_unwind(AssertUnwindSafe(|| fire("test::nth", 7)));
        assert!(r.is_err(), "hits after the threshold keep panicking");
        // Different tag at the same site is independent.
        fire("test::nth", 8);
    }

    #[test]
    fn scenario_drop_clears_armed_points() {
        {
            let _s = scenario();
            arm("test::cleared", 0, 1);
        }
        fire("test::cleared", 0); // must not panic: scenario ended
    }
}
