//! The dual-ascent bit-depth allocator (paper Eq. 6 and Figure 1).
//!
//! Given per-group rate–distortion states `(P_n, G_n², S_n²)` and a target
//! average bit rate R, alternately update
//!
//! ```text
//! B_n ← clamp(½·log2(2 ln2 · G_n²S_n² / V), 0, B_max)
//! V   ← V + β(Σ P_n B_n − (Σ P_n)·R)
//! ```
//!
//! until the rate constraint is met (tolerance 1e-6 bit, β=2 as in the
//! paper). A bisection fallback guards pathological β choices. Integer
//! assignments for the actual quantizer are produced by rounding plus a
//! greedy marginal-distortion fix-up that hits the bit budget *exactly*
//! (the paper's "Radio (3.0000 bits)" rows).

use crate::stats::distortion::{self, GroupRd};

/// Solver knobs for the dual-ascent allocation.
#[derive(Clone, Copy, Debug)]
pub struct DualAscentConfig {
    /// Maximum bits per group.
    pub bmax: f64,
    /// Dual step size β (paper: 2; normalized internally by total weights).
    pub beta: f64,
    /// Rate-convergence tolerance (average bits).
    pub tol_bits: f64,
    /// Iteration cap before the bisection fallback gives up.
    pub max_iters: usize,
}

impl Default for DualAscentConfig {
    fn default() -> Self {
        Self { bmax: 8.0, beta: 2.0, tol_bits: 1e-6, max_iters: 10_000 }
    }
}

/// Result of the continuous allocation.
#[derive(Clone, Debug)]
pub struct Allocation {
    /// Per-group fractional bit depths.
    pub bits: Vec<f64>,
    /// Final dual variable V at convergence.
    pub dual: f64,
    /// Solver iterations used.
    pub iters: usize,
    /// Achieved average bits/weight.
    pub rate: f64,
}

/// Continuous dual ascent (Eq. 6). `groups` with zero sensitivity get 0
/// bits. Returns the allocation at the meeting point of the rate curve.
pub fn solve_continuous(
    groups: &[GroupRd],
    target_rate: f64,
    cfg: &DualAscentConfig,
) -> Allocation {
    let caps = vec![cfg.bmax; groups.len()];
    solve_continuous_capped(groups, target_rate, cfg, &caps)
}

/// Continuous dual ascent with a per-group bit cap overriding `cfg.bmax`.
/// Identical to [`solve_continuous`] when every cap equals `cfg.bmax`.
/// Used by the joint weight+activation allocator, where activation
/// groups carry a higher virtual cap whose top value means "leave at
/// full precision".
pub fn solve_continuous_capped(
    groups: &[GroupRd],
    target_rate: f64,
    cfg: &DualAscentConfig,
    caps: &[f64],
) -> Allocation {
    assert!(!groups.is_empty());
    assert_eq!(groups.len(), caps.len(), "one cap per group");
    let total_w: f64 = groups.iter().map(|g| g.count as f64).sum();
    let mut v = 1e-6f64;
    let mut bits = vec![0f64; groups.len()];
    let mut iters = 0;
    // Normalized dual step: the raw paper update (β times a bit *count*
    // surplus) explodes for large models, so scale by total weights —
    // identical fixed point, stable step.
    let beta = cfg.beta / total_w;
    let mut rate = 0.0;
    for it in 0..cfg.max_iters {
        iters = it + 1;
        let mut used = 0f64;
        for (i, (b, g)) in bits.iter_mut().zip(groups).enumerate() {
            *b = g.optimal_bits(v, caps[i]);
            used += *b * g.count as f64;
        }
        rate = used / total_w;
        let surplus = used - total_w * target_rate;
        if (surplus / total_w).abs() < cfg.tol_bits {
            return Allocation { bits, dual: v, iters, rate };
        }
        v = (v + beta * surplus / total_w * v.max(1e-12)).max(1e-18);
        // The multiplicative form keeps V positive; fall through to
        // bisection if oscillating.
        if it == cfg.max_iters / 2 {
            // Bisection fallback: rate(V) is monotone nonincreasing.
            let (mut lo, mut hi) = (1e-18f64, 1e18f64);
            for _ in 0..200 {
                let mid = (lo.ln() + hi.ln()).mul_add(0.5, 0.0).exp();
                let r: f64 = groups
                    .iter()
                    .zip(caps)
                    .map(|(g, &c)| g.optimal_bits(mid, c) * g.count as f64)
                    .sum::<f64>()
                    / total_w;
                if r > target_rate {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            v = hi;
        }
    }
    Allocation { bits, dual: v, iters, rate }
}

/// Integer bit assignment meeting the budget `⌊R·ΣP⌋` exactly (when
/// feasible): continuous solve → floor → greedy refill by best marginal
/// distortion decrease per bit.
pub fn solve_integer(groups: &[GroupRd], target_rate: f64, cfg: &DualAscentConfig) -> Vec<u8> {
    let caps = vec![cfg.bmax as u8; groups.len()];
    solve_integer_capped(groups, target_rate, cfg, &caps)
}

/// Integer assignment with a per-group depth cap overriding `cfg.bmax`
/// (the capped analogue of [`solve_integer`]). The greedy refill never
/// raises a group past its own cap.
pub fn solve_integer_capped(
    groups: &[GroupRd],
    target_rate: f64,
    cfg: &DualAscentConfig,
    caps: &[u8],
) -> Vec<u8> {
    assert_eq!(groups.len(), caps.len(), "one cap per group");
    let total_w: usize = groups.iter().map(|g| g.count).sum();
    let budget: i64 = (target_rate * total_w as f64).floor() as i64;
    let fcaps: Vec<f64> = caps.iter().map(|&c| c as f64).collect();
    let cont = solve_continuous_capped(groups, target_rate, cfg, &fcaps);
    let mut bits: Vec<u8> = cont.bits.iter().map(|&b| b.floor() as u8).collect();
    let mut used: i64 = bits
        .iter()
        .zip(groups)
        .map(|(&b, g)| b as i64 * g.count as i64)
        .sum();

    // Marginal gain of adding one bit to group i at current depth b:
    // Δd = d(b) − d(b+1) = ¾·d(b); per weight-bit: Δd / P.
    let gain = |i: usize, b: u8| -> f64 {
        if b >= caps[i] {
            return f64::NEG_INFINITY;
        }
        0.75 * groups[i].distortion(b as f64) / groups[i].count as f64
    };
    let loss = |g: &GroupRd, b: u8| -> f64 {
        if b == 0 {
            return f64::INFINITY;
        }
        // Distortion increase from removing a bit, per weight-bit.
        3.0 * g.distortion(b as f64) / g.count as f64
    };

    // Greedy refill while under budget.
    loop {
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in groups.iter().enumerate() {
            if used + g.count as i64 > budget {
                continue;
            }
            let gn = gain(i, bits[i]);
            if gn.is_finite() && best.map(|(_, bg)| gn > bg).unwrap_or(true) {
                best = Some((i, gn));
            }
        }
        match best {
            Some((i, _)) => {
                bits[i] += 1;
                used += groups[i].count as i64;
            }
            None => break,
        }
    }
    // Greedy spill while over budget (can happen if floor() still
    // overshoots for degenerate inputs).
    while used > budget {
        let mut best: Option<(usize, f64)> = None;
        for (i, g) in groups.iter().enumerate() {
            if bits[i] == 0 {
                continue;
            }
            let ls = loss(g, bits[i]);
            if best.map(|(_, bl)| ls < bl).unwrap_or(true) {
                best = Some((i, ls));
            }
        }
        match best {
            Some((i, _)) => {
                used -= groups[i].count as i64;
                bits[i] -= 1;
            }
            None => break,
        }
    }
    bits
}

/// An integer bit assignment with its achieved rate and modeled
/// distortion — what the Allocate stage hands to Pack.
#[derive(Clone, Debug)]
pub struct IntegerAllocation {
    /// Per-group integer bit depths.
    pub bits: Vec<u8>,
    /// Achieved average bits/weight of the integer assignment.
    pub rate: f64,
    /// Modeled total distortion Σ dₙ(Bₙ) under the given statistics.
    pub distortion: f64,
}

/// One-call integer allocation: solve, then report achieved rate and
/// modeled distortion together (shared by the Radio trace and the
/// Allocate stage, which used to re-derive these independently).
pub fn allocate_integer(
    groups: &[GroupRd],
    target_rate: f64,
    cfg: &DualAscentConfig,
) -> IntegerAllocation {
    let bits = solve_integer(groups, target_rate, cfg);
    let rate = integer_rate(groups, &bits);
    let distortion = distortion::total_distortion_int(groups, &bits);
    IntegerAllocation { bits, rate, distortion }
}

/// Average rate of an integer assignment.
pub fn integer_rate(groups: &[GroupRd], bits: &[u8]) -> f64 {
    let total_w: usize = groups.iter().map(|g| g.count).sum();
    let used: i64 = bits
        .iter()
        .zip(groups)
        .map(|(&b, g)| b as i64 * g.count as i64)
        .sum();
    used as f64 / total_w as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::Checker;
    use crate::util::rng::Rng;

    fn random_groups(rng: &mut Rng, n: usize) -> Vec<GroupRd> {
        (0..n)
            .map(|_| {
                GroupRd::new(
                    8 + rng.below(512),
                    (rng.normal(0.0, 2.0)).exp(),
                    (rng.normal(0.0, 2.0)).exp(),
                    1.0,
                )
            })
            .collect()
    }

    #[test]
    fn continuous_meets_rate_constraint() {
        let mut rng = Rng::new(101);
        let groups = random_groups(&mut rng, 64);
        for target in [2.0, 3.0, 4.0, 6.0] {
            let a = solve_continuous(&groups, target, &DualAscentConfig::default());
            assert!(
                (a.rate - target).abs() < 1e-4,
                "target {target}: rate {}",
                a.rate
            );
        }
    }

    #[test]
    fn continuous_equalizes_marginal_distortion() {
        // Optimality: unclamped groups share the same −d'(B)/P = V.
        let mut rng = Rng::new(102);
        let groups = random_groups(&mut rng, 32);
        let cfg = DualAscentConfig::default();
        let a = solve_continuous(&groups, 4.0, &cfg);
        for (g, &b) in groups.iter().zip(&a.bits) {
            if b > 1e-9 && b < cfg.bmax - 1e-9 {
                let md = g.neg_derivative_per_weight(b);
                assert!(
                    (md / a.dual - 1.0).abs() < 1e-3,
                    "marginal {md} vs dual {}",
                    a.dual
                );
            }
        }
    }

    #[test]
    fn more_sensitive_groups_get_more_bits() {
        let groups = vec![
            GroupRd::new(100, 1e-4, 1.0, 1.0),
            GroupRd::new(100, 1.0, 1.0, 1.0),
            GroupRd::new(100, 1e4, 1.0, 1.0),
        ];
        let a = solve_continuous(&groups, 4.0, &DualAscentConfig::default());
        assert!(a.bits[0] < a.bits[1] && a.bits[1] < a.bits[2]);
        // ½log2(1e4) ≈ 6.64-bit spacing before clamping ⇒ the solution
        // clamps the extremes to [0, 8] and centers the middle at 4 to
        // meet the 4-bit average.
        assert!(a.bits[0] < 0.1, "low-sensitivity group ~0 bits: {}", a.bits[0]);
        assert!(a.bits[2] > 7.9, "high-sensitivity group ~8 bits: {}", a.bits[2]);
        assert!((a.bits[1] - 4.0).abs() < 0.1, "middle group ~4 bits: {}", a.bits[1]);
    }

    #[test]
    fn integer_assignment_hits_budget_exactly() {
        let rng = Rng::new(103);
        Checker::new(24, 0xA110C).run("integer-budget", |rng_inner, size| {
            let groups = random_groups(rng_inner, 2 + size.min(64));
            let target = 1.0 + rng_inner.uniform() * 5.0;
            let bits = solve_integer(&groups, target, &DualAscentConfig::default());
            let total_w: usize = groups.iter().map(|g| g.count).sum();
            let budget = (target * total_w as f64).floor() as i64;
            let used: i64 = bits
                .iter()
                .zip(&groups)
                .map(|(&b, g)| b as i64 * g.count as i64)
                .sum();
            crate::prop_assert!(used <= budget, "over budget: {used} > {budget}");
            // Within one max-group-size of the budget (greedy can't always
            // land exactly when counts are lumpy).
            let max_count = groups.iter().map(|g| g.count).max().unwrap() as i64;
            crate::prop_assert!(
                budget - used < max_count,
                "underfilled: used {used}, budget {budget}"
            );
            // All depths clamped.
            crate::prop_assert!(bits.iter().all(|&b| b <= 8), "depth above 8");
            Ok(())
        });
        let _ = rng;
    }

    #[test]
    fn allocate_integer_reports_consistent_stats() {
        let mut rng = Rng::new(105);
        let groups = random_groups(&mut rng, 32);
        let a = allocate_integer(&groups, 3.0, &DualAscentConfig::default());
        assert_eq!(a.bits, solve_integer(&groups, 3.0, &DualAscentConfig::default()));
        assert!((a.rate - integer_rate(&groups, &a.bits)).abs() < 1e-15);
        assert!(a.distortion > 0.0);
    }

    #[test]
    fn integer_equal_groups_get_exact_rate() {
        // With equal group sizes and divisible budgets the assignment is
        // exact — the "Radio (3.0000 bits)" property.
        let groups: Vec<GroupRd> = (0..16)
            .map(|i| GroupRd::new(256, (i as f64 * 0.3).exp(), 1.0, 1.0))
            .collect();
        let bits = solve_integer(&groups, 3.0, &DualAscentConfig::default());
        assert!((integer_rate(&groups, &bits) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_sensitivity_groups_are_pruned() {
        let groups = vec![
            GroupRd::new(100, 0.0, 0.0, 1.0),
            GroupRd::new(100, 1.0, 1.0, 1.0),
        ];
        let bits = solve_integer(&groups, 2.0, &DualAscentConfig::default());
        assert_eq!(bits[0], 0, "dead group should receive 0 bits");
        assert_eq!(bits[1], 4, "live group should take the whole budget");
    }

    #[test]
    fn per_group_caps_are_respected_and_uniform_caps_match_uncapped() {
        let mut rng = Rng::new(106);
        let groups = random_groups(&mut rng, 40);
        let cfg = DualAscentConfig::default();
        // Uniform caps equal to bmax reproduce the uncapped solver exactly.
        let caps_uniform = vec![cfg.bmax as u8; groups.len()];
        assert_eq!(
            solve_integer_capped(&groups, 3.0, &cfg, &caps_uniform),
            solve_integer(&groups, 3.0, &cfg)
        );
        // Heterogeneous caps: every group obeys its own ceiling, and
        // groups with a virtual cap above bmax may exceed it.
        let caps: Vec<u8> = (0..groups.len())
            .map(|i| match i % 3 {
                0 => 2,
                1 => 8,
                _ => 9,
            })
            .collect();
        let bits = solve_integer_capped(&groups, 6.0, &cfg, &caps);
        for (i, (&b, &c)) in bits.iter().zip(&caps).enumerate() {
            assert!(b <= c, "group {i}: {b} bits over cap {c}");
        }
        assert!(
            bits.iter().zip(&caps).any(|(&b, &c)| c == 9 && b == 9),
            "at a 6-bit average some virtual-cap group should hit 9 bits"
        );
        // Budget still respected.
        let total_w: usize = groups.iter().map(|g| g.count).sum();
        let used: i64 = bits
            .iter()
            .zip(&groups)
            .map(|(&b, g)| b as i64 * g.count as i64)
            .sum();
        assert!(used <= (6.0 * total_w as f64).floor() as i64);
    }

    #[test]
    fn integer_beats_uniform_assignment_in_model_distortion() {
        let mut rng = Rng::new(104);
        let groups = random_groups(&mut rng, 48);
        let bits = solve_integer(&groups, 3.0, &DualAscentConfig::default());
        let d_opt: f64 = groups
            .iter()
            .zip(&bits)
            .map(|(g, &b)| g.distortion(b as f64))
            .sum();
        let d_unif: f64 = groups.iter().map(|g| g.distortion(3.0)).sum();
        assert!(
            d_opt < d_unif,
            "allocated {d_opt} should beat uniform {d_unif}"
        );
    }
}
