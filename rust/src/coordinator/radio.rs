//! Algorithm 1 — the Radio quantizer, split into three explicit stages
//! with a serializable boundary between them:
//!
//! 1. **Calibrate** ([`Radio::calibrate`]) — the expensive, *rate-
//!    independent* part: EMA accumulation of per-group gradient variances
//!    G² via PCA-projected token-subsampled backprops, EMA layer-input
//!    means X̄, and the sensitivity-ranked groupings. Produces a
//!    [`CalibrationStats`] artifact (binary save/load) that can be
//!    computed once per model and reused for every target rate.
//! 2. **Allocate** ([`CalibrationStats::allocate`]) — one dual-ascent
//!    solve against the stored RD curves for *any* user target rate.
//!    Cheap; re-run per rate.
//! 3. **Pack** ([`Radio::pack`] / [`Radio::pack_streaming`]) — companded
//!    requantization + bias correction from the ORIGINAL weights,
//!    parallelized across matrices on the persistent threadpool; the
//!    streaming variant emits each packed matrix straight into a
//!    [`QuantizedModelWriter`] so no resident `QuantizedModel` is built.
//!
//! [`Radio::quantize`] is the one-shot composition of the three stages,
//! so a from-scratch single-rate run is bit-identical to allocating and
//! packing off a saved calibration artifact at the same seed.

use crate::coordinator::calibration::{CalibrationStats, MatCalib, RateAllocation};
use crate::coordinator::gradients::{subsample_mask, GradientProvider};
use crate::error::RadioError;
use crate::model::corpus::Corpus;
use crate::model::weights::{MatId, SideParams, Weights};
use crate::quant::bias::corrected_bias;
use crate::quant::format::{QuantizedModel, QuantizedModelWriter};
use crate::quant::grouping::Grouping;
use crate::quant::{quantize_matrix, PackedMatrix, QuantMode, ScaleRule};
use crate::stats::moments;
use crate::stats::pca::PcaBasis;
use crate::util::rng::Rng;
use crate::util::threadpool;

/// Algorithm 1's hyperparameters.
#[derive(Clone, Copy, Debug)]
pub struct RadioConfig {
    /// Target average bits per weight R (fractional allowed: 2.1, 3.0 …).
    pub target_bits: f64,
    /// Maximum bits per group.
    pub bmax: u8,
    /// Rows per quantization sub-group (paper's "group size").
    pub rows_per_group: usize,
    /// Calibration minibatch size (paper default 16).
    pub batch: usize,
    /// Calibration sequence length.
    pub seq: usize,
    /// Subsampled tokens per sequence for the backprop sketch (paper 17).
    pub tokens_per_seq: usize,
    /// Optimization iterations (paper max 64; ~20–30 suffice).
    pub iters: usize,
    /// EMA factor α for G² and X̄.
    pub ema_alpha: f64,
    /// PCA components cycled through (one coefficient per minibatch).
    pub pca_k: usize,
    /// Quantizer family (Companded = Radio; Uniform for ablations).
    pub mode: QuantMode,
    /// Scale selection (Mmse = Radio; Range for ablations).
    pub scale_rule: ScaleRule,
    /// Mixed-precision depths via dual ascent (false = flat R bits).
    pub mixed_depth: bool,
    /// Apply §3.2 bias correction from the EMA layer-input means.
    pub bias_correct: bool,
    /// Reference rate for the Calibrate stage's intermediate quantized
    /// points. Deliberately decoupled from `target_bits` so calibration
    /// is rate-independent: one artifact serves every target rate, and a
    /// from-scratch run at any rate reproduces the artifact exactly.
    pub calib_bits: f64,
    /// RNG seed for minibatch sampling and token subsampling.
    pub seed: u64,
}

impl Default for RadioConfig {
    fn default() -> Self {
        Self {
            target_bits: 4.0,
            bmax: 8,
            rows_per_group: 64,
            batch: 16,
            seq: 64,
            tokens_per_seq: 17,
            iters: 24,
            ema_alpha: 0.25,
            pca_k: 8,
            mode: QuantMode::Companded,
            scale_rule: ScaleRule::Mmse,
            mixed_depth: true,
            bias_correct: true,
            calib_bits: 4.0,
            seed: 0xAD10,
        }
    }
}

/// Per-iteration trace entry (drives Figure 4/5).
#[derive(Clone, Debug)]
pub struct IterTrace {
    /// Calibration iteration (1-based).
    pub iter: usize,
    /// Achieved rate of the allocation at this iteration.
    pub rate: f64,
    /// Modeled total distortion Σ d_n(B_n) under current statistics.
    pub model_distortion: f64,
}

/// Summary of a one-shot [`Radio::quantize`] run.
#[derive(Debug)]
pub struct RadioReport {
    /// Gradient iterations executed.
    pub iters_run: usize,
    /// Achieved average bits/weight of the packed model.
    pub final_rate: f64,
    /// Per-iteration rate/distortion trace (Figures 4–5).
    pub trace: Vec<IterTrace>,
    /// Wall clock of the whole run.
    pub seconds: f64,
    /// Explained-variance fraction of the PCA sketch basis.
    pub pca_explained: f64,
}

/// Outcome of the Calibrate stage alone.
#[derive(Clone, Debug)]
pub struct CalibrationReport {
    /// Gradient iterations executed.
    pub iters_run: usize,
    /// Wall clock of the Calibrate stage.
    pub seconds: f64,
    /// Explained-variance fraction of the PCA sketch basis.
    pub pca_explained: f64,
}

/// Summary returned by the streaming Pack stage (no resident model).
#[derive(Clone, Debug)]
pub struct PackSummary {
    /// Matrix records written.
    pub matrices: usize,
    /// Average payload bits/weight of everything written.
    pub avg_bits: f64,
    /// Container size on disk.
    pub bytes: u64,
    /// Matrix records recovered from a crashed pack's journal instead
    /// of being re-quantized (0 for an uninterrupted run).
    pub resumed: usize,
}

/// The Radio quantizer (Algorithm 1 driver).
pub struct Radio {
    /// The run's hyperparameters.
    pub cfg: RadioConfig,
}

impl Radio {
    /// A quantizer with the given hyperparameters.
    pub fn new(cfg: RadioConfig) -> Radio {
        Radio { cfg }
    }

    /// One-shot Calibrate → Allocate → Pack at `cfg.target_bits`.
    ///
    /// `on_iter` (optional) observes the target-rate quantized model at
    /// every calibration iteration — used by the Figure 4/5 bench to
    /// track perplexity across iterations.
    pub fn quantize(
        &self,
        w: &Weights,
        corpus: &Corpus,
        provider: &mut dyn GradientProvider,
        mut on_iter: Option<&mut dyn FnMut(usize, &QuantizedModel)>,
    ) -> (QuantizedModel, RadioReport) {
        let t0 = std::time::Instant::now();
        let cfg = self.cfg;
        let mut trace: Vec<IterTrace> = Vec::with_capacity(cfg.iters);
        let (stats, calib) = {
            let mut cb = |iter: usize, stats: &CalibrationStats| {
                if iter == 0 && on_iter.is_none() {
                    return;
                }
                let a = stats.allocate(cfg.target_bits, cfg.bmax, cfg.mixed_depth);
                if iter > 0 {
                    trace.push(IterTrace {
                        iter,
                        rate: a.rate,
                        model_distortion: a.model_distortion,
                    });
                }
                if let Some(user) = on_iter.as_deref_mut() {
                    let qm = self.pack(w, stats, &a);
                    user(iter, &qm);
                }
            };
            self.calibrate(w, corpus, provider, Some(&mut cb))
        };
        let alloc = stats.allocate(cfg.target_bits, cfg.bmax, cfg.mixed_depth);
        let qm = self.pack(w, &stats, &alloc);
        let report = RadioReport {
            iters_run: calib.iters_run,
            final_rate: qm.avg_bits(),
            trace,
            seconds: t0.elapsed().as_secs_f64(),
            pca_explained: calib.pca_explained,
        };
        (qm, report)
    }

    /// Stage 1 — Calibrate: run the stochastic gradient iterations and
    /// return the rate-independent statistics artifact. `cfg.target_bits`
    /// is NOT read here; intermediate quantized points use
    /// `cfg.calib_bits` so the artifact serves any later target.
    ///
    /// `on_iter` observes the evolving statistics after the warmup
    /// (iter 0) and after each gradient iteration (1..=iters); callbacks
    /// must not mutate anything the calibration stream depends on.
    pub fn calibrate(
        &self,
        w: &Weights,
        corpus: &Corpus,
        provider: &mut dyn GradientProvider,
        mut on_iter: Option<&mut dyn FnMut(usize, &CalibrationStats)>,
    ) -> (CalibrationStats, CalibrationReport) {
        let t0 = std::time::Instant::now();
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed);

        // ---- Warmup: one full-precision gradient sample to seed G² and
        // build the sensitivity-ranked groupings.
        let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
        let mut u0 = vec![0f32; w.config.dim];
        rng.fill_gauss(&mut u0, 0.0, 1.0);
        let s0 = subsample_mask(&mut rng, cfg.batch, cfg.seq, cfg.tokens_per_seq);
        let warm = provider.grad_sample(w, &toks, cfg.batch, cfg.seq, &u0, &s0);

        // PCA basis from warmup outputs.
        let pca = PcaBasis::fit(
            &warm.z.data,
            warm.z.rows,
            warm.z.cols,
            cfg.pca_k.min(w.config.dim),
        );

        let mut sorted: Vec<&(MatId, crate::model::tensor::Tensor)> = warm.grads.iter().collect();
        sorted.sort_by_key(|(id, _)| *id);
        let mut mats: Vec<MatCalib> = Vec::with_capacity(sorted.len());
        for (id, grad) in sorted {
            let m = w.matrix(*id);
            // Row score = G_r²·S_r² (row grad second moment × row weight var).
            let scores: Vec<f64> = (0..m.rows)
                .map(|r| {
                    let g2r = moments::mean_square(grad.row(r));
                    let s2r = moments::variance(m.row(r));
                    g2r * s2r
                })
                .collect();
            let grouping = Grouping::build(m.rows, m.cols, cfg.rows_per_group, &scores);
            let mut s2 = vec![0f64; grouping.num_groups()];
            let mut g2 = vec![0f64; grouping.num_groups()];
            for col in 0..grouping.cols {
                for sub in 0..grouping.m {
                    let gi = grouping.group_index(col, sub);
                    s2[gi] = moments::variance_iter(grouping.iter_group(m, col, sub)).max(1e-30);
                    g2[gi] = moments::mean_square_iter(grouping.iter_group(grad, col, sub));
                }
            }
            let xbar = vec![0.0; m.rows];
            let xsq = vec![0.0; m.rows];
            let xamax = vec![0.0; m.rows];
            mats.push(MatCalib { id: *id, grouping, s2, g2, xbar, xsq, xamax });
        }
        let mut stats = CalibrationStats {
            config: w.config,
            rows_per_group: cfg.rows_per_group,
            calib_bits: cfg.calib_bits,
            iters: cfg.iters,
            seed: cfg.seed,
            pca_explained: pca.explained_fraction(),
            mats,
        };
        let mut xbar_init = vec![false; stats.mats.len()];
        let mut xsq_init = vec![false; stats.mats.len()];
        update_xbar(&mut stats, &mut xbar_init, &warm.input_means, cfg.ema_alpha);
        update_act_moments(
            &mut stats,
            &mut xsq_init,
            &warm.input_sq,
            &warm.input_amax,
            cfg.ema_alpha,
        );
        if let Some(cb) = on_iter.as_deref_mut() {
            cb(0, &stats);
        }

        // ---- Iterate: quantize at the reference rate → re-estimate
        // gradients at the quantized point → fold into the EMAs.
        for iter in 1..=cfg.iters {
            let alloc = stats.allocate(cfg.calib_bits, cfg.bmax, true);
            let wq = self.pack(w, &stats, &alloc).to_weights();
            let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
            // Cycle PCA coefficients; fresh token subsample each iteration.
            let u = pca.component((iter - 1) % pca.k).to_vec();
            let s = subsample_mask(&mut rng, cfg.batch, cfg.seq, cfg.tokens_per_seq);
            let sample = provider.grad_sample(&wq, &toks, cfg.batch, cfg.seq, &u, &s);

            // EMA updates.
            for (id, grad) in &sample.grads {
                let ix = stats.index_of(*id).expect("provider returned unknown matrix");
                let mc = &mut stats.mats[ix];
                for col in 0..mc.grouping.cols {
                    for sub in 0..mc.grouping.m {
                        let gi = mc.grouping.group_index(col, sub);
                        let obs =
                            moments::mean_square_iter(mc.grouping.iter_group(grad, col, sub));
                        mc.g2[gi] = (1.0 - cfg.ema_alpha) * mc.g2[gi] + cfg.ema_alpha * obs;
                    }
                }
            }
            update_xbar(&mut stats, &mut xbar_init, &sample.input_means, cfg.ema_alpha);
            update_act_moments(
                &mut stats,
                &mut xsq_init,
                &sample.input_sq,
                &sample.input_amax,
                cfg.ema_alpha,
            );
            if let Some(cb) = on_iter.as_deref_mut() {
                cb(iter, &stats);
            }
        }

        let report = CalibrationReport {
            iters_run: cfg.iters,
            seconds: t0.elapsed().as_secs_f64(),
            pca_explained: stats.pca_explained,
        };
        (stats, report)
    }

    /// Stage 3 — Pack (resident): requantize every matrix from the
    /// ORIGINAL weights (Radio never fine-tunes weights) under a given
    /// allocation, in parallel across matrices. Deterministic regardless
    /// of thread count: each matrix is packed independently and results
    /// are assembled in `mats` order.
    pub fn pack(
        &self,
        w: &Weights,
        stats: &CalibrationStats,
        alloc: &RateAllocation,
    ) -> QuantizedModel {
        assert!(
            stats.compatible_with(w),
            "calibration artifact does not match the model (config/shape mismatch)"
        );
        assert_eq!(alloc.bits.len(), stats.mats.len(), "allocation/stats mismatch");
        let mut base = SideParams::from_weights(w);
        let results = self.pack_range(w, stats, alloc, 0, stats.mats.len());
        let mut packed = Vec::with_capacity(results.len());
        for (i, (pm, nb)) in results.into_iter().enumerate() {
            let id = stats.mats[i].id;
            if let Some(nb) = nb {
                *base.bias_mut(id) = nb;
            }
            packed.push((id, pm));
        }
        QuantizedModel { base, packed, act_quant: None }
    }

    /// Stage 3 — Pack (streaming): same quantization as [`Radio::pack`],
    /// but each window of matrices is written straight to the `.radio`
    /// container and dropped, so peak memory is one packing window
    /// (≈ 2× thread count matrices) instead of the whole model.
    ///
    /// The pack is **crash-safe and resumable**: bytes stage into
    /// `<path>.tmp` (the destination is replaced only by the final
    /// atomic rename), and after every window the writer checkpoints —
    /// fsyncs the staging file, then journals the durable records to a
    /// `<path>.journal` sidecar. If a previous pack of the same model
    /// crashed, this call verifies the journal against the surviving
    /// staging file and resumes after the last intact record
    /// ([`PackSummary::resumed`] counts the records skipped); the
    /// resumed container is bit-identical to an uninterrupted pack
    /// (tested). The journal is deleted on success.
    pub fn pack_streaming(
        &self,
        w: &Weights,
        stats: &CalibrationStats,
        alloc: &RateAllocation,
        path: &std::path::Path,
    ) -> Result<PackSummary, RadioError> {
        assert!(
            stats.compatible_with(w),
            "calibration artifact does not match the model (config/shape mismatch)"
        );
        assert_eq!(alloc.bits.len(), stats.mats.len(), "allocation/stats mismatch");
        let mut base = SideParams::from_weights(w);
        let (mut writer, mut done) = QuantizedModelWriter::create_journaled(path)?;
        // A surviving journal must describe THIS pack order; one left by
        // a different model/allocation is discarded, not trusted.
        let order_matches = done.len() <= stats.mats.len()
            && done.iter().enumerate().all(|(k, e)| e.id == stats.mats[k].id);
        if !order_matches {
            drop(writer);
            QuantizedModelWriter::discard_partial(path);
            let fresh = QuantizedModelWriter::create_journaled(path)?;
            writer = fresh.0;
            done = fresh.1;
        }
        let resumed = done.len();
        let (mut payload_bits, mut weights_total) = (0u64, 0u64);
        for e in &done {
            payload_bits += e.payload_bits;
            weights_total += e.weights;
            if let Some(b) = &e.bias {
                *base.bias_mut(e.id) = b.clone();
            }
        }
        let n = stats.mats.len();
        let window = (threadpool::num_threads().max(1) * 2).min(n.max(1));
        let mut start = resumed;
        while start < n {
            let end = (start + window).min(n);
            let results = self.pack_range(w, stats, alloc, start, end);
            for (k, (pm, nb)) in results.into_iter().enumerate() {
                let id = stats.mats[start + k].id;
                payload_bits += pm.payload_bits() as u64;
                weights_total += (pm.rows * pm.cols) as u64;
                writer.write_matrix_journaled(id, &pm, nb.as_deref())?;
                if let Some(nb) = nb {
                    *base.bias_mut(id) = nb;
                }
            }
            writer.checkpoint()?;
            start = end;
        }
        let matrices = writer.matrices_written();
        writer.finish(&base)?;
        let bytes = std::fs::metadata(path)?.len();
        Ok(PackSummary {
            matrices,
            avg_bits: payload_bits as f64 / weights_total.max(1) as f64,
            bytes,
            resumed,
        })
    }

    /// Pack matrices `[start, end)` in parallel; returns
    /// `(packed, corrected_bias)` per matrix in index order.
    fn pack_range(
        &self,
        w: &Weights,
        stats: &CalibrationStats,
        alloc: &RateAllocation,
        start: usize,
        end: usize,
    ) -> Vec<(PackedMatrix, Option<Vec<f32>>)> {
        let cfg = &self.cfg;
        let results: Vec<Option<(PackedMatrix, Option<Vec<f32>>)>> =
            threadpool::parallel_map(end - start, 1, |k| {
                let i = start + k;
                let mc = &stats.mats[i];
                let (bid, bits) = &alloc.bits[i];
                debug_assert_eq!(*bid, mc.id);
                let theta = w.matrix(mc.id);
                let pm = quantize_matrix(theta, &mc.grouping, bits, cfg.mode, cfg.scale_rule);
                let nb = if cfg.bias_correct {
                    let deq = pm.unpack();
                    let xbar: Vec<f32> = mc.xbar.iter().map(|&x| x as f32).collect();
                    Some(corrected_bias(w.bias(mc.id), theta, &deq, &xbar))
                } else {
                    None
                };
                Some((pm, nb))
            });
        results.into_iter().map(|r| r.expect("pack result")).collect()
    }
}

fn update_xbar(
    stats: &mut CalibrationStats,
    xbar_init: &mut [bool],
    input_means: &[(MatId, Vec<f32>)],
    alpha: f64,
) {
    for (id, mu) in input_means {
        let ix = stats.index_of(*id).expect("provider returned unknown matrix");
        let mc = &mut stats.mats[ix];
        if xbar_init[ix] {
            for (x, &m) in mc.xbar.iter_mut().zip(mu) {
                *x = (1.0 - alpha) * *x + alpha * m as f64;
            }
        } else {
            for (x, &m) in mc.xbar.iter_mut().zip(mu) {
                *x = m as f64;
            }
            xbar_init[ix] = true;
        }
    }
}

/// Fold one iteration's activation moments into the calibration EMAs:
/// per-channel `E[x²]` via the same first-observation-then-EMA scheme as
/// X̄, per-channel absmax as a running maximum (a scale must cover every
/// observed batch, so it never decays). Providers that do not capture
/// activation moments pass empty slices and the stats stay zero —
/// `allocate_joint` treats that as "activation quantization unavailable".
fn update_act_moments(
    stats: &mut CalibrationStats,
    xsq_init: &mut [bool],
    input_sq: &[(MatId, Vec<f32>)],
    input_amax: &[(MatId, Vec<f32>)],
    alpha: f64,
) {
    for (id, sq) in input_sq {
        let ix = stats.index_of(*id).expect("provider returned unknown matrix");
        let mc = &mut stats.mats[ix];
        if xsq_init[ix] {
            for (x, &m) in mc.xsq.iter_mut().zip(sq) {
                *x = (1.0 - alpha) * *x + alpha * m as f64;
            }
        } else {
            for (x, &m) in mc.xsq.iter_mut().zip(sq) {
                *x = m as f64;
            }
            xsq_init[ix] = true;
        }
    }
    for (id, am) in input_amax {
        let ix = stats.index_of(*id).expect("provider returned unknown matrix");
        let mc = &mut stats.mats[ix];
        for (x, &m) in mc.xamax.iter_mut().zip(am) {
            *x = x.max(m as f64);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::gradients::NativeProvider;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;

    fn tiny_setup() -> (Weights, Corpus) {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(121);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let corpus = Corpus::synthetic(122, Domain::Calib, 8 * 1024);
        (w, corpus)
    }

    fn quick_cfg(bits: f64) -> RadioConfig {
        RadioConfig {
            target_bits: bits,
            rows_per_group: 8,
            batch: 2,
            seq: 16,
            tokens_per_seq: 5,
            iters: 3,
            pca_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn radio_hits_target_rate() {
        let (w, corpus) = tiny_setup();
        let radio = Radio::new(quick_cfg(3.0));
        let mut provider = NativeProvider;
        let (qm, report) = radio.quantize(&w, &corpus, &mut provider, None);
        assert!(
            (qm.avg_bits() - 3.0).abs() < 0.05,
            "rate {} != 3.0",
            qm.avg_bits()
        );
        assert_eq!(report.iters_run, 3);
        assert!(report.trace.len() == 3);
        assert!(report.pca_explained > 0.0);
    }

    #[test]
    fn radio_fractional_rate() {
        let (w, corpus) = tiny_setup();
        let radio = Radio::new(quick_cfg(2.4));
        let mut provider = NativeProvider;
        let (qm, _) = radio.quantize(&w, &corpus, &mut provider, None);
        assert!((qm.avg_bits() - 2.4).abs() < 0.05, "rate {}", qm.avg_bits());
    }

    #[test]
    fn radio_beats_flat_allocation_in_output_distortion() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        let mut mixed_cfg = quick_cfg(3.0);
        mixed_cfg.iters = 4;
        let (qm_mixed, _) = Radio::new(mixed_cfg).quantize(&w, &corpus, &mut provider, None);
        let mut flat_cfg = quick_cfg(3.0);
        flat_cfg.mixed_depth = false;
        flat_cfg.iters = 1;
        let (qm_flat, _) = Radio::new(flat_cfg).quantize(&w, &corpus, &mut provider, None);

        // Compare end-to-end output distortion on held-out batch.
        let mut rng = Rng::new(123);
        let (toks, _) = corpus.sample_batch(&mut rng, 2, 16);
        let z_ref = crate::model::transformer::forward(&w, &toks, 2, 16).z;
        let dist = |qm: &QuantizedModel| {
            let wq = qm.to_weights();
            let z = crate::model::transformer::forward(&wq, &toks, 2, 16).z;
            let mut d = 0f64;
            for (a, b) in z.data.iter().zip(&z_ref.data) {
                d += ((a - b) as f64).powi(2);
            }
            d
        };
        let (dm, df) = (dist(&qm_mixed), dist(&qm_flat));
        assert!(
            dm < df * 1.1,
            "mixed-depth {dm} should not be much worse than flat {df}"
        );
    }

    #[test]
    fn callback_sees_every_iteration() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        let mut seen = Vec::new();
        let mut cb = |it: usize, qm: &QuantizedModel| {
            seen.push((it, qm.avg_bits()));
        };
        Radio::new(quick_cfg(4.0)).quantize(&w, &corpus, &mut provider, Some(&mut cb));
        assert_eq!(seen.len(), 4); // iter 0 (warmup quant) + 3 iters
        assert_eq!(seen[0].0, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (w, corpus) = tiny_setup();
        let run = || {
            let mut p = NativeProvider;
            let (qm, _) = Radio::new(quick_cfg(3.0)).quantize(&w, &corpus, &mut p, None);
            qm.to_weights().layers[0].wq.data.clone()
        };
        assert_eq!(run(), run());
    }

    /// The acceptance criterion of the staged split: calibrating once and
    /// sweeping rates off the artifact (including through a save/load
    /// roundtrip) is bit-identical to a from-scratch single-rate run at
    /// the same seed.
    #[test]
    fn calibrate_once_allocate_many_matches_from_scratch() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        // Calibrate once; the configured target rate is irrelevant here.
        let calibrator = Radio::new(quick_cfg(7.7));
        let (stats, report) = calibrator.calibrate(&w, &corpus, &mut provider, None);
        assert_eq!(report.iters_run, 3);

        // Persist and reload the artifact (the calibrate-once path).
        let path = std::env::temp_dir().join("radio_test_stats.radiocal");
        stats.save(&path).unwrap();
        let loaded = CalibrationStats::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);

        for target in [2.4, 3.0, 4.0] {
            let radio = Radio::new(quick_cfg(target));
            // From-scratch single-rate run (fresh provider state).
            let mut p2 = NativeProvider;
            let (qm_scratch, _) = radio.quantize(&w, &corpus, &mut p2, None);
            // Sweep path: allocate + pack off the loaded artifact.
            let alloc = loaded.allocate(target, radio.cfg.bmax, radio.cfg.mixed_depth);
            let qm_sweep = radio.pack(&w, &loaded, &alloc);

            assert_eq!(qm_scratch.avg_bits(), qm_sweep.avg_bits(), "target {target}");
            let (ws, wv) = (qm_scratch.to_weights(), qm_sweep.to_weights());
            for (a, b) in ws.layers.iter().zip(&wv.layers) {
                assert_eq!(a.wq.data, b.wq.data, "target {target}");
                assert_eq!(a.wo.data, b.wo.data, "target {target}");
                assert_eq!(a.w1.data, b.w1.data, "target {target}");
                assert_eq!(a.w2.data, b.w2.data, "target {target}");
                assert_eq!(a.bq, b.bq, "target {target} (corrected bias)");
                assert_eq!(a.b2, b.b2, "target {target} (corrected bias)");
            }
        }
    }

    /// The streaming Pack stage must produce the same container as
    /// saving the resident model.
    #[test]
    fn streaming_pack_matches_resident_pack() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        let radio = Radio::new(quick_cfg(3.0));
        let (stats, _) = radio.calibrate(&w, &corpus, &mut provider, None);
        let alloc = stats.allocate(3.0, radio.cfg.bmax, true);

        let qm = radio.pack(&w, &stats, &alloc);
        let p_res = std::env::temp_dir().join("radio_test_pack_res.radio");
        let p_str = std::env::temp_dir().join("radio_test_pack_str.radio");
        qm.save(&p_res).unwrap();
        let summary = radio.pack_streaming(&w, &stats, &alloc, &p_str).unwrap();
        assert_eq!(summary.matrices, qm.packed.len());
        assert_eq!(summary.resumed, 0, "uninterrupted pack resumes nothing");
        assert!((summary.avg_bits - qm.avg_bits()).abs() < 1e-12);
        let (a, b) = (std::fs::read(&p_res).unwrap(), std::fs::read(&p_str).unwrap());
        let _ = std::fs::remove_file(&p_res);
        let _ = std::fs::remove_file(&p_str);
        assert_eq!(summary.bytes as usize, b.len());
        assert_eq!(a, b, "streamed container must be byte-identical");
    }
}
