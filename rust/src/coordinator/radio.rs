//! Algorithm 1 — the Radio quantizer.
//!
//! Orchestrates the full stochastic rate–distortion optimization:
//! EMA accumulation of per-group gradient variances (G²) via PCA-projected
//! token-subsampled backprops, EMA layer-input means (X̄) for bias
//! correction, dual-ascent bit-depth allocation at the user's target rate,
//! companded requantization, and the final packed model.

use std::collections::BTreeMap;

use crate::coordinator::dual_ascent::{self, DualAscentConfig};
use crate::coordinator::gradients::GradientProvider;
use crate::model::corpus::Corpus;
use crate::model::weights::{MatId, Weights};
use crate::quant::format::QuantizedModel;
use crate::quant::grouping::Grouping;
use crate::quant::{quantize_matrix, QuantMode, ScaleRule};
use crate::quant::bias::corrected_bias;
use crate::stats::distortion::GroupRd;
use crate::stats::moments;
use crate::stats::pca::PcaBasis;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RadioConfig {
    /// Target average bits per weight R (fractional allowed: 2.1, 3.0 …).
    pub target_bits: f64,
    pub bmax: u8,
    /// Rows per quantization sub-group (paper's "group size").
    pub rows_per_group: usize,
    /// Calibration minibatch size (paper default 16).
    pub batch: usize,
    pub seq: usize,
    /// Subsampled tokens per sequence for the backprop sketch (paper 17).
    pub tokens_per_seq: usize,
    /// Optimization iterations (paper max 64; ~20–30 suffice).
    pub iters: usize,
    /// EMA factor α for G² and X̄.
    pub ema_alpha: f64,
    /// PCA components cycled through (one coefficient per minibatch).
    pub pca_k: usize,
    /// Quantizer family (Companded = Radio; Uniform for ablations).
    pub mode: QuantMode,
    /// Scale selection (Mmse = Radio; Range for ablations).
    pub scale_rule: ScaleRule,
    /// Mixed-precision depths via dual ascent (false = flat R bits).
    pub mixed_depth: bool,
    pub bias_correct: bool,
    pub seed: u64,
}

impl Default for RadioConfig {
    fn default() -> Self {
        Self {
            target_bits: 4.0,
            bmax: 8,
            rows_per_group: 64,
            batch: 16,
            seq: 64,
            tokens_per_seq: 17,
            iters: 24,
            ema_alpha: 0.25,
            pca_k: 8,
            mode: QuantMode::Companded,
            scale_rule: ScaleRule::Mmse,
            mixed_depth: true,
            bias_correct: true,
            seed: 0xAD10,
        }
    }
}

/// Per-iteration trace entry (drives Figure 4/5).
#[derive(Clone, Debug)]
pub struct IterTrace {
    pub iter: usize,
    pub rate: f64,
    /// Modeled total distortion Σ d_n(B_n) under current statistics.
    pub model_distortion: f64,
}

#[derive(Debug)]
pub struct RadioReport {
    pub iters_run: usize,
    pub final_rate: f64,
    pub trace: Vec<IterTrace>,
    pub seconds: f64,
    pub pca_explained: f64,
}

/// Per-matrix optimization state.
struct MatState {
    grouping: Grouping,
    /// Fixed per-group weight variances S² (original weights).
    s2: Vec<f64>,
    /// EMA per-group gradient second moments G².
    g2: Vec<f64>,
    /// EMA input means (length = rows).
    xbar: Vec<f64>,
    xbar_init: bool,
}

/// The Radio quantizer (Algorithm 1 driver).
pub struct Radio {
    pub cfg: RadioConfig,
}

impl Radio {
    pub fn new(cfg: RadioConfig) -> Radio {
        Radio { cfg }
    }

    /// Quantize `w` against calibration `corpus` using `provider` for
    /// gradients. `on_iter` (optional) observes each intermediate model —
    /// used by the Figure 4/5 bench to track perplexity across iterations.
    pub fn quantize(
        &self,
        w: &Weights,
        corpus: &Corpus,
        provider: &mut dyn GradientProvider,
        mut on_iter: Option<&mut dyn FnMut(usize, &QuantizedModel)>,
    ) -> (QuantizedModel, RadioReport) {
        let t0 = std::time::Instant::now();
        let cfg = &self.cfg;
        let mut rng = Rng::new(cfg.seed);
        let _ids = w.matrix_ids();

        // ---- Warmup: one full-precision gradient sample to seed G² and
        // build the sensitivity-ranked groupings.
        let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
        let mut u0 = vec![0f32; w.config.dim];
        rng.fill_gauss(&mut u0, 0.0, 1.0);
        let s0 = subsample_mask(&mut rng, cfg.batch, cfg.seq, cfg.tokens_per_seq);
        let warm = provider.grad_sample(w, &toks, cfg.batch, cfg.seq, &u0, &s0);

        // PCA basis from warmup outputs.
        let pca = PcaBasis::fit(
            &warm.z.data,
            warm.z.rows,
            warm.z.cols,
            cfg.pca_k.min(w.config.dim),
        );

        let mut states: BTreeMap<MatId, MatState> = BTreeMap::new();
        for (id, grad) in &warm.grads {
            let m = w.matrix(*id);
            // Row score = G_r²·S_r² (row grad second moment × row weight var).
            let scores: Vec<f64> = (0..m.rows)
                .map(|r| {
                    let g2r = moments::mean_square(grad.row(r));
                    let s2r = moments::variance(m.row(r));
                    g2r * s2r
                })
                .collect();
            let grouping = Grouping::build(m.rows, m.cols, cfg.rows_per_group, &scores);
            let mut s2 = vec![0f64; grouping.num_groups()];
            let mut g2 = vec![0f64; grouping.num_groups()];
            for col in 0..grouping.cols {
                for sub in 0..grouping.m {
                    let gi = grouping.group_index(col, sub);
                    let vals = grouping.gather(m, col, sub);
                    s2[gi] = moments::variance(&vals).max(1e-30);
                    let gvals = grouping.gather(grad, col, sub);
                    g2[gi] = moments::mean_square(&gvals);
                }
            }
            states.insert(
                *id,
                MatState { grouping, s2, g2, xbar: vec![0.0; m.rows], xbar_init: false },
            );
        }
        update_xbar(&mut states, &warm.input_means, cfg.ema_alpha);

        // ---- Iterate: quantize → re-estimate gradients at the quantized
        // point → reallocate.
        let mut trace = Vec::with_capacity(cfg.iters);
        let mut qm = self.requantize(w, &states);
        if let Some(cb) = on_iter.as_deref_mut() {
            cb(0, &qm);
        }
        for iter in 1..=cfg.iters {
            let wq = qm.to_weights();
            let (toks, _) = corpus.sample_batch(&mut rng, cfg.batch, cfg.seq);
            // Cycle PCA coefficients; fresh token subsample each iteration.
            let u = pca.component((iter - 1) % pca.k).to_vec();
            let s = subsample_mask(&mut rng, cfg.batch, cfg.seq, cfg.tokens_per_seq);
            let sample = provider.grad_sample(&wq, &toks, cfg.batch, cfg.seq, &u, &s);

            // EMA updates.
            for (id, grad) in &sample.grads {
                let st = states.get_mut(id).unwrap();
                for col in 0..st.grouping.cols {
                    for sub in 0..st.grouping.m {
                        let gi = st.grouping.group_index(col, sub);
                        let gvals = st.grouping.gather(grad, col, sub);
                        let obs = moments::mean_square(&gvals);
                        st.g2[gi] = (1.0 - cfg.ema_alpha) * st.g2[gi] + cfg.ema_alpha * obs;
                    }
                }
            }
            update_xbar(&mut states, &sample.input_means, cfg.ema_alpha);

            // Reallocate + requantize.
            qm = self.requantize(w, &states);

            // Trace.
            let (rate, dist) = self.modeled_stats(&states);
            trace.push(IterTrace { iter, rate, model_distortion: dist });
            if let Some(cb) = on_iter.as_deref_mut() {
                cb(iter, &qm);
            }
        }

        let final_rate = qm.avg_bits();
        let report = RadioReport {
            iters_run: cfg.iters,
            final_rate,
            trace,
            seconds: t0.elapsed().as_secs_f64(),
            pca_explained: pca.explained_fraction(),
        };
        (qm, report)
    }

    /// Allocate depths from current statistics and requantize every matrix
    /// from the ORIGINAL weights (Radio never fine-tunes weights).
    fn requantize(&self, w: &Weights, states: &BTreeMap<MatId, MatState>) -> QuantizedModel {
        let cfg = &self.cfg;
        // Global allocation across *all* groups of *all* matrices.
        let mut group_rd: Vec<GroupRd> = Vec::new();
        let mut owners: Vec<(MatId, usize)> = Vec::new();
        for (id, st) in states {
            for gi in 0..st.grouping.num_groups() {
                let sub = gi % st.grouping.m;
                group_rd.push(GroupRd::new(
                    st.grouping.group_len(sub),
                    st.g2[gi],
                    st.s2[gi],
                    1.0,
                ));
                owners.push((*id, gi));
            }
        }
        let bits: Vec<u8> = if cfg.mixed_depth {
            dual_ascent::solve_integer(
                &group_rd,
                cfg.target_bits,
                &DualAscentConfig { bmax: cfg.bmax as f64, ..Default::default() },
            )
        } else {
            // Flat allocation at round(R) bits (ablation).
            vec![cfg.target_bits.round() as u8; group_rd.len()]
        };

        let mut per_mat_bits: BTreeMap<MatId, Vec<u8>> = BTreeMap::new();
        for ((id, gi), &b) in owners.iter().zip(&bits) {
            let st = &states[id];
            per_mat_bits
                .entry(*id)
                .or_insert_with(|| vec![0u8; st.grouping.num_groups()])[*gi] = b;
        }

        let mut base = w.clone();
        let mut packed = Vec::with_capacity(states.len());
        for (id, st) in states {
            let theta = w.matrix(*id);
            let pm = quantize_matrix(
                theta,
                &st.grouping,
                &per_mat_bits[id],
                cfg.mode,
                cfg.scale_rule,
            );
            if cfg.bias_correct {
                let deq = pm.unpack();
                let xbar: Vec<f32> = st.xbar.iter().map(|&x| x as f32).collect();
                let nb = corrected_bias(w.bias(*id), theta, &deq, &xbar);
                *base.bias_mut(*id) = nb;
            }
            packed.push((*id, pm));
        }
        QuantizedModel { base, packed }
    }

    fn modeled_stats(&self, states: &BTreeMap<MatId, MatState>) -> (f64, f64) {
        // Recompute the allocation to report modeled rate/distortion.
        let mut group_rd: Vec<GroupRd> = Vec::new();
        for st in states.values() {
            for gi in 0..st.grouping.num_groups() {
                let sub = gi % st.grouping.m;
                group_rd.push(GroupRd::new(st.grouping.group_len(sub), st.g2[gi], st.s2[gi], 1.0));
            }
        }
        let bits = dual_ascent::solve_integer(
            &group_rd,
            self.cfg.target_bits,
            &DualAscentConfig { bmax: self.cfg.bmax as f64, ..Default::default() },
        );
        let rate = dual_ascent::integer_rate(&group_rd, &bits);
        let dist: f64 = group_rd
            .iter()
            .zip(&bits)
            .map(|(g, &b)| g.distortion(b as f64))
            .sum();
        (rate, dist)
    }
}

/// Token-subsampling sketch vector: `tokens_per_seq` ones per sequence.
fn subsample_mask(rng: &mut Rng, batch: usize, seq: usize, k: usize) -> Vec<f32> {
    let mut s = vec![0f32; batch * seq];
    for b in 0..batch {
        for idx in rng.sample_indices(seq, k.min(seq)) {
            s[b * seq + idx] = 1.0;
        }
    }
    s
}

fn update_xbar(
    states: &mut BTreeMap<MatId, MatState>,
    input_means: &[(MatId, Vec<f32>)],
    alpha: f64,
) {
    for (id, mu) in input_means {
        let st = states.get_mut(id).unwrap();
        if st.xbar_init {
            for (x, &m) in st.xbar.iter_mut().zip(mu) {
                *x = (1.0 - alpha) * *x + alpha * m as f64;
            }
        } else {
            for (x, &m) in st.xbar.iter_mut().zip(mu) {
                *x = m as f64;
            }
            st.xbar_init = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::gradients::NativeProvider;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::Domain;

    fn tiny_setup() -> (Weights, Corpus) {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(121);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let corpus = Corpus::synthetic(122, Domain::Calib, 8 * 1024);
        (w, corpus)
    }

    fn quick_cfg(bits: f64) -> RadioConfig {
        RadioConfig {
            target_bits: bits,
            rows_per_group: 8,
            batch: 2,
            seq: 16,
            tokens_per_seq: 5,
            iters: 3,
            pca_k: 2,
            ..Default::default()
        }
    }

    #[test]
    fn radio_hits_target_rate() {
        let (w, corpus) = tiny_setup();
        let radio = Radio::new(quick_cfg(3.0));
        let mut provider = NativeProvider;
        let (qm, report) = radio.quantize(&w, &corpus, &mut provider, None);
        assert!(
            (qm.avg_bits() - 3.0).abs() < 0.05,
            "rate {} != 3.0",
            qm.avg_bits()
        );
        assert_eq!(report.iters_run, 3);
        assert!(report.trace.len() == 3);
        assert!(report.pca_explained > 0.0);
    }

    #[test]
    fn radio_fractional_rate() {
        let (w, corpus) = tiny_setup();
        let radio = Radio::new(quick_cfg(2.4));
        let mut provider = NativeProvider;
        let (qm, _) = radio.quantize(&w, &corpus, &mut provider, None);
        assert!((qm.avg_bits() - 2.4).abs() < 0.05, "rate {}", qm.avg_bits());
    }

    #[test]
    fn radio_beats_flat_allocation_in_output_distortion() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        let mut mixed_cfg = quick_cfg(3.0);
        mixed_cfg.iters = 4;
        let (qm_mixed, _) = Radio::new(mixed_cfg).quantize(&w, &corpus, &mut provider, None);
        let mut flat_cfg = quick_cfg(3.0);
        flat_cfg.mixed_depth = false;
        flat_cfg.iters = 1;
        let (qm_flat, _) = Radio::new(flat_cfg).quantize(&w, &corpus, &mut provider, None);

        // Compare end-to-end output distortion on held-out batch.
        let mut rng = Rng::new(123);
        let (toks, _) = corpus.sample_batch(&mut rng, 2, 16);
        let z_ref = crate::model::transformer::forward(&w, &toks, 2, 16).z;
        let dist = |qm: &QuantizedModel| {
            let wq = qm.to_weights();
            let z = crate::model::transformer::forward(&wq, &toks, 2, 16).z;
            let mut d = 0f64;
            for (a, b) in z.data.iter().zip(&z_ref.data) {
                d += ((a - b) as f64).powi(2);
            }
            d
        };
        let (dm, df) = (dist(&qm_mixed), dist(&qm_flat));
        assert!(
            dm < df * 1.1,
            "mixed-depth {dm} should not be much worse than flat {df}"
        );
    }

    #[test]
    fn callback_sees_every_iteration() {
        let (w, corpus) = tiny_setup();
        let mut provider = NativeProvider;
        let mut seen = Vec::new();
        let mut cb = |it: usize, qm: &QuantizedModel| {
            seen.push((it, qm.avg_bits()));
        };
        Radio::new(quick_cfg(4.0)).quantize(&w, &corpus, &mut provider, Some(&mut cb));
        assert_eq!(seen.len(), 4); // iter 0 (warmup quant) + 3 iters
        assert_eq!(seen[0].0, 0);
    }

    #[test]
    fn deterministic_given_seed() {
        let (w, corpus) = tiny_setup();
        let run = || {
            let mut p = NativeProvider;
            let (qm, _) = Radio::new(quick_cfg(3.0)).quantize(&w, &corpus, &mut p, None);
            qm.to_weights().layers[0].wq.data.clone()
        };
        assert_eq!(run(), run());
    }
}
