//! The rate ladder: one model packed at N average bit rates off ONE
//! calibration artifact, stored in ONE `.radio` container (`RADIOQM3`).
//!
//! The staged pipeline already makes every rate an O(allocate + pack)
//! operation from a single [`CalibrationStats`]; the ladder materializes
//! a chosen set of those operating points *together* so serving can
//! treat rate as a runtime knob: pick a point per deployment, or run two
//! points at once — a low-rate **draft** and a high-rate **target** —
//! for self-speculative decoding (`infer::speculative`,
//! `infer::server::serve_ladder`).
//!
//! Storage is shared where the points are identical: the heavy side
//! parameters (embeddings, positional table, LayerNorms) appear once;
//! each point carries only its packed bitstreams plus its own corrected
//! biases (bias correction depends on the dequantized weights, so the
//! tiny per-layer bias vectors are the one rate-dependent piece of the
//! "side"). Materializing a point ([`RateLadder::model`]) is
//! bit-identical to packing that rate directly (tested).
//!
//! Byte-level container spec: `docs/FORMATS.md`.

use std::io::{BufWriter, Cursor, Read, Write};
use std::path::Path;

use crate::coordinator::calibration::CalibrationStats;
use crate::coordinator::radio::Radio;
use crate::error::RadioError;
use crate::infer::Engine;
use crate::model::config::ModelConfig;
use crate::model::weights::{MatId, Role, SideParams, Weights};
use crate::quant::bitpack::PackedMatrix;
use crate::quant::format::{
    read_matrix_records, write_end_of_matrices, write_matrix_record, QuantizedModel, MAGIC_QM2,
    MAGIC_QM3,
};
use crate::util::atomic_io::AtomicFile;
use crate::util::failpoint;
use crate::util::integrity::{
    self, MappedContainer, SectionWriter, SEC_HEADER, SEC_POINT, SEC_SIDE,
};

/// One operating point of the ladder: the packed bitstreams and the
/// rate-dependent corrected biases for a single target rate.
#[derive(Clone, Debug)]
pub struct RatePoint {
    /// The rate this point was allocated for (bits/weight; fractional).
    pub target_bits: f64,
    /// Packed block matrices, in `matrix_ids()` order.
    pub packed: Vec<(MatId, PackedMatrix)>,
    /// Corrected biases `b^q` per packed matrix (§3.2 — these depend on
    /// the dequantized weights, so they cannot be shared across rates).
    pub biases: Vec<(MatId, Vec<f32>)>,
}

impl RatePoint {
    /// Achieved average payload bits/weight of this point.
    pub fn avg_bits(&self) -> f64 {
        let (mut bits, mut count) = (0f64, 0usize);
        for (_, p) in &self.packed {
            bits += p.payload_bits() as f64;
            count += p.rows * p.cols;
        }
        bits / count.max(1) as f64
    }

    /// Extract a point from a fully materialized model, consuming it:
    /// the packed bitstreams move in (no copy — they dominate a point's
    /// footprint); only the small per-matrix biases are copied out of
    /// the model's side parameters.
    fn from_model(target_bits: f64, qm: QuantizedModel) -> RatePoint {
        let biases = qm
            .packed
            .iter()
            .map(|(id, _)| (*id, qm.base.bias(*id).clone()))
            .collect();
        RatePoint { target_bits, packed: qm.packed, biases }
    }
}

/// N rate points of one model sharing one set of side parameters — the
/// in-memory form of a `RADIOQM3` container.
#[derive(Clone, Debug)]
pub struct RateLadder {
    /// Shared side parameters. Block-matrix biases stored here are
    /// placeholders only: [`RateLadder::model`] overrides every one of
    /// them with the selected point's corrected biases.
    pub base: SideParams,
    /// Operating points, sorted ascending by `target_bits`.
    pub points: Vec<RatePoint>,
}

impl RateLadder {
    /// Allocate + pack `rates` off one calibration artifact. Each point
    /// is produced by the exact [`Radio::pack`] path a direct
    /// single-rate run would take (same `RadioConfig` quantizer family,
    /// `bmax`, mixed-depth setting), so `ladder.model(i)` is
    /// bit-identical to packing `rates[i]` directly — tested. Rates are
    /// sorted ascending and deduplicated.
    pub fn build(
        radio: &Radio,
        w: &Weights,
        stats: &CalibrationStats,
        rates: &[f64],
    ) -> RateLadder {
        assert!(!rates.is_empty(), "a ladder needs at least one rate point");
        let mut rates = rates.to_vec();
        rates.sort_by(|a, b| a.partial_cmp(b).expect("NaN rate"));
        rates.dedup();
        let base = SideParams::from_weights(w);
        let points = rates
            .iter()
            .map(|&r| {
                let alloc = stats.allocate(r, radio.cfg.bmax, radio.cfg.mixed_depth);
                RatePoint::from_model(r, radio.pack(w, stats, &alloc))
            })
            .collect();
        RateLadder { base, points }
    }

    /// Assemble a ladder from already-packed models (e.g. baselines
    /// packed outside the staged pipeline). All models must share one
    /// shape; their side parameters must differ only in the corrected
    /// biases (which are captured per point — the shared `base` is taken
    /// from the first model). Points are sorted ascending by the given
    /// rate labels.
    pub fn from_models(models: Vec<(f64, QuantizedModel)>) -> RateLadder {
        assert!(!models.is_empty(), "a ladder needs at least one rate point");
        let base = models[0].1.base.clone();
        let mut points: Vec<RatePoint> = models
            .into_iter()
            .map(|(bits, qm)| {
                assert_eq!(
                    qm.base.config, base.config,
                    "every ladder point must share one model shape"
                );
                RatePoint::from_model(bits, qm)
            })
            .collect();
        points.sort_by(|a, b| a.target_bits.partial_cmp(&b.target_bits).expect("NaN rate"));
        RateLadder { base, points }
    }

    /// Materialize point `i` as a standalone [`QuantizedModel`] — the
    /// shared side parameters with the point's corrected biases applied.
    pub fn model(&self, i: usize) -> QuantizedModel {
        let p = &self.points[i];
        let mut base = self.base.clone();
        for (id, b) in &p.biases {
            *base.bias_mut(*id) = b.clone();
        }
        QuantizedModel { base, packed: p.packed.clone(), act_quant: None }
    }

    /// Build a decode engine for point `i`.
    pub fn engine(&self, i: usize) -> Engine {
        Engine::from_quantized(&self.model(i))
    }

    /// Index of the point whose target rate is closest to `bits`
    /// (lowest-rate point wins ties).
    pub fn nearest_point(&self, bits: f64) -> usize {
        let mut best = 0usize;
        for (i, p) in self.points.iter().enumerate() {
            if (p.target_bits - bits).abs() < (self.points[best].target_bits - bits).abs() {
                best = i;
            }
        }
        best
    }

    // ------------------------------------------------------ serialization

    /// Write the `RADIOQM3` container: every point's packed matrices and
    /// corrected biases, then the shared side parameters once. The
    /// integrity frame checksums the header, each rate point, and the
    /// side parameters as separate sections. The write is atomic: bytes
    /// stage into `<path>.tmp` and replace `path` only once the trailer
    /// is durable, so a crash mid-save never clobbers an existing
    /// ladder.
    pub fn save(&self, path: &Path) -> Result<(), RadioError> {
        let mut f = BufWriter::new(AtomicFile::create(path)?);
        f.write_all(MAGIC_QM3)?;
        f.write_all(integrity::CHECK_MAGIC)?;
        let mut f = SectionWriter::new(f);
        f.begin(SEC_HEADER);
        f.write_all(&(self.points.len() as u32).to_le_bytes())?;
        f.end();
        for (pi, p) in self.points.iter().enumerate() {
            f.begin(SEC_POINT);
            f.write_all(&p.target_bits.to_le_bytes())?;
            for (id, pm) in &p.packed {
                write_matrix_record(&mut f, *id, pm)?;
            }
            write_end_of_matrices(&mut f)?;
            f.write_all(&(p.biases.len() as u32).to_le_bytes())?;
            for (id, b) in &p.biases {
                f.write_all(&(id.layer as u32).to_le_bytes())?;
                f.write_all(&[id.role.tag()])?;
                f.write_all(&(b.len() as u32).to_le_bytes())?;
                for &x in b {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            f.end();
            failpoint::fire("ladder::save::after_point", pi as u64);
        }
        f.begin(SEC_SIDE);
        self.base.write_to(&mut f)?;
        f.end();
        let bw = f.finish()?;
        let af = bw.into_inner().map_err(|e| RadioError::from(e.into_error()))?;
        af.commit()?;
        Ok(())
    }

    /// Load a `.radio` container as a ladder. A `RADIOQM3` file yields
    /// all its points; a single-point `RADIOQM2` file is accepted too
    /// (a one-rung ladder labeled with its achieved rate), so every
    /// historical artifact remains ladder-loadable. Checksummed
    /// containers are verified before parsing; legacy files fall back
    /// to structural validation. Failures are typed [`RadioError`]s.
    pub fn load(path: &Path) -> Result<RateLadder, RadioError> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 8 {
            return Err(RadioError::Truncated { section: "container magic".into() });
        }
        let magic: [u8; 8] = bytes[..8].try_into().unwrap();
        let payload: &[u8] = match integrity::verify(&bytes)? {
            Some(checked) => checked.payload,
            None => &bytes[8..],
        };
        let mut f = Cursor::new(payload);
        if &magic == MAGIC_QM3 {
            return Self::read_body(&mut f)
                .map_err(|e| RadioError::from(e).in_section("rate ladder body"));
        }
        if &magic != MAGIC_QM2 {
            return Err(RadioError::UnknownFormat {
                detail: format!(
                    "magic {:?} is not a .radio container",
                    String::from_utf8_lossy(&magic)
                ),
            });
        }
        let packed = read_matrix_records(&mut f)
            .map_err(|e| RadioError::from(e).in_section("matrix stream"))?;
        let base = SideParams::read_from(&mut f)
            .map_err(|e| RadioError::from(e).in_section("side parameters"))?;
        let qm = QuantizedModel { base: base.clone(), packed, act_quant: None };
        let achieved = qm.avg_bits();
        let point = RatePoint::from_model(achieved, qm);
        Ok(RateLadder { base, points: vec![point] })
    }

    /// Parse a `RADIOQM3` body (the magic has been consumed) — shared
    /// with `QuantizedModel::load`'s back-compat dispatch.
    pub(crate) fn read_body<R: Read>(f: &mut R) -> std::io::Result<RateLadder> {
        const PREALLOC_CAP: usize = 1 << 16;
        let mut l4 = [0u8; 4];
        f.read_exact(&mut l4)?;
        let n_points = u32::from_le_bytes(l4) as usize;
        let mut points: Vec<RatePoint> = Vec::with_capacity(n_points.min(PREALLOC_CAP));
        for _ in 0..n_points {
            points.push(read_point(f)?);
        }
        let base = SideParams::read_from(f)?;
        validate_bias_shapes(&base.config, &points)?;
        // Restore the ascending order every consumer assumes (the
        // highest-rate point is the serving target): `points` is a
        // public field, so a hand-assembled ladder may have been saved
        // unsorted. Stable, and labels were validated finite above.
        points.sort_by(|a, b| {
            a.target_bits.partial_cmp(&b.target_bits).expect("labels validated finite")
        });
        Ok(RateLadder { base, points })
    }

    /// Open a ladder through the *mapped*, lazily-verified path: the
    /// integrity frame is checked eagerly (no payload reads), then each
    /// section is read and CRC-verified on first touch.
    ///
    /// The header, side parameters, and the **top** (highest-rate,
    /// serving-target) point are essential — corruption there is a hard
    /// error. A corrupt *lower* rate point is instead dropped from the
    /// ladder: serving degrades to the surviving points (draft
    /// selection falls back to the nearest remaining rate) rather than
    /// refusing to serve. Returns the ladder plus the number of
    /// sections dropped this way, surfaced by
    /// `infer::server::serve_ladder_mapped` as
    /// `ServeStats::degraded_sections`. Legacy containers and
    /// single-point `RADIOQM2` files take the resident loader
    /// (degraded count 0).
    pub fn load_mapped(path: &Path) -> Result<(RateLadder, usize), RadioError> {
        let Some(mc) = MappedContainer::open(path)? else {
            return Ok((Self::load(path)?, 0));
        };
        if &mc.magic == MAGIC_QM2 {
            return Ok((Self::load(path)?, 0));
        }
        if &mc.magic != MAGIC_QM3 {
            return Err(RadioError::UnknownFormat {
                detail: format!(
                    "magic {:?} is not a .radio container",
                    String::from_utf8_lossy(&mc.magic)
                ),
            });
        }
        Self::from_mapped(&mc)
    }

    /// Assemble a ladder from an already-opened [`MappedContainer`] —
    /// the degraded-mode core behind [`Self::load_mapped`] and
    /// `QuantizedModel::load_mapped`'s QM3 dispatch.
    pub(crate) fn from_mapped(mc: &MappedContainer) -> Result<(RateLadder, usize), RadioError> {
        let secs = &mc.sections;
        let table = |detail: &str| RadioError::Corrupt {
            section: "section table".into(),
            detail: detail.into(),
        };
        if secs.len() < 3
            || secs[0].tag != SEC_HEADER
            || secs[secs.len() - 1].tag != SEC_SIDE
            || secs[1..secs.len() - 1].iter().any(|s| s.tag != SEC_POINT)
        {
            return Err(table("rate ladder must be header, rate points, side parameters"));
        }
        let header = mc.read_section(0)?;
        if header.len() != 4 {
            return Err(RadioError::Corrupt {
                section: "container header".into(),
                detail: "ladder header must be exactly a point count".into(),
            });
        }
        let n_points = u32::from_le_bytes(header[..4].try_into().unwrap()) as usize;
        if n_points != secs.len() - 2 {
            return Err(table("point count disagrees with the section table"));
        }
        let side = mc.read_section(secs.len() - 1)?;
        let base = SideParams::read_from(&mut Cursor::new(&side[..]))
            .map_err(|e| RadioError::from(e).in_section("side parameters"))?;
        let mut points: Vec<RatePoint> = Vec::with_capacity(n_points);
        let mut degraded = 0usize;
        for k in 0..n_points {
            // The last (highest-rate) point is the serving target:
            // essential. Lower points degrade away on corruption.
            let essential = k + 1 == n_points;
            let parsed = mc.read_section(1 + k).and_then(|bytes| {
                let mut cur = Cursor::new(&bytes[..]);
                let p = read_point(&mut cur)
                    .map_err(|e| RadioError::from(e).in_section("rate point"))?;
                if (cur.position() as usize) != bytes.len() {
                    return Err(RadioError::Corrupt {
                        section: "rate point".into(),
                        detail: "trailing bytes after rate point".into(),
                    });
                }
                validate_bias_shapes(&base.config, std::slice::from_ref(&p))
                    .map_err(|e| RadioError::from(e).in_section("rate point"))?;
                Ok(p)
            });
            match parsed {
                Ok(p) => points.push(p),
                Err(_) if !essential => degraded += 1,
                Err(e) => return Err(e),
            }
        }
        if points.is_empty() {
            return Err(RadioError::Corrupt {
                section: "rate ladder body".into(),
                detail: "rate ladder carries no points".into(),
            });
        }
        points.sort_by(|a, b| {
            a.target_bits.partial_cmp(&b.target_bits).expect("labels validated finite")
        });
        Ok((RateLadder { base, points }, degraded))
    }
}

/// Parse one serialized rate point: label, packed-matrix stream (with
/// sentinel), then the corrected-bias records.
fn read_point<R: Read>(f: &mut R) -> std::io::Result<RatePoint> {
    const PREALLOC_CAP: usize = 1 << 16;
    let mut l1 = [0u8; 1];
    let mut l4 = [0u8; 4];
    let mut l8 = [0u8; 8];
    f.read_exact(&mut l8)?;
    let target_bits = f64::from_le_bytes(l8);
    if !target_bits.is_finite() {
        return Err(inv("non-finite rate-point label"));
    }
    let packed = read_matrix_records(f)?;
    f.read_exact(&mut l4)?;
    let n_bias = u32::from_le_bytes(l4) as usize;
    let mut biases = Vec::with_capacity(n_bias.min(PREALLOC_CAP));
    for _ in 0..n_bias {
        f.read_exact(&mut l4)?;
        let layer = u32::from_le_bytes(l4) as usize;
        f.read_exact(&mut l1)?;
        let role = Role::from_tag(l1[0]).ok_or_else(|| inv("bad role tag"))?;
        f.read_exact(&mut l4)?;
        let blen = u32::from_le_bytes(l4) as usize;
        let mut b = Vec::with_capacity(blen.min(PREALLOC_CAP));
        for _ in 0..blen {
            f.read_exact(&mut l4)?;
            b.push(f32::from_le_bytes(l4));
        }
        biases.push((MatId { layer, role }, b));
    }
    Ok(RatePoint { target_bits, packed, biases })
}

/// Validate bias records against the (now known) model shape:
/// `RateLadder::model` indexes layers and overwrites fixed-length
/// vectors, so a corrupt record must fail at load, not panic there.
fn validate_bias_shapes(cfg: &ModelConfig, points: &[RatePoint]) -> std::io::Result<()> {
    for p in points {
        for (id, b) in &p.biases {
            if id.layer >= cfg.layers {
                return Err(inv(format!(
                    "bias layer {} out of range for {}-layer config",
                    id.layer, cfg.layers
                )));
            }
            let want = match id.role {
                Role::Up => cfg.mlp,
                _ => cfg.dim,
            };
            if b.len() != want {
                return Err(inv(format!(
                    "bias length {} != expected {want} for {:?}",
                    b.len(),
                    id.role
                )));
            }
        }
    }
    Ok(())
}

fn inv<E: std::fmt::Display>(e: E) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::gradients::NativeProvider;
    use crate::coordinator::pipeline::rtn_quantize_model;
    use crate::coordinator::radio::RadioConfig;
    use crate::model::config::ModelConfig;
    use crate::model::corpus::{Corpus, Domain};
    use crate::util::rng::Rng;

    fn tiny_setup() -> (Weights, Corpus) {
        let cfg = ModelConfig { vocab: 256, dim: 16, heads: 2, layers: 2, mlp: 32, max_seq: 16 };
        let mut rng = Rng::new(611);
        let w = Weights::init_pretrained_like(cfg, &mut rng);
        let corpus = Corpus::synthetic(612, Domain::Calib, 8 * 1024);
        (w, corpus)
    }

    fn quick_radio() -> Radio {
        Radio::new(RadioConfig {
            rows_per_group: 8,
            batch: 2,
            seq: 16,
            tokens_per_seq: 5,
            iters: 2,
            pca_k: 2,
            ..Default::default()
        })
    }

    #[test]
    fn ladder_points_are_bit_identical_to_direct_packs() {
        let (w, corpus) = tiny_setup();
        let radio = quick_radio();
        let mut provider = NativeProvider;
        let (stats, _) = radio.calibrate(&w, &corpus, &mut provider, None);
        let rates = [2.0, 3.0, 4.0];
        let ladder = RateLadder::build(&radio, &w, &stats, &rates);
        assert_eq!(ladder.points.len(), 3);
        for (i, &r) in rates.iter().enumerate() {
            let alloc = stats.allocate(r, radio.cfg.bmax, radio.cfg.mixed_depth);
            let direct = radio.pack(&w, &stats, &alloc);
            let from_ladder = ladder.model(i);
            assert_eq!(ladder.points[i].target_bits, r);
            assert!((from_ladder.avg_bits() - direct.avg_bits()).abs() < 1e-12);
            let (a, b) = (from_ladder.to_weights(), direct.to_weights());
            for (la, lb) in a.layers.iter().zip(&b.layers) {
                assert_eq!(la.wq.data, lb.wq.data, "rate {r}");
                assert_eq!(la.w2.data, lb.w2.data, "rate {r}");
                assert_eq!(la.bq, lb.bq, "rate {r} corrected bias");
                assert_eq!(la.b2, lb.b2, "rate {r} corrected bias");
            }
        }
    }

    #[test]
    fn qm3_save_load_roundtrip_and_back_compat() {
        let (w, corpus) = tiny_setup();
        let radio = quick_radio();
        let mut provider = NativeProvider;
        let (stats, _) = radio.calibrate(&w, &corpus, &mut provider, None);
        let ladder = RateLadder::build(&radio, &w, &stats, &[2.0, 4.0]);
        let path = std::env::temp_dir().join("radio_test_ladder.radio");
        ladder.save(&path).unwrap();

        let back = RateLadder::load(&path).unwrap();
        assert_eq!(back.points.len(), 2);
        for (a, b) in ladder.points.iter().zip(&back.points) {
            assert_eq!(a.target_bits, b.target_bits);
        }
        for i in 0..2 {
            let (x, y) = (ladder.model(i).to_weights(), back.model(i).to_weights());
            for (la, lb) in x.layers.iter().zip(&y.layers) {
                assert_eq!(la.wq.data, lb.wq.data, "point {i}");
                assert_eq!(la.bq, lb.bq, "point {i}");
            }
        }
        // Back-compat the other way: QuantizedModel::load on a QM3 file
        // resolves to the highest-rate point.
        let top = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!((top.avg_bits() - ladder.model(1).avg_bits()).abs() < 1e-12);
        assert_eq!(
            top.to_weights().layers[0].wq.data,
            ladder.model(1).to_weights().layers[0].wq.data
        );
    }

    #[test]
    fn qm2_files_load_as_single_rung_ladders() {
        let (w, _) = tiny_setup();
        let qm = rtn_quantize_model(&w, 4, 8);
        let path = std::env::temp_dir().join("radio_test_ladder_qm2.radio");
        qm.save(&path).unwrap();
        let ladder = RateLadder::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(ladder.points.len(), 1);
        assert!((ladder.points[0].target_bits - qm.avg_bits()).abs() < 1e-12);
        assert_eq!(
            ladder.model(0).to_weights().layers[0].wq.data,
            qm.to_weights().layers[0].wq.data
        );
    }

    #[test]
    fn from_models_sorts_and_nearest_point_selects() {
        let (w, _) = tiny_setup();
        let q2 = rtn_quantize_model(&w, 2, 8);
        let q6 = rtn_quantize_model(&w, 6, 8);
        let ladder = RateLadder::from_models(vec![(6.0, q6.clone()), (2.0, q2.clone())]);
        assert_eq!(ladder.points[0].target_bits, 2.0, "points sort ascending");
        assert_eq!(ladder.points[1].target_bits, 6.0);
        assert_eq!(ladder.nearest_point(1.0), 0);
        assert_eq!(ladder.nearest_point(5.5), 1);
        assert_eq!(ladder.nearest_point(4.0), 0, "ties go to the lower rate");
        // Materialized points reproduce the input models.
        assert_eq!(
            ladder.model(0).to_weights().layers[0].wq.data,
            q2.to_weights().layers[0].wq.data
        );
        assert_eq!(
            ladder.model(1).to_weights().layers[1].w1.data,
            q6.to_weights().layers[1].w1.data
        );
    }

    /// Write a ladder in the pre-checksum `RADIOQM3` layout (no
    /// integrity marker, table, or trailer).
    fn write_legacy_qm3(ladder: &RateLadder, path: &Path) {
        let mut f = BufWriter::new(std::fs::File::create(path).unwrap());
        f.write_all(MAGIC_QM3).unwrap();
        f.write_all(&(ladder.points.len() as u32).to_le_bytes()).unwrap();
        for p in &ladder.points {
            f.write_all(&p.target_bits.to_le_bytes()).unwrap();
            for (id, pm) in &p.packed {
                write_matrix_record(&mut f, *id, pm).unwrap();
            }
            write_end_of_matrices(&mut f).unwrap();
            f.write_all(&(p.biases.len() as u32).to_le_bytes()).unwrap();
            for (id, b) in &p.biases {
                f.write_all(&(id.layer as u32).to_le_bytes()).unwrap();
                f.write_all(&[id.role.tag()]).unwrap();
                f.write_all(&(b.len() as u32).to_le_bytes()).unwrap();
                for &x in b {
                    f.write_all(&x.to_le_bytes()).unwrap();
                }
            }
        }
        ladder.base.write_to(&mut f).unwrap();
        f.flush().unwrap();
    }

    #[test]
    fn legacy_unchecksummed_qm3_still_loads() {
        let (w, _) = tiny_setup();
        let q2 = rtn_quantize_model(&w, 2, 8);
        let q4 = rtn_quantize_model(&w, 4, 8);
        let ladder = RateLadder::from_models(vec![(2.0, q2), (4.0, q4)]);
        let path = std::env::temp_dir().join("radio_test_ladder_legacy.radio");
        write_legacy_qm3(&ladder, &path);
        let back = RateLadder::load(&path).unwrap();
        // And the cross-format dispatch: QuantizedModel::load resolves
        // a legacy QM3 to its top point too.
        let top = QuantizedModel::load(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert_eq!(back.points.len(), 2);
        assert_eq!(
            back.model(1).to_weights().layers[0].wq.data,
            ladder.model(1).to_weights().layers[0].wq.data
        );
        assert_eq!(
            top.to_weights().layers[0].wq.data,
            ladder.model(1).to_weights().layers[0].wq.data
        );
    }

    #[test]
    fn qm3_boundary_corruption_is_rejected_typed() {
        let (w, _) = tiny_setup();
        let q2 = rtn_quantize_model(&w, 2, 8);
        let q4 = rtn_quantize_model(&w, 4, 8);
        let ladder = RateLadder::from_models(vec![(2.0, q2), (4.0, q4)]);
        let path = std::env::temp_dir().join("radio_test_ladder_corrupt.radio");
        ladder.save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        let checked = integrity::verify(&good).unwrap().expect("ladders are checked");
        // header / point / point / side — four sections.
        assert_eq!(checked.sections.len(), 2 + ladder.points.len());
        let victim = std::env::temp_dir().join("radio_test_ladder_victim.radio");
        for s in &checked.sections {
            for o in [s.off as usize, (s.off + s.len) as usize] {
                std::fs::write(&victim, &good[..o]).unwrap();
                let err = RateLadder::load(&victim).unwrap_err();
                assert!(
                    matches!(
                        err,
                        RadioError::Truncated { .. }
                            | RadioError::Corrupt { .. }
                            | RadioError::ChecksumMismatch { .. }
                    ),
                    "truncation at {o} gave {err:?}"
                );
            }
            let mid = (s.off + s.len / 2) as usize;
            if s.len > 0 {
                let mut bad = good.clone();
                bad[mid] ^= 0x04;
                std::fs::write(&victim, &bad).unwrap();
                let err = RateLadder::load(&victim).unwrap_err();
                assert!(
                    matches!(err, RadioError::ChecksumMismatch { .. }),
                    "bit flip at {mid} gave {err:?}"
                );
            }
        }
        let _ = std::fs::remove_file(&victim);
    }

    #[test]
    fn load_rejects_garbage_and_truncation() {
        let p = std::env::temp_dir().join("radio_ladder_garbage.radio");
        std::fs::write(&p, b"definitely not a ladder").unwrap();
        assert!(RateLadder::load(&p).is_err());
        let (w, corpus) = tiny_setup();
        let radio = quick_radio();
        let mut provider = NativeProvider;
        let (stats, _) = radio.calibrate(&w, &corpus, &mut provider, None);
        let ladder = RateLadder::build(&radio, &w, &stats, &[3.0]);
        ladder.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 3]).unwrap();
        assert!(RateLadder::load(&p).is_err());
        assert!(QuantizedModel::load(&p).is_err());
        let _ = std::fs::remove_file(p);
    }
}
