//! The L3 coordinator: Algorithm 1 (Radio) split into explicit
//! Calibrate / Allocate / Pack stages with a serializable calibration
//! artifact, the dual-ascent allocator, gradient providers (native
//! backprop / XLA artifacts), and the quantization pipeline that
//! dispatches Radio and the baselines.

pub mod calibration;
pub mod dual_ascent;
pub mod gradients;
pub mod kvquant;
pub mod pipeline;
pub mod radio;

pub use calibration::{CalibrationStats, MatCalib, RateAllocation};
pub use gradients::{GradientProvider, NativeProvider};
pub use kvquant::{allocate_kv_bits, calibrate_kv, kv_spec_for, KvCalibStats, KvTensorStats};
pub use pipeline::{run_method, Method, PipelineResult, StageTimings};
pub use radio::{CalibrationReport, PackSummary, Radio, RadioConfig, RadioReport};
