//! The L3 coordinator: Algorithm 1 (Radio), its dual-ascent allocator,
//! gradient providers (native backprop / XLA artifacts), and the
//! quantization pipeline that dispatches Radio and the baselines.

pub mod dual_ascent;
pub mod gradients;
pub mod pipeline;
pub mod radio;

pub use gradients::{GradientProvider, NativeProvider};
pub use pipeline::{run_method, Method, PipelineResult};
pub use radio::{Radio, RadioConfig, RadioReport};
