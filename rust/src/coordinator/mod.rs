//! The L3 coordinator: Algorithm 1 (Radio) split into explicit
//! Calibrate / Allocate / Pack stages with a serializable calibration
//! artifact, the dual-ascent allocator, gradient providers (native
//! backprop / XLA artifacts), and the quantization pipeline that
//! dispatches Radio and the baselines.

/// The serializable Calibrate-stage artifact and per-rate allocation.
pub mod calibration;
/// Dual-ascent bit allocation (Algorithm 1's inner solve).
pub mod dual_ascent;
/// Gradient providers for calibration (native backprop / XLA artifacts).
pub mod gradients;
/// Serve-side KV-cache bit allocation from calibration-time variances.
pub mod kvquant;
/// Multi-rate-point packing: N operating points off one artifact.
pub mod ladder;
/// Method dispatch for Radio and the baselines, with stage timings.
pub mod pipeline;
/// The staged Radio quantizer (Calibrate / Allocate / Pack).
pub mod radio;

pub use calibration::{CalibrationStats, MatCalib, RateAllocation};
pub use gradients::{GradientProvider, NativeProvider};
pub use kvquant::{allocate_kv_bits, calibrate_kv, kv_spec_for, KvCalibStats, KvTensorStats};
pub use ladder::{RateLadder, RatePoint};
pub use pipeline::{run_method, Method, PipelineResult, StageTimings};
pub use radio::{CalibrationReport, PackSummary, Radio, RadioConfig, RadioReport};
